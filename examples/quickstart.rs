//! Quickstart: the float-float format in 60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ffgpu::ff::FF32;

fn main() {
    // --- the problem: f32 is 24 bits -------------------------------------
    let a32 = 1.0f32;
    let tiny32 = 1e-9f32;
    println!("f32:  1.0 + 1e-9          = {:.12e}  (the 1e-9 is gone)", a32 + tiny32);

    // --- the fix: a pair of f32s carries ~49 bits ------------------------
    let a = FF32::from_f32(1.0);
    let tiny = FF32::from_f64(1e-9);
    let sum = a + tiny;
    println!("FF32: 1.0 + 1e-9          = {:.12e}", sum.to_f64());
    println!("      stored as hi={:e} lo={:e}", sum.hi, sum.lo);

    // --- full arithmetic --------------------------------------------------
    let pi = FF32::from_f64(std::f64::consts::PI);
    let e = FF32::from_f64(std::f64::consts::E);
    println!("\nπ·e   (FF32) = {:.15}", (pi * e).to_f64());
    println!("π·e   (f64)  = {:.15}", std::f64::consts::PI * std::f64::consts::E);
    println!("π/e   (FF32) = {:.15}", (pi / e).to_f64());
    println!("√2    (FF32) = {:.15}", FF32::from_f32(2.0).sqrt22().to_f64());

    // --- the building blocks (paper §4.1) ----------------------------------
    let (s, r) = ffgpu::ff::two_sum(0.1f32, 0.2f32);
    println!("\ntwo_sum(0.1, 0.2): s = {s:e}, exact rounding error r = {r:e}");
    let (x, y) = ffgpu::ff::two_prod(1.1f32, 2.2f32);
    println!("two_prod(1.1, 2.2): x = {x:e}, exact error y = {y:e}");
    let (hi, lo) = ffgpu::ff::split(std::f32::consts::PI);
    println!("split(π) = {hi:e} + {lo:e}  (12-bit halves, products stay exact)");

    // --- accuracy check against f64 ---------------------------------------
    let mut acc = FF32::ZERO;
    let step = FF32::from_f64(0.1);
    for _ in 0..1000 {
        acc += step;
    }
    let err_ff = (acc.to_f64() - 100.0).abs();
    let mut acc32 = 0.0f32;
    for _ in 0..1000 {
        acc32 += 0.1;
    }
    let err_f32 = (acc32 as f64 - 100.0).abs();
    println!("\nsum of 1000 × 0.1:");
    println!("  f32  error = {err_f32:.3e}");
    println!("  FF32 error = {err_ff:.3e}  ({}x better)",
             (err_f32 / err_ff.max(1e-300)) as u64);
}
