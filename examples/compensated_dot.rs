//! Compensated algorithms (paper §7 "future work"): an ill-conditioned
//! dot product computed five ways, errors measured against the exact
//! dyadic oracle.
//!
//! ```bash
//! cargo run --release --example compensated_dot
//! ```

use ffgpu::ff::compensated;
use ffgpu::mp::Dyadic;
use ffgpu::util::Rng;

/// Build a dot product with catastrophic cancellation: condition number
/// ~10^cond. (Ogita-Rump-Oishi style generator.)
fn ill_conditioned(n: usize, cond: f64, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    let mut a = vec![0.0f32; n];
    let mut b = vec![0.0f32; n];
    let half = n / 2;
    for i in 0..half {
        let e = rng.uniform(0.0, cond.log2());
        a[i] = (rng.normal() * e.exp2()) as f32;
        b[i] = (rng.normal() * e.exp2()) as f32;
    }
    // second half cancels the partial sum so far
    let mut acc = Dyadic::zero();
    for i in 0..half {
        acc = acc.add(&Dyadic::from_f32(a[i]).mul(&Dyadic::from_f32(b[i])));
    }
    for i in half..n {
        let e = rng.uniform(0.0, cond.log2() * (n - i) as f64 / half as f64);
        a[i] = (rng.normal() * e.exp2()) as f32;
        // choose b[i] so a[i]*b[i] ~ -acc/(n-half), shrinking the sum
        let target = -acc.to_f64() / (n - i) as f64;
        b[i] = (target / a[i] as f64) as f32;
        acc = acc.add(&Dyadic::from_f32(a[i]).mul(&Dyadic::from_f32(b[i])));
    }
    (a, b)
}

fn exact_dot(a: &[f32], b: &[f32]) -> Dyadic {
    let mut acc = Dyadic::zero();
    for i in 0..a.len() {
        acc = acc.add(&Dyadic::from_f32(a[i]).mul(&Dyadic::from_f32(b[i])));
    }
    acc
}

/// Error relative to the natural scale S = sum |a_i b_i| (condition-free
/// denominator; err/|exact| explodes with the condition number for every
/// method and hides the ordering).
fn scaled_err(got: f64, exact: &Dyadic, scale: f64) -> f64 {
    (got - exact.to_f64()).abs() / scale
}

fn main() {
    let mut rng = Rng::new(2006);
    let n = 4096;
    println!("ill-conditioned dot product, n = {n}\n");
    println!("{:>10} {:>12} {:>12} {:>12} {:>12}",
             "condition", "f32", "Dot2(f32)", "FF32", "f64");
    for cond_exp in [4.0, 8.0, 12.0, 16.0] {
        let cond = 10f64.powf(cond_exp);
        let (a, b) = ill_conditioned(n, cond, &mut rng);
        let exact = exact_dot(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();

        let naive = compensated::dot_f32(&a, &b) as f64;
        let dot2 = compensated::dot2(&a, &b) as f64;
        let ff = compensated::dot_ff(&a, &b).to_f64();
        let f64dot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();

        println!(
            "{:>10.0e} {:>12.2e} {:>12.2e} {:>12.2e} {:>12.2e}",
            scale / exact.to_f64().abs().max(1e-300), // achieved condition
            scaled_err(naive, &exact, scale),
            scaled_err(dot2, &exact, scale),
            scaled_err(ff, &exact, scale),
            scaled_err(f64dot, &exact, scale),
        );
    }
    println!("\n(error / sum|a_i b_i| vs the exact dyadic value; smaller is better)");
    println!("Dot2 and FF32 track f64 quality from f32 inputs — the paper's");
    println!("§7 claim that compensated algorithms give comparable accuracy");
    println!("at lower cost than the full float-float format.");
}
