//! Replay regression gate (CI): re-drive the committed golden trace
//! and fail on any determinism or drift regression.
//!
//! ```bash
//! cargo run --release --example replay_gate
//! ```
//!
//! What it pins, in order:
//!
//! 1. the golden trace (`rust/traces/golden.fftrace`) still decodes
//!    and its shape matches `golden.expect.json` (record count, per-op
//!    counts, tenant mix, the one deliberate deadline miss);
//! 2. replaying it twice on one configuration yields **identical**
//!    `determinism_key`s — the results checksum plus every per-op
//!    request/verdict/lane count (exact match, no band);
//! 3. replaying it on a second configuration (fused + cached) yields
//!    the **same results checksum** — routing, fusion and the result
//!    cache are bit-transparent, so the fold over (verdict, reply
//!    bits) cannot move;
//! 4. run-over-run metric drift stays inside the band: per-op p95
//!    within a generous ratio (timing is hardware-noisy; correctness
//!    is gated by 2/3, not this), padding waste within ±0.15.
//!
//! Any failure prints a diff summary and exits nonzero.

use ffgpu::backend::BackendSpec;
use ffgpu::coordinator::{replay, ReplayReport, Routing, Service, ServiceSpec, Trace};
use std::path::Path;
use std::time::Duration;

const RATE: f64 = 16.0;
/// p95 drift band: run-over-run ratio cap, after a floor that keeps
/// microsecond-scale latencies from manufacturing huge ratios.
const P95_FLOOR_MS: f64 = 2.0;
const P95_RATIO_MAX: f64 = 10.0;
const PADDING_BAND: f64 = 0.15;

/// Pull `"key": <number>` out of the expect file. The file is flat
/// enough (unique keys) that a scan beats vendoring a JSON parser.
fn expect_num(json: &str, key: &str) -> f64 {
    let tag = format!("\"{key}\":");
    let at = json.find(&tag).unwrap_or_else(|| panic!("expect file lacks {key}"));
    let rest = json[at + tag.len()..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().unwrap_or_else(|e| panic!("expect {key}: {e}"))
}

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("traces");
    let trace_path = dir.join("golden.fftrace");
    let expect_path = dir.join("golden.expect.json");
    let mut failures: Vec<String> = Vec::new();

    // 1. the committed bytes still decode, and the shape matches
    let bytes = std::fs::read(&trace_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", trace_path.display()));
    let expect = std::fs::read_to_string(&expect_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", expect_path.display()));
    if bytes.len() as f64 != expect_num(&expect, "bytes") {
        failures.push(format!(
            "trace size: {} bytes on disk, expect file says {}",
            bytes.len(),
            expect_num(&expect, "bytes")
        ));
    }
    let trace = Trace::decode(&bytes).unwrap_or_else(|e| panic!("decode golden: {e}"));
    if trace.records.len() as f64 != expect_num(&expect, "records") {
        failures.push(format!(
            "record count: decoded {}, expected {}",
            trace.records.len(),
            expect_num(&expect, "records")
        ));
    }
    for (op, n) in trace.op_counts() {
        let want = expect_num(&expect, op.name());
        if n as f64 != want {
            failures.push(format!("op {op}: {n} records, expected {want}"));
        }
    }
    for tenant in ["alpha", "beta"] {
        let n = trace.records.iter().filter(|r| r.tenant == tenant).count();
        let want = expect_num(&expect, tenant);
        if n as f64 != want {
            failures.push(format!("tenant {tenant}: {n} records, expected {want}"));
        }
    }
    let misses = trace
        .records
        .iter()
        .filter(|r| r.deadline() == Some(Duration::ZERO))
        .count();
    if misses as f64 != expect_num(&expect, "deadline_misses") {
        failures.push(format!(
            "deliberate deadline misses: {misses}, expected {}",
            expect_num(&expect, "deadline_misses")
        ));
    }
    let tenants: std::collections::BTreeSet<&str> =
        trace.records.iter().map(|r| r.tenant.as_str()).collect();
    println!(
        "golden trace: {} records, {} bytes, {} tenants, {misses} deadline miss(es)",
        trace.records.len(),
        bytes.len(),
        tenants.len()
    );

    // 2. determinism on one configuration: exact key equality
    let run = |spec: ServiceSpec, label: &str| -> ReplayReport {
        let svc = Service::start(spec).unwrap_or_else(|e| panic!("{label}: {e}"));
        let rep = replay(&svc, &trace, RATE).unwrap_or_else(|e| panic!("{label}: {e}"));
        println!("[{label}] {}", rep.render().trim_end().replace('\n', "\n  "));
        rep
    };
    let sharded = || {
        ServiceSpec::uniform(BackendSpec::native(), 2).with_routing(Routing::Measured)
    };
    let a1 = run(sharded(), "sharded-measured #1");
    let a2 = run(sharded(), "sharded-measured #2");
    if a1.determinism_key() != a2.determinism_key() {
        failures.push(format!(
            "determinism key moved between identical replays: {:#018x} vs {:#018x}",
            a1.determinism_key(),
            a2.determinism_key()
        ));
    }
    for (r1, r2) in a1.per_op.iter().zip(&a2.per_op) {
        let c1 = (r1.requests, r1.ok, r1.deadline_exceeded, r1.cancelled, r1.errors);
        let c2 = (r2.requests, r2.ok, r2.deadline_exceeded, r2.cancelled, r2.errors);
        if r1.op != r2.op || c1 != c2 {
            failures.push(format!(
                "per-op counts moved: {} {c1:?} vs {} {c2:?}",
                r1.op, r2.op
            ));
        }
    }

    // 3. checksum equality across configurations
    let fused = || {
        ServiceSpec::uniform(BackendSpec::native(), 2)
            .with_fuse_window(Duration::from_millis(1))
            .with_fuse_sizes(vec![1024, 4096, 16384, 65536])
            .with_cache_mb(64)
    };
    let b = run(fused(), "fused-cached");
    if a1.results_fnv != b.results_fnv {
        failures.push(format!(
            "results checksum differs across configs: sharded {:#018x} vs fused {:#018x}",
            a1.results_fnv, b.results_fnv
        ));
    }

    // 4. drift bands (diagnostic noise stays bounded)
    for (r1, r2) in a1.per_op.iter().zip(&a2.per_op) {
        let (x, y) = (r1.p95_ms.max(P95_FLOOR_MS), r2.p95_ms.max(P95_FLOOR_MS));
        let ratio = if x > y { x / y } else { y / x };
        if ratio > P95_RATIO_MAX {
            failures.push(format!(
                "p95 drift for {}: {:.3}ms vs {:.3}ms (ratio {ratio:.1} > {P95_RATIO_MAX})",
                r1.op, r1.p95_ms, r2.p95_ms
            ));
        }
    }
    if (a1.padding_waste - a2.padding_waste).abs() > PADDING_BAND {
        failures.push(format!(
            "padding waste drift: {:.4} vs {:.4} (band ±{PADDING_BAND})",
            a1.padding_waste, a2.padding_waste
        ));
    }

    if failures.is_empty() {
        println!(
            "replay gate OK: checksum {:#018x}, determinism key {:#018x}",
            a1.results_fnv,
            a1.determinism_key()
        );
    } else {
        eprintln!("replay gate FAILED ({} finding(s)):", failures.len());
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
