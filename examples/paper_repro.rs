//! **End-to-end driver**: regenerates every table of the paper through
//! the full three-layer stack and prints paper-vs-measured side by side.
//! (The experiment index lives in DESIGN.md §4.)
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_repro
//! # quick mode:
//! FFGPU_QUICK=1 cargo run --release --example paper_repro
//! ```
//!
//! Stages:
//!   1. Table 1 — format inventory (definitions).
//!   2. Table 2 — paranoia over simulated GPU arithmetic.
//!   3. Table 3 — operator timings, XLA/PJRT path (via the coordinator).
//!   4. Table 4 — operator timings, native CPU path.
//!   5. Table 5 — accuracy sweep vs the exact dyadic oracle
//!      (native + XLA + simulated NV35).
//!   6. selftest — artifacts vs native kernels, bit-exact.

use ffgpu::coordinator::batcher::op_arity;
use ffgpu::gpusim::{algorithms as sim, Format, GpuModel};
use ffgpu::harness::{accuracy, paranoia_table, timing, workload};
use ffgpu::runtime::Runtime;
use ffgpu::util::Timer;
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let quick = std::env::var("FFGPU_QUICK").is_ok();
    let t0 = Instant::now();
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );

    println!("==============================================================");
    println!(" paper_repro — Da Graça & Defour 2006, full reproduction run");
    println!("==============================================================\n");

    // ---- Table 1 ----------------------------------------------------
    println!("### Table 1 — representation formats");
    for f in Format::table1() {
        println!("  {:<14} sign 1  exp {:>2}  mant {:>2}  specials {}",
                 f.name(), f.exp_bits, f.mant_bits,
                 if f.has_specials { "yes" } else { "no" });
    }

    // ---- Table 2 ----------------------------------------------------
    println!("\n### Table 2 — paranoia on simulated GPU arithmetic");
    let samples = if quick { 20_000 } else { 300_000 };
    print!("{}", paranoia_table::measure(samples, 0xE2E).render());

    // ---- Table 3 ----------------------------------------------------
    let rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("\nruntime unavailable ({e}); run `make artifacts`");
            std::process::exit(1);
        }
    };
    println!("\n### Table 3 — float-float operators, XLA/PJRT path");
    println!("platform: {}", rt.platform());
    let timer = if quick { Timer::new(1, 3) } else { Timer::new(3, 9) };
    let sizes: &[usize] = if quick { &[4096, 16384, 65536] } else { &workload::PAPER_SIZES };
    let grid3 = timing::gpu_grid(&rt, sizes, &workload::PAPER_OPS, &timer, 0xE3E)
        .expect("gpu grid");
    print!("{}", grid3.render("measured (normalised to Add@4096)"));
    let (psizes, p3) = timing::paper_table3();
    println!("paper (7800GTX):");
    for (s, r) in psizes.iter().zip(&p3) {
        let cells: String = r.iter().map(|v| format!("{v:>8.2}")).collect();
        println!("  {s:>9} {cells}");
    }

    // ---- Table 4 ----------------------------------------------------
    println!("\n### Table 4 — float-float operators, native CPU path");
    let grid4 = timing::cpu_grid(sizes, &workload::PAPER_OPS, &timer, 0xE4E);
    print!("{}", grid4.render("measured (normalised to Add@4096)"));
    let (_, p4) = timing::paper_table4();
    println!("paper (Pentium IV 3.2GHz):");
    for (s, r) in psizes.iter().zip(&p4) {
        let cells: String = r.iter().map(|v| format!("{v:>9.2}")).collect();
        println!("  {s:>9} {cells}");
    }

    // ---- Table 5 ----------------------------------------------------
    println!("\n### Table 5 — measured accuracy (exact dyadic oracle)");
    let acc_samples = if quick { 1 << 14 } else { 1 << 20 };
    let ops = ["add12", "mul12", "add22", "mul22"];
    println!("{:<8} {:>12} {:>12} {:>12} {:>10}",
             "op", "native", "xla", "nv35-sim", "paper");
    let m = GpuModel::NV35;
    for (op, paper_val) in ops.iter().zip(["-48.0", "(exact)", "-33.7", "-45.0"]) {
        let native = accuracy::measure_op(op, acc_samples, 1 << 14, 1, |op, planes| {
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let (_, n_out) = op_arity(op).unwrap();
            let mut outs = vec![vec![0.0f32; planes[0].len()]; n_out];
            ffgpu::ff::vector::dispatch(op, &refs, &mut outs)?;
            Ok(outs)
        })
        .unwrap();
        let xla = accuracy::measure_op(op, acc_samples.min(1 << 18), 16384, 2,
            |op, planes| {
                let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                rt.execute(&format!("{op}_n16384"), &refs)
            })
            .unwrap();
        let simr = accuracy::measure_op(op, acc_samples.min(1 << 14), 1 << 12, 3,
            |op, planes| {
                let n = planes[0].len();
                let mut outs = vec![vec![0.0f32; n]; 2];
                for i in 0..n {
                    let q = |p: usize| m.quantize(planes[p][i] as f64);
                    let (h, l) = match op {
                        "add12" => sim::add12(&m, q(0), q(1)),
                        "mul12" => sim::mul12(&m, q(0), q(1)),
                        "add22" => sim::add22(&m, (q(0), q(1)), (q(2), q(3))),
                        "mul22" => sim::mul22(&m, (q(0), q(1)), (q(2), q(3))),
                        other => return Err(format!("no sim for {other}")),
                    };
                    outs[0][i] = m.to_f64(h) as f32;
                    outs[1][i] = m.to_f64(l) as f32;
                }
                Ok(outs)
            })
            .unwrap();
        println!("{:<8} {:>12} {:>12} {:>12} {:>10}",
                 op, native.display(), xla.display(), simr.display(), paper_val);
    }

    // ---- selftest -----------------------------------------------------
    println!("\n### selftest — artifacts vs native kernels (bit-exact)");
    let mut fails = 0;
    for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
        let planes = workload::planes_for(op, 4096, 0xE5E);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let xla = rt.execute(&format!("{op}_n4096"), &refs).unwrap();
        let (_, n_out) = op_arity(op).unwrap();
        let mut native = vec![vec![0.0f32; 4096]; n_out];
        ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
        let ok = xla.iter().zip(&native)
            .all(|(a, b)| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        println!("  {op:<6} {}", if ok { "OK" } else { "FAIL" });
        if !ok {
            fails += 1;
        }
    }

    println!("\n==============================================================");
    println!(" paper_repro complete in {:.1}s  ({} failures)",
             t0.elapsed().as_secs_f64(), fails);
    println!("==============================================================");
    std::process::exit(if fails == 0 { 0 } else { 1 });
}
