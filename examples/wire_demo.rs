//! Wire front end demo: four concurrent TCP clients against a
//! [`ffgpu::net::WireServer`] — three well-behaved `standard` tenants
//! plus one `bulk` hog that deliberately exceeds its token-bucket
//! contract. The demo **asserts** the serving invariants and exits
//! non-zero if any is violated:
//!
//! * every standard-tenant request completes with correctly shaped
//!   output (no overloads, no errors);
//! * the hog sees at least one `Overloaded { retry_after_ms }` verdict;
//! * the server's status frame attributes the shed/denied traffic to
//!   the hog tenant, not to the standard tenants.
//!
//! ```bash
//! cargo run --release --example wire_demo          # self-hosted loopback
//! FFGPU_CONNECT=127.0.0.1:7070 cargo run --release --example wire_demo
//! ```
//!
//! With `FFGPU_CONNECT` the demo drives an external server (e.g.
//! `FFGPU_LISTEN=127.0.0.1:7070 ... --example serve_demo`); the
//! admission assertions assume that server runs the default
//! [`ffgpu::net::AdmissionConfig`].

use ffgpu::backend::Op;
use ffgpu::coordinator::{Routing, Service, ServiceSpec};
use ffgpu::harness::workload;
use ffgpu::net::{ClientClass, WireClient, WireConfig, WireError, WireServer};
use ffgpu::util::Rng;
use std::time::{Duration, Instant};

/// Rounds per standard client.
const STD_ROUNDS: usize = 30;
/// Rounds the hog attempts.
const HOG_ROUNDS: usize = 12;
/// Lanes per hog submit: two full-burst submits drain the default bulk
/// bucket (1M burst, 500k/s refill), so the third trips admission.
const HOG_LANES: usize = 400_000;

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

fn main() {
    // self-host a loopback server unless FFGPU_CONNECT names one
    let connect = std::env::var("FFGPU_CONNECT").ok();
    // tuple order matters: the wire server must drop (and join its
    // workers) before the service it serves
    let mut hosted: Option<(WireServer, Service)> = None;
    let addr = match &connect {
        Some(a) => a.clone(),
        None => {
            let spec = ServiceSpec::from_cli("native*2", &std::path::PathBuf::from("artifacts"))
                .expect("spec")
                .with_routing(Routing::QueueDepth)
                .with_fuse_window(Duration::from_millis(1));
            let svc = Service::start(spec).expect("service");
            let srv = WireServer::start(svc.handle(), "127.0.0.1:0", WireConfig::default())
                .expect("wire listen");
            let addr = srv.local_addr().to_string();
            println!("self-hosted wire server on {addr}");
            hosted = Some((srv, svc));
            addr
        }
    };

    let t0 = Instant::now();
    let mut joins = Vec::new();

    // three standard tenants: moderate pipelined traffic, generous
    // deadlines — these must never be pushed back
    for c in 0..3u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let tenant = format!("std-{c}");
            let mut cli =
                WireClient::connect(&addr, &tenant, ClientClass::Standard).expect("connect");
            cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
            let ops = [Op::Add22, Op::Mul22, Op::Mul12];
            let mut rng = Rng::new(0xace0 + c);
            let mut lat = Vec::new();
            for round in 0..STD_ROUNDS {
                let op = ops[(c as usize + round) % ops.len()];
                let n = 256 + rng.below(16_384);
                let planes = workload::planes_for(op.name(), n, rng.next_u64());
                let t = Instant::now();
                match cli.call(op, planes, Some(5_000)) {
                    Ok(out) => {
                        lat.push(t.elapsed().as_secs_f64());
                        assert_eq!(out.len(), op.n_out(), "{tenant}: output plane count");
                        assert_eq!(out[0].len(), n, "{tenant}: output length");
                    }
                    Err(e) => panic!("{tenant} round {round}: {e}"),
                }
            }
            lat
        }));
    }

    // the hog: a bulk tenant hammering full-burst submits with no
    // pause — must see Overloaded, must also eventually complete work
    let hog_addr = addr.clone();
    let hog = std::thread::spawn(move || {
        let mut cli =
            WireClient::connect(&hog_addr, "hog", ClientClass::Bulk).expect("hog connect");
        cli.set_io_timeout(Some(Duration::from_secs(30))).expect("timeout");
        let mut rng = Rng::new(0xb1f);
        let mut done = 0u64;
        let mut overloaded = 0u64;
        for _ in 0..HOG_ROUNDS {
            let planes = workload::planes_for(Op::Add22.name(), HOG_LANES, rng.next_u64());
            match cli.call(Op::Add22, planes, None) {
                Ok(out) => {
                    assert_eq!(out[0].len(), HOG_LANES, "hog: output length");
                    done += 1;
                }
                Err(WireError::Overloaded { retry_after_ms }) => {
                    overloaded += 1;
                    // honour the hint, capped so the demo stays quick
                    std::thread::sleep(Duration::from_millis(retry_after_ms.min(150)));
                }
                Err(e) => panic!("hog: unexpected error: {e}"),
            }
        }
        (done, overloaded)
    });

    let mut std_lat: Vec<f64> = Vec::new();
    for j in joins {
        std_lat.extend(j.join().expect("standard client"));
    }
    let (hog_done, hog_overloaded) = hog.join().expect("hog client");
    let wall = t0.elapsed().as_secs_f64();

    std_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{} standard requests in {wall:.2}s: p50={:.2}ms p95={:.2}ms",
        std_lat.len(),
        percentile(&std_lat, 0.50) * 1e3,
        percentile(&std_lat, 0.95) * 1e3,
    );
    println!("hog: {hog_done} completed, {hog_overloaded} pushed back");

    // pull the server's own view of the run over the wire
    let mut probe = WireClient::connect(&addr, "probe", ClientClass::Interactive)
        .expect("probe connect");
    let status = probe.status().expect("status");
    let tiers: Vec<String> = status
        .shards
        .iter()
        .map(|s| match s.tier {
            Some(t) => format!("{}={}", s.label, t.name()),
            None => format!("{}=-", s.label),
        })
        .collect();
    println!("server shards: [{}]", tiers.join(", "));
    for t in &status.tenants {
        println!(
            "  tenant {}: requests={} lanes={} shed={} denied={}",
            t.tenant, t.requests, t.lanes, t.shed, t.denied
        );
    }

    // the serving invariants this demo exists to pin
    assert_eq!(
        std_lat.len(),
        3 * STD_ROUNDS,
        "every standard request must complete"
    );
    assert!(
        hog_overloaded > 0,
        "the bulk hog must see at least one Overloaded verdict"
    );
    assert!(hog_done > 0, "pushback must shape the hog, not starve it");
    let hog_row = status.tenants.iter().find(|t| t.tenant == "hog");
    match hog_row {
        Some(row) => assert!(
            row.shed + row.denied > 0,
            "server status must attribute pushback to the hog"
        ),
        None => panic!("server status must list the hog tenant"),
    }
    for t in &status.tenants {
        if t.tenant.starts_with("std-") {
            assert_eq!(t.shed + t.denied, 0, "standard tenant {} was pushed back", t.tenant);
        }
    }
    println!("wire demo OK");
    drop(hosted);
}
