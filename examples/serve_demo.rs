//! Coordinator serving demo: concurrent clients, dynamic batching,
//! sharded dispatch, metrics — the L3 layer exercised as a service.
//!
//! ```bash
//! cargo run --release --example serve_demo                  # native backend
//! FFGPU_BACKEND=native:2 FFGPU_SHARDS=4 cargo run --release --example serve_demo
//! FFGPU_BACKEND=gpusim:nv35 cargo run --release --example serve_demo
//! FFGPU_BACKEND=xla cargo run --release --example serve_demo
//! ```

use ffgpu::backend::BackendSpec;
use ffgpu::coordinator::{Service, ServiceConfig};
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let explicit = std::env::var("FFGPU_BACKEND").ok();
    let backend_name = explicit.clone().unwrap_or_else(|| {
        if artifacts.join("manifest.json").exists() {
            "xla".into()
        } else {
            println!("(no artifacts; using the native backend)");
            "native".into()
        }
    });
    let shards: usize = std::env::var("FFGPU_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let spec = BackendSpec::from_cli(&backend_name, &artifacts).expect("backend spec");
    println!("backend: {} x {shards} shard(s)", spec.label());
    let svc = match Service::start(ServiceConfig { backend: spec, shards, max_batch: 64 }) {
        Ok(svc) => svc,
        // auto-detected xla but the engine is unavailable (e.g. built
        // without the `xla` feature): fall back to native rather than
        // panic; an explicit FFGPU_BACKEND request still fails loudly
        Err(e) if explicit.is_none() => {
            println!("(xla backend unavailable: {e}; falling back to native)");
            Service::start(ServiceConfig {
                backend: BackendSpec::native(),
                shards,
                max_batch: 64,
            })
            .expect("service")
        }
        Err(e) => panic!("service: {e}"),
    };

    // a mixed workload: 8 clients, varying ops and sizes
    let ops = ["add22", "mul22", "mul12", "add12", "div22"];
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c);
            let mut lat = Vec::new();
            for round in 0..40 {
                let op = ops[(c as usize + round) % ops.len()];
                let n = 256 + rng.below(32_000);
                let planes = workload::planes_for(op, n, rng.next_u64());
                let t = Instant::now();
                let out = h.call(op, planes).expect("call");
                lat.push(t.elapsed().as_secs_f64());
                assert_eq!(out[0].len(), n);
            }
            lat
        }));
    }
    let mut all_lat: Vec<f64> = Vec::new();
    for j in joins {
        all_lat.extend(j.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| all_lat[((all_lat.len() as f64 * p) as usize).min(all_lat.len() - 1)];

    let m = svc.metrics();
    println!("\n{} requests in {wall:.2}s  ->  {:.0} req/s", m.requests,
             m.requests as f64 / wall);
    println!("elements processed: {} ({:.1} Melem/s)", m.elements,
             m.elements as f64 / wall / 1e6);
    println!("batches: {}  launches: {}  padding: {:.1}%", m.batches, m.launches,
             m.padding_fraction() * 100.0);
    println!("client latency: p50={:.2}ms  p95={:.2}ms  p99={:.2}ms",
             pct(0.50) * 1e3, pct(0.95) * 1e3, pct(0.99) * 1e3);
    println!("errors: {}", m.errors);
    for (i, s) in svc.shard_metrics().iter().enumerate() {
        println!("shard {i}: requests={} batches={} elements={} mean lat={:.2}ms",
                 s.requests, s.batches, s.elements, s.mean_latency_s * 1e3);
    }
}
