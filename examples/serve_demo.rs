//! Coordinator serving demo: concurrent clients, typed Plan/Ticket
//! dispatch, heterogeneous shard sets, routing policies, metrics — the
//! L3 layer exercised as a service.
//!
//! ```bash
//! cargo run --release --example serve_demo                  # native backend
//! FFGPU_BACKEND=native:2 FFGPU_SHARDS=4 cargo run --release --example serve_demo
//! FFGPU_SHARD_SPEC=native*2,gpusim:nv35 FFGPU_ROUTING=op-affinity \
//!     cargo run --release --example serve_demo
//! FFGPU_ROUTING=queue-depth cargo run --release --example serve_demo
//! FFGPU_SHARD_SPEC=native*2,gpusim FFGPU_ROUTING=measured \
//!     cargo run --release --example serve_demo              # telemetry-driven
//! FFGPU_DEADLINE_MS=5 cargo run --release --example serve_demo
//! FFGPU_FUSE_WINDOW_MS=2 cargo run --release --example serve_demo  # fusion stage
//! FFGPU_WORKERS=4 cargo run --release --example serve_demo
//! FFGPU_KERNEL_TIER=scalar cargo run --release --example serve_demo
//! FFGPU_CHUNK_ELEMS=65536 cargo run --release --example serve_demo
//! FFGPU_NUMA=off cargo run --release --example serve_demo  # no node pinning
//! FFGPU_OBSERVE=0.25 FFGPU_OBSERVE_MODELS=nv35,r300 \
//!     cargo run --release --example serve_demo          # accuracy observatory
//! FFGPU_CACHE_MB=64 cargo run --release --example serve_demo  # result cache
//! FFGPU_FUSE_WINDOW_MS=2 FFGPU_ADAPTIVE_LADDER=1 \
//!     cargo run --release --example serve_demo      # waste-fed fuse ladders
//! FFGPU_BACKEND=xla cargo run --release --example serve_demo
//! FFGPU_LISTEN=127.0.0.1:7070 FFGPU_SERVE_SECS=30 \
//!     cargo run --release --example serve_demo          # TCP wire front end
//! FFGPU_RECORD=/tmp/session.fftrace \
//!     cargo run --release --example serve_demo          # capture a trace
//! FFGPU_REPLAY=/tmp/session.fftrace FFGPU_REPLAY_RATE=8 \
//!     cargo run --release --example serve_demo          # re-drive it at 8x
//! ```
//!
//! `FFGPU_KERNEL_TIER` (scalar | blocked | blocked-fma | auto) is read
//! by every native shard at construction ([`ffgpu::backend::KernelTier`]
//! resolution order: explicit spec > env > CPU detection), so it needs
//! no plumbing here; `FFGPU_CHUNK_ELEMS` overrides the L2-sized
//! auto-chunk on every native shard. `FFGPU_NUMA` (`auto` | `off` |
//! `<node>`) controls NUMA placement of native shards and needs no
//! plumbing either — [`ServiceSpec`] reads it at start. The demo ends
//! with a deterministic `results checksum:` line over a fixed dispatch
//! grid; it must be bit-identical between `FFGPU_NUMA=auto` and `=off`
//! runs (the CI smoke diffs exactly that line).

use ffgpu::backend::{BackendSpec, Op, ServiceError};
use ffgpu::coordinator::{
    replay, ObservatorySpec, Plan, ResultChecksum, Routing, Service, ServiceSpec, Trace,
    TraceRecorder,
};
use ffgpu::harness::workload;
use ffgpu::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let artifacts = PathBuf::from(
        std::env::var("FFGPU_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let routing = Routing::from_cli(
        &std::env::var("FFGPU_ROUTING").unwrap_or_else(|_| "round-robin".into()),
    )
    .expect("routing policy");
    // FFGPU_DEADLINE_MS arms every ticket; misses are counted, not fatal
    let deadline_ms: u64 = std::env::var("FFGPU_DEADLINE_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    // FFGPU_FUSE_WINDOW_MS arms the fusion stage (window + the paper's
    // stream-size ladder); FFGPU_WORKERS retunes every native shard's
    // persistent worker crew
    let fuse_window_ms: u64 = std::env::var("FFGPU_FUSE_WINDOW_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let workers_env: Option<usize> =
        std::env::var("FFGPU_WORKERS").ok().and_then(|s| s.parse().ok());
    // FFGPU_CHUNK_ELEMS retunes every native shard's chunk size (0 =
    // the L2-sized auto chunk, which is also the default)
    let chunk_env: Option<usize> =
        std::env::var("FFGPU_CHUNK_ELEMS").ok().and_then(|s| s.parse().ok());
    // FFGPU_CACHE_MB arms the content-addressed result cache (MiB byte
    // budget); the workload below pins itself to a small repeated-grid
    // set when it's armed so hits and single-flight coalescing show up
    let cache_mb: usize = std::env::var("FFGPU_CACHE_MB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    // FFGPU_ADAPTIVE_LADDER=1 lets every shard densify its fuse ladder
    // around sizes whose padding-waste EWMA runs hot (needs the fusion
    // stage armed via FFGPU_FUSE_WINDOW_MS)
    let adaptive_ladder = matches!(
        std::env::var("FFGPU_ADAPTIVE_LADDER").as_deref(),
        Ok("1") | Ok("true")
    );
    // FFGPU_RECORD=<path> arms the trace recorder: every dispatch that
    // crosses the coordinator boundary (demo clients, the checksum
    // grid, wire traffic) is captured into a versioned binary trace
    // and saved at exit. FFGPU_RECORD_INLINE=1 stores full plane bits
    // (bit-exact replays, bigger files); the default stores content
    // fingerprints. FFGPU_REPLAY=<path> re-drives a recorded trace
    // against whatever configuration this process was given, instead
    // of the synthetic workload; FFGPU_REPLAY_RATE compresses the
    // recorded arrival gaps (deadlines keep their recorded spans).
    let record_path = std::env::var("FFGPU_RECORD").ok().map(PathBuf::from);
    let record_inline = matches!(
        std::env::var("FFGPU_RECORD_INLINE").as_deref(),
        Ok("1") | Ok("true")
    );
    let replay_path = std::env::var("FFGPU_REPLAY").ok().map(PathBuf::from);
    let replay_rate: f64 = std::env::var("FFGPU_REPLAY_RATE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    // FFGPU_OBSERVE + FFGPU_OBSERVE_MODELS arm the accuracy
    // observatory: that fraction of the demo traffic is mirrored onto
    // a native reference + the listed GPU models, and the live
    // Table-2/Table-5 accuracy report prints at the end
    let observe_env = std::env::var("FFGPU_OBSERVE").ok();
    let observe_models = std::env::var("FFGPU_OBSERVE_MODELS")
        .unwrap_or_else(|_| "nv35,r300,chopped".into());
    // FFGPU_SHARD_SPEC gives every shard its own backend; otherwise a
    // uniform set from FFGPU_BACKEND/FFGPU_SHARDS (xla auto-detected)
    let explicit_backend = std::env::var("FFGPU_BACKEND").ok();
    let shard_spec = std::env::var("FFGPU_SHARD_SPEC").ok();
    let shards: usize = std::env::var("FFGPU_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let spec = match &shard_spec {
        Some(list) => ServiceSpec::from_cli(list, &artifacts).expect("shard spec"),
        None => {
            let backend_name = explicit_backend.clone().unwrap_or_else(|| {
                if artifacts.join("manifest.json").exists() {
                    "xla".into()
                } else {
                    println!("(no artifacts; using the native backend)");
                    "native".into()
                }
            });
            let b = BackendSpec::from_cli(&backend_name, &artifacts).expect("backend spec");
            ServiceSpec::uniform(b, shards)
        }
    };
    let mut spec = spec.with_routing(routing);
    if let Some(w) = workers_env {
        for s in &mut spec.shards {
            if let BackendSpec::Native { workers, .. } = s {
                *workers = w;
            }
        }
    }
    if let Some(c) = chunk_env {
        for s in &mut spec.shards {
            if let BackendSpec::Native { chunk, .. } = s {
                *chunk = c;
            }
        }
    }
    if fuse_window_ms > 0 {
        spec = spec
            .with_fuse_window(Duration::from_millis(fuse_window_ms))
            .with_fuse_sizes(ffgpu::coordinator::PAPER_FUSE_SIZES.to_vec());
    }
    if let Some(f) = &observe_env {
        let obs = ObservatorySpec::from_cli(f, &observe_models).expect("observe spec");
        spec = spec.with_observatory(obs);
    }
    if cache_mb > 0 {
        spec = spec.with_cache_mb(cache_mb);
    }
    if adaptive_ladder {
        spec = spec.with_adaptive_ladder(true);
    }
    // the caller-side Arc clone keeps the capture reachable for the
    // save at the end of the run (drop-not-block: 64 MiB budget)
    let recorder = record_path
        .as_ref()
        .map(|_| Arc::new(TraceRecorder::new(64 << 20, record_inline)));
    if let Some(rec) = &recorder {
        spec = spec.with_recorder(Arc::clone(rec));
    }
    let labels: Vec<&str> = spec.shards.iter().map(|s| s.label()).collect();
    println!(
        "shards: [{}]  routing: {}  fusion: {}  observatory: {}  cache: {}",
        labels.join(", "),
        routing.name(),
        if fuse_window_ms > 0 {
            format!(
                "{fuse_window_ms}ms window{}",
                if adaptive_ladder { " (adaptive ladder)" } else { "" }
            )
        } else {
            "off".into()
        },
        match &spec.observe {
            Some(o) => format!("{:.0}% -> [{}]", o.fraction * 100.0, o.models.join(", ")),
            None => "off".into(),
        },
        if cache_mb > 0 { format!("{cache_mb} MiB") } else { "off".into() }
    );
    let fallback = spec.clone();
    let svc = match Service::start(spec) {
        Ok(svc) => svc,
        // auto-detected xla but the engine is unavailable (e.g. built
        // without the `xla` feature): fall back to native rather than
        // panic; an explicit FFGPU_BACKEND/FFGPU_SHARD_SPEC request
        // still fails loudly
        Err(e) if explicit_backend.is_none() && shard_spec.is_none() => {
            println!("(xla backend unavailable: {e}; falling back to native)");
            let mut native = fallback;
            // keep routing/fusion AND the FFGPU_WORKERS /
            // FFGPU_CHUNK_ELEMS overrides (tier: None defers to
            // FFGPU_KERNEL_TIER / CPU detection at construction)
            native.shards = vec![
                BackendSpec::Native {
                    chunk: chunk_env.unwrap_or(0),
                    workers: workers_env.unwrap_or(0),
                    tier: None,
                    node: None,
                };
                shards.max(1)
            ];
            Service::start(native).expect("service")
        }
        Err(e) => panic!("service: {e}"),
    };
    // NUMA placement resolved at start (FFGPU_NUMA; auto degrades to
    // unpinned on single-node hosts)
    let nodes: Vec<String> = svc
        .shard_numa_nodes()
        .iter()
        .map(|n| n.map_or("-".to_string(), |n| format!("node{n}")))
        .collect();
    println!("numa nodes: [{}]", nodes.join(", "));

    // FFGPU_REPLAY: re-drive a recorded session through this exact
    // service configuration and print the scenario report instead of
    // running the synthetic workload. The report's results checksum is
    // the regression gate: same trace, any config -> identical line.
    if let Some(path) = &replay_path {
        let trace = Trace::load(path)
            .unwrap_or_else(|e| panic!("load trace {}: {e}", path.display()));
        println!(
            "replaying {} ({} records, inline: {}) at {replay_rate}x",
            path.display(),
            trace.records.len(),
            trace.all_inline()
        );
        let report = replay(&svc, &trace, replay_rate).expect("replay");
        print!("{}", report.render());
        println!("determinism key: {:#018x}", report.determinism_key());
        return;
    }

    // FFGPU_LISTEN arms the TCP wire front end beside the in-process
    // demo traffic; FFGPU_SERVE_SECS keeps it up after the workload so
    // out-of-process clients (examples/wire_demo.rs) can connect
    let listen = std::env::var("FFGPU_LISTEN").ok();
    let serve_secs: u64 = std::env::var("FFGPU_SERVE_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let wire = listen.as_deref().map(|addr| {
        let srv =
            ffgpu::net::WireServer::start(svc.handle(), addr, ffgpu::net::WireConfig::default())
                .expect("wire listen");
        println!("wire front end listening on {}", srv.local_addr());
        srv
    });

    // a mixed workload: 8 concurrent clients, varying ops and sizes,
    // dispatched through the typed Plan/Ticket API
    let ops = [Op::Add22, Op::Mul22, Op::Mul12, Op::Add12, Op::Div22];
    // the gpusim soft-float VM is ~1000x slower than native kernels:
    // keep it responsive by shrinking the batches it may be routed —
    // the observatory mirrors onto the same soft-float models, so an
    // observed run shrinks too
    let slow = svc.shard_labels().iter().any(|&l| l == "gpusim") || svc.has_observatory();
    let top = if slow { 4_000 } else { 32_000 };
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c);
            let mut lat = Vec::new();
            let mut missed = 0u64;
            for round in 0..40 {
                let op = ops[(c as usize + round) % ops.len()];
                // with the result cache armed, every client draws from
                // the same small repeated-grid set: later rounds (and
                // concurrent identical dispatches) hit or coalesce
                let (n, seed) = if cache_mb > 0 {
                    (4096, (round % 5) as u64)
                } else {
                    (256 + rng.below(top), rng.next_u64())
                };
                let planes = workload::planes_for(op.name(), n, seed);
                let plan = Plan::new(op, planes).expect("plan");
                // timer spans dispatch -> reply only, so the printed
                // percentiles are honest client latency
                let t = Instant::now();
                let mut ticket = h.dispatch(plan).expect("dispatch");
                if deadline_ms > 0 {
                    ticket = ticket.deadline(Duration::from_millis(deadline_ms));
                }
                match ticket.wait() {
                    Ok(out) => {
                        lat.push(t.elapsed().as_secs_f64());
                        assert_eq!(out[0].len(), n);
                    }
                    Err(ServiceError::DeadlineExceeded) => missed += 1,
                    Err(e) => panic!("reply: {e}"),
                }
            }
            (lat, missed)
        }));
    }
    let mut all_lat: Vec<f64> = Vec::new();
    let mut missed = 0u64;
    for j in joins {
        let (lat, m) = j.join().unwrap();
        all_lat.extend(lat);
        missed += m;
    }
    if all_lat.is_empty() {
        // every ticket missed its deadline (tiny FFGPU_DEADLINE_MS):
        // still report cleanly instead of indexing into an empty vec
        all_lat.push(0.0);
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| all_lat[((all_lat.len() as f64 * p) as usize).min(all_lat.len() - 1)];

    let m = svc.metrics();
    println!("\n{} requests in {wall:.2}s  ->  {:.0} req/s", m.requests,
             m.requests as f64 / wall);
    println!("elements processed: {} ({:.1} Melem/s)", m.elements,
             m.elements as f64 / wall / 1e6);
    println!("batches: {}  launches: {}  padding: {:.1}%", m.batches, m.launches,
             m.padding_fraction() * 100.0);
    println!("client latency: p50={:.2}ms  p95={:.2}ms  p99={:.2}ms",
             pct(0.50) * 1e3, pct(0.95) * 1e3, pct(0.99) * 1e3);
    println!("errors: {}  deadline misses: {missed} (shard-side skipped={} cancelled={})",
             m.errors, m.expired, m.cancelled);
    let tiers = svc.shard_kernel_tiers();
    for (i, (s, label)) in svc
        .shard_metrics()
        .iter()
        .zip(svc.shard_labels())
        .enumerate()
    {
        let rates: Vec<String> = ops
            .iter()
            .map(|&op| match svc.measured_rate(i, op) {
                Some(r) => format!("{op}={r:.1}"),
                None => format!("{op}=cold"),
            })
            .collect();
        // attribute the shard's Melem/s to the CPU kernel tier that
        // produced them (non-native shards report no tier)
        let tier = match tiers.get(i).copied().flatten() {
            Some(t) => format!(" tier={t}"),
            None => String::new(),
        };
        println!("shard {i} [{label}]{tier}: requests={} batches={} elements={} mean lat={:.2}ms",
                 s.requests, s.batches, s.elements, s.mean_latency_s * 1e3);
        println!("  measured Melem/s: {}", rates.join("  "));
    }
    // gather/execute/scatter split of each shard's fused groups (EWMA;
    // only fused groups record one, so unfused runs print nothing)
    for i in 0..svc.shards() {
        if let Some((g, e, s)) = svc.shard_stage_split(i) {
            println!(
                "shard {i} data path: gather={:.3}ms execute={:.3}ms scatter={:.3}ms",
                g * 1e3, e * 1e3, s * 1e3
            );
        }
    }
    // deterministic results checksum: a fixed dispatch grid, FNV-1a
    // over the reply bits ([`ResultChecksum`] — the same fold the
    // replay verifier and the CI gate use). This line must be
    // identical run to run — and in particular between FFGPU_NUMA=auto
    // and =off serves (the CI smoke diffs exactly this line) — because
    // placement may move the copies across threads and nodes but must
    // never change a bit
    let mut sum = ResultChecksum::new();
    for (k, &op) in ops.iter().enumerate() {
        let planes = workload::planes_for(op.name(), 1537, 0xC0FFEE + k as u64);
        let out = svc
            .handle()
            .dispatch(Plan::new(op, planes).expect("plan"))
            .expect("dispatch")
            .wait()
            .expect("checksum reply");
        sum.update(&out);
    }
    println!("results checksum: {:#018x}", sum.value());
    // the result-cache banner: how much traffic resolved before routing
    if let Some(cs) = svc.cache_stats() {
        println!(
            "cache: hits={} misses={} coalesced={} hit-rate={:.1}% \
             inserted={}B evictions={} live={}B/{}B",
            cs.hits, cs.misses, cs.coalesced, cs.hit_rate() * 100.0,
            cs.inserted_bytes, cs.evictions, cs.live_bytes, cs.budget_bytes
        );
        // the repeated-grid workload above guarantees warm traffic:
        // zero hits here would mean the cache is broken, so fail loudly
        // (CI smokes run with FFGPU_CACHE_MB=64 and rely on this)
        assert!(
            cs.hits > 0,
            "result cache armed with a repeated-grid workload but saw no hits"
        );
    }
    // the live accuracy surface the observatory measured beside the run
    if let Some(rep) = svc.accuracy_report() {
        print!("\n{}", rep.render_table2_live());
        print!("\n{}", rep.render_table5_live());
    }
    // per-tenant wire attribution (only populated via the wire front end)
    let tenants = svc.tenant_metrics();
    if !tenants.is_empty() {
        println!("\ntenants:");
        for (tenant, c) in &tenants {
            println!(
                "  {tenant}: requests={} lanes={} shed={} denied={}",
                c.requests, c.lanes, c.shed, c.denied
            );
        }
    }
    if let Some(srv) = wire {
        if serve_secs > 0 {
            println!("serving on {} for {serve_secs}s ...", srv.local_addr());
            std::thread::sleep(Duration::from_secs(serve_secs));
        }
        srv.shutdown();
        // tenants that arrived over the wire during the serve window
        let tenants = svc.tenant_metrics();
        if !tenants.is_empty() {
            println!("tenants after serve window:");
            for (tenant, c) in &tenants {
                println!(
                    "  {tenant}: requests={} lanes={} shed={} denied={}",
                    c.requests, c.lanes, c.shed, c.denied
                );
            }
        }
    }
    // FFGPU_RECORD: persist everything the recorder captured above
    // (workload, checksum grid, any wire traffic) for later replays
    if let (Some(path), Some(rec)) = (&record_path, &recorder) {
        let trace = rec.trace();
        trace
            .save(path)
            .unwrap_or_else(|e| panic!("save trace {}: {e}", path.display()));
        println!(
            "trace recorded: {} ({} records, {} bytes, dropped: {})",
            path.display(),
            trace.records.len(),
            rec.bytes(),
            rec.dropped()
        );
    }
}
