//! Deep-zoom Mandelbrot — the "multipass real-time algorithm" motivation
//! (paper §7: float-float ops "remain fast enough to be used in precise
//! sensitive parts of real-time multipass algorithms").
//!
//! At zoom depths beyond ~2^-23 of the complex plane, binary32 pixel
//! coordinates collapse onto each other and the image turns to banding;
//! float-float keeps iterating correctly down to ~2^-45. We render the
//! same window in f32, FF32 and f64 (truth), and report pixel agreement.
//!
//! ```bash
//! cargo run --release --example mandelbrot_deep_zoom
//! ```

use ffgpu::ff::FF32;

const W: usize = 64;
const H: usize = 32;
const MAX_ITER: u32 = 2048;

/// Escape-time iteration in any arithmetic, via a small trait.
trait Complexish: Copy {
    fn from_f64(v: f64) -> Self;
    fn mul(self, o: Self) -> Self;
    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn to_f64(self) -> f64;
}

impl Complexish for f32 {
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn mul(self, o: Self) -> Self {
        self * o
    }
    fn add(self, o: Self) -> Self {
        self + o
    }
    fn sub(self, o: Self) -> Self {
        self - o
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Complexish for f64 {
    fn from_f64(v: f64) -> Self {
        v
    }
    fn mul(self, o: Self) -> Self {
        self * o
    }
    fn add(self, o: Self) -> Self {
        self + o
    }
    fn sub(self, o: Self) -> Self {
        self - o
    }
    fn to_f64(self) -> f64 {
        self
    }
}

impl Complexish for FF32 {
    fn from_f64(v: f64) -> Self {
        FF32::from_f64(v)
    }
    fn mul(self, o: Self) -> Self {
        self * o
    }
    fn add(self, o: Self) -> Self {
        self + o
    }
    fn sub(self, o: Self) -> Self {
        self - o
    }
    fn to_f64(self) -> f64 {
        self.to_f64()
    }
}

fn escape_time<T: Complexish>(cr: f64, ci: f64) -> u32 {
    let (cr, ci) = (T::from_f64(cr), T::from_f64(ci));
    let mut zr = T::from_f64(0.0);
    let mut zi = T::from_f64(0.0);
    for it in 0..MAX_ITER {
        let zr2 = zr.mul(zr);
        let zi2 = zi.mul(zi);
        if zr2.to_f64() + zi2.to_f64() > 4.0 {
            return it;
        }
        let new_zr = zr2.sub(zi2).add(cr);
        zi = zr.mul(zi).add(zr.mul(zi)).add(ci); // 2·zr·zi + ci
        zr = new_zr;
    }
    MAX_ITER
}

fn render<T: Complexish>(cx: f64, cy: f64, scale: f64) -> Vec<u32> {
    let mut img = Vec::with_capacity(W * H);
    for y in 0..H {
        for x in 0..W {
            let cr = cx + (x as f64 / W as f64 - 0.5) * scale;
            let ci = cy + (y as f64 / H as f64 - 0.5) * scale * 0.5;
            img.push(escape_time::<T>(cr, ci));
        }
    }
    img
}

fn agreement(a: &[u32], b: &[u32]) -> f64 {
    let same = a.iter().zip(b).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

fn ascii(img: &[u32]) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut s = String::new();
    for y in 0..H {
        for x in 0..W {
            let v = img[y * W + x];
            // log-scale the ramp so deep-zoom structure is visible
            let lv = ((v.max(1) as f64).ln() / (MAX_ITER as f64).ln() * (RAMP.len() - 1) as f64) as usize;
            let idx = lv.min(RAMP.len() - 1);
            s.push(RAMP[idx] as char);
        }
        s.push('\n');
    }
    s
}

fn main() {
    // a seahorse-valley point, zoomed far past f32 resolution
    let (cx, cy) = (-0.743643887037151, 0.131825904205330);
    println!("deep zoom at ({cx}, {cy})\n");
    println!("{:>12} {:>10} {:>10}", "scale", "f32 vs f64", "FF32 vs f64");
    for exp in [-18i32, -24, -30, -33, -36] {
        let scale = (exp as f64).exp2();
        let truth = render::<f64>(cx, cy, scale);
        let img32 = render::<f32>(cx, cy, scale);
        let imgff = render::<FF32>(cx, cy, scale);
        println!(
            "{:>12} {:>9.1}% {:>9.1}%",
            format!("2^{exp}"),
            agreement(&img32, &truth) * 100.0,
            agreement(&imgff, &truth) * 100.0
        );
    }

    // show the collapse visually at 2^-36
    let scale = (-36f64).exp2();
    println!("\nf32 render at 2^-36 (banding = precision collapse):");
    print!("{}", ascii(&render::<f32>(cx, cy, scale)));
    println!("\nFF32 render at 2^-36 (matches f64):");
    print!("{}", ascii(&render::<FF32>(cx, cy, scale)));
}
