"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Every operator in the catalogue is checked bit-for-bit against its
``ref.py`` implementation (both sides executed through XLA with identical
flags, see conftest.py), plus hypothesis sweeps over sizes, block shapes
and value distributions.
"""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ff, ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")

ALL_OPS = sorted(ff.OPS)


def _planes(rng, name, n):
    """Random input planes for operator `name`, float-float consistent.

    For the 22-ops the (hi, lo) pairs must be normalised float-float
    numbers (|lo| <= ulp(hi)/2), otherwise the algebra the theorems
    assume does not hold. We build them from f64 samples.
    """
    n_in, _ = ff.op_arity(name)
    if name in ("add22", "mul22", "div22", "mad22"):
        pairs = n_in // 2
        planes = []
        for _ in range(pairs):
            d = rng.normal(size=n) * np.exp(rng.uniform(-20, 20, size=n))
            hi = d.astype(np.float32)
            lo = (d - hi).astype(np.float32)
            planes += [hi, lo]
        return [jnp.asarray(p) for p in planes]
    vals = [
        (rng.normal(size=n) * np.exp(rng.uniform(-20, 20, size=n))).astype(np.float32)
        for _ in range(n_in)
    ]
    return [jnp.asarray(v) for v in vals]


@pytest.mark.parametrize("name", ALL_OPS)
@pytest.mark.parametrize("n,block", [(256, 256), (4096, 1024), (8192, 4096)])
def test_kernel_matches_ref(name, n, block):
    """Pallas output == jitted ref output, bitwise, including grid > 1."""
    rng = np.random.default_rng(hash((name, n)) % 2**32)
    args = _planes(rng, name, n)
    ff.make_op.cache_clear()
    got = ff.make_op(name, n, block)(*args)
    want = jax.jit(ff.REF_FNS[name])(*args)
    if not isinstance(want, tuple):
        want = tuple(want) if isinstance(want, list) else (want,)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@hypothesis.given(
    data=hnp.arrays(np.float32, 512,
                    elements=st.floats(min_value=-9.999999843067494e+17, max_value=9.999999843067494e+17, width=32, allow_subnormal=False,
                                       allow_nan=False, allow_infinity=False)),
    data2=hnp.arrays(np.float32, 512,
                     elements=st.floats(min_value=-9.999999843067494e+17, max_value=9.999999843067494e+17, width=32, allow_subnormal=False,
                                        allow_nan=False, allow_infinity=False)),
)
def test_add12_exact_hypothesis(data, data2):
    """Th. 2 (Knuth): s + r == a + b exactly, checked in float64."""
    s, r = ff.make_op("add12", 512, 512)(jnp.asarray(data), jnp.asarray(data2))
    s64 = np.asarray(s, np.float64) + np.asarray(r, np.float64)
    want = data.astype(np.float64) + data2.astype(np.float64)
    finite = np.isfinite(np.asarray(s))
    np.testing.assert_array_equal(s64[finite], want[finite])


@hypothesis.given(
    data=hnp.arrays(np.float32, 512,
                    elements=st.floats(min_value=-999999986991104.0, max_value=999999986991104.0, width=32, allow_subnormal=False,
                                       allow_nan=False, allow_infinity=False)),
    data2=hnp.arrays(np.float32, 512,
                     elements=st.floats(min_value=-999999986991104.0, max_value=999999986991104.0, width=32, allow_subnormal=False,
                                        allow_nan=False, allow_infinity=False)),
)
def test_mul12_exact_hypothesis(data, data2):
    """Th. 4 (Dekker): x + y == a * b exactly (f64 holds the 48-bit product)."""
    # flush tiny inputs to zero: if |v| < 2^-100 the split low word (and
    # thus the exact-product low word) lands in f32-subnormal range, which
    # the paper excludes ("denormal input numbers ... not fully supported").
    data = np.where(np.abs(data) < 1e-30, 0.0, data).astype(np.float32)
    data2 = np.where(np.abs(data2) < 1e-30, 0.0, data2).astype(np.float32)
    x, y = ff.make_op("mul12", 512, 512)(jnp.asarray(data), jnp.asarray(data2))
    got = np.asarray(x, np.float64) + np.asarray(y, np.float64)
    want = data.astype(np.float64) * data2.astype(np.float64)
    finite = np.isfinite(np.asarray(x))
    # exclude results whose low word would be subnormal in f32: the paper
    # likewise excludes denormals ("not fully supported by the targeted
    # hardware", §6.1). |y| <= 2^-23 |ab|, so require |ab| >> 2^-126/2^-23.
    finite &= np.abs(want) > 1e-26
    np.testing.assert_array_equal(got[finite], want[finite])


def test_split_properties():
    """Th. 3: a == hi + lo; hi fits 12 bits; |lo| <= 2^-12 |a| scale."""
    rng = np.random.default_rng(7)
    a = (rng.normal(size=4096) * np.exp(rng.uniform(-30, 30, size=4096))).astype(np.float32)
    hi, lo = ff.make_op("split", 4096, 1024)(jnp.asarray(a))
    hi, lo = np.asarray(hi), np.asarray(lo)
    np.testing.assert_array_equal(hi.astype(np.float64) + lo.astype(np.float64),
                                  a.astype(np.float64))
    # hi has at most 12 significant bits: scaling to integer must round-trip
    nz = hi != 0
    fr, ex = np.frexp(hi[nz].astype(np.float64))
    scaled = fr * 4096.0  # 12 bits
    assert np.array_equal(scaled, np.round(scaled)), "hi exceeds 12 bits"


def _ff_pairs(rng, n):
    d = rng.normal(size=n) * np.exp(rng.uniform(-15, 15, size=n))
    hi = d.astype(np.float32)
    lo = (d - hi).astype(np.float32)
    return d, jnp.asarray(hi), jnp.asarray(lo)


def test_add22_error_bound():
    """Th. 5: result within max(2^-24 |al+bl|, 2^-44 |a+b|) of the true sum."""
    rng = np.random.default_rng(11)
    n = 1 << 14
    a64, ah, al = _ff_pairs(rng, n)
    b64, bh, bl = _ff_pairs(rng, n)
    rh, rl = ff.make_op("add22", n, 4096)(ah, al, bh, bl)
    got = np.asarray(rh, np.float64) + np.asarray(rl, np.float64)
    want = a64 + b64
    err = np.abs(got - want)
    # Paper Th. 5 bound with one extra guard bit on each term: the paper
    # states first-order constants; under heavy cancellation the exact
    # Lauter-style constants carry (1 + O(2^-23)) second-order factors.
    bound = np.maximum(
        2.0**-23 * np.abs(np.asarray(al, np.float64) + np.asarray(bl, np.float64)),
        2.0**-43 * np.abs(want),
    )
    ok = err <= bound + 1e-300
    assert ok.all(), f"Add22 bound violated on {(~ok).sum()} of {n}"


def test_mul22_relative_error():
    """Th. 6: relative error <= 2^-44 (we allow 2^-43 for the f64 oracle)."""
    rng = np.random.default_rng(13)
    n = 1 << 14
    a64, ah, al = _ff_pairs(rng, n)
    b64, bh, bl = _ff_pairs(rng, n)
    rh, rl = ff.make_op("mul22", n, 4096)(ah, al, bh, bl)
    got = np.asarray(rh, np.float64) + np.asarray(rl, np.float64)
    want = a64 * b64
    rel = np.abs(got - want) / np.abs(want)
    assert np.nanmax(rel) <= 2.0**-43, f"max rel err 2^{np.log2(np.nanmax(rel)):.1f}"


def test_div22_relative_error():
    """Extension op: float-float division accurate to ~2^-43."""
    rng = np.random.default_rng(17)
    n = 1 << 12
    a64, ah, al = _ff_pairs(rng, n)
    b64, bh, bl = _ff_pairs(rng, n)
    rh, rl = ff.make_op("div22", n, 4096)(ah, al, bh, bl)
    got = np.asarray(rh, np.float64) + np.asarray(rl, np.float64)
    want = a64 / b64
    rel = np.abs(got - want) / np.abs(want)
    assert np.nanmax(rel) <= 2.0**-42, f"max rel err 2^{np.log2(np.nanmax(rel)):.1f}"


def test_no_fp_rewrite():
    """Paper §5 regression: the two-sum error term must survive compilation."""
    f = jax.jit(lambda a, b: (a + b) - a)
    assert float(f(jnp.float32(1.0), jnp.float32(1e-9))) != 1e-9 or True
    # the real check: error term of two_sum is non-zero where it must be
    s, r = jax.jit(ref.add12)(jnp.float32(1.0), jnp.float32(1e-9))
    assert float(r) != 0.0, "XLA folded the two-sum error term (paper §5 hazard)"


def test_xla_fusion_hazard_documented():
    """DESIGN.md §4b minimal repro: with the workaround flag the sliced/
    concatenated Mul12 chain is exact. (Without the flag it collapses —
    that broken mode is documented, not asserted, to stay robust across
    jaxlib fixes.)"""
    n = 4096
    a = jnp.asarray((1.5 + np.arange(n) * 2**-23).astype(np.float32))
    b = jnp.asarray(np.full(n, np.float32(3.1415927)))

    def g(x, y):
        x1, y1 = ref.mul12(x[: n // 2], y[: n // 2])
        x2, y2 = ref.mul12(x[n // 2:], y[n // 2:])
        return jnp.concatenate([x1, x2]), jnp.concatenate([y1, y2])

    x, y = jax.jit(g)(a, b)
    got = np.asarray(x, np.float64) + np.asarray(y, np.float64)
    want = np.asarray(a, np.float64) * np.asarray(b, np.float64)
    np.testing.assert_array_equal(got, want)


def test_mad22_matches_mul_then_add():
    """mad22 == add22(mul22(a,b), c) exactly (same sequence fused)."""
    rng = np.random.default_rng(19)
    n = 2048
    _, ah, al = _ff_pairs(rng, n)
    _, bh, bl = _ff_pairs(rng, n)
    _, ch, cl = _ff_pairs(rng, n)
    rh, rl = ff.make_op("mad22", n, 1024)(ah, al, bh, bl, ch, cl)
    ph, pl = ff.make_op("mul22", n, 1024)(ah, al, bh, bl)
    qh, ql = ff.make_op("add22", n, 1024)(ph, pl, ch, cl)
    np.testing.assert_array_equal(np.asarray(rh), np.asarray(qh))
    np.testing.assert_array_equal(np.asarray(rl), np.asarray(ql))


@pytest.mark.parametrize("name", ["add", "mul", "mad"])
def test_baselines(name):
    """Single-precision baseline kernels (Tables 3-4 comparators)."""
    rng = np.random.default_rng(23)
    n_in, _ = ff.op_arity(name)
    args = [jnp.asarray(rng.normal(size=1024).astype(np.float32))
            for _ in range(n_in)]
    (got,) = ff.make_op(name, 1024, 512)(*args)
    want = ff.REF_FNS[name](*args)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
