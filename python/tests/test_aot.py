"""AOT pipeline: lowering produces loadable HLO text + a sound manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(["--out", str(out), "--sizes", "256", "--block", "128",
                   "--ops", "add", "add22", "mul12"])
    assert rc == 0
    return out


def test_manifest_schema(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text-v1"
    names = {e["name"] for e in manifest["entries"]}
    assert {"add_n256", "add22_n256", "mul12_n256"} <= names
    for e in manifest["entries"]:
        assert (tiny_artifacts / e["file"]).exists()
        assert e["hlo_bytes"] > 0
        assert e["n_in"] >= 1 and e["n_out"] >= 1


def test_hlo_text_is_parseable(tiny_artifacts):
    """HLO text must start with HloModule and contain an ENTRY computation
    (what HloModuleProto::from_text_file on the rust side requires)."""
    for f in os.listdir(tiny_artifacts):
        if not f.endswith(".hlo.txt"):
            continue
        text = (tiny_artifacts / f).read_text()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f


def test_mask_split_in_artifacts(tiny_artifacts):
    """The fold-proof mask split must be what ships (DESIGN.md §4b)."""
    text = (tiny_artifacts / "mul12_n256.hlo.txt").read_text()
    assert "4294963200" in text or "and(" in text, "mask split missing"
    assert "4097" not in text, "FP-only Dekker split leaked into artifacts"


def test_only_filter():
    cat = model.catalogue(sizes=(256,), ops=("add",))
    assert "add_n256" in cat
    # catalogue always appends the composites
    assert any(k.startswith("dot2_") for k in cat)
    assert any(k.startswith("multipass_") for k in cat)
    assert any(k.startswith("horner2_") for k in cat)
