"""Pytest config: XLA flags MUST be set before jax initializes a backend.

``--xla_disable_hlo_passes=fusion`` works around the XLA CPU fusion
miscompilation of error-free-transformation chains (DESIGN.md §4b "XLA
FP-rewrite hazard"). The rust runtime sets the same flag programmatically
in ``runtime::client``; keeping both sides identical means the pytest
oracle checks validate exactly what the coordinator will execute.
"""

import os
import sys

# allow `pytest python/tests/` from the repo root as well as `cd python`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_FLAG = "--xla_disable_hlo_passes=fusion"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
