"""L2 composite graphs: multipass, dot2, horner2 vs numpy-f64 references."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ff, ref


def _ff_pairs(rng, n, scale=4.0):
    d = rng.normal(size=n) * np.exp(rng.uniform(-scale, scale, size=n))
    hi = d.astype(np.float32)
    lo = (d - hi).astype(np.float32)
    return d, jnp.asarray(hi), jnp.asarray(lo)


def test_stream_op_catalogue_arities():
    cat = model.catalogue(sizes=(256,), ops=("add22", "mul", "split"))
    for name, (fn, args, meta) in cat.items():
        assert meta["n_in"] == len(args) or meta["kind"] != "stream"
        out = jax.jit(fn)(*args_to_zeros(args))
        out = out if isinstance(out, tuple) else (out,)
        assert len(out) == meta["n_out"]


def args_to_zeros(args):
    return tuple(jnp.zeros(a.shape, a.dtype) for a in args)


def test_dot2_accuracy():
    """ff dot product ~2^-40 relative vs f64; f32 dot much worse on
    ill-conditioned data."""
    rng = np.random.default_rng(3)
    n = 4096
    a64, ah, al = _ff_pairs(rng, n, scale=8.0)
    b64, bh, bl = _ff_pairs(rng, n, scale=8.0)
    g = model.dot2(n, block=1024)
    rh, rl = jax.jit(g)(ah, al, bh, bl)
    got = float(rh) + float(rl)
    want = float(np.dot(a64, b64))
    rel = abs(got - want) / abs(want)
    f32 = float(np.dot(np.asarray(ah), np.asarray(bh)))
    rel32 = abs(f32 - want) / abs(want)
    assert rel < 2.0**-38, f"dot2 rel err 2^{np.log2(rel + 1e-300):.1f}"
    assert rel <= rel32 + 1e-18


def test_multipass_matches_reference():
    """x <- x*b + a iterated: pallas-pipelined graph == scalar f-f model."""
    rng = np.random.default_rng(5)
    n, iters = 512, 8
    _, ah, al = _ff_pairs(rng, n, scale=0.5)
    # keep |b| < 1 so the iteration stays bounded
    b64 = rng.uniform(-0.9, 0.9, size=n)
    bh = b64.astype(np.float32)
    bl = (b64 - bh).astype(np.float32)
    g = model.multipass(n, iters, block=256)
    xh, xl = jax.jit(g)(ah, al, jnp.asarray(bh), jnp.asarray(bl))
    # reference via jitted ref ops (same arithmetic path)
    rxh, rxl = ah, al
    mul = jax.jit(ref.mul22)
    add = jax.jit(ref.add22)
    for _ in range(iters):
        th, tl = mul(rxh, rxl, jnp.asarray(bh), jnp.asarray(bl))
        rxh, rxl = add(th, tl, ah, al)
    np.testing.assert_array_equal(np.asarray(xh), np.asarray(rxh))
    np.testing.assert_array_equal(np.asarray(xl), np.asarray(rxl))


def test_horner2_vs_f64():
    """float-float Horner gets ~f64 accuracy on a wobbly polynomial."""
    rng = np.random.default_rng(9)
    deg = 15
    c64 = rng.normal(size=deg + 1)
    ch = c64.astype(np.float32)
    cl = (c64 - ch).astype(np.float32)
    x64 = 1.337
    xh = np.float32(x64)
    xl = np.float32(x64 - float(xh))
    g = model.horner2(deg)
    rh, rl = jax.jit(g)(jnp.asarray(ch), jnp.asarray(cl),
                        jnp.asarray(xh), jnp.asarray(xl))
    got = float(rh) + float(rl)
    want = 0.0
    for c in c64:
        want = want * x64 + c
    assert abs(got - want) / abs(want) < 2.0**-40


def test_paper_grid_constants():
    assert model.PAPER_SIZES == (4096, 16384, 65536, 262144, 1048576)
    assert model.PAPER_OPS == ("add", "mul", "mad", "add12", "mul12",
                               "add22", "mul22")
