"""Pure-jnp reference oracle for the float-float kernels.

Every algorithm here is the textbook (Dekker/Knuth/Shewchuk) sequence the
paper gives in section 4, written in plain ``jax.numpy`` with **no pallas**.
These are the correctness oracles the Pallas kernels in :mod:`ff` are
pytest-checked against, and also serve as the "exact" float64 references
(pass ``dtype=jnp.float64`` with x64 enabled).

Notation follows the paper: ``Add12`` is the error-free transformation of
the sum (Knuth two-sum, the *branch-free* 6-op variant the paper prefers
for GPUs), ``Split`` is Dekker's splitting, ``Mul12`` Dekker's exact
product, ``Add22``/``Mul22`` the float-float add/mul of [5, 17].

All functions are elementwise over arrays and return tuples of arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Splitting constant for binary32: s = 12 (p=24, ceil(p/2)), 2^12 + 1.
# The paper (Th. 3) allows any p/2 <= s <= p-1; Dekker's choice s=ceil(p/2)
# maximises the bits of the low part. For float64 the constant is 2^27+1.
SPLIT_CONST_F32 = 4097.0  # 2**12 + 1
SPLIT_CONST_F64 = 134217729.0  # 2**27 + 1


def _split_const(dtype) -> float:
    return SPLIT_CONST_F64 if jnp.dtype(dtype) == jnp.float64 else SPLIT_CONST_F32


# ---------------------------------------------------------------------------
# Error-free transformations (paper section 4.1)
# ---------------------------------------------------------------------------

def add12(a, b):
    """Knuth two-sum: s = a (+) b and r with s + r == a + b exactly.

    Branch-free 6-flop variant (paper: "one with one test and another one,
    that should be preferred, with 3 extra floating-point operations").
    """
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def fast_add12(a, b):
    """Dekker fast-two-sum; requires |a| >= |b| (or a == 0). 3 flops."""
    s = a + b
    err = b - (s - a)
    return s, err


def split_dekker(a):
    """Dekker FP-only splitting, verbatim from the paper's Th. 3.

    WARNING: only safe under *eager* execution. XLA's CPU fusion emitter
    miscompiles the ``c - (c - a)`` error-extraction pattern when this
    lands inside a fused computation (verified on jaxlib 0.8.2 and
    xla_extension 0.5.1) — the modern incarnation of the paper's §5
    Brook/DirectX hazard. The production kernels use :func:`split`
    (mask-based) instead, and the runtime disables the ``fusion`` HLO
    pass; see DESIGN.md. The GPU-conditions validation of Th. 3 itself
    lives in the rust ``gpusim`` crate where we control the arithmetic.
    """
    a = jnp.asarray(a)
    c = a * a.dtype.type(_split_const(a.dtype))
    a_big = c - a
    a_hi = c - a_big
    a_lo = a - a_hi
    return a_hi, a_lo


def split(a):
    """Veltkamp 12|12 split via mantissa masking: a == hi + lo exactly.

    Equivalent to Dekker's split for every Mul12 purpose (all four
    sub-products stay exact); immune to FP rewrites because the high part
    is produced by integer masking. This is the kernel oracle.
    """
    a = jnp.asarray(a)
    if a.dtype == jnp.float64:
        bits = jax.lax.bitcast_convert_type(a, jnp.uint64)
        a_hi = jax.lax.bitcast_convert_type(
            bits & jnp.uint64(0xFFFFFFFFF8000000), jnp.float64)
    else:
        bits = jax.lax.bitcast_convert_type(a, jnp.uint32)
        a_hi = jax.lax.bitcast_convert_type(
            bits & jnp.uint32(0xFFFFF000), jnp.float32)
    a_lo = a - a_hi
    return a_hi, a_lo


def mul12(a, b):
    """Dekker exact product (paper Th. 4): x + y == a * b exactly."""
    x = a * b
    a_hi, a_lo = split(a)
    b_hi, b_lo = split(b)
    err1 = x - (a_hi * b_hi)
    err2 = err1 - (a_lo * b_hi)
    err3 = err2 - (a_hi * b_lo)
    y = (a_lo * b_lo) - err3
    return x, y


# ---------------------------------------------------------------------------
# Float-float operators (paper Th. 5 / Th. 6)
# ---------------------------------------------------------------------------

def add22(ah, al, bh, bl):
    """Float-float addition, branch-free (the GPU variant of the paper).

    (rh + rl) == (ah + al) + (bh + bl) + delta, |delta| bounded per Th. 5.
    """
    sh, se = add12(ah, bh)
    te = (al + bl) + se
    rh, rl = fast_add12(sh, te)
    return rh, rl


def add22_accurate(ah, al, bh, bl):
    """Higher-accuracy float-float add (two two-sums).

    The double-double literature's "accurate" variant: EFT on both the high
    and low planes. Used as a tighter comparator in accuracy sweeps.
    """
    sh, se = add12(ah, bh)
    tl, te = add12(al, bl)
    se = se + tl
    sh2, se2 = fast_add12(sh, se)
    se2 = se2 + te
    rh, rl = fast_add12(sh2, se2)
    return rh, rl


def mul22(ah, al, bh, bl):
    """Float-float multiplication (paper Th. 6): rel. error <= 2^-44."""
    ph, pl = mul12(ah, bh)
    pl = pl + (ah * bl + al * bh)
    rh, rl = fast_add12(ph, pl)
    return rh, rl


def div22(ah, al, bh, bl):
    """Float-float division (paper §7 future work; Dekker-style).

    q1 = ah/bh; refine with one float-float residual step.
    """
    q1 = ah / bh
    th, tl = mul12(q1, bh)
    # residual r = (ah - th - tl + al - q1*bl) / bh
    r = (((ah - th) - tl) + al - q1 * bl) / bh
    rh, rl = fast_add12(q1, r)
    return rh, rl


def mad22(ah, al, bh, bl, ch, cl):
    """Fused float-float multiply-add: (a*b) + c in float-float."""
    ph, pl = mul22(ah, al, bh, bl)
    return add22(ph, pl, ch, cl)


# ---------------------------------------------------------------------------
# Baseline single-precision ops (paper Tables 3/4 comparators)
# ---------------------------------------------------------------------------

def base_add(a, b):
    return (a + b,)


def base_mul(a, b):
    return (a * b,)


def base_mad(a, b, c):
    return (a * b + c,)


# ---------------------------------------------------------------------------
# L2 composite references
# ---------------------------------------------------------------------------

def dot2(ah, al, bh, bl):
    """Compensated float-float dot product: sum_i a_i * b_i in ff.

    Reference sequential reduction (matches the scan order of the L2 graph).
    Returns scalar (rh, rl).
    """
    init = (jnp.zeros((), ah.dtype), jnp.zeros((), ah.dtype))

    def body(carry, xs):
        sh, sl = carry
        xah, xal, xbh, xbl = xs
        ph, pl = mul22(xah, xal, xbh, xbl)
        sh, sl = add22(sh, sl, ph, pl)
        return (sh, sl), None

    (sh, sl), _ = jax.lax.scan(body, init, (ah, al, bh, bl))
    return sh, sl


def horner2(ch, cl, xh, xl):
    """Horner polynomial evaluation in float-float.

    coeffs c[0..n-1] (highest degree first), scalar x; returns ff value.
    """
    init = (jnp.zeros((), xh.dtype), jnp.zeros((), xh.dtype))

    def body(carry, c):
        rh, rl = carry
        cih, cil = c
        th, tl = mul22(rh, rl, xh, xl)
        rh, rl = add22(th, tl, cih, cil)
        return (rh, rl), None

    (rh, rl), _ = jax.lax.scan(body, init, (ch, cl))
    return rh, rl


def iterated_map(ah, al, bh, bl, iters: int):
    """Multipass stream kernel: x <- x*b + a repeated `iters` times in ff.

    Models the paper's "real-time multipass algorithms" (§7): the same
    fragment program applied repeatedly to the stream.
    """

    def body(i, carry):
        xh, xl = carry
        th, tl = mul22(xh, xl, bh, bl)
        return add22(th, tl, ah, al)

    return jax.lax.fori_loop(0, iters, body, (ah, al))
