"""L1 Pallas kernels for the float-float operators.

Each paper operator (Add12, Split, Mul12, Add22, Mul22, plus the Div22 /
Mad22 extensions and the Add/Mul/Mad single-precision baselines of Tables
3-4) is one **fused** elementwise Pallas kernel: the whole EFT sequence
runs on a VMEM-resident block, exactly like the paper's fragment programs
ran the whole sequence per texel. One ``pallas_call`` per operator — never
one per EFT line — so the HBM<->VMEM traffic is one load per input plane
and one store per output plane.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the 2006 fragment
processor becomes a blocked VPU kernel. Streams are SoA ``(hi, lo)`` f32
planes; ``BlockSpec`` expresses the HBM->VMEM schedule the paper expressed
with texture fetches. Kernels are branch-free, as required on NV40-class
pixel shaders (and as the paper recommends even where branches exist).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO that the rust runtime can
compile and run. Real-TPU perf is estimated structurally in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default VMEM block (elements). 4096 f32 = 16 KiB per plane; with the
# widest kernel (mad22: 6 in + 2 out planes) that is 128 KiB of VMEM,
# far under the 16 MiB budget, leaving room for double buffering.
DEFAULT_BLOCK = 4096

# Dekker splitting constant for binary32 (2^12 + 1); see ref.SPLIT_CONST_F32.
_SPLIT = 4097.0


def _block_elems(n: int, block: int) -> int:
    """Block size actually used for a problem of n elements."""
    return min(block, n)


def _grid(n: int, block: int) -> int:
    b = _block_elems(n, block)
    assert n % b == 0, f"n={n} must be a multiple of block={b}"
    return n // b


# ---------------------------------------------------------------------------
# In-kernel EFT sequences (operate on loaded VMEM values, branch-free)
# ---------------------------------------------------------------------------
# These mirror ref.py exactly but are written against plain array values so
# they inline into a single kernel body.

def _k_add12(a, b):
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _k_fast_add12(a, b):
    s = a + b
    err = b - (s - a)
    return s, err


def _k_split(a):
    """Veltkamp/Dekker 12|12 split via mantissa masking.

    The paper's FP-only SPLIT (Th. 3) — ``c = a*(2^12+1); hi = c-(c-a)`` —
    is *miscompiled by XLA*: an optimization pass folds the ``c - (c - a)``
    error-extraction pattern back to ``a`` (observed on both jaxlib 0.8.2
    and xla_extension 0.5.1; see DESIGN.md "XLA FP-rewrite hazard"). This
    is the exact hazard the paper hit with Brook's DirectX backend in its
    §5, where the generated fragment program had to be hand-corrected.
    Our hand-correction: split via integer masking, which no FP pass can
    touch. Clearing the low 12 explicit-mantissa bits leaves a 12-bit
    ``hi`` (11 explicit + implicit); ``lo = a - hi`` is exact (Sterbenz)
    and fits 12 bits, so all Mul12 sub-products stay exact — the Dekker
    proof goes through unchanged.
    """
    bits = jax.lax.bitcast_convert_type(a, jnp.uint32)
    a_hi = jax.lax.bitcast_convert_type(bits & jnp.uint32(0xFFFFF000), jnp.float32)
    a_lo = a - a_hi
    return a_hi, a_lo


def _k_mul12(a, b):
    x = a * b
    a_hi, a_lo = _k_split(a)
    b_hi, b_lo = _k_split(b)
    err1 = x - (a_hi * b_hi)
    err2 = err1 - (a_lo * b_hi)
    err3 = err2 - (a_hi * b_lo)
    y = (a_lo * b_lo) - err3
    return x, y


def _k_add22(ah, al, bh, bl):
    sh, se = _k_add12(ah, bh)
    te = (al + bl) + se
    return _k_fast_add12(sh, te)


def _k_mul22(ah, al, bh, bl):
    ph, pl = _k_mul12(ah, bh)
    pl = pl + (ah * bl + al * bh)
    return _k_fast_add12(ph, pl)


def _k_div22(ah, al, bh, bl):
    q1 = ah / bh
    th, tl = _k_mul12(q1, bh)
    r = (((ah - th) - tl) + al - q1 * bl) / bh
    return _k_fast_add12(q1, r)


def _k_mad22(ah, al, bh, bl, ch, cl):
    ph, pl = _k_mul22(ah, al, bh, bl)
    return _k_add22(ph, pl, ch, cl)


# ---------------------------------------------------------------------------
# Kernel bodies (refs -> refs)
# ---------------------------------------------------------------------------

def _body(fn, n_in, n_out):
    """Wrap an elementwise value-function into a pallas kernel body."""

    def kernel(*refs):
        ins = [r[...] for r in refs[:n_in]]
        outs = fn(*ins)
        for o_ref, o in zip(refs[n_in:], outs):
            o_ref[...] = o

    kernel.__name__ = f"ffgpu_{fn.__name__.lstrip('_k_')}_kernel"
    return kernel


# Operator table: name -> (value_fn, n_inputs, n_outputs)
OPS = {
    # paper section 4 operators
    "add12": (_k_add12, 2, 2),
    "split": (lambda a: _k_split(a), 1, 2),
    "mul12": (_k_mul12, 2, 2),
    "add22": (_k_add22, 4, 2),
    "mul22": (_k_mul22, 4, 2),
    # extensions (paper §7 future work)
    "div22": (_k_div22, 4, 2),
    "mad22": (_k_mad22, 6, 2),
    # single-precision baselines (Tables 3-4 comparators)
    "add": (lambda a, b: (a + b,), 2, 1),
    "mul": (lambda a, b: (a * b,), 2, 1),
    "mad": (lambda a, b, c: (a * b + c,), 3, 1),
}

# Reference (pure-jnp) implementations keyed the same way, for pytest.
REF_FNS = {
    "add12": lambda a, b: ref.add12(a, b),
    "split": lambda a: ref.split(a),
    "mul12": lambda a, b: ref.mul12(a, b),
    "add22": lambda ah, al, bh, bl: ref.add22(ah, al, bh, bl),
    "mul22": lambda ah, al, bh, bl: ref.mul22(ah, al, bh, bl),
    "div22": lambda ah, al, bh, bl: ref.div22(ah, al, bh, bl),
    "mad22": lambda ah, al, bh, bl, ch, cl: ref.mad22(ah, al, bh, bl, ch, cl),
    "add": ref.base_add,
    "mul": ref.base_mul,
    "mad": ref.base_mad,
}


@functools.lru_cache(maxsize=None)
def make_op(name: str, n: int, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Build the Pallas elementwise operator `name` over length-n f32 streams.

    Returns a callable taking ``n_in`` arrays of shape (n,) float32 and
    returning a tuple of ``n_out`` arrays of shape (n,) float32.
    """
    fn, n_in, n_out = OPS[name]
    b = _block_elems(n, block)
    grid = _grid(n, block)
    spec = pl.BlockSpec((b,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(n_out)]

    call = pl.pallas_call(
        _body(fn, n_in, n_out),
        grid=(grid,),
        in_specs=[spec] * n_in,
        out_specs=spec if n_out == 1 else [spec] * n_out,
        out_shape=out_shape[0] if n_out == 1 else out_shape,
        interpret=interpret,
    )

    def op(*args):
        out = call(*args)
        return (out,) if n_out == 1 else tuple(out)

    op.__name__ = f"{name}_n{n}"
    return op


def op_arity(name: str) -> tuple[int, int]:
    """(n_inputs, n_outputs) of operator `name` (stream planes)."""
    _, n_in, n_out = OPS[name]
    return n_in, n_out
