"""L2: the compute graphs the paper motivates, built on the L1 kernels.

Three families of graphs, all AOT-lowered by :mod:`compile.aot`:

1. **Stream operators** — one graph per (operator x stream size) from the
   paper's evaluation grid (Tables 3-4): the Pallas kernel applied to the
   whole stream. This is the paper's workload verbatim.

2. **Multipass** — the same fragment program applied ``iters`` times to the
   stream (paper §7: "precise sensitive parts of real-time multipass
   algorithms"). Exercises XLA loop fusion around the Pallas body.

3. **Compensated algorithms** (paper §7 future work) — float-float dot
   product and Horner polynomial evaluation: elementwise Pallas kernel for
   the products, jnp-level float-float reduction on top.

Everything is float32 SoA: a float-float stream is a pair of (n,) planes
(hi, lo). Python here runs at build time only; the rust runtime executes
the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ff, ref

# The paper's evaluation sizes (Tables 3 and 4).
PAPER_SIZES = (4096, 16384, 65536, 262144, 1048576)

# Extended artifact grid: power-of-two steps between the paper sizes so
# the coordinator's pad-to-next-size waste stays below 2x (L3 §Perf).
EXTENDED_SIZES = (4096, 8192, 16384, 32768, 65536, 131072, 262144, 524288, 1048576)

# Operators in the paper's column order, plus the §7 extensions.
PAPER_OPS = ("add", "mul", "mad", "add12", "mul12", "add22", "mul22")
EXT_OPS = ("div22", "mad22", "split")
ALL_OPS = PAPER_OPS + EXT_OPS


# ---------------------------------------------------------------------------
# 1. Stream operators
# ---------------------------------------------------------------------------

def stream_op(name: str, n: int, block: int = ff.DEFAULT_BLOCK):
    """The (op, n) stream graph: n_in planes of shape (n,) -> n_out planes."""
    op = ff.make_op(name, n, block)

    def graph(*planes):
        return op(*planes)

    graph.__name__ = f"stream_{name}_n{n}"
    return graph


def stream_op_args(name: str, n: int):
    """Example ShapeDtypeStructs for lowering `stream_op(name, n)`."""
    n_in, _ = ff.op_arity(name)
    return tuple(jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(n_in))


# ---------------------------------------------------------------------------
# 2. Multipass iterated map
# ---------------------------------------------------------------------------

def multipass(n: int, iters: int, block: int = ff.DEFAULT_BLOCK):
    """x <- x (*) b (+) a, `iters` passes, all in float-float on the stream.

    Inputs: ah, al, bh, bl planes of shape (n,). Outputs: xh, xl planes.
    """
    mul22 = ff.make_op("mul22", n, block)
    add22 = ff.make_op("add22", n, block)

    def graph(ah, al, bh, bl):
        def body(_, carry):
            xh, xl = carry
            th, tl = mul22(xh, xl, bh, bl)
            rh, rl = add22(th, tl, ah, al)
            return (rh, rl)

        xh, xl = jax.lax.fori_loop(0, iters, body, (ah, al))
        return xh, xl

    graph.__name__ = f"multipass_n{n}_k{iters}"
    return graph


def multipass_args(n: int):
    s = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (s, s, s, s)


# ---------------------------------------------------------------------------
# 3. Compensated algorithms (paper §7)
# ---------------------------------------------------------------------------

def dot2(n: int, block: int = ff.DEFAULT_BLOCK):
    """Float-float dot product of two ff streams -> scalar ff.

    Products via the Pallas mul22 kernel; reduction via a log-depth
    float-float pairwise tree (jnp add22), which keeps the reduction error
    O(log n) in ulps and lowers to a compact HLO graph.
    """
    mul22 = ff.make_op("mul22", n, block)

    def graph(ah, al, bh, bl):
        ph, pl = mul22(ah, al, bh, bl)
        # pairwise float-float reduction; n is a power of two in our grid
        while ph.shape[0] > 1:
            half = ph.shape[0] // 2
            ph, pl = ref.add22(ph[:half], pl[:half], ph[half:], pl[half:])
        return ph[0], pl[0]

    graph.__name__ = f"dot2_n{n}"
    return graph


def dot2_args(n: int):
    s = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (s, s, s, s)


def horner2(degree: int):
    """Float-float Horner evaluation of a degree-`degree` polynomial.

    Inputs: ch, cl of shape (degree+1,) highest-first, xh, xl scalars ().
    """

    def graph(ch, cl, xh, xl):
        return ref.horner2(ch, cl, xh, xl)

    graph.__name__ = f"horner2_d{degree}"
    return graph


def horner2_args(degree: int):
    c = jax.ShapeDtypeStruct((degree + 1,), jnp.float32)
    s = jax.ShapeDtypeStruct((), jnp.float32)
    return (c, c, s, s)


# ---------------------------------------------------------------------------
# Catalogue used by aot.py (name -> (graph fn, example args, meta))
# ---------------------------------------------------------------------------

def catalogue(sizes=EXTENDED_SIZES, ops=ALL_OPS, *, block: int = ff.DEFAULT_BLOCK,
              multipass_iters: int = 16, composite_n: int = 65536,
              horner_degree: int = 31):
    """Full artifact catalogue: {name: (fn, args, meta)}."""
    cat = {}
    for op in ops:
        n_in, n_out = ff.op_arity(op)
        for n in sizes:
            name = f"{op}_n{n}"
            cat[name] = (
                stream_op(op, n, block),
                stream_op_args(op, n),
                {"kind": "stream", "op": op, "n": n,
                 "n_in": n_in, "n_out": n_out, "block": min(block, n)},
            )
    mp_n = composite_n
    cat[f"multipass_n{mp_n}_k{multipass_iters}"] = (
        multipass(mp_n, multipass_iters, block),
        multipass_args(mp_n),
        {"kind": "multipass", "op": "multipass", "n": mp_n,
         "iters": multipass_iters, "n_in": 4, "n_out": 2, "block": min(block, mp_n)},
    )
    cat[f"dot2_n{composite_n}"] = (
        dot2(composite_n, block),
        dot2_args(composite_n),
        {"kind": "dot2", "op": "dot2", "n": composite_n,
         "n_in": 4, "n_out": 2, "block": min(block, composite_n)},
    )
    cat[f"horner2_d{horner_degree}"] = (
        horner2(horner_degree),
        horner2_args(horner_degree),
        {"kind": "horner2", "op": "horner2", "degree": horner_degree,
         "n": horner_degree + 1, "n_in": 4, "n_out": 2, "block": 0},
    )
    return cat
