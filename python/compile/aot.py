"""AOT lowering: every L2 graph -> artifacts/<name>.hlo.txt + manifest.json.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly. Lowered with ``return_tuple=True``; the rust side
unwraps with ``to_tuple1()``/``to_vec()``.

Python runs exactly once (``make artifacts``); the rust binary is
self-contained afterwards. Re-running is a no-op unless inputs changed
(make dependency on this file + kernels/ + model.py).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, args, meta, out_dir):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    entry = dict(meta)
    entry.update(
        name=name,
        file=fname,
        hlo_bytes=len(text),
        sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
        in_shapes=[list(a.shape) for a in args],
        lower_seconds=round(time.time() - t0, 3),
    )
    return entry


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("--sizes", type=int, nargs="*", default=None,
                   help="override stream sizes (default: paper grid)")
    p.add_argument("--ops", nargs="*", default=None,
                   help="override operator list (default: all)")
    p.add_argument("--block", type=int, default=None,
                   help="override Pallas block size")
    p.add_argument("--only", nargs="*", default=None,
                   help="lower only these catalogue entries")
    args = p.parse_args(argv)

    kwargs = {}
    if args.sizes:
        kwargs["sizes"] = tuple(args.sizes)
    if args.ops:
        kwargs["ops"] = tuple(args.ops)
    if args.block:
        kwargs["block"] = args.block
    cat = model.catalogue(**kwargs)
    if args.only:
        cat = {k: v for k, v in cat.items() if k in set(args.only)}

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "entries": []}
    for name, (fn, ex_args, meta) in sorted(cat.items()):
        entry = lower_one(name, fn, ex_args, meta, args.out)
        manifest["entries"].append(entry)
        print(f"  lowered {name:<28} {entry['hlo_bytes']:>9} B "
              f"({entry['lower_seconds']}s)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
