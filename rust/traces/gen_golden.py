#!/usr/bin/env python3
"""Regenerate golden.fftrace + golden.expect.json.

Mirrors the FFTR v1 codec in rust/src/coordinator/trace.rs (the Rust
property suite round-trips the same layout; this script only exists so
the committed golden bytes can be rebuilt and audited by hand).

Layout (little-endian):
  header:  b"FFTR"  u16 version=1  u16 flags  u32 count
  record:  u8 op  u8 class  u8 verdict  u8 payload_kind
           u8 tenant_len  tenant bytes
           u64 arrival_ns  u64 deadline_ns  u64 cancel_ns
           u32 lanes  u64 seed            (payload_kind 2 = seeded)

The golden session: 24 seeded records, six float-float ops in
rotation, two tenants (alpha=interactive, beta=bulk), 0.4 ms arrival
gaps, and exactly one deliberate deadline miss (record 10 carries a
0 ns deadline, which the replay scheduler triages deterministically).
"""

import json
import struct
from pathlib import Path

NS_NONE = 2**64 - 1

# op codes: catalogue order of backend::Op
OPS = [("add22", 3), ("mul22", 4), ("mul12", 2), ("add12", 0), ("div22", 5), ("mad22", 6)]
LANES = [1024, 1537, 4096, 257, 2048, 769]
V_OK, V_DEADLINE = 1, 2
CLASS_INTERACTIVE, CLASS_BULK = 1, 3
COUNT = 24
DEADLINE_MISS_AT = 10
GAP_NS = 400_000

records = []
for i in range(COUNT):
    name, op = OPS[i % len(OPS)]
    tenant = "alpha" if i % 2 == 0 else "beta"
    klass = CLASS_INTERACTIVE if i % 2 == 0 else CLASS_BULK
    lanes = LANES[i % len(LANES)]
    seed = (0x60D1DEA + i * 0x9E3779B97F4A7C15) % 2**64
    deadline = 0 if i == DEADLINE_MISS_AT else NS_NONE
    verdict = V_DEADLINE if i == DEADLINE_MISS_AT else V_OK
    records.append((name, op, klass, tenant, i * GAP_NS, deadline, lanes, seed, verdict))

out = bytearray()
out += b"FFTR"
out += struct.pack("<HHI", 1, 0, COUNT)  # version, flags (no inline), count
for name, op, klass, tenant, arrival, deadline, lanes, seed, verdict in records:
    t = tenant.encode()
    out += struct.pack("<BBBBB", op, klass, verdict, 2, len(t)) + t
    out += struct.pack("<QQQIQ", arrival, deadline, NS_NONE, lanes, seed)

here = Path(__file__).parent
(here / "golden.fftrace").write_bytes(out)

expect = {
    "records": COUNT,
    "deadline_misses": 1,
    "tenants": {"alpha": COUNT // 2, "beta": COUNT // 2},
    "op_counts": {name: COUNT // len(OPS) for name, _ in OPS},
    "virtual_s": records[-1][4] / 1e9,
    "bytes": len(out),
}
(here / "golden.expect.json").write_text(json.dumps(expect, indent=2) + "\n")
print(f"golden.fftrace: {len(out)} bytes, {COUNT} records")
