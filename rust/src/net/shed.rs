//! Telemetry-driven load shedding: refuse work the service already
//! knows it cannot finish in time.
//!
//! Admission ([`super::admission`]) answers "is this client within its
//! contract?"; shedding answers "can the *service* honour this
//! request's deadline right now?". The input is the coordinator's live
//! [`TelemetryView`]: for the best shard serving the op,
//! `estimated_wait = (queue_depth + 1) x measured group latency`
//! (EWMA, seconds). If that projection already exceeds the declared
//! deadline, the server sheds with an `Overloaded` frame instead of
//! queueing work that will only expire server-side — the client gets
//! its answer *now* at zero kernel cost, and the queue stays short for
//! requests that can still make it.
//!
//! Requests without a deadline are never shed (they asked for
//! best-effort), and cold telemetry admits — shedding on guesses would
//! refuse the very traffic that warms the estimator.

use crate::backend::Op;
use crate::coordinator::TelemetryView;

/// The shedding rule. `headroom` scales the wait projection before
/// comparing against the deadline: `1.0` sheds exactly at the
/// break-even point, above 1.0 sheds earlier (pessimistic), below 1.0
/// gambles on the EWMA overestimating.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShedPolicy {
    pub headroom: f64,
}

impl Default for ShedPolicy {
    fn default() -> ShedPolicy {
        ShedPolicy { headroom: 1.0 }
    }
}

impl ShedPolicy {
    /// Judge one request: `Ok(())` to enqueue, `Err(retry_after_ms)`
    /// to shed. The retry hint is the projected excess over the
    /// deadline — the earliest moment retrying could plausibly succeed
    /// if the queue only drains.
    pub fn assess(
        &self,
        view: &TelemetryView<'_>,
        op: Op,
        deadline_ms: Option<u64>,
    ) -> Result<(), u64> {
        let Some(deadline_ms) = deadline_ms else {
            return Ok(());
        };
        let Some(wait_s) = view.best_estimated_wait(op) else {
            return Ok(()); // cold telemetry: admit and learn
        };
        let projected_ms = wait_s * 1000.0 * self.headroom.max(0.0);
        if projected_ms <= deadline_ms as f64 {
            return Ok(());
        }
        let excess = (projected_ms - deadline_ms as f64).ceil();
        // cap the hint at a minute — beyond that it is "much later"
        Err((excess as u64).clamp(1, 60_000))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::routing::ShardMeta;

    fn warm_meta(latency_s: f64) -> ShardMeta {
        let m = ShardMeta::new("native");
        // telemetry EWMA seeds with the first observation, so one
        // record pins the measured group latency exactly
        m.telemetry().record(Op::Add22, 1024, latency_s, 0);
        m
    }

    #[test]
    fn no_deadline_is_never_shed() {
        let metas = [warm_meta(10.0)];
        let view = TelemetryView::new(&metas);
        assert!(ShedPolicy::default().assess(&view, Op::Add22, None).is_ok());
    }

    #[test]
    fn cold_telemetry_admits() {
        let metas = [ShardMeta::new("native")];
        let view = TelemetryView::new(&metas);
        assert!(ShedPolicy::default().assess(&view, Op::Add22, Some(1)).is_ok());
    }

    #[test]
    fn hopeless_deadline_sheds_with_excess_hint() {
        // 125 ms measured latency (exact in binary), empty queue:
        // wait = 1 x 125 ms
        let metas = [warm_meta(0.125)];
        let view = TelemetryView::new(&metas);
        let p = ShedPolicy::default();
        // deadline 50 ms: projected 125 ms -> shed, retry 75 ms
        let retry = p.assess(&view, Op::Add22, Some(50)).unwrap_err();
        assert_eq!(retry, 75);
        // deadline 125 ms: exactly break-even -> admit
        assert!(p.assess(&view, Op::Add22, Some(125)).is_ok());
    }

    #[test]
    fn queue_depth_scales_the_projection() {
        let metas = [warm_meta(0.0625)];
        metas[0].enter();
        metas[0].enter();
        metas[0].enter();
        // depth 3 -> wait = (3 + 1) x 62.5 ms = 250 ms
        let view = TelemetryView::new(&metas);
        let p = ShedPolicy::default();
        assert!(p.assess(&view, Op::Add22, Some(250)).is_ok());
        assert_eq!(p.assess(&view, Op::Add22, Some(200)).unwrap_err(), 50);
    }

    #[test]
    fn best_shard_wins_not_worst() {
        // one drowning shard + one idle fast shard: admit
        let drowning = warm_meta(5.0);
        for _ in 0..10 {
            drowning.enter();
        }
        let fast = warm_meta(0.001);
        let metas = [drowning, fast];
        let view = TelemetryView::new(&metas);
        assert!(ShedPolicy::default().assess(&view, Op::Add22, Some(10)).is_ok());
    }

    #[test]
    fn headroom_shifts_the_break_even_point() {
        let metas = [warm_meta(0.125)];
        let view = TelemetryView::new(&metas);
        // 2x headroom: 125 ms measured projects as 250 ms
        let pessimist = ShedPolicy { headroom: 2.0 };
        assert!(pessimist.assess(&view, Op::Add22, Some(200)).is_err());
        let neutral = ShedPolicy::default();
        assert!(neutral.assess(&view, Op::Add22, Some(200)).is_ok());
    }
}
