//! Per-client admission control: token-bucket lane budgets plus an
//! in-flight-bytes cap, keyed by [`ClientClass`].
//!
//! The unit of cost is the **lane** (one element of one SoA plane set)
//! because that is what actually consumes kernel time downstream —
//! request *count* is nearly free once fusion packs small requests
//! into shared launches, but lanes are conserved. Each connection owns
//! one [`Admission`] built from its class's [`ClassLimits`]: a
//! [`TokenBucket`] refilled at `lanes_per_sec` with `burst_lanes`
//! capacity, and a `max_inflight_bytes` budget released as replies
//! drain. Denials are advisory — the server answers with an
//! `Overloaded { retry_after_ms }` frame and the connection stays
//! healthy.
//!
//! Time is injected (`Instant` parameters) so the maths is testable
//! without sleeping.

use std::time::{Duration, Instant};

/// Admission class a client declares in its hello. Classes are a
/// **contract shape**, not a priority bit: each maps to its own
/// [`ClassLimits`] row in the server's [`AdmissionConfig`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ClientClass {
    /// Small, latency-sensitive requests (dashboards, probes).
    Interactive,
    /// The default contract for ordinary clients.
    Standard,
    /// Throughput clients that tolerate backoff (bulk loaders).
    Bulk,
}

impl ClientClass {
    pub const ALL: [ClientClass; 3] =
        [ClientClass::Interactive, ClientClass::Standard, ClientClass::Bulk];

    /// Stable wire/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ClientClass::Interactive => "interactive",
            ClientClass::Standard => "standard",
            ClientClass::Bulk => "bulk",
        }
    }

    pub fn parse(s: &str) -> Option<ClientClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Some(ClientClass::Interactive),
            "standard" => Some(ClientClass::Standard),
            "bulk" => Some(ClientClass::Bulk),
            _ => None,
        }
    }
}

/// The budget one class grants each connection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassLimits {
    /// Sustained lane rate the bucket refills at.
    pub lanes_per_sec: f64,
    /// Bucket capacity — the largest burst admitted from a full bucket.
    pub burst_lanes: f64,
    /// Cap on bytes of submitted-but-unanswered payload.
    pub max_inflight_bytes: usize,
}

/// Per-class limits table. The defaults are sized for the demo and CI
/// loopback scale: `Standard` never trips under a well-behaved client,
/// while `Bulk` is deliberately tight enough that a hot loop of large
/// submits hits the bucket within a few requests.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionConfig {
    interactive: ClassLimits,
    standard: ClassLimits,
    bulk: ClassLimits,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            interactive: ClassLimits {
                lanes_per_sec: 50_000_000.0,
                burst_lanes: 8_000_000.0,
                max_inflight_bytes: 64 << 20,
            },
            standard: ClassLimits {
                lanes_per_sec: 20_000_000.0,
                burst_lanes: 4_000_000.0,
                max_inflight_bytes: 64 << 20,
            },
            bulk: ClassLimits {
                lanes_per_sec: 500_000.0,
                burst_lanes: 1_000_000.0,
                max_inflight_bytes: 16 << 20,
            },
        }
    }
}

impl AdmissionConfig {
    /// The limits row for `class`.
    pub fn limits(&self, class: ClientClass) -> &ClassLimits {
        match class {
            ClientClass::Interactive => &self.interactive,
            ClientClass::Standard => &self.standard,
            ClientClass::Bulk => &self.bulk,
        }
    }

    /// Builder-style override of one class's row.
    pub fn with_limits(mut self, class: ClientClass, limits: ClassLimits) -> AdmissionConfig {
        match class {
            ClientClass::Interactive => self.interactive = limits,
            ClientClass::Standard => self.standard = limits,
            ClientClass::Bulk => self.bulk = limits,
        }
        self
    }
}

/// A classic token bucket over fractional tokens, refilled lazily on
/// each take.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    capacity: f64,
    refill_per_sec: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A bucket that starts full.
    pub fn new(capacity: f64, refill_per_sec: f64, now: Instant) -> TokenBucket {
        TokenBucket {
            capacity: capacity.max(1.0),
            refill_per_sec: refill_per_sec.max(1e-6),
            tokens: capacity.max(1.0),
            last: now,
        }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.refill_per_sec).min(self.capacity);
    }

    /// Take `cost` tokens, or report how long (ms, >= 1) until the
    /// deficit refills. A cost above the bucket capacity is clamped to
    /// it — a single giant request must remain admissible eventually,
    /// it just drains the whole bucket when it goes.
    pub fn try_take(&mut self, cost: f64, now: Instant) -> Result<(), u64> {
        self.refill(now);
        let cost = cost.clamp(0.0, self.capacity);
        if self.tokens >= cost {
            self.tokens -= cost;
            return Ok(());
        }
        let deficit = cost - self.tokens;
        let secs = deficit / self.refill_per_sec;
        Err(((secs * 1000.0).ceil() as u64).max(1))
    }

    /// Tokens currently available (after refill to `now`).
    pub fn available(&mut self, now: Instant) -> f64 {
        self.refill(now);
        self.tokens
    }
}

/// One connection's live admission state.
#[derive(Clone, Debug)]
pub struct Admission {
    bucket: TokenBucket,
    max_inflight_bytes: usize,
    inflight_bytes: usize,
}

impl Admission {
    pub fn new(limits: &ClassLimits, now: Instant) -> Admission {
        Admission {
            bucket: TokenBucket::new(limits.burst_lanes, limits.lanes_per_sec, now),
            max_inflight_bytes: limits.max_inflight_bytes,
            inflight_bytes: 0,
        }
    }

    /// Admit a submit of `lanes` lanes carrying `bytes` of payload, or
    /// return the suggested backoff in milliseconds. The in-flight
    /// budget is checked **before** the bucket so a denial there never
    /// burns tokens.
    pub fn admit(&mut self, lanes: u64, bytes: usize, now: Instant) -> Result<(), u64> {
        if self.inflight_bytes.saturating_add(bytes) > self.max_inflight_bytes
            && self.inflight_bytes > 0
        {
            // budget frees as replies drain, not on a clock — suggest
            // a short poll rather than a computed horizon
            return Err(INFLIGHT_RETRY_MS);
        }
        self.bucket.try_take(lanes as f64, now)?;
        self.inflight_bytes = self.inflight_bytes.saturating_add(bytes);
        Ok(())
    }

    /// Release payload bytes when their reply (or failure) is sent.
    pub fn release(&mut self, bytes: usize) {
        self.inflight_bytes = self.inflight_bytes.saturating_sub(bytes);
    }

    /// Bytes submitted but not yet answered.
    pub fn inflight_bytes(&self) -> usize {
        self.inflight_bytes
    }
}

/// Backoff hint when the in-flight-bytes budget (not the rate bucket)
/// is what denied the request.
pub const INFLIGHT_RETRY_MS: u64 = 5;

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn bucket_grants_burst_then_denies_with_backoff() {
        let now = t0();
        let mut b = TokenBucket::new(1000.0, 1000.0, now);
        assert!(b.try_take(600.0, now).is_ok());
        assert!(b.try_take(400.0, now).is_ok());
        // bucket empty: the 500-lane deficit refills in 500 ms
        let retry = b.try_take(500.0, now).unwrap_err();
        assert_eq!(retry, 500);
    }

    #[test]
    fn bucket_refills_over_time_and_caps_at_capacity() {
        let now = t0();
        let mut b = TokenBucket::new(1000.0, 1000.0, now);
        assert!(b.try_take(1000.0, now).is_ok());
        let later = now + Duration::from_millis(250);
        assert!((b.available(later) - 250.0).abs() < 1.0);
        let much_later = now + Duration::from_secs(60);
        assert!((b.available(much_later) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn oversized_cost_clamps_to_capacity() {
        let now = t0();
        let mut b = TokenBucket::new(100.0, 100.0, now);
        // 10x the capacity still admits from a full bucket (draining it)
        assert!(b.try_take(1000.0, now).is_ok());
        assert!(b.available(now) < 1e-9);
    }

    #[test]
    fn inflight_budget_denies_before_burning_tokens() {
        let limits = ClassLimits {
            lanes_per_sec: 1_000_000.0,
            burst_lanes: 1_000_000.0,
            max_inflight_bytes: 100,
        };
        let now = t0();
        let mut a = Admission::new(&limits, now);
        assert!(a.admit(10, 80, now).is_ok());
        assert_eq!(a.inflight_bytes(), 80);
        // second submit would blow the byte budget
        assert_eq!(a.admit(10, 80, now).unwrap_err(), INFLIGHT_RETRY_MS);
        // tokens were not consumed by the denial
        assert!((a.bucket.available(now) - (1_000_000.0 - 10.0)).abs() < 1e-6);
        a.release(80);
        assert!(a.admit(10, 80, now).is_ok());
    }

    #[test]
    fn single_oversize_submit_is_still_admissible() {
        // a first submit larger than the whole budget must not deadlock
        let limits = ClassLimits {
            lanes_per_sec: 1000.0,
            burst_lanes: 1000.0,
            max_inflight_bytes: 100,
        };
        let now = t0();
        let mut a = Admission::new(&limits, now);
        assert!(a.admit(10, 500, now).is_ok());
        assert_eq!(a.inflight_bytes(), 500);
        // and everything behind it queues on the budget
        assert!(a.admit(10, 1, now).is_err());
        a.release(500);
        assert!(a.admit(10, 1, now).is_ok());
    }

    #[test]
    fn default_config_shapes_bulk_below_standard() {
        let cfg = AdmissionConfig::default();
        let bulk = cfg.limits(ClientClass::Bulk);
        let std_ = cfg.limits(ClientClass::Standard);
        assert!(bulk.lanes_per_sec < std_.lanes_per_sec);
        assert!(bulk.burst_lanes < std_.burst_lanes);
        let tightened = cfg
            .clone()
            .with_limits(ClientClass::Standard, *bulk);
        assert_eq!(tightened.limits(ClientClass::Standard), bulk);
    }

    #[test]
    fn class_names_round_trip() {
        for c in ClientClass::ALL {
            assert_eq!(ClientClass::parse(c.name()), Some(c));
        }
        assert_eq!(ClientClass::parse("STANDARD"), Some(ClientClass::Standard));
        assert_eq!(ClientClass::parse("vip"), None);
    }
}
