//! [`WireClient`]: the blocking client half of the wire protocol,
//! mirroring the in-process `dispatch` / `wait` Ticket surface.
//!
//! `dispatch` writes a Submit and returns its correlation id
//! immediately — pipeline as many as you like — and `wait(id)` blocks
//! until *that* id resolves, stashing any other replies that arrive
//! first (the server answers in completion order, not submit order).
//! Server pushback surfaces as [`WireError::Overloaded`] (with the
//! server's `retry_after_ms` hint) and typed failures as
//! [`WireError::Remote`] carrying the reconstructed
//! [`crate::backend::ServiceError`].

use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use crate::backend::Op;

use super::admission::ClientClass;
use super::frame::{
    encode_frame, read_frame, ClientHello, ErrorFrame, Frame, FrameKind, OverloadedFrame,
    Reply, ServerHello, Status, Submit, WireError,
};

/// One blocking connection to a [`super::WireServer`].
pub struct WireClient {
    stream: TcpStream,
    hello: ServerHello,
    next_id: u64,
    /// Replies that arrived while waiting for a different id.
    stash: BTreeMap<u64, Result<Vec<Vec<f32>>, WireError>>,
}

impl WireClient {
    /// Connect, introduce ourselves as `tenant` under `class`, and
    /// complete the hello handshake.
    pub fn connect(addr: &str, tenant: &str, class: ClientClass) -> Result<WireClient, WireError> {
        let mut stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let hello = ClientHello { tenant: tenant.to_string(), class };
        stream.write_all(&encode_frame(FrameKind::ClientHello, &hello.encode()))?;
        let frame = read_frame(&mut stream)?.ok_or(WireError::Truncated)?;
        let hello = match frame.kind {
            FrameKind::ServerHello => ServerHello::decode(&frame.payload)?,
            FrameKind::Error => return Err(decode_error(&frame.payload)?),
            // the server's accept cap refuses connections with the
            // same retryable backoff signal as per-request pushback
            FrameKind::Overloaded => {
                let o = OverloadedFrame::decode(&frame.payload)?;
                return Err(WireError::Overloaded { retry_after_ms: o.retry_after_ms });
            }
            k => {
                return Err(WireError::BadPayload(format!(
                    "expected ServerHello, got {k:?}"
                )))
            }
        };
        Ok(WireClient { stream, hello, next_id: 1, stash: BTreeMap::new() })
    }

    /// The server's hello: protocol version and shard set (labels +
    /// kernel tiers).
    pub fn server_hello(&self) -> &ServerHello {
        &self.hello
    }

    /// Arm socket read/write timeouts — a safety net for callers that
    /// submit without deadlines. `None` blocks forever (the default).
    pub fn set_io_timeout(&self, timeout: Option<Duration>) -> Result<(), WireError> {
        self.stream.set_read_timeout(timeout)?;
        self.stream.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Send one submit; returns its correlation id without waiting.
    pub fn dispatch(
        &mut self,
        op: Op,
        planes: Vec<Vec<f32>>,
        deadline_ms: Option<u64>,
    ) -> Result<u64, WireError> {
        let id = self.next_id;
        self.next_id += 1;
        let sub = Submit { id, op, deadline_ms, planes };
        self.stream
            .write_all(&encode_frame(FrameKind::Submit, &sub.encode()))?;
        Ok(id)
    }

    /// Block until `id` resolves: output planes, a typed remote error,
    /// or an overload verdict. Replies for other in-flight ids are
    /// stashed for their own `wait` calls.
    pub fn wait(&mut self, id: u64) -> Result<Vec<Vec<f32>>, WireError> {
        loop {
            if let Some(res) = self.stash.remove(&id) {
                return res;
            }
            let frame = read_frame(&mut self.stream)?.ok_or(WireError::Truncated)?;
            self.absorb(frame)?;
        }
    }

    /// `dispatch` + `wait` in one call.
    pub fn call(
        &mut self,
        op: Op,
        planes: Vec<Vec<f32>>,
        deadline_ms: Option<u64>,
    ) -> Result<Vec<Vec<f32>>, WireError> {
        let id = self.dispatch(op, planes, deadline_ms)?;
        self.wait(id)
    }

    /// Fetch the server's live status snapshot (shard tiers, queue
    /// depths, per-tenant counters, and — when the server has one
    /// armed — result-cache counters in [`Status::cache`]). In-flight
    /// replies arriving first are stashed, not lost.
    pub fn status(&mut self) -> Result<Status, WireError> {
        self.stream
            .write_all(&encode_frame(FrameKind::StatusReq, &[]))?;
        loop {
            let frame = read_frame(&mut self.stream)?.ok_or(WireError::Truncated)?;
            if frame.kind == FrameKind::Status {
                return Status::decode(&frame.payload);
            }
            self.absorb(frame)?;
        }
    }

    /// Fold one server frame into the stash. Connection-level errors
    /// (`id == 0`) abort the caller directly.
    fn absorb(&mut self, frame: Frame) -> Result<(), WireError> {
        match frame.kind {
            FrameKind::Reply => {
                let r = Reply::decode(&frame.payload)?;
                self.stash.insert(r.id, Ok(r.planes));
                Ok(())
            }
            FrameKind::Overloaded => {
                let o = OverloadedFrame::decode(&frame.payload)?;
                self.stash.insert(
                    o.id,
                    Err(WireError::Overloaded { retry_after_ms: o.retry_after_ms }),
                );
                Ok(())
            }
            FrameKind::Error => {
                let ef = ErrorFrame::decode(&frame.payload)?;
                let id = ef.id;
                let err = error_frame_to_wire(ef);
                if id == 0 {
                    Err(err)
                } else {
                    self.stash.insert(id, Err(err));
                    Ok(())
                }
            }
            // a stale status (from an aborted status() call) is noise
            FrameKind::Status => Ok(()),
            k => Err(WireError::BadPayload(format!(
                "unexpected frame kind {k:?} from server"
            ))),
        }
    }
}

fn error_frame_to_wire(ef: ErrorFrame) -> WireError {
    match ef.to_service() {
        Some(e) => WireError::Remote(e),
        None => WireError::BadPayload(ef.message),
    }
}

fn decode_error(payload: &[u8]) -> Result<WireError, WireError> {
    let ef = ErrorFrame::decode(payload)?;
    Ok(error_frame_to_wire(ef))
}
