//! The wire framing layer: a std-only, length-prefixed binary protocol.
//!
//! Every frame is `magic(4) + version(1) + kind(1) + payload_len(4 LE)`
//! followed by `payload_len` bytes of payload. Control payloads
//! (hellos, errors, status) are UTF-8 JSON rendered by [`crate::json`];
//! the two data-bearing frames ([`Submit`] / [`Reply`]) prefix a JSON
//! control block with its `u32` length and carry the SoA planes after
//! it as raw little-endian `f32` words — no base64, no copy-through
//! text encoding on the hot path.
//!
//! Decoding is defensive end to end: bad magic, unknown version or
//! kind, oversized declarations, truncated payloads and garbled JSON
//! all surface as typed [`WireError`]s — the codec never panics on
//! attacker-controlled bytes (pinned by the fuzz corpus in this
//! module's tests and `rust/tests/wire.rs`).

use std::fmt;
use std::io::{self, Read};

use crate::backend::{Op, ServiceError};
use crate::coordinator::CacheStats;
use crate::ff::simd::KernelTier;
use crate::json::{self, Value};

use super::admission::ClientClass;

/// Frame preamble: `b"FFGW"` — float-float gateway.
pub const MAGIC: [u8; 4] = *b"FFGW";

/// Protocol version spoken by this build.
pub const VERSION: u8 = 1;

/// Bytes of header before the payload: magic + version + kind + len.
pub const HEADER_LEN: usize = 10;

/// Hard ceiling on a single frame's payload (64 MiB). A declared
/// length above this is rejected *before* any allocation happens, so a
/// hostile header cannot balloon server memory.
pub const MAX_FRAME_BYTES: usize = 1 << 26;

/// Frame discriminants on the wire (the `kind` header byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server, once, first: tenant name + admission class.
    ClientHello = 1,
    /// Server → client reply to the hello: protocol + shard set.
    ServerHello = 2,
    /// Client → server: one operator request (JSON control + planes).
    Submit = 3,
    /// Server → client: the output planes for one submit id.
    Reply = 4,
    /// Server → client: typed failure (`id == 0` ⇒ connection-level).
    Error = 5,
    /// Server → client: request shed or rate-limited; retry later.
    Overloaded = 6,
    /// Client → server: ask for the status snapshot (empty payload).
    StatusReq = 7,
    /// Server → client: shard tiers, queue depths, tenant counters.
    Status = 8,
}

impl FrameKind {
    fn from_byte(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::ClientHello),
            2 => Some(FrameKind::ServerHello),
            3 => Some(FrameKind::Submit),
            4 => Some(FrameKind::Reply),
            5 => Some(FrameKind::Error),
            6 => Some(FrameKind::Overloaded),
            7 => Some(FrameKind::StatusReq),
            8 => Some(FrameKind::Status),
            _ => None,
        }
    }
}

/// Everything that can go wrong on the wire, typed. The codec maps
/// malformed bytes here — never to a panic — and the client surfaces
/// server-side verdicts ([`WireError::Remote`],
/// [`WireError::Overloaded`]) through the same enum so call sites
/// match once.
#[derive(Debug)]
pub enum WireError {
    /// Socket-level failure.
    Io(io::Error),
    /// The four preamble bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Peer speaks a protocol version this build does not.
    BadVersion(u8),
    /// Unknown `kind` header byte.
    UnknownKind(u8),
    /// Declared payload length exceeds [`MAX_FRAME_BYTES`].
    Oversized(u32),
    /// Peer disconnected mid-frame.
    Truncated,
    /// Frame parsed but its payload is malformed.
    BadPayload(String),
    /// The server answered with a typed [`ServiceError`].
    Remote(ServiceError),
    /// The server shed the request; retry after the given delay.
    Overloaded { retry_after_ms: u64 },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Oversized(n) => {
                write!(f, "declared payload {n} B exceeds cap {MAX_FRAME_BYTES} B")
            }
            WireError::Truncated => write!(f, "peer disconnected mid-frame"),
            WireError::BadPayload(msg) => write!(f, "malformed payload: {msg}"),
            WireError::Remote(e) => write!(f, "server error: {e}"),
            WireError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded; retry after {retry_after_ms} ms")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// One decoded frame: the kind byte plus its raw payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

/// Encode a complete frame (header + payload) ready for the socket.
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME_BYTES);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Incremental frame decoder for nonblocking reads: push whatever
/// bytes the socket had, then drain complete frames with
/// [`FrameBuffer::next`]. Also the fuzz surface — `next` returns typed
/// errors for every malformed prefix and never panics.
#[derive(Debug, Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
}

impl FrameBuffer {
    pub fn new() -> FrameBuffer {
        FrameBuffer::default()
    }

    /// Bytes buffered but not yet drained into frames.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Append bytes read from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame. `Ok(None)` means "need more
    /// bytes"; an `Err` means the stream is unrecoverably out of sync
    /// (the connection should be dropped after reporting it).
    pub fn next(&mut self) -> Result<Option<Frame>, WireError> {
        if self.buf.len() < HEADER_LEN {
            // a partial header that already disagrees with MAGIC can
            // be rejected without waiting for the rest
            let n = self.buf.len().min(4);
            if self.buf[..n] != MAGIC[..n] {
                let mut m = [0u8; 4];
                m[..n].copy_from_slice(&self.buf[..n]);
                return Err(WireError::BadMagic(m));
            }
            return Ok(None);
        }
        if self.buf[..4] != MAGIC {
            let mut m = [0u8; 4];
            m.copy_from_slice(&self.buf[..4]);
            return Err(WireError::BadMagic(m));
        }
        if self.buf[4] != VERSION {
            return Err(WireError::BadVersion(self.buf[4]));
        }
        let kind = FrameKind::from_byte(self.buf[5]).ok_or(WireError::UnknownKind(self.buf[5]))?;
        let len = u32::from_le_bytes([self.buf[6], self.buf[7], self.buf[8], self.buf[9]]);
        if len as usize > MAX_FRAME_BYTES {
            return Err(WireError::Oversized(len));
        }
        let total = HEADER_LEN + len as usize;
        if self.buf.len() < total {
            return Ok(None);
        }
        let payload = self.buf[HEADER_LEN..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(Frame { kind, payload }))
    }
}

/// Blocking read of one frame. `Ok(None)` on a clean EOF at a frame
/// boundary; [`WireError::Truncated`] if the peer vanished mid-frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    if header[..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&header[..4]);
        return Err(WireError::BadMagic(m));
    }
    if header[4] != VERSION {
        return Err(WireError::BadVersion(header[4]));
    }
    let kind = FrameKind::from_byte(header[5]).ok_or(WireError::UnknownKind(header[5]))?;
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]);
    if len as usize > MAX_FRAME_BYTES {
        return Err(WireError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    let mut got = 0;
    while got < payload.len() {
        match r.read(&mut payload[got..]) {
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(Some(Frame { kind, payload }))
}

fn bad(msg: impl Into<String>) -> WireError {
    WireError::BadPayload(msg.into())
}

fn parse_json(payload: &[u8]) -> Result<Value, WireError> {
    let text = std::str::from_utf8(payload).map_err(|_| bad("control block is not UTF-8"))?;
    json::parse(text).map_err(|e| bad(format!("control block is not JSON: {e:?}")))
}

/// Split a data frame payload into its JSON control block and the raw
/// plane bytes after it.
fn split_control(payload: &[u8]) -> Result<(Value, &[u8]), WireError> {
    if payload.len() < 4 {
        return Err(bad("payload shorter than control-length prefix"));
    }
    let jlen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    let rest = &payload[4..];
    if jlen > rest.len() {
        return Err(bad(format!(
            "control block claims {jlen} B but only {} B follow",
            rest.len()
        )));
    }
    let ctl = parse_json(&rest[..jlen])?;
    Ok((ctl, &rest[jlen..]))
}

/// Decode `count` planes of `n` lanes each from raw LE f32 bytes.
fn decode_planes(bytes: &[u8], count: usize, n: usize) -> Result<Vec<Vec<f32>>, WireError> {
    let want = count
        .checked_mul(n)
        .and_then(|lanes| lanes.checked_mul(4))
        .ok_or_else(|| bad("plane geometry overflows"))?;
    if bytes.len() != want {
        return Err(bad(format!(
            "expected {count} plane(s) x {n} lanes = {want} B of f32 data, got {} B",
            bytes.len()
        )));
    }
    let mut planes = Vec::with_capacity(count);
    for p in 0..count {
        let mut plane = Vec::with_capacity(n);
        let base = p * n * 4;
        for i in 0..n {
            let o = base + i * 4;
            plane.push(f32::from_le_bytes([
                bytes[o],
                bytes[o + 1],
                bytes[o + 2],
                bytes[o + 3],
            ]));
        }
        planes.push(plane);
    }
    Ok(planes)
}

fn encode_planes(out: &mut Vec<u8>, planes: &[Vec<f32>]) {
    for plane in planes {
        for &x in plane {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn encode_with_control(ctl: &Value, planes: &[Vec<f32>]) -> Vec<u8> {
    let jtext = ctl.render();
    let jbytes = jtext.as_bytes();
    let data: usize = planes.iter().map(|p| p.len() * 4).sum();
    let mut out = Vec::with_capacity(4 + jbytes.len() + data);
    out.extend_from_slice(&(jbytes.len() as u32).to_le_bytes());
    out.extend_from_slice(jbytes);
    encode_planes(&mut out, planes);
    out
}

fn get_u64(ctl: &Value, key: &str) -> Result<u64, WireError> {
    ctl.get(key)
        .and_then(Value::as_u64)
        .ok_or_else(|| bad(format!("missing/invalid '{key}'")))
}

fn get_str<'a>(ctl: &'a Value, key: &str) -> Result<&'a str, WireError> {
    ctl.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| bad(format!("missing/invalid '{key}'")))
}

/// One operator request on the wire. `planes` must hold exactly
/// `op.n_in()` planes of equal length (the server re-validates through
/// [`crate::coordinator::Plan::new`], so a lying control block becomes
/// a typed error, not a crash).
#[derive(Clone, Debug, PartialEq)]
pub struct Submit {
    /// Client-chosen correlation id; must be non-zero (0 is reserved
    /// for connection-level [`ErrorFrame`]s).
    pub id: u64,
    pub op: Op,
    /// Client deadline in milliseconds. Drives both server-side load
    /// shedding and the dispatched ticket's deadline.
    pub deadline_ms: Option<u64>,
    pub planes: Vec<Vec<f32>>,
}

impl Submit {
    pub fn encode(&self) -> Vec<u8> {
        let n = self.planes.first().map_or(0, Vec::len);
        let mut pairs = vec![
            ("id", Value::Number(self.id as f64)),
            ("op", Value::String(self.op.name().to_string())),
            ("n", Value::Number(n as f64)),
        ];
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", Value::Number(d as f64)));
        }
        encode_with_control(&json::obj(pairs), &self.planes)
    }

    pub fn decode(payload: &[u8]) -> Result<Submit, WireError> {
        let (ctl, data) = split_control(payload)?;
        let id = get_u64(&ctl, "id")?;
        if id == 0 {
            return Err(bad("submit id 0 is reserved"));
        }
        let op = Op::parse(get_str(&ctl, "op")?).map_err(WireError::Remote)?;
        let n = get_u64(&ctl, "n")? as usize;
        let deadline_ms = match ctl.get("deadline_ms") {
            None => None,
            Some(v) => Some(v.as_u64().ok_or_else(|| bad("invalid 'deadline_ms'"))?),
        };
        let planes = decode_planes(data, op.n_in(), n)?;
        Ok(Submit { id, op, deadline_ms, planes })
    }
}

/// The output planes for one completed submit.
#[derive(Clone, Debug, PartialEq)]
pub struct Reply {
    pub id: u64,
    pub planes: Vec<Vec<f32>>,
}

impl Reply {
    pub fn encode(&self) -> Vec<u8> {
        let n = self.planes.first().map_or(0, Vec::len);
        let ctl = json::obj(vec![
            ("id", Value::Number(self.id as f64)),
            ("planes", Value::Number(self.planes.len() as f64)),
            ("n", Value::Number(n as f64)),
        ]);
        encode_with_control(&ctl, &self.planes)
    }

    pub fn decode(payload: &[u8]) -> Result<Reply, WireError> {
        let (ctl, data) = split_control(payload)?;
        let id = get_u64(&ctl, "id")?;
        let count = get_u64(&ctl, "planes")? as usize;
        let n = get_u64(&ctl, "n")? as usize;
        if count > 16 {
            return Err(bad(format!("implausible plane count {count}")));
        }
        let planes = decode_planes(data, count, n)?;
        Ok(Reply { id, planes })
    }
}

/// A typed failure. `id == 0` marks a connection-level protocol error
/// (the server closes the connection after sending it); otherwise the
/// id names the submit that failed. `code` is `0` for protocol errors,
/// else the stable [`ServiceError::to_code`] value — `message` carries
/// the canonical `Display` rendering so structured variants survive
/// the round trip through [`ServiceError::from_code`].
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorFrame {
    pub id: u64,
    pub code: u16,
    pub message: String,
}

impl ErrorFrame {
    pub fn from_service(id: u64, err: &ServiceError) -> ErrorFrame {
        ErrorFrame { id, code: err.to_code(), message: err.to_string() }
    }

    /// Reconstruct the [`ServiceError`] when `code` names one.
    pub fn to_service(&self) -> Option<ServiceError> {
        ServiceError::from_code(self.code, &self.message)
    }

    pub fn encode(&self) -> Vec<u8> {
        json::obj(vec![
            ("id", Value::Number(self.id as f64)),
            ("code", Value::Number(self.code as f64)),
            ("message", Value::String(self.message.clone())),
        ])
        .render()
        .into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<ErrorFrame, WireError> {
        let ctl = parse_json(payload)?;
        Ok(ErrorFrame {
            id: get_u64(&ctl, "id")?,
            code: get_u64(&ctl, "code")? as u16,
            message: get_str(&ctl, "message")?.to_string(),
        })
    }
}

/// Request shed (admission bucket empty, in-flight budget blown, or
/// telemetry says the deadline is already lost). Purely advisory
/// backoff hint — the connection stays healthy.
#[derive(Clone, Debug, PartialEq)]
pub struct OverloadedFrame {
    pub id: u64,
    pub retry_after_ms: u64,
}

impl OverloadedFrame {
    pub fn encode(&self) -> Vec<u8> {
        json::obj(vec![
            ("id", Value::Number(self.id as f64)),
            ("retry_after_ms", Value::Number(self.retry_after_ms as f64)),
        ])
        .render()
        .into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<OverloadedFrame, WireError> {
        let ctl = parse_json(payload)?;
        Ok(OverloadedFrame {
            id: get_u64(&ctl, "id")?,
            retry_after_ms: get_u64(&ctl, "retry_after_ms")?,
        })
    }
}

/// First frame on every connection: who is calling and under which
/// admission class.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientHello {
    pub tenant: String,
    pub class: ClientClass,
}

impl ClientHello {
    pub fn encode(&self) -> Vec<u8> {
        json::obj(vec![
            ("tenant", Value::String(self.tenant.clone())),
            ("class", Value::String(self.class.name().to_string())),
        ])
        .render()
        .into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<ClientHello, WireError> {
        let ctl = parse_json(payload)?;
        let tenant = get_str(&ctl, "tenant")?.to_string();
        if tenant.is_empty() || tenant.len() > 128 {
            return Err(bad("tenant must be 1..=128 bytes"));
        }
        let class = ClientClass::parse(get_str(&ctl, "class")?)
            .ok_or_else(|| bad("unknown client class"))?;
        Ok(ClientHello { tenant, class })
    }
}

/// One shard as the serving surface describes it: substrate label plus
/// the CPU kernel tier it runs (`None` on substrates without tiers —
/// gpusim, XLA).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardInfo {
    pub label: String,
    pub tier: Option<KernelTier>,
}

impl ShardInfo {
    fn to_value(&self) -> Value {
        let mut pairs = vec![("label", Value::String(self.label.clone()))];
        if let Some(t) = self.tier {
            pairs.push(("tier", Value::String(t.name().to_string())));
        }
        json::obj(pairs)
    }

    fn from_value(v: &Value) -> Result<ShardInfo, WireError> {
        let label = get_str(v, "label")?.to_string();
        let tier = match v.get("tier") {
            None => None,
            Some(t) => {
                let name = t.as_str().ok_or_else(|| bad("invalid 'tier'"))?;
                Some(KernelTier::parse(name).map_err(bad)?)
            }
        };
        Ok(ShardInfo { label, tier })
    }
}

fn shards_to_value(shards: &[ShardInfo]) -> Value {
    Value::Array(shards.iter().map(ShardInfo::to_value).collect())
}

fn shards_from_value(ctl: &Value) -> Result<Vec<ShardInfo>, WireError> {
    ctl.get("shards")
        .and_then(Value::as_array)
        .ok_or_else(|| bad("missing/invalid 'shards'"))?
        .iter()
        .map(ShardInfo::from_value)
        .collect()
}

/// Server's answer to the hello: the protocol version it speaks and
/// the shard set it serves (labels + kernel tiers — the serving-surface
/// face of [`crate::coordinator::Service::shard_kernel_tiers`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerHello {
    pub protocol: u8,
    pub shards: Vec<ShardInfo>,
}

impl ServerHello {
    pub fn encode(&self) -> Vec<u8> {
        json::obj(vec![
            ("protocol", Value::Number(self.protocol as f64)),
            ("shards", shards_to_value(&self.shards)),
        ])
        .render()
        .into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<ServerHello, WireError> {
        let ctl = parse_json(payload)?;
        Ok(ServerHello {
            protocol: get_u64(&ctl, "protocol")? as u8,
            shards: shards_from_value(&ctl)?,
        })
    }
}

/// Per-tenant counters as the status frame carries them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantStatus {
    pub tenant: String,
    pub requests: u64,
    pub lanes: u64,
    pub shed: u64,
    pub denied: u64,
}

/// Point-in-time serving snapshot: shard set with live queue depths
/// plus per-tenant dispatch/shed/denial attribution.
#[derive(Clone, Debug, PartialEq)]
pub struct Status {
    pub shards: Vec<ShardInfo>,
    /// Queue depth per shard, index-aligned with `shards`.
    pub queue_depths: Vec<u64>,
    /// Sorted by tenant name.
    pub tenants: Vec<TenantStatus>,
    /// Result-cache counters; `None` when the server serves without a
    /// cache (the field is omitted on the wire, so pre-cache peers
    /// interoperate both ways).
    pub cache: Option<CacheStats>,
}

impl Status {
    pub fn encode(&self) -> Vec<u8> {
        let depths = Value::Array(
            self.queue_depths.iter().map(|&d| Value::Number(d as f64)).collect(),
        );
        let tenants = Value::Array(
            self.tenants
                .iter()
                .map(|t| {
                    json::obj(vec![
                        ("tenant", Value::String(t.tenant.clone())),
                        ("requests", Value::Number(t.requests as f64)),
                        ("lanes", Value::Number(t.lanes as f64)),
                        ("shed", Value::Number(t.shed as f64)),
                        ("denied", Value::Number(t.denied as f64)),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("shards", shards_to_value(&self.shards)),
            ("queue_depths", depths),
            ("tenants", tenants),
        ];
        if let Some(c) = &self.cache {
            fields.push((
                "cache",
                json::obj(vec![
                    ("hits", Value::Number(c.hits as f64)),
                    ("misses", Value::Number(c.misses as f64)),
                    ("coalesced", Value::Number(c.coalesced as f64)),
                    ("inserted_bytes", Value::Number(c.inserted_bytes as f64)),
                    ("evictions", Value::Number(c.evictions as f64)),
                    ("live_bytes", Value::Number(c.live_bytes as f64)),
                    ("budget_bytes", Value::Number(c.budget_bytes as f64)),
                ]),
            ));
        }
        json::obj(fields).render().into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<Status, WireError> {
        let ctl = parse_json(payload)?;
        let shards = shards_from_value(&ctl)?;
        let queue_depths = ctl
            .get("queue_depths")
            .and_then(Value::as_array)
            .ok_or_else(|| bad("missing/invalid 'queue_depths'"))?
            .iter()
            .map(|v| v.as_u64().ok_or_else(|| bad("invalid queue depth")))
            .collect::<Result<Vec<u64>, WireError>>()?;
        let tenants = ctl
            .get("tenants")
            .and_then(Value::as_array)
            .ok_or_else(|| bad("missing/invalid 'tenants'"))?
            .iter()
            .map(|v| {
                Ok(TenantStatus {
                    tenant: get_str(v, "tenant")?.to_string(),
                    requests: get_u64(v, "requests")?,
                    lanes: get_u64(v, "lanes")?,
                    shed: get_u64(v, "shed")?,
                    denied: get_u64(v, "denied")?,
                })
            })
            .collect::<Result<Vec<TenantStatus>, WireError>>()?;
        // optional for both-ways compat with pre-cache peers; when
        // present, every counter must parse
        let cache = match ctl.get("cache") {
            None => None,
            Some(c) => Some(CacheStats {
                hits: get_u64(c, "hits")?,
                misses: get_u64(c, "misses")?,
                coalesced: get_u64(c, "coalesced")?,
                inserted_bytes: get_u64(c, "inserted_bytes")?,
                evictions: get_u64(c, "evictions")?,
                live_bytes: get_u64(c, "live_bytes")?,
                budget_bytes: get_u64(c, "budget_bytes")?,
            }),
        };
        Ok(Status { shards, queue_depths, tenants, cache })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_and_drain(bytes: &[u8]) -> Result<Option<Frame>, WireError> {
        let mut fb = FrameBuffer::new();
        fb.push(bytes);
        fb.next()
    }

    #[test]
    fn frame_round_trips_through_buffer() {
        let sub = Submit {
            id: 7,
            op: Op::Add22,
            deadline_ms: Some(250),
            planes: vec![vec![1.0, 2.0], vec![0.5, 0.25], vec![3.0, 4.0], vec![0.0, -0.0]],
        };
        let wire = encode_frame(FrameKind::Submit, &sub.encode());
        let mut fb = FrameBuffer::new();
        // feed byte by byte: no boundary assumption survives untested
        for &b in &wire {
            fb.push(&[b]);
        }
        let frame = fb.next().unwrap().expect("complete frame");
        assert_eq!(frame.kind, FrameKind::Submit);
        assert_eq!(Submit::decode(&frame.payload).unwrap(), sub);
        assert!(fb.next().unwrap().is_none());
        assert_eq!(fb.pending_bytes(), 0);
    }

    #[test]
    fn blocking_read_frame_round_trips() {
        let rep = Reply { id: 9, planes: vec![vec![1.5f32; 3], vec![0.0f32; 3]] };
        let wire = encode_frame(FrameKind::Reply, &rep.encode());
        let mut cursor = io::Cursor::new(wire);
        let frame = read_frame(&mut cursor).unwrap().expect("frame");
        assert_eq!(frame.kind, FrameKind::Reply);
        assert_eq!(Reply::decode(&frame.payload).unwrap(), rep);
        // clean EOF at the boundary
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn bad_magic_is_typed_and_early() {
        // rejected from the very first wrong byte — no need for 10
        let mut fb = FrameBuffer::new();
        fb.push(b"XF");
        assert!(matches!(fb.next(), Err(WireError::BadMagic(_))));
        assert!(matches!(push_and_drain(b"HTTP/1.1 GET /"), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn bad_version_and_kind_are_typed() {
        let mut wire = encode_frame(FrameKind::StatusReq, &[]);
        wire[4] = 99;
        assert!(matches!(push_and_drain(&wire), Err(WireError::BadVersion(99))));
        let mut wire = encode_frame(FrameKind::StatusReq, &[]);
        wire[5] = 0;
        assert!(matches!(push_and_drain(&wire), Err(WireError::UnknownKind(0))));
    }

    #[test]
    fn oversized_declaration_rejected_before_allocation() {
        let mut wire = encode_frame(FrameKind::Submit, &[]);
        wire[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(push_and_drain(&wire), Err(WireError::Oversized(_))));
        let mut cursor = io::Cursor::new(wire);
        assert!(matches!(read_frame(&mut cursor), Err(WireError::Oversized(_))));
    }

    #[test]
    fn truncated_stream_is_need_more_or_truncated() {
        let sub = Submit {
            id: 1,
            op: Op::Add,
            deadline_ms: None,
            planes: vec![vec![1.0; 8], vec![2.0; 8]],
        };
        let wire = encode_frame(FrameKind::Submit, &sub.encode());
        for cut in [1, 5, HEADER_LEN, HEADER_LEN + 3, wire.len() - 1] {
            // incremental decoder: a prefix is just "not yet"
            assert!(push_and_drain(&wire[..cut]).unwrap().is_none(), "cut={cut}");
            // blocking reader: mid-frame EOF is typed
            let mut cursor = io::Cursor::new(wire[..cut].to_vec());
            assert!(
                matches!(read_frame(&mut cursor), Err(WireError::Truncated)),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn submit_decode_rejects_malformed_controls() {
        // lying lane count: control says 4 lanes, data carries 2
        let mut sub = Submit {
            id: 3,
            op: Op::Mul,
            deadline_ms: None,
            planes: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
        };
        let mut payload = sub.encode();
        // rewrite "n":2 → "n":4 in the control block
        let jlen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
        let text = String::from_utf8(payload[4..4 + jlen].to_vec()).unwrap();
        let lied = text.replace("\"n\":2", "\"n\":4");
        assert_ne!(text, lied);
        let mut forged = Vec::new();
        forged.extend_from_slice(&(lied.len() as u32).to_le_bytes());
        forged.extend_from_slice(lied.as_bytes());
        forged.extend_from_slice(&payload[4 + jlen..]);
        assert!(matches!(Submit::decode(&forged), Err(WireError::BadPayload(_))));

        // id 0 reserved
        sub.id = 0;
        payload = sub.encode();
        assert!(matches!(Submit::decode(&payload), Err(WireError::BadPayload(_))));

        // unknown op surfaces the typed service error
        let ctl = r#"{"id":1,"op":"frob","n":0}"#;
        let mut p = Vec::new();
        p.extend_from_slice(&(ctl.len() as u32).to_le_bytes());
        p.extend_from_slice(ctl.as_bytes());
        assert!(matches!(
            Submit::decode(&p),
            Err(WireError::Remote(ServiceError::UnknownOp(_)))
        ));
    }

    #[test]
    fn fuzz_corpus_never_panics() {
        // deterministic pseudo-random corpus over the incremental
        // decoder: every outcome must be Ok or a typed error
        let mut x = 0x9e3779b97f4a7c15u64;
        let mut step = move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        for case in 0..200 {
            let len = (step() % 64) as usize;
            let mut bytes: Vec<u8> = (0..len).map(|_| (step() & 0xff) as u8).collect();
            if case % 3 == 0 && bytes.len() >= 4 {
                // bias towards valid magic so deeper paths get hit
                bytes[..4].copy_from_slice(&MAGIC);
            }
            if case % 6 == 0 && bytes.len() >= 6 {
                bytes[4] = VERSION;
                bytes[5] = 1 + (bytes[5] % 8);
            }
            let mut fb = FrameBuffer::new();
            fb.push(&bytes);
            while let Ok(Some(frame)) = fb.next() {
                // decoding any frame kind from garbage must also not panic
                let _ = Submit::decode(&frame.payload);
                let _ = Reply::decode(&frame.payload);
                let _ = ErrorFrame::decode(&frame.payload);
                let _ = ClientHello::decode(&frame.payload);
                let _ = Status::decode(&frame.payload);
            }
        }
    }

    #[test]
    fn control_frames_round_trip() {
        let hello = ClientHello { tenant: "acme".into(), class: ClientClass::Bulk };
        assert_eq!(ClientHello::decode(&hello.encode()).unwrap(), hello);

        let sh = ServerHello {
            protocol: VERSION,
            shards: vec![
                ShardInfo { label: "native".into(), tier: Some(KernelTier::BlockedFma) },
                ShardInfo { label: "gpusim:nv35".into(), tier: None },
            ],
        };
        assert_eq!(ServerHello::decode(&sh.encode()).unwrap(), sh);

        let over = OverloadedFrame { id: 12, retry_after_ms: 40 };
        assert_eq!(OverloadedFrame::decode(&over.encode()).unwrap(), over);

        let status = Status {
            shards: sh.shards.clone(),
            queue_depths: vec![3, 0],
            tenants: vec![TenantStatus {
                tenant: "acme".into(),
                requests: 5,
                lanes: 4096,
                shed: 1,
                denied: 2,
            }],
            cache: None,
        };
        assert_eq!(Status::decode(&status.encode()).unwrap(), status);

        // cache counters ride along when the server has a cache armed
        let cached = Status {
            cache: Some(CacheStats {
                hits: 10,
                misses: 4,
                coalesced: 3,
                inserted_bytes: 1 << 20,
                evictions: 1,
                live_bytes: 900_000,
                budget_bytes: 64 << 20,
            }),
            ..status
        };
        assert_eq!(Status::decode(&cached.encode()).unwrap(), cached);
    }

    #[test]
    fn status_without_cache_field_decodes_for_old_peers() {
        // a pre-cache server's status payload has no "cache" key at
        // all; a new client must decode it as None, not error
        let payload = br#"{"shards":[{"label":"native"}],"queue_depths":[0],"tenants":[]}"#;
        let s = Status::decode(payload).unwrap();
        assert_eq!(s.cache, None);
        assert_eq!(s.shards.len(), 1);
        // a present-but-garbled cache block is a decode error, not None
        let garbled = br#"{"shards":[],"queue_depths":[],"tenants":[],"cache":{"hits":"lots"}}"#;
        assert!(Status::decode(garbled).is_err());
    }

    #[test]
    fn error_frame_round_trips_service_errors() {
        let err = ServiceError::Arity { op: Op::Add22, want: 4, got: 3 };
        let ef = ErrorFrame::from_service(11, &err);
        let back = ErrorFrame::decode(&ef.encode()).unwrap();
        assert_eq!(back, ef);
        assert_eq!(back.to_service(), Some(err));
        // protocol-level error (code 0) has no service mapping
        let proto = ErrorFrame { id: 0, code: 0, message: "bad magic".into() };
        assert_eq!(ErrorFrame::decode(&proto.encode()).unwrap().to_service(), None);
    }
}
