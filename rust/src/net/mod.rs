//! L4 wire front end — the TCP serving surface over the coordinator.
//!
//! The paper's operators become a *service* here: a std-only,
//! length-prefixed binary protocol ([`frame`]: JSON control blocks via
//! [`crate::json`], SoA planes as raw little-endian `f32` — no text
//! encoding on the data path), served by [`WireServer`] over a
//! [`crate::coordinator::Handle`] and consumed by the blocking
//! [`WireClient`] whose `dispatch`/`wait` surface mirrors the
//! in-process Ticket API. Outputs over the wire are **bit-identical**
//! to in-process dispatch — the server adds transport, not arithmetic
//! (pinned by `rust/tests/wire.rs`).
//!
//! Multi-tenant serving is defended in depth:
//!
//! * **admission** ([`admission`]) — per-connection token buckets in
//!   units of lanes plus an in-flight-bytes budget, keyed by the
//!   [`ClientClass`] the client declares in its hello;
//! * **load shedding** ([`shed`]) — the live telemetry plane
//!   ([`crate::coordinator::TelemetryView::best_estimated_wait`])
//!   projects each deadline-bearing request's completion; hopeless
//!   ones are refused *now* with a typed `Overloaded` frame instead of
//!   expiring server-side after burning kernel time;
//! * **fairness** — each worker sweep admits at most one submit per
//!   connection, so pipelined bulk traffic interleaves lane-by-lane
//!   with everyone else into the coordinator's fuse window;
//! * **attribution** — every dispatch, shed and denial is recorded
//!   per tenant in the coordinator's
//!   [`crate::coordinator::TenantLedger`], surfaced over the wire in
//!   the status frame and in-process via
//!   [`crate::coordinator::Service::tenant_metrics`].

pub mod admission;
pub mod client;
pub mod frame;
pub mod server;
pub mod shed;

pub use admission::{Admission, AdmissionConfig, ClassLimits, ClientClass, TokenBucket};
pub use client::WireClient;
pub use frame::{
    encode_frame, read_frame, ClientHello, ErrorFrame, Frame, FrameBuffer, FrameKind,
    OverloadedFrame, Reply, ServerHello, ShardInfo, Status, Submit, TenantStatus,
    WireError, MAGIC, MAX_FRAME_BYTES, VERSION,
};
pub use server::{WireConfig, WireServer};
pub use shed::ShedPolicy;
