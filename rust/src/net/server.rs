//! [`WireServer`]: the TCP serving surface over a
//! [`crate::coordinator::Handle`].
//!
//! Shape: one **acceptor** thread (nonblocking accept, round-robin
//! hand-off) feeds a small pool of **connection workers**. Each worker
//! owns a disjoint set of connections and runs a readiness-style sweep
//! loop over them — nonblocking reads into a per-connection
//! [`FrameBuffer`] (capped at `READ_BACKLOG_CAP` undrained bytes,
//! after which TCP backpressure throttles the sender), frame dispatch,
//! [`Ticket::try_wait`] polling of in-flight requests, and nonblocking
//! flushes of each connection's outbound backlog — so no thread ever
//! blocks on one client while another has work ready.
//!
//! **Fairness**: each sweep admits at most *one* Submit per connection
//! (control frames drain freely). A bulk client that pipelines a
//! hundred submits therefore interleaves with every other connection
//! on the worker lane by lane, and the coordinator's fuse window sees
//! round-robin arrivals it can pack into shared launches — one hot
//! socket cannot monopolise the batch former.
//!
//! **Pushback** is layered, cheapest first: the accept-time connection
//! cap, then telemetry-driven shedding ([`ShedPolicy`], zero state),
//! then the connection's token-bucket admission ([`Admission`]). All
//! three answer with an
//! [`OverloadedFrame`] carrying `retry_after_ms`; typed request
//! failures travel as [`ErrorFrame`]s with stable
//! [`crate::backend::ServiceError::to_code`] codes; protocol
//! violations get an `id == 0` error frame and the connection is
//! closed. A malformed or hostile byte stream can end its own
//! connection — never the process.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{trace, Handle, Plan, Ticket};

use super::admission::{Admission, AdmissionConfig, ClientClass};
use super::frame::{
    encode_frame, ClientHello, ErrorFrame, Frame, FrameBuffer, FrameKind, OverloadedFrame,
    Reply, ServerHello, ShardInfo, Status, Submit, TenantStatus, WireError, HEADER_LEN,
    MAX_FRAME_BYTES, VERSION,
};
use super::shed::ShedPolicy;

/// Tuning for one [`WireServer`].
#[derive(Clone, Debug)]
pub struct WireConfig {
    pub admission: AdmissionConfig,
    pub shed: ShedPolicy,
    /// Connection-worker threads (each owns a subset of connections).
    pub workers: usize,
    /// Accept bound: connections beyond this are refused at accept.
    pub max_conns: usize,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            admission: AdmissionConfig::default(),
            shed: ShedPolicy::default(),
            workers: 2,
            max_conns: 64,
        }
    }
}

/// Sweep sleep when a worker found no work anywhere.
const IDLE_SLEEP: Duration = Duration::from_micros(300);
/// Per-connection read chunk.
const READ_CHUNK: usize = 64 * 1024;
/// Reads drained per connection per sweep before yielding to peers.
const READS_PER_SWEEP: usize = 4;
/// Per-connection ceiling on buffered-but-undrained inbound bytes.
/// Once the backlog is past this, the sweep stops reading the socket
/// and lets TCP backpressure throttle the sender — a client pipelining
/// thousands of small submits cannot balloon server memory. Sized so
/// one maximum frame can always complete.
const READ_BACKLOG_CAP: usize = MAX_FRAME_BYTES + HEADER_LEN + READ_CHUNK;
/// Budget for an outbound backlog to make zero byte progress before
/// the client is declared unresponsive and dropped.
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(5);
/// Backoff hint sent when the accept cap refuses a connection.
const ACCEPT_RETRY_MS: u64 = 100;

/// A live TCP front end serving one coordinator handle. Dropping the
/// server stops the acceptor and workers and closes every connection;
/// the coordinator service underneath is untouched.
pub struct WireServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7070"`, port 0 for ephemeral) and
    /// start serving `handle` under `cfg`.
    pub fn start(handle: Handle, addr: &str, cfg: WireConfig) -> io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let live_conns = Arc::new(AtomicUsize::new(0));

        let n_workers = cfg.workers.max(1);
        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = mpsc::channel::<TcpStream>();
            txs.push(tx);
            let worker = ConnWorker {
                rx,
                handle: handle.clone(),
                admission: cfg.admission.clone(),
                shed: cfg.shed,
                stop: stop.clone(),
                live_conns: live_conns.clone(),
            };
            workers.push(
                thread::Builder::new()
                    .name(format!("wire-worker-{w}"))
                    .spawn(move || worker.run())?,
            );
        }

        let max_conns = cfg.max_conns.max(1);
        let stop_a = stop.clone();
        let acceptor = thread::Builder::new().name("wire-accept".into()).spawn(move || {
            let mut next = 0usize;
            while !stop_a.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if live_conns.load(Ordering::Relaxed) >= max_conns {
                            refuse(stream);
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        live_conns.fetch_add(1, Ordering::Relaxed);
                        if txs[next % txs.len()].send(stream).is_err() {
                            // worker died; stop accepting
                            live_conns.fetch_sub(1, Ordering::Relaxed);
                            break;
                        }
                        next = next.wrapping_add(1);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(10)),
                }
            }
        })?;

        Ok(WireServer { local, stop, acceptor: Some(acceptor), workers })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting, close every connection, join the threads.
    /// Also runs on drop.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.acceptor.take() {
            let _ = j.join();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Best-effort "over capacity" verdict for a refused accept: a
/// retryable `Overloaded` frame (id 0 — connection-level), the same
/// backoff signal every other capacity refusal uses, not a hard
/// typed error.
fn refuse(mut stream: TcpStream) {
    let over = OverloadedFrame { id: 0, retry_after_ms: ACCEPT_RETRY_MS };
    let _ = stream.write_all(&encode_frame(FrameKind::Overloaded, &over.encode()));
    // drain what the client already sent (typically its hello) before
    // closing — dropping a socket with unread inbound data turns the
    // close into a RST that can destroy the refusal frame in flight
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 256];
    while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
}

/// One request dispatched into the coordinator, awaiting its reply.
struct Pending {
    id: u64,
    ticket: Ticket,
    /// Payload bytes charged against the connection's in-flight budget.
    bytes: usize,
}

/// Per-connection state owned by exactly one worker.
struct Conn {
    stream: TcpStream,
    fb: FrameBuffer,
    tenant: String,
    admission: Admission,
    hello_done: bool,
    pending: Vec<Pending>,
    /// Outbound bytes the socket has not yet accepted; flushed
    /// incrementally each sweep so a slow reader never stalls the
    /// worker. Growth is bounded in time by [`WRITE_STALL_LIMIT`].
    out: Vec<u8>,
    /// When the outbound backlog last stopped making progress.
    stalled_since: Option<Instant>,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, cfg: &AdmissionConfig) -> Conn {
        Conn {
            stream,
            fb: FrameBuffer::new(),
            tenant: String::new(),
            // pre-hello traffic runs under the tightest class
            admission: Admission::new(cfg.limits(ClientClass::Bulk), Instant::now()),
            hello_done: false,
            pending: Vec::new(),
            out: Vec::new(),
            stalled_since: None,
            dead: false,
        }
    }
}

struct ConnWorker {
    rx: mpsc::Receiver<TcpStream>,
    handle: Handle,
    admission: AdmissionConfig,
    shed: ShedPolicy,
    stop: Arc<AtomicBool>,
    live_conns: Arc<AtomicUsize>,
}

impl ConnWorker {
    fn run(self) {
        let mut conns: Vec<Conn> = Vec::new();
        let mut scratch = vec![0u8; READ_CHUNK];
        while !self.stop.load(Ordering::Relaxed) {
            let mut progress = false;
            while let Ok(stream) = self.rx.try_recv() {
                conns.push(Conn::new(stream, &self.admission));
                progress = true;
            }
            for conn in conns.iter_mut() {
                progress |= self.sweep(conn, &mut scratch);
            }
            let before = conns.len();
            conns.retain(|c| !c.dead);
            let dropped = before - conns.len();
            if dropped > 0 {
                self.live_conns.fetch_sub(dropped, Ordering::Relaxed);
            }
            if !progress {
                thread::sleep(IDLE_SLEEP);
            }
        }
        self.live_conns.fetch_sub(conns.len(), Ordering::Relaxed);
    }

    /// One readiness pass over one connection. Returns whether any
    /// byte or frame moved.
    fn sweep(&self, conn: &mut Conn, scratch: &mut [u8]) -> bool {
        let mut progress = false;

        // 0. push any outbound backlog from earlier sweeps
        progress |= flush_out(conn);

        // 1. pull whatever the socket has (bounded per sweep) — unless
        //    undrained frames already exceed the backlog cap, in which
        //    case stop reading and let TCP backpressure do its job
        if conn.fb.pending_bytes() < READ_BACKLOG_CAP {
            for _ in 0..READS_PER_SWEEP {
                match conn.stream.read(scratch) {
                    Ok(0) => {
                        conn.dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.fb.push(&scratch[..n]);
                        progress = true;
                        if n < scratch.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
        }

        // 2. drain control frames; admit at most ONE submit per sweep
        //    so pipelined bulk clients interleave with everyone else
        while !conn.dead {
            match conn.fb.next() {
                Ok(None) => break,
                Ok(Some(frame)) => {
                    progress = true;
                    let was_submit = frame.kind == FrameKind::Submit;
                    self.dispatch_frame(conn, frame);
                    if was_submit {
                        break;
                    }
                }
                Err(e) => {
                    let ef = ErrorFrame { id: 0, code: 0, message: e.to_string() };
                    write_frame(conn, FrameKind::Error, &ef.encode());
                    conn.dead = true;
                }
            }
        }

        // 3. poll in-flight tickets; push replies out as they resolve
        if !conn.pending.is_empty() {
            let mut resolved: Vec<(usize, u64, usize, crate::coordinator::request::OpResult)> =
                Vec::new();
            for (ix, p) in conn.pending.iter().enumerate() {
                if let Some(result) = p.ticket.try_wait() {
                    resolved.push((ix, p.id, p.bytes, result));
                }
            }
            for &(ix, ..) in resolved.iter().rev() {
                conn.pending.swap_remove(ix);
            }
            for (_, id, bytes, result) in resolved {
                progress = true;
                conn.admission.release(bytes);
                match result {
                    Ok(planes) => {
                        let rep = Reply { id, planes };
                        write_frame(conn, FrameKind::Reply, &rep.encode());
                    }
                    Err(err) => {
                        let ef = ErrorFrame::from_service(id, &err);
                        write_frame(conn, FrameKind::Error, &ef.encode());
                    }
                }
            }
        }

        progress
    }

    fn dispatch_frame(&self, conn: &mut Conn, frame: Frame) {
        match frame.kind {
            FrameKind::ClientHello => {
                if conn.hello_done {
                    // a second hello would mint a fresh Admission — a
                    // full bucket and zeroed in-flight budget — letting
                    // a client launder away every rate limit by
                    // re-helloing after each denial. Protocol error.
                    self.protocol_error(
                        conn,
                        &WireError::BadPayload(
                            "duplicate ClientHello: admission is fixed at connection setup"
                                .into(),
                        ),
                    );
                    return;
                }
                match ClientHello::decode(&frame.payload) {
                    Ok(hello) => {
                        // an armed trace recorder learns the tenant's
                        // class here, so replayed traces carry the same
                        // attribution the wire saw
                        if let Some(rec) = self.handle.trace_recorder() {
                            let code = match hello.class {
                                ClientClass::Interactive => trace::CLASS_INTERACTIVE,
                                ClientClass::Standard => trace::CLASS_STANDARD,
                                ClientClass::Bulk => trace::CLASS_BULK,
                            };
                            rec.note_class(&hello.tenant, code);
                        }
                        conn.tenant = hello.tenant;
                        conn.admission =
                            Admission::new(self.admission.limits(hello.class), Instant::now());
                        conn.hello_done = true;
                        let sh = ServerHello { protocol: VERSION, shards: self.shard_infos() };
                        write_frame(conn, FrameKind::ServerHello, &sh.encode());
                    }
                    Err(e) => self.protocol_error(conn, &e),
                }
            }
            FrameKind::Submit => {
                if !conn.hello_done {
                    self.protocol_error(conn, &WireError::BadPayload(
                        "ClientHello must precede Submit".into(),
                    ));
                    return;
                }
                match Submit::decode(&frame.payload) {
                    Ok(sub) => self.handle_submit(conn, sub),
                    Err(WireError::Remote(err)) => {
                        // e.g. unknown op name: typed, request-scoped —
                        // the id is unrecoverable from a bad control
                        // block, so it reports as connection-scoped 0
                        // only when parsing never got that far
                        let id = submit_id_best_effort(&frame.payload);
                        let ef = ErrorFrame::from_service(id, &err);
                        write_frame(conn, FrameKind::Error, &ef.encode());
                    }
                    Err(e) => self.protocol_error(conn, &e),
                }
            }
            FrameKind::StatusReq => {
                let status = self.status();
                write_frame(conn, FrameKind::Status, &status.encode());
            }
            // server-to-client kinds arriving at the server are a
            // protocol violation
            FrameKind::ServerHello
            | FrameKind::Reply
            | FrameKind::Error
            | FrameKind::Overloaded
            | FrameKind::Status => {
                self.protocol_error(
                    conn,
                    &WireError::BadPayload(format!(
                        "client sent server-only frame kind {:?}",
                        frame.kind
                    )),
                );
            }
        }
    }

    fn handle_submit(&self, conn: &mut Conn, sub: Submit) {
        let lanes = sub.planes.first().map_or(0, Vec::len) as u64;
        let bytes: usize = sub.planes.iter().map(|p| p.len() * 4).sum();

        // cheapest refusal first: telemetry already says the deadline
        // is unreachable — no tokens burned on a doomed request
        if let Err(retry) = self.shed.assess(&self.handle.telemetry(), sub.op, sub.deadline_ms)
        {
            self.handle.tenant_ledger().record_shed(&conn.tenant);
            let over = OverloadedFrame { id: sub.id, retry_after_ms: retry };
            write_frame(conn, FrameKind::Overloaded, &over.encode());
            return;
        }

        // then the client's own contract
        if let Err(retry) = conn.admission.admit(lanes, bytes, Instant::now()) {
            self.handle.tenant_ledger().record_denied(&conn.tenant);
            let over = OverloadedFrame { id: sub.id, retry_after_ms: retry };
            write_frame(conn, FrameKind::Overloaded, &over.encode());
            return;
        }

        let plan = match Plan::new(sub.op, sub.planes) {
            Ok(plan) => plan,
            Err(err) => {
                conn.admission.release(bytes);
                let ef = ErrorFrame::from_service(sub.id, &err);
                write_frame(conn, FrameKind::Error, &ef.encode());
                return;
            }
        };
        // deadline travels with the dispatch so it is armed before the
        // request enters the shard queue (deterministic triage) and so
        // an armed trace recorder captures it alongside the tenant
        let deadline = sub.deadline_ms.map(Duration::from_millis);
        match self.handle.dispatch_tagged_deadline(&conn.tenant, plan, deadline) {
            Ok(ticket) => {
                conn.pending.push(Pending { id: sub.id, ticket, bytes });
            }
            Err(err) => {
                conn.admission.release(bytes);
                let ef = ErrorFrame::from_service(sub.id, &err);
                write_frame(conn, FrameKind::Error, &ef.encode());
            }
        }
    }

    fn shard_infos(&self) -> Vec<ShardInfo> {
        let view = self.handle.telemetry();
        (0..view.len())
            .map(|s| ShardInfo {
                label: view.label(s).to_string(),
                tier: view.kernel_tier(s),
            })
            .collect()
    }

    fn status(&self) -> Status {
        let view = self.handle.telemetry();
        let queue_depths = (0..view.len()).map(|s| view.queue_depth(s) as u64).collect();
        let tenants = self
            .handle
            .tenant_ledger()
            .snapshot()
            .into_iter()
            .map(|(tenant, c)| TenantStatus {
                tenant,
                requests: c.requests,
                lanes: c.lanes,
                shed: c.shed,
                denied: c.denied,
            })
            .collect();
        Status {
            shards: self.shard_infos(),
            queue_depths,
            tenants,
            cache: self.handle.cache_stats(),
        }
    }

    fn protocol_error(&self, conn: &mut Conn, err: &WireError) {
        let ef = ErrorFrame { id: 0, code: 0, message: err.to_string() };
        write_frame(conn, FrameKind::Error, &ef.encode());
        conn.dead = true;
    }
}

/// Recover the submit id from a payload whose planes failed to decode,
/// so the error can still be request-scoped. Falls back to 0
/// (connection-scoped) when even the control block is unreadable.
fn submit_id_best_effort(payload: &[u8]) -> u64 {
    if payload.len() < 4 {
        return 0;
    }
    let jlen = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    let rest = &payload[4..];
    if jlen > rest.len() {
        return 0;
    }
    std::str::from_utf8(&rest[..jlen])
        .ok()
        .and_then(|t| crate::json::parse(t).ok())
        .and_then(|v| v.get("id").and_then(crate::json::Value::as_u64))
        .unwrap_or(0)
}

/// Queue one frame on the connection's outbound buffer and push as
/// much as the socket will take right now. Whatever the socket refuses
/// is flushed incrementally by later sweeps — no sleeps, no retries —
/// so one client with a full receive window never stalls the other
/// connections its worker owns.
fn write_frame(conn: &mut Conn, kind: FrameKind, payload: &[u8]) {
    if conn.dead {
        return;
    }
    let bytes = encode_frame(kind, payload);
    conn.out.extend_from_slice(&bytes);
    flush_out(conn);
}

/// Nonblocking drain of the outbound backlog. Returns whether any byte
/// moved. A backlog that makes zero progress for [`WRITE_STALL_LIMIT`]
/// marks the connection dead (which also bounds how long an unread
/// backlog can keep growing).
fn flush_out(conn: &mut Conn) -> bool {
    if conn.dead || conn.out.is_empty() {
        return false;
    }
    let mut off = 0;
    while off < conn.out.len() {
        match conn.stream.write(&conn.out[off..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    let moved = off > 0;
    if moved {
        conn.out.drain(..off);
    }
    if conn.out.is_empty() || moved {
        conn.stalled_since = None;
    }
    if !conn.out.is_empty() {
        let since = *conn.stalled_since.get_or_insert_with(Instant::now);
        if since.elapsed() > WRITE_STALL_LIMIT {
            conn.dead = true;
        }
    }
    moved
}
