//! `ffgpu` — CLI for the float-float-on-stream-processor reproduction.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md §4):
//!
//! ```text
//! ffgpu info                # platform, backends, artifact inventory, Table 1
//! ffgpu paranoia            # Table 2 (simulated GPU arithmetic)
//! ffgpu table3              # Table 3 (XLA/PJRT "GPU path" timings)
//! ffgpu table4              # Table 4 (native CPU path timings)
//! ffgpu tablex              # timing grid on any backend (--backend ...)
//! ffgpu accuracy            # Table 5 (vs exact dyadic oracle)
//! ffgpu serve-demo          # coordinator smoke: batched requests + metrics
//! ffgpu selftest            # end-to-end: artifacts vs native, bit-exact
//! ```
//!
//! Backend selection (serve-demo, tablex): `--backend native`,
//! `--backend native:<workers>`, `--backend gpusim:<model>`,
//! `--backend xla`; `--shards N` runs N identical device threads.
//! Heterogeneous shard sets (serve-demo): `--shard-spec
//! native*6,gpusim:nv35` gives every shard its own backend,
//! `--routing round-robin|queue-depth|op-affinity|measured` picks the
//! placement policy, and `--deadline-ms N` arms every demo ticket with
//! a deadline (missed ones count as `deadline misses`, not failures).
//! `--fuse-window N` holds each shard's batch open N ms so cross-client
//! requests fuse into padded ladder launches, and `--workers N`
//! overrides the persistent worker-crew size of every native shard.
//! `--kernel-tier scalar|blocked|blocked-fma|auto` pins the CPU kernel
//! tier of every native shard (default: `FFGPU_KERNEL_TIER`, then
//! runtime CPU detection) and `--chunk-elems N` its chunk size (0 =
//! L2-sized auto chunk); both also apply to `table4` / `tablex`.
//! `--numa auto|off|<node>` (default: `FFGPU_NUMA`, then `auto`)
//! controls NUMA placement of native shards — worker crews and their
//! staging buffers pin to one node each.
//! `--observe F` mirrors fraction F of the demo traffic through the
//! accuracy observatory (`--observe-models nv35,r300,chopped`) and
//! prints the live Table-2/Table-5 accuracy report at the end.
//! `--cache-mb N` (default: `FFGPU_CACHE_MB`) arms the coordinator's
//! content-addressed result cache with an N MiB budget — repeated
//! identical grids resolve without touching a shard — and
//! `--adaptive-ladder` (default: `FFGPU_ADAPTIVE_LADDER=1`) lets each
//! shard densify its fuse ladder around sizes whose padding-waste EWMA
//! runs hot.
//! `--listen ADDR` (default: the `FFGPU_LISTEN` env var) additionally
//! serves the coordinator over TCP through the wire front end
//! ([`ffgpu::net`]) while the demo runs, and `--serve-secs N` keeps
//! the listener up N seconds after the demo workload finishes so
//! out-of-process clients (`examples/wire_demo.rs`) can connect.
//! `--record PATH` (default: `FFGPU_RECORD`) captures every dispatch
//! into a binary trace saved at exit; `--replay PATH` (default:
//! `FFGPU_REPLAY`) re-drives a recorded trace through the configured
//! service instead of the synthetic workload, at `--replay-rate Nx`
//! (default: `FFGPU_REPLAY_RATE`, then 1) recorded speed.
//!
//! Hand-rolled argument parsing: the build image vendors no CLI crate
//! (documented substitution, DESIGN.md).

use ffgpu::backend::{BackendSpec, KernelTier, NumaMode, Op};
use ffgpu::coordinator::{
    replay, ObservatorySpec, Plan, Routing, Service, ServiceSpec, Trace, TraceRecorder,
};
use ffgpu::harness::{accuracy, paranoia_table, timing, workload};
use ffgpu::runtime::Runtime;
use ffgpu::util::{Rng, Timer};
use std::path::{Path, PathBuf};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let get_flag = |name: &str, default: String| -> String {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or(default)
    };
    let artifacts = PathBuf::from(get_flag("--artifacts", "artifacts".into()));
    let samples: usize = get_flag("--samples", String::new()).parse().unwrap_or(0);
    let backend_flag = get_flag("--backend", "native".into());
    let shards: usize = get_flag("--shards", String::new()).parse().unwrap_or(1);
    let shard_spec_flag = get_flag("--shard-spec", String::new());
    let routing_flag = get_flag("--routing", "round-robin".into());
    let deadline_ms: u64 = get_flag("--deadline-ms", String::new()).parse().unwrap_or(0);
    let fuse_window_ms: u64 = get_flag("--fuse-window", String::new()).parse().unwrap_or(0);
    let workers_flag: Option<usize> = get_flag("--workers", String::new()).parse().ok();
    let observe_flag = get_flag("--observe", String::new());
    let observe_models = get_flag("--observe-models", "nv35,r300,chopped".into());
    // --kernel-tier pins the CPU tier of every native shard; absent it
    // stays None so KernelTier::resolve falls through to
    // FFGPU_KERNEL_TIER and then runtime CPU detection
    let tier_raw = get_flag("--kernel-tier", String::new());
    let tier_flag: Option<KernelTier> = if tier_raw.is_empty() {
        None
    } else {
        match KernelTier::parse(&tier_raw) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    };
    let chunk_flag: Option<usize> = get_flag("--chunk-elems", String::new()).parse().ok();
    // --listen arms the TCP wire front end beside serve-demo; the
    // FFGPU_LISTEN env var is the no-flag default so harnesses can arm
    // it without touching the argv
    let listen_flag =
        get_flag("--listen", std::env::var("FFGPU_LISTEN").unwrap_or_default());
    let serve_secs: u64 = get_flag(
        "--serve-secs",
        std::env::var("FFGPU_SERVE_SECS").unwrap_or_default(),
    )
    .parse()
    .unwrap_or(0);
    // --cache-mb arms the content-addressed result cache; the env var
    // is the no-flag default so CI smokes can arm it without argv edits
    let cache_mb: usize = get_flag(
        "--cache-mb",
        std::env::var("FFGPU_CACHE_MB").unwrap_or_default(),
    )
    .parse()
    .unwrap_or(0);
    let adaptive_ladder = args.iter().any(|a| a == "--adaptive-ladder")
        || matches!(
            std::env::var("FFGPU_ADAPTIVE_LADDER").as_deref(),
            Ok("1") | Ok("true")
        );
    // --record captures serve-demo traffic into a binary trace;
    // --replay re-drives a recorded trace instead of the synthetic
    // workload; --replay-rate compresses the recorded arrival gaps.
    // Env vars are the no-flag defaults so harnesses can arm them
    // without touching the argv
    let record_flag =
        get_flag("--record", std::env::var("FFGPU_RECORD").unwrap_or_default());
    let replay_flag =
        get_flag("--replay", std::env::var("FFGPU_REPLAY").unwrap_or_default());
    let replay_rate: f64 = get_flag(
        "--replay-rate",
        std::env::var("FFGPU_REPLAY_RATE").unwrap_or_default(),
    )
    .parse()
    .unwrap_or(1.0);
    // --numa pins native shards to NUMA nodes (auto | off | <node>);
    // absent, the service itself reads FFGPU_NUMA (default: auto)
    let numa_raw = get_flag("--numa", String::new());
    let numa_flag: Option<NumaMode> = if numa_raw.is_empty() {
        None
    } else {
        match NumaMode::from_cli(&numa_raw) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    };

    let code = match cmd {
        "info" => cmd_info(&artifacts),
        "paranoia" => cmd_paranoia(if samples > 0 { samples } else { 200_000 }),
        "table3" => cmd_table3(&artifacts),
        "table4" => cmd_table4(tier_flag),
        "tablex" => cmd_tablex(&artifacts, &backend_flag, tier_flag, chunk_flag),
        "accuracy" => cmd_accuracy(&artifacts, if samples > 0 { samples } else { 1 << 20 }),
        "serve-demo" => cmd_serve_demo(
            &artifacts, &backend_flag, shards, &shard_spec_flag, &routing_flag,
            deadline_ms, fuse_window_ms, workers_flag, tier_flag, chunk_flag,
            &observe_flag, &observe_models, &listen_flag, serve_secs,
            cache_mb, adaptive_ladder, numa_flag, &record_flag, &replay_flag,
            replay_rate,
        ),
        "selftest" => cmd_selftest(&artifacts),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
ffgpu — float-float operators on a stream processor (Da Graça & Defour 2006)

USAGE: ffgpu <command> [--artifacts DIR] [--samples N]
                       [--backend B] [--shards N] [--workers N]
                       [--kernel-tier T] [--chunk-elems N]
                       [--shard-spec LIST] [--routing P] [--deadline-ms N]
                       [--fuse-window N] [--observe F] [--observe-models LIST]

COMMANDS:
  info        platform, backend catalogues, artifact inventory, Table 1
  paranoia    Table 2: error intervals of simulated GPU arithmetic
  table3      Table 3: operator timings on the XLA/PJRT path
  table4      Table 4: operator timings on the native CPU path
  tablex      operator timing grid on any backend (see --backend)
  accuracy    Table 5: measured accuracy vs the exact dyadic oracle
  serve-demo  coordinator demo: typed Plan API, routing, metrics report
  selftest    artifacts vs native kernels, bit-exact check

BACKENDS (--backend):
  native          multicore ff::vector kernels (one worker per core)
  native:<N>      same, with N workers per shard
  gpusim          stream VM on IEEE round-to-nearest arithmetic
  gpusim:<model>  stream VM on a GPU model: nv35, nv40, r300, chopped
  xla             PJRT/XLA artifacts (needs the `xla` feature + artifacts)

SHARD SETS (serve-demo):
  --shard-spec native*2,gpusim:nv35   one backend per shard (overrides
                                      --backend/--shards); *N repeats
  --routing round-robin|queue-depth|op-affinity|measured
                                      placement policy across shards
                                      (measured = telemetry-driven: prefer
                                      shards that serve the op, weight by
                                      live Melem/s)
  --deadline-ms N                     arm every demo ticket with an N ms
                                      deadline; misses are counted, the
                                      shards stay live
  --fuse-window N                     hold each shard's batch open N ms so
                                      cross-client same-op requests fuse
                                      into padded launches over the paper's
                                      stream-size ladder (4096..1048576)
  --workers N                         persistent worker-crew size of every
                                      native shard (0 = one per core)
  --kernel-tier scalar|blocked|blocked-fma|auto
                                      CPU kernel tier of every native shard
                                      and of table4/tablex (default: the
                                      FFGPU_KERNEL_TIER env var, then
                                      runtime CPU detection; blocked-fma
                                      needs fast FMA — a build with
                                      -C target-cpu=native or the
                                      simd-intrinsics feature on avx2+fma
                                      hardware)
  --chunk-elems N                     per-worker chunk size (elements) of
                                      every native shard (0 = L2-sized
                                      auto chunk; also FFGPU_CHUNK_ELEMS)
  --numa auto|off|<node>              NUMA placement of native shards:
                                      auto round-robins shards (and their
                                      worker crews + staging buffers)
                                      across the host's nodes — a no-op
                                      on single-node hosts — off disables
                                      pinning, a node id pins every shard
                                      there (default: FFGPU_NUMA, then
                                      auto)
  --observe F                         mirror fraction F (0..1) of the demo
                                      traffic through the accuracy
                                      observatory (native reference + GPU
                                      models) and print the live Table-2/5
                                      accuracy report
  --observe-models M1,M2              GPU models the observatory diffs
                                      against (default nv35,r300,chopped;
                                      also: ieee-rn, nv40)
  --cache-mb N                        arm the content-addressed result
                                      cache with an N MiB byte budget:
                                      repeated identical grids resolve
                                      without touching a shard, and the
                                      demo workload pins itself to a
                                      small repeated-grid set so hits
                                      show up (default: FFGPU_CACHE_MB)
  --adaptive-ladder                   let each shard densify its fuse
                                      ladder around sizes whose padding
                                      waste EWMA runs hot (needs
                                      --fuse-window; also
                                      FFGPU_ADAPTIVE_LADDER=1)
  --listen ADDR                       serve the coordinator over TCP on
                                      ADDR (e.g. 127.0.0.1:7070) through
                                      the wire front end while the demo
                                      runs (default: FFGPU_LISTEN)
  --serve-secs N                      keep the TCP listener up N seconds
                                      after the demo workload, for
                                      out-of-process wire clients
                                      (default: FFGPU_SERVE_SECS)
  --record PATH                       capture every dispatch (demo
                                      workload + wire traffic) into a
                                      binary trace at PATH, saved when
                                      the demo exits; set
                                      FFGPU_RECORD_INLINE=1 to store
                                      full plane bits instead of
                                      content fingerprints (default:
                                      FFGPU_RECORD)
  --replay PATH                       re-drive the recorded trace at
                                      PATH through the configured
                                      service instead of the synthetic
                                      workload, and print the replay
                                      report (p50/p95 per op, padding
                                      waste, cache hit rate, results
                                      checksum) (default: FFGPU_REPLAY)
  --replay-rate N                     replay arrival gaps N times
                                      faster than recorded; deadlines
                                      and cancel offsets stay unscaled
                                      (default: FFGPU_REPLAY_RATE,
                                      then 1)
";

fn cmd_info(artifacts: &Path) -> i32 {
    println!("ffgpu — float-float operators (reproduction of Da Graça & Defour 2006)\n");
    println!("Table 1 formats:");
    for f in ffgpu::gpusim::Format::table1() {
        println!(
            "  {:<14} sign 1  exp {:>2}  mant {:>2}  specials {}",
            f.name(), f.exp_bits, f.mant_bits,
            if f.has_specials { "yes" } else { "no" }
        );
    }
    println!("\nbackends:");
    for spec in [BackendSpec::native(), BackendSpec::gpusim_ieee()] {
        match spec.build() {
            Ok(b) => {
                let ops: Vec<&str> = b.ops().iter().map(|o| o.name()).collect();
                println!("  {:<7} ops: {}", b.name(), ops.join(", "));
            }
            Err(e) => println!("  {:<7} unavailable: {e}", spec.label()),
        }
    }
    match Runtime::new(artifacts) {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            println!("artifacts: {} entries in {}", rt.manifest().entries.len(),
                     artifacts.display());
            for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
                let sizes: Vec<String> = rt
                    .manifest()
                    .by_op(op)
                    .iter()
                    .map(|e| e.n.to_string())
                    .collect();
                println!("  {:<6} n = {}", op, sizes.join(", "));
            }
            0
        }
        Err(e) => {
            println!("\n(no xla runtime: {e})");
            0
        }
    }
}

fn cmd_paranoia(samples: usize) -> i32 {
    let t = paranoia_table::measure(samples, 0xFACE);
    print!("{}", t.render());
    0
}

fn cmd_table3(artifacts: &Path) -> i32 {
    let rt = match Runtime::new(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    let timer = Timer::new(2, 7);
    match timing::gpu_grid(&rt, &workload::PAPER_SIZES, &workload::PAPER_OPS, &timer, 3) {
        Ok(grid) => {
            print!("{}", grid.render(
                "Table 3 — float-float operators on the XLA/PJRT path \
                 (normalised to Add @ 4096)"));
            print_paper_grid("paper Table 3", timing::paper_table3());
            0
        }
        Err(e) => {
            eprintln!("table3: {e}");
            1
        }
    }
}

fn cmd_table4(tier_flag: Option<KernelTier>) -> i32 {
    // default to the paper-faithful scalar protocol; --kernel-tier (or
    // --kernel-tier auto) opts into the blocked/FMA reproductions
    let tier = tier_flag.unwrap_or(KernelTier::Scalar);
    let timer = Timer::new(2, 7);
    let grid = timing::cpu_grid_tier(
        &workload::PAPER_SIZES, &workload::PAPER_OPS, &timer, 4, tier,
    );
    print!("{}", grid.render(&format!(
        "Table 4 — float-float operators on the native CPU path, \
         kernel tier '{tier}' (normalised to Add @ 4096)")));
    print_paper_grid("paper Table 4", timing::paper_table4());
    0
}

/// Substrate-neutral timing table through the backend layer.
fn cmd_tablex(
    artifacts: &Path, backend_flag: &str, tier_flag: Option<KernelTier>,
    chunk_flag: Option<usize>,
) -> i32 {
    let mut spec = match BackendSpec::from_cli(backend_flag, artifacts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let BackendSpec::Native { chunk, tier, .. } = &mut spec {
        if let Some(t) = tier_flag {
            *tier = Some(t);
        }
        if let Some(c) = chunk_flag {
            *chunk = c;
        }
    }
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("backend: {e}");
            return 1;
        }
    };
    // the soft-float VM is orders of magnitude slower than hardware:
    // shrink the grid so gpusim tables come back in seconds
    let (sizes, timer): (Vec<usize>, Timer) = if spec.label() == "gpusim" {
        (vec![1024, 4096, 16384], Timer::new(0, 3))
    } else {
        (workload::PAPER_SIZES.to_vec(), Timer::new(2, 7))
    };
    match timing::backend_grid(backend.as_mut(), &sizes, &workload::PAPER_OPS, &timer, 5)
    {
        Ok(grid) => {
            // attribute the table to the kernel tier when the backend
            // has one (native); gpusim/xla report no tier
            let tier = match backend.kernel_tier() {
                Some(t) => format!(", kernel tier '{t}'"),
                None => String::new(),
            };
            print!("{}", grid.render(&format!(
                "Operator timings on backend '{}'{tier} (normalised to Add @ {})",
                backend.name(), sizes[0]
            )));
            let st = backend.stats();
            println!(
                "\nbackend stats: {} executions, {} elements, {:.3}s busy",
                st.executions, st.elements, st.busy_seconds
            );
            0
        }
        Err(e) => {
            eprintln!("tablex: {e}");
            1
        }
    }
}

fn print_paper_grid(title: &str, (sizes, rows): (Vec<usize>, Vec<Vec<f64>>)) {
    println!("\n{title}:");
    let ops_header: String =
        workload::PAPER_OPS.iter().map(|o| format!("{o:>8}")).collect();
    println!("  {:>9} {}", "Size", ops_header);
    for (s, r) in sizes.iter().zip(rows) {
        let cells: String = r.iter().map(|v| format!("{v:>8.2}")).collect();
        println!("  {s:>9} {cells}");
    }
}

fn cmd_accuracy(artifacts: &Path, samples: usize) -> i32 {
    println!("Table 5 — measured accuracy, {samples} samples per op, exact dyadic oracle\n");
    let ops = ["add12", "mul12", "add22", "mul22", "div22", "mad22"];

    // native path
    println!("native CPU kernels (IEEE RN):");
    for op in ops {
        let row = accuracy::measure_op(op, samples, 1 << 16, 0xACC0, |op, planes| {
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let (_, n_out) = ffgpu::coordinator::batcher::op_arity(op).unwrap();
            let mut outs = vec![vec![0.0f32; planes[0].len()]; n_out];
            ffgpu::ff::vector::dispatch(op, &refs, &mut outs)?;
            Ok(outs)
        })
        .unwrap();
        println!("  {:<6} {}", row.op, row.display());
    }

    // XLA path (chunk = compiled size)
    if let Ok(rt) = Runtime::new(artifacts) {
        println!("\nXLA artifacts via PJRT:");
        for op in ops {
            let name = format!("{op}_n4096");
            if rt.manifest().get(&name).is_none() {
                continue;
            }
            let row = accuracy::measure_op(op, samples.min(1 << 20), 4096, 0xACC1,
                |op, planes| {
                    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                    rt.execute(&format!("{op}_n4096"), &refs)
                })
                .unwrap();
            println!("  {:<6} {}", row.op, row.display());
        }
    }

    println!("\npaper Table 5 (measured on real 2006 GPU):");
    for (op, v) in accuracy::paper_table5() {
        println!("  {op:<6} {v}");
    }
    0
}

#[allow(clippy::too_many_arguments)]
fn cmd_serve_demo(
    artifacts: &Path, backend_flag: &str, shards: usize, shard_spec: &str,
    routing_flag: &str, deadline_ms: u64, fuse_window_ms: u64,
    workers_flag: Option<usize>, tier_flag: Option<KernelTier>,
    chunk_flag: Option<usize>, observe_flag: &str, observe_models: &str,
    listen: &str, serve_secs: u64, cache_mb: usize, adaptive_ladder: bool,
    numa_flag: Option<NumaMode>, record: &str, replay_path: &str,
    replay_rate: f64,
) -> i32 {
    // --shard-spec describes the set shard by shard; otherwise fall
    // back to the uniform --backend/--shards pair
    let spec = if shard_spec.is_empty() {
        match BackendSpec::from_cli(backend_flag, artifacts) {
            Ok(s) => ServiceSpec::uniform(s, shards),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        match ServiceSpec::from_cli(shard_spec, artifacts) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
    let routing = match Routing::from_cli(routing_flag) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut spec = spec.with_routing(routing);
    // --workers / --kernel-tier / --chunk-elems retune every native
    // shard's persistent crew, CPU kernel tier and chunk size
    if workers_flag.is_some() || tier_flag.is_some() || chunk_flag.is_some() {
        for s in &mut spec.shards {
            if let BackendSpec::Native { chunk, workers, tier, .. } = s {
                if let Some(w) = workers_flag {
                    *workers = w;
                }
                if let Some(t) = tier_flag {
                    *tier = Some(t);
                }
                if let Some(c) = chunk_flag {
                    *chunk = c;
                }
            }
        }
    }
    // --fuse-window arms cross-request fusion; the paper's stream-size
    // grid is the default launch ladder
    if fuse_window_ms > 0 {
        spec = spec
            .with_fuse_window(std::time::Duration::from_millis(fuse_window_ms))
            .with_fuse_sizes(ffgpu::coordinator::PAPER_FUSE_SIZES.to_vec());
    }
    // --cache-mb arms the content-addressed result cache in front of
    // routing; --adaptive-ladder opts every shard into waste-fed fuse
    // ladder densification
    if cache_mb > 0 {
        spec = spec.with_cache_mb(cache_mb);
    }
    if adaptive_ladder {
        spec = spec.with_adaptive_ladder(true);
    }
    // --numa overrides FFGPU_NUMA; absent, the service resolves the
    // env var itself at start
    if let Some(mode) = numa_flag {
        spec = spec.with_numa(mode);
    }
    // --record arms the trace recorder at the dispatch boundary
    // (drop-not-block, 64 MiB budget); the caller-side Arc clone keeps
    // the capture reachable for the save at exit
    let recorder = (!record.is_empty()).then(|| {
        let inline = matches!(
            std::env::var("FFGPU_RECORD_INLINE").as_deref(),
            Ok("1") | Ok("true")
        );
        std::sync::Arc::new(TraceRecorder::new(64 << 20, inline))
    });
    if let Some(rec) = &recorder {
        spec = spec.with_recorder(std::sync::Arc::clone(rec));
    }
    // --observe arms the accuracy observatory: a fraction of the demo
    // traffic is mirrored onto a native reference + the listed GPU
    // models, and a live Table-2/Table-5 report prints at the end
    if !observe_flag.is_empty() {
        match ObservatorySpec::from_cli(observe_flag, observe_models) {
            Ok(o) => spec = spec.with_observatory(o),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let labels: Vec<&str> = spec.shards.iter().map(|s| s.label()).collect();
    println!(
        "shards: [{}]  routing: {}  fusion: {}  observatory: {}  cache: {}",
        labels.join(", "),
        routing.name(),
        if fuse_window_ms > 0 {
            format!(
                "{fuse_window_ms}ms window, ladder {:?}{}",
                spec.fuse_sizes,
                if adaptive_ladder { " (adaptive)" } else { "" }
            )
        } else {
            "off".to_string()
        },
        match &spec.observe {
            Some(o) => format!("{:.0}% -> [{}]", o.fraction * 100.0, o.models.join(", ")),
            None => "off".to_string(),
        },
        if cache_mb > 0 {
            format!("{cache_mb} MiB")
        } else {
            "off".to_string()
        }
    );
    let svc = match Service::start(spec) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("service: {e}");
            return 1;
        }
    };
    // kernel tiers are resolved per shard at backend construction and
    // published before start() returned — print the attribution line
    let shard_tiers = svc.shard_kernel_tiers();
    let tier_cells: Vec<String> = shard_tiers
        .iter()
        .map(|t| match t {
            Some(t) => t.to_string(),
            None => "-".to_string(),
        })
        .collect();
    println!("kernel tiers: [{}]", tier_cells.join(", "));
    // NUMA placement resolved at start: the node (or '-') per shard
    let node_cells: Vec<String> = svc
        .shard_numa_nodes()
        .iter()
        .map(|n| match n {
            Some(n) => format!("node{n}"),
            None => "-".to_string(),
        })
        .collect();
    println!(
        "numa: {} -> [{}]",
        numa_flag.unwrap_or_else(NumaMode::from_env).describe(),
        node_cells.join(", ")
    );
    // --replay: re-drive a recorded session through this exact service
    // configuration and print the scenario report instead of running
    // the synthetic workload. The report's results checksum is the
    // regression gate: same trace, any config -> identical line
    if !replay_path.is_empty() {
        let trace = match Trace::load(Path::new(replay_path)) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("load trace {replay_path}: {e}");
                return 1;
            }
        };
        println!(
            "replaying {replay_path} ({} records, inline: {}) at {replay_rate}x",
            trace.records.len(),
            trace.all_inline()
        );
        match replay(&svc, &trace, replay_rate) {
            Ok(report) => {
                print!("{}", report.render());
                println!("determinism key: {:#018x}", report.determinism_key());
                return 0;
            }
            Err(e) => {
                eprintln!("replay: {e}");
                return 1;
            }
        }
    }
    // --listen: serve the same coordinator over TCP while the demo runs
    let wire = if listen.is_empty() {
        None
    } else {
        match ffgpu::net::WireServer::start(
            svc.handle(),
            listen,
            ffgpu::net::WireConfig::default(),
        ) {
            Ok(srv) => {
                println!("wire front end listening on {}", srv.local_addr());
                Some(srv)
            }
            Err(e) => {
                eprintln!("wire listen {listen}: {e}");
                return 1;
            }
        }
    };
    // mixed-op workload over the whole catalogue, dispatched through
    // the typed Plan API; the gpusim soft-float VM is orders of
    // magnitude slower than native, so shrink batches when it serves —
    // or when the observatory mirrors onto it
    let slow = svc.shard_labels().iter().any(|&l| l == "gpusim") || svc.has_observatory();
    let (top, rounds) = if slow { (2000, 20) } else { (9000, 50) };
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for client in 0..4u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(client);
            let mut served = 0u64;
            let mut missed = 0u64;
            for round in 0..rounds {
                let op = Op::ALL[(client as usize + round) % Op::COUNT];
                // with the result cache armed, pin every client to a
                // small repeated-grid set so hits (and single-flight
                // coalescing across clients) actually show up
                let (n, seed) = if cache_mb > 0 {
                    (4096, (round % 5) as u64)
                } else {
                    (1000 + rng.below(top), rng.next_u64())
                };
                let planes = workload::planes_for(op.name(), n, seed);
                let plan = Plan::new(op, planes).expect("plan");
                let mut ticket = h.dispatch(plan).expect("dispatch");
                if deadline_ms > 0 {
                    ticket = ticket
                        .deadline(std::time::Duration::from_millis(deadline_ms));
                }
                match ticket.wait() {
                    Ok(out) => {
                        assert_eq!(out[0].len(), n);
                        served += 1;
                    }
                    Err(ffgpu::backend::ServiceError::DeadlineExceeded) => missed += 1,
                    Err(e) => panic!("reply: {e}"),
                }
            }
            (served, missed)
        }));
    }
    let mut served = 0u64;
    let mut missed = 0u64;
    for j in joins {
        let (s, x) = j.join().unwrap();
        served += s;
        missed += x;
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    println!("serve-demo: {} requests in {wall:.3}s ({:.0} req/s)",
             m.requests, m.requests as f64 / wall);
    println!("  served={served} deadline misses={missed} (shard-side skipped={} cancelled={})",
             m.expired, m.cancelled);
    println!("  batches={} launches={} elements={} padding={:.1}%",
             m.batches, m.launches, m.elements, m.padding_fraction() * 100.0);
    println!("  batch latency mean={:.2}ms max={:.2}ms errors={}",
             m.mean_latency_s * 1e3, m.max_latency_s * 1e3, m.errors);
    let telemetry_ops = [Op::Add22, Op::Mul22, Op::Div22];
    for (i, (s, label)) in svc
        .shard_metrics()
        .iter()
        .zip(svc.shard_labels())
        .enumerate()
    {
        let rates: Vec<String> = telemetry_ops
            .iter()
            .map(|&op| match svc.measured_rate(i, op) {
                Some(r) => format!("{op}={r:.1}"),
                None => format!("{op}=cold"),
            })
            .collect();
        let tier = match shard_tiers.get(i).copied().flatten() {
            Some(t) => format!(" tier={t}"),
            None => String::new(),
        };
        println!("  shard {i} [{label}]{tier}: requests={} batches={} elements={} \
                  measured Melem/s: {}",
                 s.requests, s.batches, s.elements, rates.join(" "));
    }
    // the result-cache banner: how much traffic resolved before routing
    if let Some(cs) = svc.cache_stats() {
        println!(
            "  cache: hits={} misses={} coalesced={} hit-rate={:.1}% \
             inserted={}B evictions={} live={}B/{}B",
            cs.hits, cs.misses, cs.coalesced, cs.hit_rate() * 100.0,
            cs.inserted_bytes, cs.evictions, cs.live_bytes, cs.budget_bytes
        );
    }
    // the live accuracy surface: what the paper measured once, observed
    // continuously under the demo's traffic
    if let Some(rep) = svc.accuracy_report() {
        print!("\n{}", rep.render_table2_live());
        print!("\n{}", rep.render_table5_live());
    }
    if let Some(srv) = wire {
        if serve_secs > 0 {
            println!("serving on {} for {serve_secs}s ...", srv.local_addr());
            std::thread::sleep(std::time::Duration::from_secs(serve_secs));
        }
        srv.shutdown();
        // per-tenant attribution of whatever arrived over the wire
        let tenants = svc.tenant_metrics();
        if !tenants.is_empty() {
            println!("wire tenants:");
            for (tenant, c) in &tenants {
                println!(
                    "  {tenant}: requests={} lanes={} shed={} denied={}",
                    c.requests, c.lanes, c.shed, c.denied
                );
            }
        }
    }
    // --record: persist everything the recorder captured above for
    // later replays
    if let Some(rec) = &recorder {
        let trace = rec.trace();
        if let Err(e) = trace.save(Path::new(record)) {
            eprintln!("save trace {record}: {e}");
            return 1;
        }
        println!(
            "trace recorded: {record} ({} records, {} bytes, dropped: {})",
            trace.records.len(),
            rec.bytes(),
            rec.dropped()
        );
    }
    0
}

fn cmd_selftest(artifacts: &Path) -> i32 {
    let rt = match Runtime::new(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!("selftest: XLA artifacts vs native kernels (bit-exact)\n");
    let mut failures = 0;
    for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
        let name = format!("{op}_n4096");
        if rt.manifest().get(&name).is_none() {
            println!("  {op:<6} SKIP (no artifact)");
            continue;
        }
        let planes = workload::planes_for(op, 4096, 0x5E1F);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let xla = match rt.execute(&name, &refs) {
            Ok(o) => o,
            Err(e) => {
                println!("  {op:<6} FAIL execute: {e}");
                failures += 1;
                continue;
            }
        };
        let (_, n_out) = ffgpu::coordinator::batcher::op_arity(op).unwrap();
        let mut native = vec![vec![0.0f32; 4096]; n_out];
        ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
        let bitwise = xla
            .iter()
            .zip(&native)
            .all(|(a, b)| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        if bitwise {
            println!("  {op:<6} OK");
        } else {
            let bad: usize = xla
                .iter()
                .zip(&native)
                .map(|(a, b)| {
                    a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count()
                })
                .sum();
            println!("  {op:<6} FAIL ({bad} lanes differ)");
            failures += 1;
        }
    }
    if failures == 0 {
        println!("\nselftest OK");
        0
    } else {
        println!("\nselftest FAILED ({failures} ops)");
        1
    }
}
