//! `ffgpu` — CLI for the float-float-on-stream-processor reproduction.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md §3):
//!
//! ```text
//! ffgpu info                # platform, artifact inventory, Table 1 formats
//! ffgpu paranoia            # Table 2 (simulated GPU arithmetic)
//! ffgpu table3              # Table 3 (XLA/PJRT "GPU path" timings)
//! ffgpu table4              # Table 4 (native CPU path timings)
//! ffgpu accuracy            # Table 5 (vs exact dyadic oracle)
//! ffgpu serve-demo          # coordinator smoke: batched requests + metrics
//! ffgpu selftest            # end-to-end: artifacts vs native, bit-exact
//! ```
//!
//! Hand-rolled argument parsing: the build image vendors no CLI crate
//! (documented substitution, DESIGN.md).

use ffgpu::coordinator::service::Backend;
use ffgpu::coordinator::{Service, ServiceConfig};
use ffgpu::harness::{accuracy, paranoia_table, timing, workload};
use ffgpu::runtime::Runtime;
use ffgpu::util::{Rng, Timer};
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let get_flag = |name: &str, default: String| -> String {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or(default)
    };
    let artifacts = PathBuf::from(get_flag("--artifacts", "artifacts".into()));
    let samples: usize = get_flag("--samples", String::new()).parse().unwrap_or(0);

    let code = match cmd {
        "info" => cmd_info(&artifacts),
        "paranoia" => cmd_paranoia(if samples > 0 { samples } else { 200_000 }),
        "table3" => cmd_table3(&artifacts),
        "table4" => cmd_table4(),
        "accuracy" => cmd_accuracy(&artifacts, if samples > 0 { samples } else { 1 << 20 }),
        "serve-demo" => cmd_serve_demo(&artifacts),
        "selftest" => cmd_selftest(&artifacts),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
ffgpu — float-float operators on a stream processor (Da Graça & Defour 2006)

USAGE: ffgpu <command> [--artifacts DIR] [--samples N]

COMMANDS:
  info        platform, artifact inventory, Table 1 formats
  paranoia    Table 2: error intervals of simulated GPU arithmetic
  table3      Table 3: operator timings on the XLA/PJRT path
  table4      Table 4: operator timings on the native CPU path
  accuracy    Table 5: measured accuracy vs the exact dyadic oracle
  serve-demo  coordinator demo: batched requests, metrics report
  selftest    artifacts vs native kernels, bit-exact check
";

fn cmd_info(artifacts: &PathBuf) -> i32 {
    println!("ffgpu — float-float operators (reproduction of Da Graça & Defour 2006)\n");
    println!("Table 1 formats:");
    for f in ffgpu::gpusim::Format::table1() {
        println!(
            "  {:<14} sign 1  exp {:>2}  mant {:>2}  specials {}",
            f.name(), f.exp_bits, f.mant_bits,
            if f.has_specials { "yes" } else { "no" }
        );
    }
    match Runtime::new(artifacts) {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            println!("artifacts: {} entries in {}", rt.manifest().entries.len(),
                     artifacts.display());
            for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
                let sizes: Vec<String> = rt
                    .manifest()
                    .by_op(op)
                    .iter()
                    .map(|e| e.n.to_string())
                    .collect();
                println!("  {:<6} n = {}", op, sizes.join(", "));
            }
            0
        }
        Err(e) => {
            println!("\n(no runtime: {e})");
            0
        }
    }
}

fn cmd_paranoia(samples: usize) -> i32 {
    let t = paranoia_table::measure(samples, 0xFACE);
    print!("{}", t.render());
    0
}

fn cmd_table3(artifacts: &PathBuf) -> i32 {
    let rt = match Runtime::new(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    let timer = Timer::new(2, 7);
    match timing::gpu_grid(&rt, &workload::PAPER_SIZES, &workload::PAPER_OPS, &timer, 3) {
        Ok(grid) => {
            print!("{}", grid.render(
                "Table 3 — float-float operators on the XLA/PJRT path \
                 (normalised to Add @ 4096)"));
            print_paper_grid("paper Table 3", timing::paper_table3());
            0
        }
        Err(e) => {
            eprintln!("table3: {e}");
            1
        }
    }
}

fn cmd_table4() -> i32 {
    let timer = Timer::new(2, 7);
    let grid = timing::cpu_grid(&workload::PAPER_SIZES, &workload::PAPER_OPS, &timer, 4);
    print!("{}", grid.render(
        "Table 4 — float-float operators on the native CPU path \
         (normalised to Add @ 4096)"));
    print_paper_grid("paper Table 4", timing::paper_table4());
    0
}

fn print_paper_grid(title: &str, (sizes, rows): (Vec<usize>, Vec<Vec<f64>>)) {
    println!("\n{title}:");
    let ops_header: String =
        workload::PAPER_OPS.iter().map(|o| format!("{o:>8}")).collect();
    println!("  {:>9} {}", "Size", ops_header);
    for (s, r) in sizes.iter().zip(rows) {
        let cells: String = r.iter().map(|v| format!("{v:>8.2}")).collect();
        println!("  {s:>9} {cells}");
    }
}

fn cmd_accuracy(artifacts: &PathBuf, samples: usize) -> i32 {
    println!("Table 5 — measured accuracy, {samples} samples per op, exact dyadic oracle\n");
    let ops = ["add12", "mul12", "add22", "mul22", "div22", "mad22"];

    // native path
    println!("native CPU kernels (IEEE RN):");
    for op in ops {
        let row = accuracy::measure_op(op, samples, 1 << 16, 0xACC0, |op, planes| {
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let (_, n_out) = ffgpu::coordinator::batcher::op_arity(op).unwrap();
            let mut outs = vec![vec![0.0f32; planes[0].len()]; n_out];
            ffgpu::ff::vector::dispatch(op, &refs, &mut outs)?;
            Ok(outs)
        })
        .unwrap();
        println!("  {:<6} {}", row.op, row.display());
    }

    // XLA path (chunk = compiled size)
    if let Ok(rt) = Runtime::new(artifacts) {
        println!("\nXLA artifacts via PJRT:");
        for op in ops {
            let name = format!("{op}_n4096");
            if rt.manifest().get(&name).is_none() {
                continue;
            }
            let row = accuracy::measure_op(op, samples.min(1 << 20), 4096, 0xACC1,
                |op, planes| {
                    let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
                    rt.execute(&format!("{op}_n4096"), &refs)
                })
                .unwrap();
            println!("  {:<6} {}", row.op, row.display());
        }
    }

    println!("\npaper Table 5 (measured on real 2006 GPU):");
    for (op, v) in accuracy::paper_table5() {
        println!("  {op:<6} {v}");
    }
    0
}

fn cmd_serve_demo(artifacts: &PathBuf) -> i32 {
    let backend = if artifacts.join("manifest.json").exists() {
        Backend::Xla(artifacts.clone())
    } else {
        println!("(no artifacts; using CPU backend)");
        Backend::Cpu
    };
    let svc = match Service::start(ServiceConfig { backend, ..Default::default() }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("service: {e}");
            return 1;
        }
    };
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for client in 0..4u64 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(client);
            for _ in 0..50 {
                let n = 1000 + rng.below(9000);
                let planes = workload::planes_for("add22", n, rng.next_u64());
                let out = h.call("add22", planes).expect("add22");
                assert_eq!(out[0].len(), n);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    println!("serve-demo: {} requests in {wall:.3}s ({:.0} req/s)",
             m.requests, m.requests as f64 / wall);
    println!("  batches={} launches={} elements={} padding={:.1}%",
             m.batches, m.launches, m.elements, m.padding_fraction() * 100.0);
    println!("  batch latency mean={:.2}ms max={:.2}ms errors={}",
             m.mean_latency_s * 1e3, m.max_latency_s * 1e3, m.errors);
    0
}

fn cmd_selftest(artifacts: &PathBuf) -> i32 {
    let rt = match Runtime::new(artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime: {e}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!("selftest: XLA artifacts vs native kernels (bit-exact)\n");
    let mut failures = 0;
    for op in workload::PAPER_OPS.iter().chain(workload::EXT_OPS.iter()) {
        let name = format!("{op}_n4096");
        if rt.manifest().get(&name).is_none() {
            println!("  {op:<6} SKIP (no artifact)");
            continue;
        }
        let planes = workload::planes_for(op, 4096, 0x5E1F);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let xla = match rt.execute(&name, &refs) {
            Ok(o) => o,
            Err(e) => {
                println!("  {op:<6} FAIL execute: {e}");
                failures += 1;
                continue;
            }
        };
        let (_, n_out) = ffgpu::coordinator::batcher::op_arity(op).unwrap();
        let mut native = vec![vec![0.0f32; 4096]; n_out];
        ffgpu::ff::vector::dispatch(op, &refs, &mut native).unwrap();
        let bitwise = xla
            .iter()
            .zip(&native)
            .all(|(a, b)| a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        if bitwise {
            println!("  {op:<6} OK");
        } else {
            let bad: usize = xla
                .iter()
                .zip(&native)
                .map(|(a, b)| {
                    a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count()
                })
                .sum();
            println!("  {op:<6} FAIL ({bad} lanes differ)");
            failures += 1;
        }
    }
    if failures == 0 {
        println!("\nselftest OK");
        0
    } else {
        println!("\nselftest FAILED ({failures} ops)");
        1
    }
}
