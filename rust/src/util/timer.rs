//! Measurement helpers for the bench harness: warmup + repeated timing
//! with median-of-runs, the protocol all paper tables use.

use std::time::Instant;

/// Repeated-measurement timer.
pub struct Timer {
    /// Warmup iterations before measurement (amortises PJRT first-run
    /// compilation, cache warmup).
    pub warmup: usize,
    /// Measured iterations; the reported value is the median.
    pub reps: usize,
}

impl Default for Timer {
    fn default() -> Self {
        Timer { warmup: 3, reps: 9 }
    }
}

impl Timer {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Timer { warmup, reps }
    }

    /// Median wall-clock seconds of `f` over `reps` runs.
    pub fn median_secs<F: FnMut()>(&self, mut f: F) -> f64 {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<f64> = (0..self.reps.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64()
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        times[times.len() / 2]
    }

    /// Minimum wall-clock seconds (tightest lower bound, less noisy for
    /// very short kernels).
    pub fn min_secs<F: FnMut()>(&self, mut f: F) -> f64 {
        for _ in 0..self.warmup {
            f();
        }
        (0..self.reps.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_is_positive_and_sane() {
        let t = Timer::new(1, 5);
        let s = t.median_secs(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s >= 0.0 && s < 1.0);
    }

    #[test]
    fn min_leq_median() {
        let t = Timer::new(1, 7);
        let mut v = vec![0u64; 2048];
        let med = t.median_secs(|| {
            for (i, x) in v.iter_mut().enumerate() {
                *x = std::hint::black_box(i as u64 * 3);
            }
        });
        let min = t.min_secs(|| {
            for (i, x) in v.iter_mut().enumerate() {
                *x = std::hint::black_box(i as u64 * 3);
            }
        });
        assert!(min <= med * 1.5 + 1e-9);
    }
}
