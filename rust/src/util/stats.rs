//! Streaming summary statistics for accuracy sweeps and benchmarks.

/// Online min/max/mean/count accumulator (Welford for the variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub min: f64,
    pub max: f64,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY, mean: 0.0, m2: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.count < 2 { 0.0 } else { self.m2 / (self.count - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.count as f64;
        let n2 = other.count as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constants() {
        let mut s = Summary::new();
        for _ in 0..10 {
            s.add(3.0);
        }
        assert_eq!(s.count, 10);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin();
            whole.add(x);
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
        }
        a.merge(&b);
        assert_eq!(a.count, whole.count);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.min, whole.min);
        assert_eq!(a.max, whole.max);
    }
}
