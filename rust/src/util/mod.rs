//! Small shared utilities: deterministic RNG, timing, ulp helpers.
//!
//! No external crates: the image vendors only the `xla` dependency tree,
//! so randomness, timing and stats are implemented here (documented
//! substitution in DESIGN.md — the paper's harness likewise rolled its
//! own test-vector generation).

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;

/// Units in the last place of an `f32`, as an `f64` distance.
///
/// `ulp_f32(x)` is the gap between `x` and the next representable `f32`
/// of larger magnitude. Used by accuracy harnesses to express errors in
/// ulps the way the paranoia tool of the paper's Table 2 does.
pub fn ulp_f32(x: f32) -> f64 {
    if x == 0.0 {
        return f32::from_bits(1) as f64; // smallest subnormal
    }
    let bits = x.to_bits() & 0x7fff_ffff;
    if bits >= 0x7f80_0000 {
        return f64::INFINITY; // inf/nan
    }
    let next = f32::from_bits(bits + 1);
    (next as f64) - (f32::from_bits(bits) as f64)
}

/// log2 of |err| relative to |reference|: the paper's Table 5 metric
/// ("Error max −48.0" means max |err| = 2^-48 · |reference|).
/// Returns `None` when the error is exactly zero.
pub fn log2_rel_error(err: f64, reference: f64) -> Option<f64> {
    if err == 0.0 {
        return None;
    }
    if reference == 0.0 {
        return Some(f64::INFINITY);
    }
    Some((err.abs() / reference.abs()).log2())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ulp_of_one_is_2pow_neg23() {
        assert_eq!(ulp_f32(1.0), 2f64.powi(-23));
    }

    #[test]
    fn ulp_of_two_is_2pow_neg22() {
        assert_eq!(ulp_f32(2.0), 2f64.powi(-22));
    }

    #[test]
    fn ulp_of_zero_is_smallest_subnormal() {
        assert!(ulp_f32(0.0) > 0.0);
        assert!(ulp_f32(0.0) < 1e-44);
    }

    #[test]
    fn ulp_is_sign_symmetric() {
        assert_eq!(ulp_f32(-1.5), ulp_f32(1.5));
    }

    #[test]
    fn log2_rel_error_basics() {
        assert_eq!(log2_rel_error(0.0, 1.0), None);
        let e = log2_rel_error(2f64.powi(-44), 1.0).unwrap();
        assert!((e + 44.0).abs() < 1e-12);
    }
}
