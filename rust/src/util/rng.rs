//! Deterministic PRNG for workloads and property tests.
//!
//! xoshiro256** (Blackman & Vigna) — small, fast, and good enough for
//! test-vector generation; reproducible across platforms so recorded
//! experiment numbers stay stable. Not for cryptography.

/// xoshiro256** generator with convenience float/distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction. Uses splitmix64 to expand the seed so that
    /// nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// A "well-spread" finite normal f32: random sign, random exponent in
    /// [min_exp, max_exp], full random mantissa. This is the distribution
    /// the paper's accuracy runs need (denormals and specials excluded,
    /// §6.1).
    pub fn spread_f32(&mut self, min_exp: i32, max_exp: i32) -> f32 {
        let exp = self.uniform(min_exp as f64, max_exp as f64);
        let mant = 1.0 + self.f64();
        let sign = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        (sign * mant * exp.exp2()) as f32
    }

    /// A normalised float-float pair (hi, lo) with |lo| <= ulp(hi)/2,
    /// drawn from a wide f64 value (the natural way to build valid ff
    /// test vectors).
    pub fn ff_pair(&mut self, min_exp: i32, max_exp: i32) -> (f32, f32) {
        let exp = self.uniform(min_exp as f64, max_exp as f64);
        let v = self.normal() * exp.exp2();
        let hi = v as f32;
        let lo = (v - hi as f64) as f32;
        (hi, lo)
    }

    /// Fill a vector with spread f32s.
    pub fn fill_spread(&mut self, n: usize, min_exp: i32, max_exp: i32) -> Vec<f32> {
        (0..n).map(|_| self.spread_f32(min_exp, max_exp)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn ff_pair_is_normalised() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let (hi, lo) = r.ff_pair(-10, 10);
            if hi != 0.0 && lo != 0.0 {
                assert!(lo.abs() as f64 <= crate::util::ulp_f32(hi) * 0.5 + 1e-300,
                        "hi={hi} lo={lo}");
            }
            // round-trip: hi + lo == original within f64
            assert_eq!((hi as f64 + lo as f64) as f32, hi);
        }
    }

    #[test]
    fn spread_f32_respects_exponent_range() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            let v = r.spread_f32(-6, 6);
            let a = v.abs();
            assert!(a > 2f32.powi(-8) && a < 2f32.powi(8), "{v}");
        }
    }
}
