//! Table 2 emitter: paranoia intervals, measured vs paper.

use crate::gpusim::paranoia::{self, ParanoiaRow};
use crate::gpusim::GpuModel;

/// Measured Table 2 across the standard model columns.
pub struct Table2 {
    pub rows: Vec<(String, ParanoiaRow)>,
}

/// Run paranoia on the four Table 2 columns.
pub fn measure(samples: usize, seed: u64) -> Table2 {
    let models = [GpuModel::IEEE, GpuModel::CHOPPED, GpuModel::R300, GpuModel::NV35];
    Table2 {
        rows: models
            .iter()
            .map(|m| (m.name.to_string(), paranoia::run(m, samples, seed)))
            .collect(),
    }
}

impl Table2 {
    /// Render measured intervals next to the paper's.
    pub fn render(&self) -> String {
        let mut t = super::table::Table::new(
            "Table 2 — floating-point error intervals (ulp), measured on simulated models",
            &["Operation", "ieee-rn", "chopped", "r300", "nv35"],
        );
        let fmt = |i: crate::gpusim::paranoia::Interval| {
            format!("[{:.2}, {:.2}]", i.min, i.max)
        };
        let ops: [(&str, fn(&ParanoiaRow) -> crate::gpusim::paranoia::Interval); 4] = [
            ("Addition", |r| r.add),
            ("Subtraction", |r| r.sub),
            ("Multiplication", |r| r.mul),
            ("Division", |r| r.div),
        ];
        for (name, sel) in ops {
            let mut cells = vec![name.to_string()];
            for (_, row) in &self.rows {
                cells.push(fmt(sel(row)));
            }
            t.row(cells);
        }
        let mut out = t.render();
        out.push_str("\npaper reference:\n");
        for (op, vals) in paranoia::paper_reference() {
            out.push_str(&format!(
                "  {op:<15} exact [{}, {}]  chopped ({}, {}]  r300 [{}, {}]  nv35 [{}, {}]\n",
                vals[0], vals[1], vals[2], vals[3], vals[4], vals[5], vals[6], vals[7]
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_rows_and_columns() {
        let t = measure(2_000, 9);
        let s = t.render();
        assert!(s.contains("Addition"));
        assert!(s.contains("Division"));
        assert!(s.contains("nv35"));
        assert!(s.contains("paper reference"));
    }

    #[test]
    fn measured_add_classes_match_paper() {
        let t = measure(20_000, 10);
        let get = |name: &str| {
            &t.rows.iter().find(|(n, _)| n == name).unwrap().1
        };
        // ieee within [-0.5, 0.5]
        let ieee = get("ieee-rn");
        assert!(ieee.add.min >= -0.51 && ieee.add.max <= 0.51);
        // chopped add within (-1, 0]
        let ch = get("chopped");
        assert!(ch.add.min > -1.01 && ch.add.max <= 1e-9);
        // r300 sub wider than nv35 sub
        let r300 = get("r300");
        let nv35 = get("nv35");
        assert!(r300.sub.max - r300.sub.min > nv35.sub.max - nv35.sub.min);
        // division beyond 1 ulp on the GPU models
        assert!(r300.div.min < -1.0 || r300.div.max > 1.0);
    }
}
