//! Benchmark & accuracy harness: regenerates every table of the paper's
//! evaluation section (the experiment index lives in DESIGN.md §4).
//!
//! * [`table`] — plain-text table rendering (fixed-width, same row/column
//!   layout as the paper);
//! * [`workload`] — deterministic input generators (random streams,
//!   normalised float-float streams; denormals and specials excluded as
//!   in the paper §6.1);
//! * [`timing`] — Tables 3 & 4: operator timing grids over the paper's
//!   sizes, normalised to "the single addition of 4096 data";
//! * [`accuracy`] — Table 5: max observed log2 relative error against
//!   the exact [`crate::mp::Dyadic`] oracle;
//! * [`paranoia_table`] — Table 2 via [`crate::gpusim::paranoia`].

pub mod accuracy;
pub mod paranoia_table;
pub mod table;
pub mod timing;
pub mod workload;
