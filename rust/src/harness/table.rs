//! Fixed-width text tables matching the paper's layout.

/// A simple text table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column auto width.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }
}

/// Format a normalised timing the way the paper prints it ("1,09").
pub fn paper_num(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Size", "Add", "Mul22"]);
        t.row(vec!["4096".into(), "1.00".into(), "1.54".into()]);
        t.row(vec!["1048576".into(), "10.64".into(), "24.64".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| 1048576 |"));
        // all data lines equal length
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn paper_num_format() {
        assert_eq!(paper_num(1.0), "1.00");
        assert_eq!(paper_num(10.6449), "10.64");
    }
}
