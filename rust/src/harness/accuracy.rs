//! Table 5: measured accuracy of the float-float operators.
//!
//! The paper runs 2^24 random vectors and reports, per operator, the
//! maximum observed error as `log2(|err| / |exact|)` against MPFR (their
//! "-48.0" notation; "(exact)" when no error was ever observed). Our
//! oracle is the exact [`Dyadic`] type — zero oracle error.
//!
//! The executor is abstract so the same sweep measures:
//! * the native rust kernels (IEEE RN hardware),
//! * the XLA artifacts through the PJRT runtime,
//! * the simulated NV35/R300 GPU arithmetic — the configuration that
//!   actually reproduces the paper's anomaly rows (§6.1).

use super::workload::planes_for;
use crate::mp::Dyadic;

/// One Table 5 row.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub op: String,
    /// max log2(|err|/|exact|); None = exact on every sample.
    pub max_log2: Option<f64>,
    pub samples: usize,
}

impl AccuracyRow {
    /// Paper formatting: "-48.0" or "(exact)".
    pub fn display(&self) -> String {
        match self.max_log2 {
            None => "(exact)".to_string(),
            Some(v) => format!("{v:.1}"),
        }
    }
}

/// Exact expected value of `op` on sample `i` of the input planes.
fn exact_result(op: &str, planes: &[Vec<f32>], i: usize) -> Option<Dyadic> {
    let g = |p: usize| Dyadic::from_f32(planes[p][i]);
    Some(match op {
        "add12" => g(0).add(&g(1)),
        "mul12" => g(0).mul(&g(1)),
        "split" => g(0),
        "add22" => Dyadic::from_ff(planes[0][i], planes[1][i])
            .add(&Dyadic::from_ff(planes[2][i], planes[3][i])),
        "mul22" => Dyadic::from_ff(planes[0][i], planes[1][i])
            .mul(&Dyadic::from_ff(planes[2][i], planes[3][i])),
        "div22" => Dyadic::from_ff(planes[0][i], planes[1][i])
            .div(&Dyadic::from_ff(planes[2][i], planes[3][i]), 256),
        "mad22" => Dyadic::from_ff(planes[0][i], planes[1][i])
            .mul(&Dyadic::from_ff(planes[2][i], planes[3][i]))
            .add(&Dyadic::from_ff(planes[4][i], planes[5][i])),
        _ => return None,
    })
}

/// Measure one operator with an arbitrary executor.
///
/// `exec(op, input_planes) -> output_planes`; output pairs are summed as
/// float-float values. `total` samples are streamed in chunks so the
/// sweep scales to the paper's 2^24 without holding 2^24 × planes.
pub fn measure_op<F>(
    op: &str, total: usize, chunk: usize, seed: u64, mut exec: F,
) -> Result<AccuracyRow, String>
where
    F: FnMut(&str, &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String>,
{
    let mut max_log2: Option<f64> = None;
    let mut done = 0usize;
    let mut chunk_idx = 0u64;
    while done < total {
        let n = chunk.min(total - done);
        let planes = planes_for(op, n, seed ^ (chunk_idx << 20));
        let outs = exec(op, &planes)?;
        for i in 0..n {
            let exact = match exact_result(op, &planes, i) {
                Some(e) => e,
                None => return Err(format!("no oracle for op '{op}'")),
            };
            let got = if outs.len() == 2 {
                Dyadic::from_ff(outs[0][i], outs[1][i])
            } else {
                Dyadic::from_f32(outs[0][i])
            };
            let err = got.sub(&exact);
            if err.is_zero() {
                continue;
            }
            if exact.is_zero() {
                continue; // relative error undefined; paper skips these
            }
            let l = err.log2_abs() - exact.log2_abs();
            max_log2 = Some(max_log2.map_or(l, |m: f64| m.max(l)));
        }
        done += n;
        chunk_idx += 1;
    }
    Ok(AccuracyRow { op: op.to_string(), max_log2, samples: total })
}

/// The paper's Table 5 reference.
pub fn paper_table5() -> Vec<(&'static str, &'static str)> {
    vec![
        ("add12", "-48.0"),
        ("mul12", "(exact)"),
        ("add22", "-33.7"),
        ("mul22", "-45.0"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::op_arity;
    use crate::ff::vector;

    fn native_exec(op: &str, planes: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let (_, n_out) = op_arity(op).ok_or("bad op")?;
        let n = planes[0].len();
        let mut outs = vec![vec![0.0f32; n]; n_out];
        vector::dispatch(op, &refs, &mut outs)?;
        Ok(outs)
    }

    #[test]
    fn native_add12_is_exact() {
        let row = measure_op("add12", 1 << 14, 4096, 1, native_exec).unwrap();
        assert_eq!(row.max_log2, None, "{row:?}");
        assert_eq!(row.display(), "(exact)");
    }

    #[test]
    fn native_mul12_is_exact() {
        let row = measure_op("mul12", 1 << 14, 4096, 2, native_exec).unwrap();
        assert_eq!(row.max_log2, None, "{row:?}");
    }

    #[test]
    fn native_add22_bounded() {
        let row = measure_op("add22", 1 << 14, 4096, 3, native_exec).unwrap();
        let m = row.max_log2.expect("add22 is not exact");
        // IEEE hardware: within the Th.5 class (paper GPU measured -33.7
        // due to the truncation anomaly; RN hardware is better)
        assert!(m <= -30.0, "max_log2={m}"); // paper itself measured -33.7 (cancellation term)
    }

    #[test]
    fn native_mul22_bounded() {
        let row = measure_op("mul22", 1 << 14, 4096, 4, native_exec).unwrap();
        let m = row.max_log2.expect("mul22 is not exact");
        assert!(m <= -43.0, "max_log2={m}");
    }

    #[test]
    fn gpusim_nv35_reproduces_table5_shape() {
        // run the sweep on simulated NV35 arithmetic: add12 no longer
        // exact (paper: -48.0), add22 notably worse than mul22's class
        use crate::gpusim::{algorithms as alg, GpuModel};
        let m = GpuModel::NV35;
        let exec = |op: &str, planes: &[Vec<f32>]| -> Result<Vec<Vec<f32>>, String> {
            let n = planes[0].len();
            let mut outs = vec![vec![0.0f32; n]; 2];
            for i in 0..n {
                let q = |p: usize| m.quantize(planes[p][i] as f64);
                let (h, l) = match op {
                    "add12" => alg::add12(&m, q(0), q(1)),
                    "mul12" => alg::mul12(&m, q(0), q(1)),
                    "add22" => alg::add22(&m, (q(0), q(1)), (q(2), q(3))),
                    "mul22" => alg::mul22(&m, (q(0), q(1)), (q(2), q(3))),
                    _ => return Err("unsupported".into()),
                };
                outs[0][i] = m.to_f64(h) as f32;
                outs[1][i] = m.to_f64(l) as f32;
            }
            Ok(outs)
        };
        let add12 = measure_op("add12", 1 << 12, 1024, 5, exec).unwrap();
        let add22 = measure_op("add22", 1 << 12, 1024, 6, exec).unwrap();
        let mul22 = measure_op("mul22", 1 << 12, 1024, 7, exec).unwrap();
        // add12 under truncated-guard addition: tiny residuals may appear
        if let Some(m12) = add12.max_log2 {
            assert!(m12 <= -40.0, "add12 {m12}");
        }
        // add22 must be worse than (or equal to) mul22 — the paper's
        // anomaly ordering (-33.7 vs -45.0)
        let a22 = add22.max_log2.unwrap_or(f64::NEG_INFINITY);
        let m22 = mul22.max_log2.unwrap_or(f64::NEG_INFINITY);
        assert!(a22 >= m22 - 1.0, "add22 {a22} vs mul22 {m22}");
    }

    #[test]
    fn paper_reference_rows() {
        let t = paper_table5();
        assert_eq!(t.len(), 4);
        assert_eq!(t[1], ("mul12", "(exact)"));
    }
}
