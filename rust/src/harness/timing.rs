//! Tables 3 & 4: operator timing grids normalised to the time of 4096
//! additions (the paper's unit).

use super::workload::planes_for;
use crate::ff::simd::{self, KernelTier};
use crate::ff::vector;
use crate::runtime::Runtime;
use crate::util::Timer;

/// A (size x op) grid of raw median seconds.
#[derive(Clone, Debug)]
pub struct TimingGrid {
    pub ops: Vec<String>,
    pub sizes: Vec<usize>,
    /// seconds[size_idx][op_idx]
    pub seconds: Vec<Vec<f64>>,
}

impl TimingGrid {
    /// Normalise to the (smallest size, first op) cell — the paper's
    /// "time of the single addition of 4096 data".
    pub fn normalised(&self) -> Vec<Vec<f64>> {
        let unit = self.seconds[0][0].max(1e-12);
        self.seconds
            .iter()
            .map(|row| row.iter().map(|&s| s / unit).collect())
            .collect()
    }

    /// Render in the paper's layout.
    pub fn render(&self, title: &str) -> String {
        let mut header: Vec<&str> = vec!["Size"];
        let caps: Vec<String> = self.ops.iter().map(|o| capitalize(o)).collect();
        header.extend(caps.iter().map(String::as_str));
        let mut t = super::table::Table::new(title, &header);
        let norm = self.normalised();
        for (si, &size) in self.sizes.iter().enumerate() {
            let mut cells = vec![size.to_string()];
            cells.extend(norm[si].iter().map(|&v| super::table::paper_num(v)));
            t.row(cells);
        }
        t.render()
    }
}

fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// Table 4 — the CPU path on the scalar kernel tier (the paper-faithful
/// protocol: its 2006 CPU baseline was scalar-era code).
///
/// Per the paper, the CPU Add22 is the *branchy* variant ("the test in
/// the Add22 algorithm is time consuming … as it breaks the execution
/// pipeline"); everything else is the branch-free code.
pub fn cpu_grid(sizes: &[usize], ops: &[&str], timer: &Timer, seed: u64) -> TimingGrid {
    cpu_grid_tier(sizes, ops, timer, seed, KernelTier::Scalar)
}

/// [`cpu_grid`] on an explicit kernel tier — what `benches/table4_cpu`
/// uses to attribute modern-CPU reproductions to the tier that ran
/// them. Add22 stays the branchy scalar variant in every tier (it *is*
/// the paper's CPU protocol; there is no blocked branchy kernel).
pub fn cpu_grid_tier(
    sizes: &[usize], ops: &[&str], timer: &Timer, seed: u64, tier: KernelTier,
) -> TimingGrid {
    let mut seconds = Vec::with_capacity(sizes.len());
    for (si, &n) in sizes.iter().enumerate() {
        let mut row = Vec::with_capacity(ops.len());
        for op in ops {
            let planes = planes_for(op, n, seed + si as u64);
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let (_, n_out) = crate::coordinator::batcher::op_arity(op).unwrap();
            let mut outs = vec![vec![0.0f32; n]; n_out];
            let secs = timer.median_secs(|| {
                if *op == "add22" {
                    // paper's CPU variant
                    let (a, b) = outs.split_at_mut(1);
                    vector::add22_branchy(refs[0], refs[1], refs[2], refs[3],
                                          &mut a[0], &mut b[0]);
                } else {
                    simd::dispatch(tier, op, &refs, &mut outs).unwrap();
                }
                std::hint::black_box(&outs);
            });
            row.push(secs);
        }
        seconds.push(row);
    }
    TimingGrid {
        ops: ops.iter().map(|s| s.to_string()).collect(),
        sizes: sizes.to_vec(),
        seconds,
    }
}

/// Table 3 — the "GPU" path: XLA artifacts through the PJRT engine.
///
/// Timing includes upload/execute/download per launch, matching the
/// paper's protocol (stream upload + kernel + readback; their ×100 bus
/// overhead discussion applies to the CPU↔GPU hop, which PJRT-CPU
/// doesn't have, so absolute ratios shift while shapes hold).
pub fn gpu_grid(
    rt: &Runtime, sizes: &[usize], ops: &[&str], timer: &Timer, seed: u64,
) -> Result<TimingGrid, String> {
    let mut seconds = Vec::with_capacity(sizes.len());
    for (si, &n) in sizes.iter().enumerate() {
        let mut row = Vec::with_capacity(ops.len());
        for op in ops {
            let name = format!("{op}_n{n}");
            rt.compiled(&name)?; // compile outside the timed region
            let planes = planes_for(op, n, seed + si as u64);
            let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
            let mut err = None;
            let secs = timer.median_secs(|| {
                match rt.execute(&name, &refs) {
                    Ok(out) => {
                        std::hint::black_box(&out);
                    }
                    Err(e) => err = Some(e),
                }
            });
            if let Some(e) = err {
                return Err(e);
            }
            row.push(secs);
        }
        seconds.push(row);
    }
    Ok(TimingGrid {
        ops: ops.iter().map(|s| s.to_string()).collect(),
        sizes: sizes.to_vec(),
        seconds,
    })
}

/// Generic timing grid over any [`crate::backend::KernelBackend`] —
/// the substrate-neutral emitter the backend layer unlocks: the same
/// table for native (any worker count), gpusim (any GPU model), or XLA.
pub fn backend_grid(
    backend: &mut dyn crate::backend::KernelBackend, sizes: &[usize], ops: &[&str],
    timer: &Timer, seed: u64,
) -> Result<TimingGrid, crate::backend::ServiceError> {
    let mut seconds = Vec::with_capacity(sizes.len());
    for (si, &n) in sizes.iter().enumerate() {
        let mut row = Vec::with_capacity(ops.len());
        for op in ops {
            let op = crate::backend::Op::parse(op)?;
            let planes = planes_for(op.name(), n, seed + si as u64);
            // one job per (op, size), reused across reps — the owned
            // job model makes the measured loop copy-free
            let job = crate::backend::ExecJob::new(op, planes)?;
            let mut outs = vec![vec![0.0f32; n]; op.n_out()];
            let mut err = None;
            let secs = timer.median_secs(|| {
                if let Err(e) = backend.execute(&job, &mut outs) {
                    err = Some(e);
                }
                std::hint::black_box(&outs);
            });
            if let Some(e) = err {
                return Err(e);
            }
            row.push(secs);
        }
        seconds.push(row);
    }
    Ok(TimingGrid {
        ops: ops.iter().map(|s| s.to_string()).collect(),
        sizes: sizes.to_vec(),
        seconds,
    })
}

/// The paper's Table 3 values, for side-by-side printing.
pub fn paper_table3() -> (Vec<usize>, Vec<Vec<f64>>) {
    (
        vec![4096, 16384, 65536, 262144, 1048576],
        vec![
            vec![1.00, 0.97, 1.00, 1.09, 1.57, 1.55, 1.54],
            vec![1.11, 1.11, 1.15, 1.20, 1.87, 1.73, 2.02],
            vec![1.55, 1.58, 1.69, 1.64, 2.09, 2.87, 2.94],
            vec![3.55, 3.40, 3.44, 3.74, 3.99, 7.15, 7.47],
            vec![10.64, 10.74, 10.75, 10.79, 14.64, 23.92, 24.64],
        ],
    )
}

/// The paper's Table 4 values.
pub fn paper_table4() -> (Vec<usize>, Vec<Vec<f64>>) {
    (
        vec![4096, 16384, 65536, 262144, 1048576],
        vec![
            vec![1.00, 0.98, 1.35, 1.52, 2.86, 11.71, 4.12],
            vec![3.88, 3.88, 3.46, 6.04, 17.86, 47.93, 17.62],
            vec![17.13, 16.20, 17.67, 28.35, 49.14, 192.10, 69.33],
            vec![68.77, 66.68, 77.10, 100.10, 187.49, 760.65, 272.13],
            vec![269.49, 267.88, 312.45, 312.45, 1027.62, 3083.74, 1091.59],
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload::PAPER_OPS;

    #[test]
    fn cpu_grid_small_is_sane() {
        let timer = Timer::new(0, 3);
        let grid = cpu_grid(&[1024, 4096], &PAPER_OPS, &timer, 42);
        assert_eq!(grid.seconds.len(), 2);
        assert_eq!(grid.seconds[0].len(), 7);
        // all positive
        assert!(grid.seconds.iter().flatten().all(|&s| s > 0.0));
        let norm = grid.normalised();
        assert_eq!(norm[0][0], 1.0);
        // 4x data should take noticeably longer than 1x for the same op
        assert!(norm[1][0] > norm[0][0]);
        // mul22 costs more than add at the same size
        let mul22 = grid.ops.iter().position(|o| o == "mul22").unwrap();
        assert!(norm[1][mul22] > norm[1][0]);
    }

    #[test]
    fn render_contains_paper_columns() {
        let timer = Timer::new(0, 1);
        let grid = cpu_grid(&[256], &PAPER_OPS, &timer, 1);
        let s = grid.render("Table 4");
        assert!(s.contains("Add12"));
        assert!(s.contains("Mul22"));
        assert!(s.contains("256"));
    }

    #[test]
    fn backend_grid_runs_on_native_and_gpusim() {
        use crate::backend::{BackendSpec, ServiceError};
        let timer = Timer::new(0, 1);
        let mut native = BackendSpec::native_single().build().unwrap();
        let grid =
            backend_grid(native.as_mut(), &[256], &["add", "add22"], &timer, 1).unwrap();
        assert_eq!(grid.seconds.len(), 1);
        assert!(grid.seconds[0].iter().all(|&s| s > 0.0));

        let mut sim = BackendSpec::gpusim_ieee().build().unwrap();
        let grid =
            backend_grid(sim.as_mut(), &[64], &["add12", "mul22"], &timer, 2).unwrap();
        assert!(grid.seconds[0].iter().all(|&s| s > 0.0));

        assert!(matches!(
            backend_grid(native.as_mut(), &[64], &["nope"], &timer, 3),
            Err(ServiceError::UnknownOp(_))
        ));
    }

    #[test]
    fn paper_reference_shapes() {
        let (s3, t3) = paper_table3();
        assert_eq!(s3.len(), 5);
        assert!(t3.iter().all(|r| r.len() == 7));
        let (_, t4) = paper_table4();
        assert!(t4[4][5] > 3000.0); // the famous CPU Add22 blowup
    }
}
