//! Deterministic workload generators for the benchmark grids.
//!
//! Paper §6.1: "randomly generated test vectors … we excluded denormal
//! input numbers and special cases numbers as there are not fully
//! supported by the targeted hardware."

use crate::coordinator::batcher::op_arity;
use crate::util::Rng;

/// Input planes for operator `op`, length `n`, deterministic in `seed`.
///
/// Float-float pair planes are properly normalised (|lo| <= ulp(hi)/2);
/// plain planes are exponent-spread normal f32s. Divisor planes avoid
/// zero neighbourhoods.
pub fn planes_for(op: &str, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let (n_in, _) = op_arity(op).expect("known op");
    let mut rng = Rng::new(seed ^ 0xFF60_1234);
    match op {
        // ff-pair inputs: (ah, al, bh, bl[, ch, cl])
        "add22" | "mul22" | "div22" | "mad22" => {
            let pairs = n_in / 2;
            let mut planes = vec![Vec::with_capacity(n); n_in];
            for _ in 0..n {
                for p in 0..pairs {
                    let (hi, lo) = rng.ff_pair(-8, 8);
                    // divisors: keep well away from zero (paper excludes
                    // specials; 0 divisor produces inf)
                    let (hi, lo) = if op == "div22" && p == 1 && hi.abs() < 1e-3 {
                        (hi + 1.0f32.copysign(hi), lo)
                    } else {
                        (hi, lo)
                    };
                    planes[2 * p].push(hi);
                    planes[2 * p + 1].push(lo);
                }
            }
            planes
        }
        _ => (0..n_in)
            .map(|_| rng.fill_spread(n, -8, 8))
            .collect(),
    }
}

/// The paper's evaluation sizes (Tables 3-4).
pub const PAPER_SIZES: [usize; 5] = [4096, 16384, 65536, 262144, 1048576];

/// The paper's operator columns (Tables 3-4).
pub const PAPER_OPS: [&str; 7] = ["add", "mul", "mad", "add12", "mul12", "add22", "mul22"];

/// Extension operators (§7) benchmarked in the extended tables.
pub const EXT_OPS: [&str; 3] = ["split", "div22", "mad22"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ulp_f32;

    #[test]
    fn deterministic() {
        assert_eq!(planes_for("add22", 64, 7), planes_for("add22", 64, 7));
        assert_ne!(planes_for("add22", 64, 7), planes_for("add22", 64, 8));
    }

    #[test]
    fn arity_and_length() {
        for op in PAPER_OPS.iter().chain(EXT_OPS.iter()) {
            let planes = planes_for(op, 128, 1);
            let (n_in, _) = op_arity(op).unwrap();
            assert_eq!(planes.len(), n_in, "op {op}");
            assert!(planes.iter().all(|p| p.len() == 128));
        }
    }

    #[test]
    fn ff_pairs_are_normalised() {
        let planes = planes_for("mul22", 4096, 3);
        for i in 0..4096 {
            let (hi, lo) = (planes[0][i], planes[1][i]);
            if lo != 0.0 {
                assert!(lo.abs() as f64 <= ulp_f32(hi) * 0.5 + 1e-300);
            }
        }
    }

    #[test]
    fn div22_divisors_away_from_zero() {
        let planes = planes_for("div22", 4096, 5);
        for &bh in &planes[2] {
            assert!(bh.abs() >= 1e-3, "divisor too small: {bh}");
        }
    }

    #[test]
    fn no_specials_or_denormals() {
        for op in ["add", "add22"] {
            let planes = planes_for(op, 4096, 11);
            for p in &planes {
                for &v in p {
                    assert!(v.is_finite());
                    assert!(v == 0.0 || v.abs() >= f32::MIN_POSITIVE);
                }
            }
        }
    }
}
