//! Stub PJRT engine for builds without the vendored `xla` crate.
//!
//! Mirrors the `engine.rs` call surface the repo uses; construction
//! always fails with a descriptive error, so every XLA code path — the
//! coordinator's `XlaBackend`, `ffgpu table3`, the integration tests —
//! degrades to "artifacts unavailable" and the native/gpusim substrates
//! keep working. Build with `--features xla` (and the vendored crate)
//! for the real engine.
//!
//! One deliberate divergence: the real `compiled` returns
//! `Rc<xla::PjRtLoadedExecutable>`, which is not nameable without the
//! crate, so the stub's `compiled` returns `()` in the Ok position.
//! Every in-tree caller discards that value; code that binds it must
//! be gated on `#[cfg(feature = "xla")]`.

use super::manifest::{Entry, Manifest};
use std::path::Path;

/// Compilation/execution statistics (observability for `ffgpu info`).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiled: usize,
    pub compile_seconds: f64,
    pub executions: u64,
    pub execute_seconds: f64,
}

/// Stub engine: never constructible (see module docs).
pub struct Runtime {
    manifest: Manifest,
    stats: RuntimeStats,
}

/// Ensure the EFT-preserving XLA flag is present in the environment.
///
/// Kept in the stub so harness code can set the flag unconditionally;
/// XLA parses `XLA_FLAGS` once at first client creation.
pub fn ensure_xla_flags() {
    const FLAG: &str = "--xla_disable_hlo_passes=fusion";
    let current = std::env::var("XLA_FLAGS").unwrap_or_default();
    if !current.contains(FLAG) {
        std::env::set_var("XLA_FLAGS", format!("{current} {FLAG}").trim().to_string());
    }
}

impl Runtime {
    /// Always fails: this build has no PJRT engine.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime, String> {
        ensure_xla_flags();
        Err(format!(
            "PJRT engine unavailable: ffgpu was built without the `xla` feature \
             (artifacts dir: {})",
            artifacts_dir.display()
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats
    }

    pub fn platform(&self) -> String {
        "stub (built without the `xla` feature)".to_string()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn compiled(&self, name: &str) -> Result<(), String> {
        Err(format!("cannot compile '{name}': built without the `xla` feature"))
    }

    /// Pre-compile a set of artifacts (warmup for benchmarking).
    pub fn precompile(&self, names: &[&str]) -> Result<(), String> {
        match names.first() {
            Some(n) => self.compiled(n),
            None => Ok(()),
        }
    }

    /// Execute artifact `name` on f32 input planes; returns output planes.
    pub fn execute(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        Err(format!("cannot execute '{name}': built without the `xla` feature"))
    }

    /// Entries of one operator family (mirrors the real engine's
    /// manifest access pattern; unreachable in practice since `new`
    /// always fails).
    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.manifest.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_never_constructs() {
        let err = Runtime::new(Path::new("artifacts")).unwrap_err();
        assert!(err.contains("xla"), "{err}");
    }

    #[test]
    fn flag_is_set_into_env() {
        ensure_xla_flags();
        assert!(std::env::var("XLA_FLAGS").unwrap().contains("fusion"));
    }
}
