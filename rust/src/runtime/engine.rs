//! The PJRT execution engine: compile-on-demand cache + typed execute.
//!
//! One `Runtime` owns one PJRT CPU client and a cache of compiled
//! executables keyed by artifact name. PJRT wrapper types are not
//! `Sync`, so a `Runtime` lives on one thread — the coordinator gives it
//! a dedicated "device thread" and feeds it through channels, exactly
//! like a GPU command queue (see [`crate::coordinator`]).

use super::manifest::{Entry, Manifest};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Compilation/execution statistics (observability for `ffgpu info`).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiled: usize,
    pub compile_seconds: f64,
    pub executions: u64,
    pub execute_seconds: f64,
}

/// PJRT engine with a lazy executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

/// Ensure the EFT-preserving XLA flag is present in the environment.
///
/// Must run before the first PJRT client is created in the process; XLA
/// parses `XLA_FLAGS` once. DESIGN.md §4b documents the miscompilation
/// this disables (the paper hit the same hazard class in Brook, §5).
pub fn ensure_xla_flags() {
    const FLAG: &str = "--xla_disable_hlo_passes=fusion";
    let current = std::env::var("XLA_FLAGS").unwrap_or_default();
    if !current.contains(FLAG) {
        std::env::set_var("XLA_FLAGS", format!("{current} {FLAG}").trim().to_string());
    }
}

impl Runtime {
    /// Create the engine over an artifacts directory (reads the
    /// manifest; compiles nothing yet).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime, String> {
        ensure_xla_flags();
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    pub fn platform(&self) -> String {
        format!("{} ({})", self.client.platform_name(), self.client.platform_version())
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn compiled(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>, String> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| format!("unknown artifact '{name}'"))?;
        let path = self.manifest.path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        let mut st = self.stats.borrow_mut();
        st.compiled += 1;
        st.compile_seconds += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (warmup for benchmarking).
    pub fn precompile(&self, names: &[&str]) -> Result<(), String> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` on f32 input planes; returns output planes.
    ///
    /// Shapes must match the manifest entry (scalar inputs = length-1
    /// slices). All artifacts are lowered with `return_tuple=True`, so
    /// the single result literal is a tuple of `n_out` arrays.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, String> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| format!("unknown artifact '{name}'"))?
            .clone();
        self.validate_inputs(&entry, inputs)?;
        let exe = self.compiled(name)?;
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&entry.in_shapes)
            .map(|(data, shape)| {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data)
                }
            })
            .collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| format!("untuple {name}: {e}"))?;
        if parts.len() != entry.n_out {
            return Err(format!(
                "{name}: expected {} outputs, got {}", entry.n_out, parts.len()
            ));
        }
        let out: Result<Vec<Vec<f32>>, String> = parts
            .iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| format!("download {name}: {e}")))
            .collect();
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_seconds += t0.elapsed().as_secs_f64();
        out
    }

    fn validate_inputs(&self, entry: &Entry, inputs: &[&[f32]]) -> Result<(), String> {
        if inputs.len() != entry.n_in {
            return Err(format!(
                "{}: expected {} inputs, got {}", entry.name, entry.n_in, inputs.len()
            ));
        }
        for (i, (data, shape)) in inputs.iter().zip(&entry.in_shapes).enumerate() {
            let want = shape.iter().product::<usize>().max(1);
            if data.len() != want {
                return Err(format!(
                    "{}: input {i} has {} elements, expected {want}",
                    entry.name, data.len()
                ));
            }
        }
        Ok(())
    }
}
