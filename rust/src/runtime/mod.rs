//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! This is the "GPU" of the reproduction: `python/compile/aot.py` lowers
//! the Pallas/JAX graphs once to HLO text; this module compiles them on
//! the PJRT CPU client and executes them from rust — Python is never on
//! the request path (Brook's runtime played this role in the paper).
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`;
//! * [`engine`] — the [`engine::Runtime`]: PJRT client, lazy compile
//!   cache, literal marshalling, execute-by-name.
//!
//! **Feature gate**: the real engine needs the vendored `xla` crate and
//! builds only with `--features xla`. Default builds swap in
//! `engine_stub.rs` — the same public surface with `Runtime::new`
//! returning `Err`, so the coordinator's [`crate::backend::XlaBackend`]
//! degrades to a clean startup failure instead of a link error.
//!
//! **XLA flag requirement**: every client must run with
//! `--xla_disable_hlo_passes=fusion` (set automatically by
//! [`engine::Runtime::new`]) — see DESIGN.md §4b for the XLA fusion
//! miscompilation of EFT chains this works around.

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub mod manifest;

pub use engine::Runtime;
pub use manifest::{Entry, Manifest};
