//! Typed view of `artifacts/manifest.json` (emitted by `compile.aot`).

use crate::json;
use std::path::{Path, PathBuf};

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Catalogue name, e.g. `add22_n65536`.
    pub name: String,
    /// Operator family (`add22`, `mul12`, `dot2`, `multipass`, ...).
    pub op: String,
    /// Stream length (elements per plane).
    pub n: usize,
    /// Number of input planes.
    pub n_in: usize,
    /// Number of output planes.
    pub n_out: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Input shapes as lowered (empty vec = scalar).
    pub in_shapes: Vec<Vec<usize>>,
    /// Kind: `stream`, `multipass`, `dot2`, `horner2`.
    pub kind: String,
    /// Pallas block size used at lowering (0 for non-blocked graphs).
    pub block: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated for testability).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let doc = json::parse(text).map_err(|e| e.to_string())?;
        let format = doc.get("format").and_then(|v| v.as_str()).unwrap_or("");
        if format != "hlo-text-v1" {
            return Err(format!("unsupported manifest format '{format}'"));
        }
        let raw = doc
            .get("entries")
            .and_then(|v| v.as_array())
            .ok_or("manifest missing 'entries'")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            let get_str = |k: &str| -> Result<String, String> {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or(format!("entry missing '{k}'"))
            };
            let get_num = |k: &str| -> Result<usize, String> {
                e.get(k).and_then(|v| v.as_usize()).ok_or(format!("entry missing '{k}'"))
            };
            let in_shapes = e
                .get("in_shapes")
                .and_then(|v| v.as_array())
                .map(|shapes| {
                    shapes
                        .iter()
                        .map(|s| {
                            s.as_array()
                                .map(|dims| {
                                    dims.iter().filter_map(|d| d.as_usize()).collect()
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            entries.push(Entry {
                name: get_str("name")?,
                op: get_str("op")?,
                n: get_num("n")?,
                n_in: get_num("n_in")?,
                n_out: get_num("n_out")?,
                file: get_str("file")?,
                in_shapes,
                kind: get_str("kind")?,
                block: get_num("block").unwrap_or(0),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Entries of one operator family, sorted by n.
    pub fn by_op(&self, op: &str) -> Vec<&Entry> {
        let mut v: Vec<&Entry> = self.entries.iter().filter(|e| e.op == op).collect();
        v.sort_by_key(|e| e.n);
        v
    }

    /// Artifact path for an entry.
    pub fn path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "format": "hlo-text-v1",
      "entries": [
        {"name": "add_n4096", "op": "add", "n": 4096, "n_in": 2, "n_out": 1,
         "file": "add_n4096.hlo.txt", "kind": "stream", "block": 4096,
         "in_shapes": [[4096],[4096]]},
        {"name": "add_n16384", "op": "add", "n": 16384, "n_in": 2, "n_out": 1,
         "file": "add_n16384.hlo.txt", "kind": "stream", "block": 4096,
         "in_shapes": [[16384],[16384]]},
        {"name": "horner2_d31", "op": "horner2", "n": 32, "n_in": 4, "n_out": 2,
         "file": "horner2_d31.hlo.txt", "kind": "horner2", "block": 0,
         "in_shapes": [[32],[32],[],[]]}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp/a"), DOC).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.get("add_n4096").unwrap();
        assert_eq!(e.n_in, 2);
        assert_eq!(e.in_shapes[0], vec![4096]);
        assert_eq!(m.path(e), Path::new("/tmp/a/add_n4096.hlo.txt"));
    }

    #[test]
    fn scalar_shapes_are_empty() {
        let m = Manifest::parse(Path::new("."), DOC).unwrap();
        let h = m.get("horner2_d31").unwrap();
        assert_eq!(h.in_shapes[2], Vec::<usize>::new());
    }

    #[test]
    fn by_op_sorted() {
        let m = Manifest::parse(Path::new("."), DOC).unwrap();
        let adds = m.by_op("add");
        assert_eq!(adds.len(), 2);
        assert!(adds[0].n < adds[1].n);
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Manifest::parse(Path::new("."), r#"{"format": "v2", "entries": []}"#)
            .is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // when `make artifacts` has run, validate the real thing end-to-end
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.len() >= 50, "expected full catalogue");
            for e in &m.entries {
                assert!(m.path(e).exists(), "{} missing", e.file);
            }
            // the paper grid must be present
            for op in ["add", "mul", "mad", "add12", "mul12", "add22", "mul22"] {
                assert_eq!(m.by_op(op).len(), 9, "op {op}");
            }
        }
    }
}
