//! [`ServiceError`]: the typed error surface of the serving stack.
//!
//! The seed coordinator reported everything as `String`, which meant
//! callers could neither distinguish "you sent a bad request" from "the
//! service is shutting down" nor use `?` against `std::error::Error`
//! consumers. Every layer above the kernels — backends, batcher,
//! coordinator, handles — now speaks this enum.

use std::error::Error;
use std::fmt;

/// Typed error for the backend layer and the coordinator service.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The service (or one of its shards) has stopped; the submission
    /// queue or the reply channel is closed.
    QueueClosed,
    /// Operator name not in the catalogue.
    UnknownOp(String),
    /// Wrong number of input planes for the operator.
    Arity { op: String, want: usize, got: usize },
    /// Ragged or empty input planes (every plane must have the same
    /// non-zero length), or mismatched output buffers.
    Shape(String),
    /// The operator is in the catalogue but this backend cannot serve it
    /// (e.g. no compiled artifact, no lowered program).
    Unsupported { backend: &'static str, op: String },
    /// Substrate failure: PJRT compile/execute error, stream-VM fault,
    /// worker-pool failure, missing artifacts directory, ...
    Backend(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueClosed => write!(f, "service stopped (queue closed)"),
            ServiceError::UnknownOp(op) => write!(f, "unknown op '{op}'"),
            ServiceError::Arity { op, want, got } => {
                write!(f, "op '{op}' wants {want} input planes, got {got}")
            }
            ServiceError::Shape(msg) => write!(f, "bad shape: {msg}"),
            ServiceError::Unsupported { backend, op } => {
                write!(f, "backend '{backend}' does not serve op '{op}'")
            }
            ServiceError::Backend(msg) => write!(f, "backend failure: {msg}"),
        }
    }
}

impl Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let cases: Vec<(ServiceError, &str)> = vec![
            (ServiceError::QueueClosed, "queue closed"),
            (ServiceError::UnknownOp("frob".into()), "frob"),
            (
                ServiceError::Arity { op: "add22".into(), want: 4, got: 3 },
                "wants 4 input planes, got 3",
            ),
            (ServiceError::Shape("ragged".into()), "ragged"),
            (
                ServiceError::Unsupported { backend: "xla", op: "mad22".into() },
                "does not serve",
            ),
            (ServiceError::Backend("pjrt died".into()), "pjrt died"),
        ];
        for (e, needle) in cases {
            assert!(e.to_string().contains(needle), "{e}");
        }
    }

    #[test]
    fn implements_std_error() {
        fn takes_err(_: &dyn Error) {}
        takes_err(&ServiceError::QueueClosed);
        let boxed: Box<dyn Error> = Box::new(ServiceError::UnknownOp("x".into()));
        assert!(boxed.to_string().contains("unknown op"));
    }
}
