//! [`ServiceError`]: the typed error surface of the serving stack.
//!
//! The seed coordinator reported everything as `String`, which meant
//! callers could neither distinguish "you sent a bad request" from "the
//! service is shutting down" nor use `?` against `std::error::Error`
//! consumers. Every layer above the kernels — backends, batcher,
//! coordinator, handles — now speaks this enum.
//!
//! For the wire front end ([`crate::net`]) every variant additionally
//! carries a **stable numeric code** ([`ServiceError::to_code`]):
//! error frames ship `(code, display message)` and
//! [`ServiceError::from_code`] reconstructs the typed error on the
//! client side — structured payloads (op, plane counts) are recovered
//! by parsing the canonical `Display` grammar, which is part of the
//! wire contract and pinned by the round-trip tests below. Codes are
//! append-only: never renumber, never reuse.

use super::op::Op;
use std::error::Error;
use std::fmt;

/// Typed error for the backend layer and the coordinator service.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The service (or one of its shards) has stopped; the submission
    /// queue or the reply channel is closed.
    QueueClosed,
    /// Operator name not in the catalogue (only [`Op::parse`] and the
    /// deprecated string entry points can produce this).
    UnknownOp(String),
    /// Wrong number of input planes for the operator.
    Arity { op: Op, want: usize, got: usize },
    /// Input plane `plane` has a different length than plane 0 — every
    /// plane of a request must have the same length.
    RaggedPlanes { op: Op, plane: usize, want: usize, got: usize },
    /// Zero-length batch: there is nothing to execute, and letting it
    /// through used to panic deep inside backends.
    EmptyBatch { op: Op },
    /// Mismatched output buffers or other shape violations not covered
    /// by the specific variants above.
    Shape(String),
    /// The operator is in the catalogue but this backend cannot serve it
    /// (e.g. no compiled artifact, no lowered program).
    Unsupported { backend: &'static str, op: Op },
    /// The request was cancelled ([`crate::coordinator::Ticket::cancel`])
    /// before a shard executed it.
    Cancelled,
    /// The request's deadline ([`crate::coordinator::Ticket::deadline`])
    /// passed before a reply arrived; the shard skips expired requests
    /// instead of burning backend time on them.
    DeadlineExceeded,
    /// Substrate failure: PJRT compile/execute error, stream-VM fault,
    /// worker-pool failure, missing artifacts directory, ...
    Backend(String),
}

impl ServiceError {
    /// Stable wire code of this variant (1-based; 0 is reserved for
    /// protocol-level errors that are not `ServiceError`s). Codes are
    /// append-only across releases so old clients keep decoding new
    /// servers' errors.
    pub fn to_code(&self) -> u16 {
        match self {
            ServiceError::QueueClosed => 1,
            ServiceError::UnknownOp(_) => 2,
            ServiceError::Arity { .. } => 3,
            ServiceError::RaggedPlanes { .. } => 4,
            ServiceError::EmptyBatch { .. } => 5,
            ServiceError::Shape(_) => 6,
            ServiceError::Unsupported { .. } => 7,
            ServiceError::Cancelled => 8,
            ServiceError::DeadlineExceeded => 9,
            ServiceError::Backend(_) => 10,
        }
    }

    /// Reconstruct the typed error from a wire `(code, message)` pair.
    /// The message is the canonical [`fmt::Display`] rendering;
    /// structured variants are re-parsed from it, so
    /// `from_code(e.to_code(), &e.to_string()) == Some(e)` for every
    /// error the server can emit (pinned exhaustively below). Returns
    /// `None` for unknown codes or a message that does not match the
    /// variant's grammar — callers should degrade to
    /// [`ServiceError::Backend`] with the raw message rather than drop
    /// the error.
    pub fn from_code(code: u16, message: &str) -> Option<ServiceError> {
        // shared helpers over the Display grammar
        let quoted = |s: &str| -> Option<(String, &str)> {
            // first '...'-quoted span; returns (content, rest-after)
            let start = s.find('\'')? + 1;
            let end = start + s[start..].find('\'')?;
            Some((s[start..end].to_string(), &s[end + 1..]))
        };
        let num = |s: &str| -> Option<usize> {
            let digits: String =
                s.chars().skip_while(|c| !c.is_ascii_digit()).take_while(char::is_ascii_digit).collect();
            digits.parse().ok()
        };
        match code {
            1 => Some(ServiceError::QueueClosed),
            2 => quoted(message).map(|(op, _)| ServiceError::UnknownOp(op)),
            3 => {
                // "op 'x' wants W input planes, got G"
                let (opname, rest) = quoted(message)?;
                let op = Op::parse(&opname).ok()?;
                let (want_part, got_part) = rest.split_once(", got")?;
                Some(ServiceError::Arity { op, want: num(want_part)?, got: num(got_part)? })
            }
            4 => {
                // "op 'x': input plane P has length G, expected W (ragged planes)"
                let (opname, rest) = quoted(message)?;
                let op = Op::parse(&opname).ok()?;
                let (plane_part, rest) = rest.split_once(" has length ")?;
                let (got_part, want_part) = rest.split_once(", expected ")?;
                Some(ServiceError::RaggedPlanes {
                    op,
                    plane: num(plane_part)?,
                    want: num(want_part)?,
                    got: num(got_part)?,
                })
            }
            5 => {
                let (opname, _) = quoted(message)?;
                Some(ServiceError::EmptyBatch { op: Op::parse(&opname).ok()? })
            }
            6 => Some(ServiceError::Shape(
                message.strip_prefix("bad shape: ").unwrap_or(message).to_string(),
            )),
            7 => {
                // "backend 'b' does not serve op 'x'"; the backend name
                // must map back to a &'static str — the known substrate
                // labels do, anything else decodes as "remote"
                let (backend, rest) = quoted(message)?;
                let backend: &'static str = match backend.as_str() {
                    "native" => "native",
                    "gpusim" => "gpusim",
                    "xla" => "xla",
                    _ => "remote",
                };
                let (opname, _) = quoted(rest)?;
                Some(ServiceError::Unsupported { backend, op: Op::parse(&opname).ok()? })
            }
            8 => Some(ServiceError::Cancelled),
            9 => Some(ServiceError::DeadlineExceeded),
            10 => Some(ServiceError::Backend(
                message.strip_prefix("backend failure: ").unwrap_or(message).to_string(),
            )),
            _ => None,
        }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueClosed => write!(f, "service stopped (queue closed)"),
            ServiceError::UnknownOp(op) => write!(f, "unknown op '{op}'"),
            ServiceError::Arity { op, want, got } => {
                write!(f, "op '{op}' wants {want} input planes, got {got}")
            }
            ServiceError::RaggedPlanes { op, plane, want, got } => {
                write!(
                    f,
                    "op '{op}': input plane {plane} has length {got}, \
                     expected {want} (ragged planes)"
                )
            }
            ServiceError::EmptyBatch { op } => {
                write!(f, "op '{op}': zero-length batch")
            }
            ServiceError::Shape(msg) => write!(f, "bad shape: {msg}"),
            ServiceError::Unsupported { backend, op } => {
                write!(f, "backend '{backend}' does not serve op '{op}'")
            }
            ServiceError::Cancelled => write!(f, "request cancelled"),
            ServiceError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServiceError::Backend(msg) => write!(f, "backend failure: {msg}"),
        }
    }
}

impl Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let cases: Vec<(ServiceError, &str)> = vec![
            (ServiceError::QueueClosed, "queue closed"),
            (ServiceError::UnknownOp("frob".into()), "frob"),
            (
                ServiceError::Arity { op: Op::Add22, want: 4, got: 3 },
                "wants 4 input planes, got 3",
            ),
            (
                ServiceError::RaggedPlanes { op: Op::Mul22, plane: 2, want: 16, got: 7 },
                "plane 2 has length 7",
            ),
            (ServiceError::EmptyBatch { op: Op::Add }, "zero-length batch"),
            (ServiceError::Shape("ragged".into()), "ragged"),
            (
                ServiceError::Unsupported { backend: "xla", op: Op::Mad22 },
                "does not serve",
            ),
            (ServiceError::Cancelled, "cancelled"),
            (ServiceError::DeadlineExceeded, "deadline"),
            (ServiceError::Backend("pjrt died".into()), "pjrt died"),
        ];
        for (e, needle) in cases {
            assert!(e.to_string().contains(needle), "{e}");
        }
    }

    #[test]
    fn implements_std_error() {
        fn takes_err(_: &dyn Error) {}
        takes_err(&ServiceError::QueueClosed);
        let boxed: Box<dyn Error> = Box::new(ServiceError::UnknownOp("x".into()));
        assert!(boxed.to_string().contains("unknown op"));
    }

    /// One representative per variant, every field populated with
    /// non-default values so a lossy decode cannot hide.
    fn wire_representatives() -> Vec<ServiceError> {
        vec![
            ServiceError::QueueClosed,
            ServiceError::UnknownOp("frob".into()),
            ServiceError::Arity { op: Op::Mad22, want: 6, got: 2 },
            ServiceError::RaggedPlanes { op: Op::Div22, plane: 3, want: 4096, got: 17 },
            ServiceError::EmptyBatch { op: Op::Split },
            ServiceError::Shape("output plane 1 has 5 lanes, want 9".into()),
            ServiceError::Unsupported { backend: "xla", op: Op::Mul22 },
            ServiceError::Cancelled,
            ServiceError::DeadlineExceeded,
            ServiceError::Backend("pjrt died: exit 3".into()),
        ]
    }

    #[test]
    fn wire_codes_are_stable_and_unique() {
        // the numbers themselves are the contract: renumbering breaks
        // every deployed client, so they are pinned here literally
        let expect: Vec<(u16, ServiceError)> = vec![
            (1, ServiceError::QueueClosed),
            (2, ServiceError::UnknownOp(String::new())),
            (3, ServiceError::Arity { op: Op::Add, want: 0, got: 0 }),
            (4, ServiceError::RaggedPlanes { op: Op::Add, plane: 0, want: 0, got: 0 }),
            (5, ServiceError::EmptyBatch { op: Op::Add }),
            (6, ServiceError::Shape(String::new())),
            (7, ServiceError::Unsupported { backend: "native", op: Op::Add }),
            (8, ServiceError::Cancelled),
            (9, ServiceError::DeadlineExceeded),
            (10, ServiceError::Backend(String::new())),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for (code, e) in expect {
            assert_eq!(e.to_code(), code, "{e:?}");
            assert!(seen.insert(code), "code {code} reused");
        }
    }

    #[test]
    fn from_code_round_trips_every_variant() {
        for e in wire_representatives() {
            let decoded = ServiceError::from_code(e.to_code(), &e.to_string());
            assert_eq!(decoded, Some(e.clone()), "via code {} / '{}'", e.to_code(), e);
        }
    }

    #[test]
    fn from_code_round_trips_every_op_in_structured_variants() {
        // the structured decoders re-parse op names out of the Display
        // grammar; sweep the whole catalogue so no op name (including
        // the digit-bearing ones like add12/mul22) confuses the parsers
        for op in Op::ALL {
            let cases = vec![
                ServiceError::Arity { op, want: op.n_in(), got: op.n_in() + 1 },
                ServiceError::RaggedPlanes { op, plane: 1, want: 8, got: 9 },
                ServiceError::EmptyBatch { op },
                ServiceError::Unsupported { backend: "gpusim", op },
            ];
            for e in cases {
                assert_eq!(
                    ServiceError::from_code(e.to_code(), &e.to_string()),
                    Some(e.clone()),
                    "{e}"
                );
            }
        }
    }

    #[test]
    fn from_code_rejects_unknown_codes() {
        assert_eq!(ServiceError::from_code(0, "protocol error"), None);
        assert_eq!(ServiceError::from_code(11, "future variant"), None);
        assert_eq!(ServiceError::from_code(u16::MAX, ""), None);
    }

    #[test]
    fn from_code_rejects_garbled_structured_messages() {
        // a structured code with a message that doesn't match the
        // grammar must fail typed (None), never panic or fabricate
        for code in [3u16, 4, 5, 7] {
            assert_eq!(ServiceError::from_code(code, ""), None, "code {code}");
            assert_eq!(ServiceError::from_code(code, "op 'nosuch' mangled"), None);
        }
    }

    #[test]
    fn unknown_backend_label_decodes_as_remote() {
        let e = ServiceError::Unsupported { backend: "remote", op: Op::Add22 };
        let weird = "backend 'fpga-farm-7' does not serve op 'add22'";
        assert_eq!(ServiceError::from_code(7, weird), Some(e));
    }
}
