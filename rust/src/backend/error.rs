//! [`ServiceError`]: the typed error surface of the serving stack.
//!
//! The seed coordinator reported everything as `String`, which meant
//! callers could neither distinguish "you sent a bad request" from "the
//! service is shutting down" nor use `?` against `std::error::Error`
//! consumers. Every layer above the kernels — backends, batcher,
//! coordinator, handles — now speaks this enum.

use super::op::Op;
use std::error::Error;
use std::fmt;

/// Typed error for the backend layer and the coordinator service.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The service (or one of its shards) has stopped; the submission
    /// queue or the reply channel is closed.
    QueueClosed,
    /// Operator name not in the catalogue (only [`Op::parse`] and the
    /// deprecated string entry points can produce this).
    UnknownOp(String),
    /// Wrong number of input planes for the operator.
    Arity { op: Op, want: usize, got: usize },
    /// Input plane `plane` has a different length than plane 0 — every
    /// plane of a request must have the same length.
    RaggedPlanes { op: Op, plane: usize, want: usize, got: usize },
    /// Zero-length batch: there is nothing to execute, and letting it
    /// through used to panic deep inside backends.
    EmptyBatch { op: Op },
    /// Mismatched output buffers or other shape violations not covered
    /// by the specific variants above.
    Shape(String),
    /// The operator is in the catalogue but this backend cannot serve it
    /// (e.g. no compiled artifact, no lowered program).
    Unsupported { backend: &'static str, op: Op },
    /// The request was cancelled ([`crate::coordinator::Ticket::cancel`])
    /// before a shard executed it.
    Cancelled,
    /// The request's deadline ([`crate::coordinator::Ticket::deadline`])
    /// passed before a reply arrived; the shard skips expired requests
    /// instead of burning backend time on them.
    DeadlineExceeded,
    /// Substrate failure: PJRT compile/execute error, stream-VM fault,
    /// worker-pool failure, missing artifacts directory, ...
    Backend(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueClosed => write!(f, "service stopped (queue closed)"),
            ServiceError::UnknownOp(op) => write!(f, "unknown op '{op}'"),
            ServiceError::Arity { op, want, got } => {
                write!(f, "op '{op}' wants {want} input planes, got {got}")
            }
            ServiceError::RaggedPlanes { op, plane, want, got } => {
                write!(
                    f,
                    "op '{op}': input plane {plane} has length {got}, \
                     expected {want} (ragged planes)"
                )
            }
            ServiceError::EmptyBatch { op } => {
                write!(f, "op '{op}': zero-length batch")
            }
            ServiceError::Shape(msg) => write!(f, "bad shape: {msg}"),
            ServiceError::Unsupported { backend, op } => {
                write!(f, "backend '{backend}' does not serve op '{op}'")
            }
            ServiceError::Cancelled => write!(f, "request cancelled"),
            ServiceError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServiceError::Backend(msg) => write!(f, "backend failure: {msg}"),
        }
    }
}

impl Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let cases: Vec<(ServiceError, &str)> = vec![
            (ServiceError::QueueClosed, "queue closed"),
            (ServiceError::UnknownOp("frob".into()), "frob"),
            (
                ServiceError::Arity { op: Op::Add22, want: 4, got: 3 },
                "wants 4 input planes, got 3",
            ),
            (
                ServiceError::RaggedPlanes { op: Op::Mul22, plane: 2, want: 16, got: 7 },
                "plane 2 has length 7",
            ),
            (ServiceError::EmptyBatch { op: Op::Add }, "zero-length batch"),
            (ServiceError::Shape("ragged".into()), "ragged"),
            (
                ServiceError::Unsupported { backend: "xla", op: Op::Mad22 },
                "does not serve",
            ),
            (ServiceError::Cancelled, "cancelled"),
            (ServiceError::DeadlineExceeded, "deadline"),
            (ServiceError::Backend("pjrt died".into()), "pjrt died"),
        ];
        for (e, needle) in cases {
            assert!(e.to_string().contains(needle), "{e}");
        }
    }

    #[test]
    fn implements_std_error() {
        fn takes_err(_: &dyn Error) {}
        takes_err(&ServiceError::QueueClosed);
        let boxed: Box<dyn Error> = Box::new(ServiceError::UnknownOp("x".into()));
        assert!(boxed.to_string().contains("unknown op"));
    }
}
