//! [`Op`]: the operator catalogue as a type.
//!
//! The seed service was stringly typed end to end — `submit("add22",
//! ...)` → `HashMap`-style name lookup in the coordinator → another
//! lookup in every backend. This enum makes the catalogue a closed set:
//! arity and plane count are encoded per variant, an unknown operator
//! is unrepresentable past [`Op::parse`], and backends dispatch on a
//! `Copy` value instead of comparing strings on the hot path.
//!
//! The variant order is load-bearing: `Op::ALL[op.index()] == op`, and
//! [`crate::backend::CATALOG`] mirrors the same order (pinned by a
//! test), so `op.index()` doubles as a catalogue row index — the
//! op-affinity routing policy hashes on it.
//!
//! # Examples
//!
//! ```
//! use ffgpu::backend::Op;
//!
//! // the parse boundary: wire names in, typed operators out
//! let op = Op::parse("mul22")?;
//! assert_eq!(op, Op::Mul22);
//! assert_eq!(op.arity(), (4, 2));
//! assert_eq!(Op::ALL[op.index()], op);
//! // shape rules live on the type: four equal-length planes or bust
//! assert!(op.validate_planes(&vec![vec![1.0f32; 8]; 4]).is_ok());
//! assert!(op.validate_planes(&vec![vec![1.0f32; 8]; 3]).is_err());
//! # Ok::<(), ffgpu::backend::ServiceError>(())
//! ```

use super::error::ServiceError;
use std::fmt;
use std::str::FromStr;

/// One float-float operator of the paper's catalogue (plus the `f32`
/// baseline ops), with arity and plane counts encoded in the type.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(usize)]
pub enum Op {
    /// Error-free addition of two `f32` (Knuth): 2 planes in, (hi, lo) out.
    Add12 = 0,
    /// Dekker split of one `f32` into high/low parts.
    Split,
    /// Error-free product of two `f32`.
    Mul12,
    /// Float-float addition: (ah, al, bh, bl) -> (hi, lo).
    Add22,
    /// Float-float multiplication.
    Mul22,
    /// Float-float division.
    Div22,
    /// Float-float multiply-add (§7 extension): 6 planes in.
    Mad22,
    /// Plain `f32` addition (the paper's timing baseline).
    Add,
    /// Plain `f32` multiplication.
    Mul,
    /// Plain `f32` multiply-add.
    Mad,
}

impl Op {
    /// Every operator, in catalogue order (`ALL[op.index()] == op`).
    pub const ALL: [Op; 10] = [
        Op::Add12,
        Op::Split,
        Op::Mul12,
        Op::Add22,
        Op::Mul22,
        Op::Div22,
        Op::Mad22,
        Op::Add,
        Op::Mul,
        Op::Mad,
    ];

    /// Number of operators in the catalogue.
    pub const COUNT: usize = Self::ALL.len();

    /// Wire/CLI name, identical to the seed's string keys.
    pub const fn name(self) -> &'static str {
        match self {
            Op::Add12 => "add12",
            Op::Split => "split",
            Op::Mul12 => "mul12",
            Op::Add22 => "add22",
            Op::Mul22 => "mul22",
            Op::Div22 => "div22",
            Op::Mad22 => "mad22",
            Op::Add => "add",
            Op::Mul => "mul",
            Op::Mad => "mad",
        }
    }

    /// Number of SoA input planes.
    pub const fn n_in(self) -> usize {
        match self {
            Op::Split => 1,
            Op::Add12 | Op::Mul12 | Op::Add | Op::Mul => 2,
            Op::Mad => 3,
            Op::Add22 | Op::Mul22 | Op::Div22 => 4,
            Op::Mad22 => 6,
        }
    }

    /// Number of SoA output planes.
    pub const fn n_out(self) -> usize {
        match self {
            Op::Add | Op::Mul | Op::Mad => 1,
            _ => 2,
        }
    }

    /// `(n_in, n_out)` — the tuple form the harnesses grew up on.
    pub const fn arity(self) -> (usize, usize) {
        (self.n_in(), self.n_out())
    }

    /// Catalogue row index (`Op::ALL[op.index()] == op`).
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Neutral pad value for input `plane`: 1.0 for the divisor high
    /// word of `div22` (so padding lanes never divide by zero), 0.0
    /// elsewhere.
    pub const fn pad_value(self, plane: usize) -> f32 {
        match (self, plane) {
            (Op::Div22, 2) => 1.0, // bh
            _ => 0.0,
        }
    }

    /// Parse a wire/CLI name; unknown names become
    /// [`ServiceError::UnknownOp`] — the only place that error can
    /// originate now.
    pub fn parse(name: &str) -> Result<Op, ServiceError> {
        Op::ALL
            .iter()
            .copied()
            .find(|o| o.name() == name)
            .ok_or_else(|| ServiceError::UnknownOp(name.to_string()))
    }

    /// Validate SoA input planes against this operator's arity and
    /// shape rules; returns the batch length. **The** single source of
    /// those rules — build-time `Plan` validation and backend-side
    /// `execute` checks both call this, over owned planes
    /// (`&[Vec<f32>]`) or borrowed ones (`&[&[f32]]`):
    ///
    /// * wrong plane count → [`ServiceError::Arity`];
    /// * differing plane lengths → [`ServiceError::RaggedPlanes`]
    ///   naming the offending plane;
    /// * zero-length batch → [`ServiceError::EmptyBatch`].
    pub fn validate_planes<P: AsRef<[f32]>>(
        self, inputs: &[P],
    ) -> Result<usize, ServiceError> {
        if inputs.len() != self.n_in() {
            return Err(ServiceError::Arity {
                op: self,
                want: self.n_in(),
                got: inputs.len(),
            });
        }
        let n = inputs.first().map_or(0, |p| p.as_ref().len());
        for (i, p) in inputs.iter().enumerate() {
            if p.as_ref().len() != n {
                return Err(ServiceError::RaggedPlanes {
                    op: self,
                    plane: i,
                    want: n,
                    got: p.as_ref().len(),
                });
            }
        }
        if n == 0 {
            return Err(ServiceError::EmptyBatch { op: self });
        }
        Ok(n)
    }

    /// Whether this operator's kernel contains an exact product —
    /// the ops whose `BlockedFma` tier swaps Dekker's `two_prod` for
    /// the 2-flop FMA form ([`crate::ff::two_prod_fma`]). The baseline
    /// `mad` is *not* in this set: it is deliberately two-rounding in
    /// every tier.
    pub const fn uses_exact_product(self) -> bool {
        matches!(self, Op::Mul12 | Op::Mul22 | Op::Div22 | Op::Mad22)
    }

    /// Catalogue row ([`crate::backend::OpSpec`]) for this operator.
    pub fn spec(self) -> &'static super::OpSpec {
        &super::CATALOG[self.index()]
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Op {
    type Err = ServiceError;

    fn from_str(s: &str) -> Result<Op, ServiceError> {
        Op::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_is_in_index_order_and_roundtrips_names() {
        for (i, op) in Op::ALL.into_iter().enumerate() {
            assert_eq!(op.index(), i, "{op}");
            assert_eq!(Op::parse(op.name()).unwrap(), op);
            assert_eq!(op.name().parse::<Op>().unwrap(), op);
            assert_eq!(format!("{op}"), op.name());
        }
        assert_eq!(Op::COUNT, 10);
    }

    #[test]
    fn arities_match_the_paper_catalogue() {
        assert_eq!(Op::Add12.arity(), (2, 2));
        assert_eq!(Op::Split.arity(), (1, 2));
        assert_eq!(Op::Mul12.arity(), (2, 2));
        assert_eq!(Op::Add22.arity(), (4, 2));
        assert_eq!(Op::Mul22.arity(), (4, 2));
        assert_eq!(Op::Div22.arity(), (4, 2));
        assert_eq!(Op::Mad22.arity(), (6, 2));
        assert_eq!(Op::Add.arity(), (2, 1));
        assert_eq!(Op::Mul.arity(), (2, 1));
        assert_eq!(Op::Mad.arity(), (3, 1));
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        assert!(matches!(
            Op::parse("frobnicate"),
            Err(ServiceError::UnknownOp(s)) if s == "frobnicate"
        ));
        assert!("".parse::<Op>().is_err());
    }

    #[test]
    fn exact_product_set_matches_kernels() {
        let want = [Op::Mul12, Op::Mul22, Op::Div22, Op::Mad22];
        for op in Op::ALL {
            assert_eq!(op.uses_exact_product(), want.contains(&op), "{op}");
        }
    }

    #[test]
    fn div22_pads_divisor_high_word_with_one() {
        assert_eq!(Op::Div22.pad_value(2), 1.0);
        assert_eq!(Op::Div22.pad_value(3), 0.0);
        assert_eq!(Op::Add22.pad_value(2), 0.0);
    }
}
