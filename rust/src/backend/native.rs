//! [`NativeBackend`]: the `ff::vector` SoA kernels, multicore with a
//! **persistent** worker crew.
//!
//! The seed served the native path single-threaded from the device
//! loop; PR 1 parallelised it with a scoped-thread pool spawned and
//! joined inside every `execute` (tens of µs of spawn/join per batch —
//! exactly the launch overhead the paper's long packed streams exist to
//! amortise). This revision removes that per-batch cost: workers are
//! spawned **once**, at backend construction, and fed chunk jobs over a
//! channel. No `thread::scope` remains on the execute hot path.
//!
//! What makes that possible is the owned-buffer job model
//! ([`crate::backend::ExecJob`]): input planes live behind `Arc`s, so a
//! chunk job can ride the channel into a long-lived worker (a scoped
//! borrow could never leave the `execute` call). Each worker computes
//! its chunk into buffers taken from *its own* arena
//! ([`crate::backend::WorkerArenas`] — no contention on a shared pool)
//! and reports `(output range, chunk planes)` back; the execute call
//! assembles the ranges into the caller's output planes and returns the
//! chunk buffers to the arena they came from. Elementwise kernels make
//! the chunking exact — lane `i` of every output depends only on lane
//! `i` of every input, so chunked results are bit-identical to one
//! sweep, and the assembly is a straight `copy_from_slice` per range.
//!
//! Small batches (under two chunks) skip the crew entirely: a channel
//! round-trip costs more than the kernel at that size.
//!
//! Kernels themselves are tiered ([`crate::ff::simd::KernelTier`]):
//! the tier is resolved **once**, at construction (explicit spec >
//! `FFGPU_KERNEL_TIER` > CPU detection), stored on the backend, and
//! rides every [`ChunkJob`] into the crew — both the serial path and
//! every worker run the *same* tier, so chunking never mixes kernels.
//! The chunk size is likewise configurable (`chunk == 0` picks an
//! L2-sized block per worker), keeping the lane-blocked kernels
//! cache-resident.

use super::pool::WorkerArenas;
use super::{
    check_outputs, BackendStats, ExecJob, ExecReport, KernelBackend, Op, ServiceError,
};
use crate::ff::simd::{self, KernelTier};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Fixed fallback chunk: 16k lanes ≈ 64 KiB per plane. Kept for callers
/// that want a deterministic size; specs default to `0` = auto, which
/// sizes a chunk to the machine's L2 instead ([`auto_chunk`]).
pub const DEFAULT_CHUNK: usize = 16 * 1024;

/// Floor on the chunk size; below this the queue overhead dominates.
const MIN_CHUNK: usize = 1024;

/// Ceiling on the auto-sized chunk: past ~1 MiB per plane the block no
/// longer fits any L2 and splitting finer only helps parallelism.
const MAX_CHUNK: usize = 256 * 1024;

/// One chunk of a batch, dispatched to a persistent worker: shared
/// input planes plus the per-chunk output range `[start, start + len)`
/// this job covers.
struct ChunkJob {
    op: Op,
    /// Kernel tier the owning backend resolved at construction.
    tier: KernelTier,
    inputs: Vec<Arc<Vec<f32>>>,
    start: usize,
    len: usize,
    /// Completion channel of the batch this chunk belongs to.
    done: mpsc::Sender<ChunkResult>,
}

/// A computed chunk on its way back to the batch assembler.
struct ChunkResult {
    start: usize,
    /// Which arena the output buffers must return to.
    worker: usize,
    outs: Vec<Vec<f32>>,
    err: Option<String>,
}

/// The standing crew: one shared job queue, N long-lived threads,
/// per-worker buffer arenas. Dropping it disconnects the queue and
/// joins every worker.
struct WorkerPool {
    /// `Some` for the pool's whole life; taken in `drop` so the queue
    /// disconnects before the joins.
    job_tx: Option<mpsc::Sender<ChunkJob>>,
    arenas: Arc<WorkerArenas>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads; `None` when one worker (or fewer) is
    /// requested — the serial path needs no crew. Spawn failures
    /// degrade to however many threads came up.
    fn spawn(workers: usize) -> Option<WorkerPool> {
        if workers <= 1 {
            return None;
        }
        let (job_tx, job_rx) = mpsc::channel::<ChunkJob>();
        let queue = Arc::new(Mutex::new(job_rx));
        let arenas = Arc::new(WorkerArenas::new(workers));
        let mut handles = Vec::with_capacity(workers);
        for me in 0..workers {
            let (q, a) = (queue.clone(), arenas.clone());
            match std::thread::Builder::new()
                .name(format!("ffgpu-native-worker-{me}"))
                .spawn(move || worker_main(me, q, a))
            {
                Ok(h) => handles.push(h),
                Err(_) => break,
            }
        }
        if handles.is_empty() {
            return None;
        }
        Some(WorkerPool { job_tx: Some(job_tx), arenas, handles })
    }

    fn size(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // dropping the sender disconnects the queue; each worker's recv
        // errors out and its loop exits
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A worker's whole life: pull a chunk job, compute it into buffers
/// from this worker's arena, report the range back, repeat until the
/// queue disconnects.
fn worker_main(
    me: usize, queue: Arc<Mutex<mpsc::Receiver<ChunkJob>>>, arenas: Arc<WorkerArenas>,
) {
    loop {
        // the lock is held across the blocking recv: idle workers queue
        // on the mutex and each arriving job wakes exactly one of them
        let job = match queue.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break,
        };
        let Ok(ChunkJob { op, tier, inputs, start, len, done }) = job else { break };
        let ins: Vec<&[f32]> = inputs.iter().map(|p| &p[start..start + len]).collect();
        let mut outs: Vec<Vec<f32>> =
            (0..op.n_out()).map(|_| arenas.take(me, len)).collect();
        let err = {
            let mut windows: Vec<&mut [f32]> =
                outs.iter_mut().map(|v| v.as_mut_slice()).collect();
            simd::dispatch_slices(tier, op.name(), &ins, &mut windows).err()
        };
        drop(ins);
        // release the Arc clones *before* signalling completion, so a
        // caller that drains all chunk results can reclaim its gather
        // buffers through `Arc::try_unwrap` immediately
        drop(inputs);
        let _ = done.send(ChunkResult { start, worker: me, outs, err });
    }
}

/// Native CPU backend: chunked execution over a persistent channel-fed
/// worker crew.
pub struct NativeBackend {
    chunk: usize,
    tier: KernelTier,
    /// `None` in single-worker (serial) mode.
    pool: Option<WorkerPool>,
    stats: BackendStats,
}

impl NativeBackend {
    /// `workers == 0` selects one worker per available core; `1` is the
    /// serial (seed-comparable) mode with no crew at all. `chunk == 0`
    /// picks an L2-sized chunk; the kernel tier comes from
    /// [`KernelTier::resolve`] (env var, then CPU detection).
    pub fn new(chunk: usize, workers: usize) -> NativeBackend {
        NativeBackend::with_tier(chunk, workers, None)
    }

    /// [`Self::new`] with an explicit kernel tier (`None` = resolve via
    /// `FFGPU_KERNEL_TIER` / CPU detection). Forcing a tier the host
    /// cannot run fast is allowed — results stay bit-correct.
    pub fn with_tier(
        chunk: usize, workers: usize, tier: Option<KernelTier>,
    ) -> NativeBackend {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let chunk = if chunk == 0 { auto_chunk() } else { chunk.max(MIN_CHUNK) };
        NativeBackend {
            chunk,
            tier: KernelTier::resolve(tier),
            pool: WorkerPool::spawn(workers),
            stats: BackendStats::default(),
        }
    }

    /// Live worker threads (1 in serial mode).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map_or(1, WorkerPool::size)
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// The kernel tier every chunk of every batch runs on.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Chunk buffers currently parked across the worker arenas (0 in
    /// serial mode) — observability for the arena recycling path.
    pub fn idle_buffers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.arenas.idle())
    }
}

impl KernelBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn ops(&self) -> Vec<Op> {
        Op::ALL.to_vec()
    }

    fn execute(
        &mut self, job: &ExecJob, outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let n = check_outputs("native", job, outputs)?;
        let t0 = Instant::now();
        let chunks = n.div_ceil(self.chunk);
        // parallel only from two *full* chunks up (a batch barely past
        // one chunk would ship a degenerate tail job through the crew)
        let launches = match &self.pool {
            Some(pool) if n >= self.chunk * 2 => {
                let tx = pool.job_tx.as_ref().expect("queue lives as long as the pool");
                let (done_tx, done_rx) = mpsc::channel::<ChunkResult>();
                let mut start = 0usize;
                while start < n {
                    let len = self.chunk.min(n - start);
                    tx.send(ChunkJob {
                        op: job.op(),
                        tier: self.tier,
                        inputs: job.inputs().to_vec(),
                        start,
                        len,
                        done: done_tx.clone(),
                    })
                    .map_err(|_| {
                        ServiceError::Backend("native worker crew is gone".into())
                    })?;
                    start += len;
                }
                drop(done_tx);
                // assemble the per-chunk output ranges; keep draining
                // even after a failure so every buffer returns home
                let mut failure: Option<String> = None;
                for _ in 0..chunks {
                    let Ok(res) = done_rx.recv() else {
                        failure
                            .get_or_insert_with(|| "native worker died mid-batch".into());
                        break;
                    };
                    match res.err {
                        Some(e) => {
                            failure.get_or_insert(e);
                        }
                        None => {
                            for (o, plane) in outputs.iter_mut().enumerate() {
                                plane[res.start..res.start + res.outs[o].len()]
                                    .copy_from_slice(&res.outs[o]);
                            }
                        }
                    }
                    for b in res.outs {
                        pool.arenas.put(res.worker, b);
                    }
                }
                if let Some(e) = failure {
                    return Err(ServiceError::Backend(e));
                }
                chunks
            }
            // small batches (or serial mode) run inline: a channel
            // round-trip costs more than the kernel at this size
            _ => {
                let ins = job.input_refs();
                simd::dispatch(self.tier, job.op().name(), &ins, outputs)
                    .map_err(ServiceError::Backend)?;
                1
            }
        };
        self.stats.executions += 1;
        self.stats.elements += n as u64;
        self.stats.busy_seconds += t0.elapsed().as_secs_f64();
        Ok(ExecReport { launches, padded_elements: 0 })
    }

    fn kernel_tier(&self) -> Option<KernelTier> {
        Some(self.tier)
    }

    fn stats(&self) -> BackendStats {
        self.stats
    }
}

/// Chunk lanes sized so one chunk's working set (inputs + outputs,
/// ~8 planes × 4 bytes for the widest op) fills about 3/4 of the L2
/// cache, rounded to a [`MIN_CHUNK`] multiple and clamped to
/// `[MIN_CHUNK, MAX_CHUNK]`. Falls back to [`DEFAULT_CHUNK`] territory
/// (512 KiB assumed L2) when the cache size cannot be read.
fn auto_chunk() -> usize {
    let l2 = detect_l2_bytes().unwrap_or(512 * 1024);
    let lanes = (l2 / 4 * 3) / 32; // 3/4 of L2, 32 B/lane working set
    (lanes / MIN_CHUNK * MIN_CHUNK).clamp(MIN_CHUNK, MAX_CHUNK)
}

/// L2 data-cache size of cpu0 via sysfs (Linux; `None` elsewhere —
/// there is no portable std API for cache geometry).
fn detect_l2_bytes() -> Option<usize> {
    if cfg!(target_os = "linux") {
        let s =
            std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cache/index2/size")
                .ok()?;
        parse_cache_size(s.trim())
    } else {
        None
    }
}

/// Parse sysfs cache sizes: `"512K"`, `"1M"`, `"1024"` (bytes).
fn parse_cache_size(s: &str) -> Option<usize> {
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload;

    fn run(backend: &mut NativeBackend, op: Op, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let planes = workload::planes_for(op.name(), n, seed);
        let job = ExecJob::new(op, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; op.n_out()];
        backend.execute(&job, &mut outs).unwrap();
        outs
    }

    #[test]
    fn chunked_parallel_matches_single_sweep_bitwise() {
        let mut serial = NativeBackend::new(DEFAULT_CHUNK, 1);
        let mut parallel = NativeBackend::new(MIN_CHUNK, 4);
        for op in [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22, Op::Add] {
            // 9 full chunks + a ragged tail
            let n = MIN_CHUNK * 9 + 137;
            let a = run(&mut serial, op, n, 0xC0DE);
            let b = run(&mut parallel, op, n, 0xC0DE);
            for (pa, pb) in a.iter().zip(&b) {
                for i in 0..n {
                    assert_eq!(
                        pa[i].to_bits(),
                        pb[i].to_bits(),
                        "op={op} lane={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn persistent_workers_survive_consecutive_batches() {
        // the tentpole property: ONE crew serves many batches — no
        // spawn/join between them, answers stay bit-identical
        let mut serial = NativeBackend::new(DEFAULT_CHUNK, 1);
        let mut crew = NativeBackend::new(MIN_CHUNK, 4);
        let workers_before = crew.workers();
        for round in 0..4u64 {
            let n = MIN_CHUNK * (3 + round as usize) + 41 * round as usize;
            let a = run(&mut serial, Op::Mul22, n, 0xBEE5 + round);
            let b = run(&mut crew, Op::Mul22, n, 0xBEE5 + round);
            for i in 0..n {
                assert_eq!(
                    (a[0][i].to_bits(), a[1][i].to_bits()),
                    (b[0][i].to_bits(), b[1][i].to_bits()),
                    "round={round} lane={i}"
                );
            }
        }
        assert_eq!(crew.workers(), workers_before, "crew changed size");
        let st = crew.stats();
        assert_eq!(st.executions, 4, "every batch went through the same backend");
        // chunk buffers were recycled into the worker arenas, not leaked
        assert!(crew.idle_buffers() > 0, "arenas never saw a buffer back");
    }

    #[test]
    fn parallel_path_reports_chunk_launches() {
        let mut b = NativeBackend::new(MIN_CHUNK, 4);
        let n = MIN_CHUNK * 4;
        let planes = workload::planes_for("add22", n, 3);
        let job = ExecJob::new(Op::Add22, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; 2];
        let rep = b.execute(&job, &mut outs).unwrap();
        assert_eq!(rep.launches, 4);
        assert_eq!(rep.padded_elements, 0);
        let st = b.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.elements, n as u64);
    }

    #[test]
    fn small_batches_take_the_serial_path() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 8);
        let planes = workload::planes_for("add22", 100, 5);
        let job = ExecJob::new(Op::Add22, planes).unwrap();
        let mut outs = vec![vec![0.0f32; 100]; 2];
        let rep = b.execute(&job, &mut outs).unwrap();
        assert_eq!(rep.launches, 1);
        assert_eq!(b.idle_buffers(), 0, "serial path must not touch the arenas");
    }

    #[test]
    fn rejects_bad_output_buffers() {
        // input-shape errors die at ExecJob construction now; the
        // backend still rejects mismatched output buffers
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 2);
        assert!(matches!(
            ExecJob::new(Op::Add22, vec![vec![1.0f32; 8]; 2]),
            Err(ServiceError::Arity { .. })
        ));
        let job = ExecJob::new(Op::Add, vec![vec![1.0f32; 8]; 2]).unwrap();
        let mut wrong_count = vec![vec![0.0f32; 8]; 2];
        assert!(matches!(
            b.execute(&job, &mut wrong_count),
            Err(ServiceError::Shape(_))
        ));
        let mut wrong_len = vec![vec![0.0f32; 4]];
        assert!(matches!(
            b.execute(&job, &mut wrong_len),
            Err(ServiceError::Shape(_))
        ));
    }

    #[test]
    fn auto_worker_count_is_positive() {
        let b = NativeBackend::new(0, 0);
        assert!(b.workers() >= 1);
        assert!(b.chunk() >= MIN_CHUNK);
        assert!(b.supports(Op::Add22));
        assert_eq!(b.ops().len(), Op::COUNT);
    }

    #[test]
    fn forced_tiers_agree_bitwise_through_the_backend() {
        use crate::ff::simd::KernelTier;
        // the whole execute pipeline — chunking, crew, arenas — under
        // each tier must reproduce the scalar reference bit-for-bit
        let mut scalar = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
        for tier in [KernelTier::Blocked, KernelTier::BlockedFma] {
            let mut tiered = NativeBackend::with_tier(MIN_CHUNK, 4, Some(tier));
            assert_eq!(tiered.tier(), tier);
            assert_eq!(tiered.kernel_tier(), Some(tier));
            for op in [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22, Op::Mad] {
                let n = MIN_CHUNK * 5 + 77;
                let a = run(&mut scalar, op, n, 0xD00D);
                let b = run(&mut tiered, op, n, 0xD00D);
                for (pa, pb) in a.iter().zip(&b) {
                    for i in 0..n {
                        assert_eq!(
                            pa[i].to_bits(),
                            pb[i].to_bits(),
                            "tier={tier} op={op} lane={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn auto_chunk_is_sane() {
        let c = auto_chunk();
        assert!((MIN_CHUNK..=MAX_CHUNK).contains(&c), "auto chunk {c}");
        assert_eq!(c % MIN_CHUNK, 0, "auto chunk {c} not a MIN_CHUNK multiple");
        // chunk == 0 routes through auto sizing; explicit sizes clamp up
        assert_eq!(NativeBackend::new(0, 1).chunk(), c);
        assert_eq!(NativeBackend::new(17, 1).chunk(), MIN_CHUNK);
    }

    #[test]
    fn cache_size_parsing() {
        assert_eq!(parse_cache_size("512K"), Some(512 * 1024));
        assert_eq!(parse_cache_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_cache_size("2048k"), Some(2048 * 1024));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("big"), None);
    }

    #[test]
    fn execute_planes_convenience_matches_job_path() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 1);
        let planes = workload::planes_for("add", 64, 9);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut via_planes = vec![vec![0.0f32; 64]];
        b.execute_planes(Op::Add, &refs, &mut via_planes).unwrap();
        let via_job = run(&mut b, Op::Add, 64, 9);
        assert_eq!(via_planes[0], via_job[0]);
    }
}
