//! [`NativeBackend`]: the `ff::vector` SoA kernels, multicore.
//!
//! The seed served the native path single-threaded from the device
//! loop. This backend keeps the kernels bit-identical but executes a
//! batch in parallel over fixed-size chunks: output planes are split
//! into disjoint `&mut` windows, chunk jobs go into a shared queue, and
//! a scoped-thread worker pool drains it. Elementwise kernels make the
//! chunking exact — lane `i` of every output depends only on lane `i`
//! of every input, so chunked results are bit-identical to one sweep.
//!
//! Small batches (under two chunks) skip the pool entirely: thread
//! wake-up costs more than the kernel at that size.
//!
//! The pool is scoped per `execute` call (spawn + join each batch).
//! That costs tens of microseconds per large batch — acceptable next
//! to the ≥ 2-chunk kernel work it gates, and it keeps the backend
//! borrow-only (jobs hold `&mut` windows into the caller's planes, no
//! channels or owned buffers). A persistent worker pool fed by a
//! channel would shave that overhead; ROADMAP lists it under
//! "Backends & sharding".

use super::{check_shapes, BackendStats, ExecReport, KernelBackend, Op, ServiceError};
use crate::ff::vector;
use std::sync::Mutex;
use std::time::Instant;

/// Default chunk: 16k lanes ≈ 64 KiB per plane, L2-friendly and small
/// enough that a 4-chunk batch spreads over 4 cores.
pub const DEFAULT_CHUNK: usize = 16 * 1024;

/// Floor on the chunk size; below this the queue overhead dominates.
const MIN_CHUNK: usize = 1024;

/// Native CPU backend with a chunked scoped-thread worker pool.
pub struct NativeBackend {
    chunk: usize,
    workers: usize,
    stats: BackendStats,
}

/// One chunk of work: parallel input windows and disjoint output windows.
struct Job<'a> {
    ins: Vec<&'a [f32]>,
    outs: Vec<&'a mut [f32]>,
}

impl NativeBackend {
    /// `workers == 0` selects one worker per available core.
    pub fn new(chunk: usize, workers: usize) -> NativeBackend {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        NativeBackend {
            chunk: chunk.max(MIN_CHUNK),
            workers,
            stats: BackendStats::default(),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }
}

impl KernelBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn ops(&self) -> Vec<Op> {
        Op::ALL.to_vec()
    }

    fn execute(
        &mut self, op: Op, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let n = check_shapes("native", op, inputs, outputs)?;
        let t0 = Instant::now();
        let launches = if self.workers <= 1 || n < self.chunk * 2 {
            vector::dispatch(op.name(), inputs, outputs).map_err(ServiceError::Backend)?;
            1
        } else {
            // carve the batch into chunk jobs with disjoint output windows
            let mut jobs: Vec<Job> = Vec::with_capacity(n.div_ceil(self.chunk));
            let mut tails: Vec<&mut [f32]> =
                outputs.iter_mut().map(|v| v.as_mut_slice()).collect();
            let mut start = 0usize;
            while start < n {
                let len = self.chunk.min(n - start);
                let ins: Vec<&[f32]> =
                    inputs.iter().map(|p| &p[start..start + len]).collect();
                let mut outs = Vec::with_capacity(tails.len());
                for t in tails.iter_mut() {
                    let (head, rest) = std::mem::take(t).split_at_mut(len);
                    outs.push(head);
                    *t = rest;
                }
                jobs.push(Job { ins, outs });
                start += len;
            }
            let launches = jobs.len();
            let workers = self.workers.min(launches);
            let queue = Mutex::new(jobs);
            let failure: Mutex<Option<String>> = Mutex::new(None);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let job = queue.lock().unwrap().pop();
                        let Some(mut job) = job else { break };
                        if let Err(e) =
                            vector::dispatch_slices(op.name(), &job.ins, &mut job.outs)
                        {
                            *failure.lock().unwrap() = Some(e);
                            break;
                        }
                    });
                }
            });
            if let Some(e) = failure.into_inner().unwrap_or(None) {
                return Err(ServiceError::Backend(e));
            }
            launches
        };
        self.stats.executions += 1;
        self.stats.elements += n as u64;
        self.stats.busy_seconds += t0.elapsed().as_secs_f64();
        Ok(ExecReport { launches, padded_elements: 0 })
    }

    fn stats(&self) -> BackendStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload;

    fn run(backend: &mut NativeBackend, op: Op, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let planes = workload::planes_for(op.name(), n, seed);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; n]; op.n_out()];
        backend.execute(op, &refs, &mut outs).unwrap();
        outs
    }

    #[test]
    fn chunked_parallel_matches_single_sweep_bitwise() {
        let mut serial = NativeBackend::new(DEFAULT_CHUNK, 1);
        let mut parallel = NativeBackend::new(MIN_CHUNK, 4);
        for op in [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22, Op::Add] {
            // 9 full chunks + a ragged tail
            let n = MIN_CHUNK * 9 + 137;
            let a = run(&mut serial, op, n, 0xC0DE);
            let b = run(&mut parallel, op, n, 0xC0DE);
            for (pa, pb) in a.iter().zip(&b) {
                for i in 0..n {
                    assert_eq!(
                        pa[i].to_bits(),
                        pb[i].to_bits(),
                        "op={op} lane={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_path_reports_chunk_launches() {
        let mut b = NativeBackend::new(MIN_CHUNK, 4);
        let n = MIN_CHUNK * 4;
        let planes = workload::planes_for("add22", n, 3);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; n]; 2];
        let rep = b.execute(Op::Add22, &refs, &mut outs).unwrap();
        assert_eq!(rep.launches, 4);
        assert_eq!(rep.padded_elements, 0);
        let st = b.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.elements, n as u64);
    }

    #[test]
    fn small_batches_take_the_serial_path() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 8);
        let planes = workload::planes_for("add22", 100, 5);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; 100]; 2];
        let rep = b.execute(Op::Add22, &refs, &mut outs).unwrap();
        assert_eq!(rep.launches, 1);
    }

    #[test]
    fn rejects_bad_calls() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 2);
        let a = vec![1.0f32; 8];
        let ins: Vec<&[f32]> = vec![&a, &a];
        let mut outs = vec![vec![0.0f32; 8]];
        assert!(matches!(
            b.execute(Op::Add22, &ins, &mut outs),
            Err(ServiceError::Arity { .. })
        ));
        let mut wrong = vec![vec![0.0f32; 8]; 2];
        assert!(matches!(
            b.execute(Op::Add, &ins, &mut wrong),
            Err(ServiceError::Shape(_))
        ));
    }

    #[test]
    fn auto_worker_count_is_positive() {
        let b = NativeBackend::new(0, 0);
        assert!(b.workers() >= 1);
        assert!(b.chunk() >= MIN_CHUNK);
        assert!(b.supports(Op::Add22));
        assert_eq!(b.ops().len(), Op::COUNT);
    }
}
