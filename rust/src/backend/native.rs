//! [`NativeBackend`]: the `ff::vector` SoA kernels, multicore with a
//! **persistent** worker crew.
//!
//! The seed served the native path single-threaded from the device
//! loop; PR 1 parallelised it with a scoped-thread pool spawned and
//! joined inside every `execute` (tens of µs of spawn/join per batch —
//! exactly the launch overhead the paper's long packed streams exist to
//! amortise). This revision removes that per-batch cost: workers are
//! spawned **once**, at backend construction, and fed chunk jobs over a
//! channel. No `thread::scope` remains on the execute hot path.
//!
//! What makes that possible is the owned-buffer job model
//! ([`crate::backend::ExecJob`]): input planes live behind `Arc`s, so a
//! chunk job can ride the channel into a long-lived worker (a scoped
//! borrow could never leave the `execute` call). Each worker computes
//! its chunk into buffers taken from *its own* arena
//! ([`crate::backend::WorkerArenas`] — no contention on a shared pool)
//! and reports `(output range, chunk planes)` back; the execute call
//! assembles the ranges into the caller's output planes and returns the
//! chunk buffers to the arena they came from. Elementwise kernels make
//! the chunking exact — lane `i` of every output depends only on lane
//! `i` of every input, so chunked results are bit-identical to one
//! sweep, and the assembly is a straight `copy_from_slice` per range.
//!
//! Small batches (under two chunks) skip the crew entirely: a channel
//! round-trip costs more than the kernel at that size.
//!
//! Kernels themselves are tiered ([`crate::ff::simd::KernelTier`]):
//! the tier is resolved **once**, at construction (explicit spec >
//! `FFGPU_KERNEL_TIER` > CPU detection), stored on the backend, and
//! rides every [`ChunkJob`] into the crew — both the serial path and
//! every worker run the *same* tier, so chunking never mixes kernels.
//! The chunk size is likewise configurable (`chunk == 0` picks an
//! L2-sized block per worker), keeping the lane-blocked kernels
//! cache-resident.
//!
//! Since the NUMA revision the same crew is also the coordinator's
//! **staging engine**: the channel carries [`WorkerJob`]s — execute
//! chunks as before, plus [`GatherJob`]s (one per input plane: gather a
//! launch window from request planes into an arena buffer) and
//! [`ScatterJob`]s (slice executed launches back into per-request
//! output planes, sharded by request range). Gather buffers come from
//! the gathering worker's own arena, so on a pinned crew every staging
//! page is first-touched on the owning node and, because buffers only
//! ever return to the arena they came from
//! ([`KernelBackend::stage_reclaim`]), never migrates off it. A spec
//! `node` pins the constructing thread (the shard thread builds its
//! backend on-thread) and every worker via
//! [`super::topology::pin_current_thread`]; unknown nodes and
//! single-node hosts degrade to no pinning.

use super::pool::WorkerArenas;
use super::topology::{self, Topology};
use super::{
    check_outputs, BackendStats, ExecJob, ExecReport, KernelBackend, LaunchOut, Op,
    ServiceError,
};
use crate::ff::simd::{self, KernelTier};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Fixed fallback chunk: 16k lanes ≈ 64 KiB per plane. Kept for callers
/// that want a deterministic size; specs default to `0` = auto, which
/// sizes a chunk to the machine's L2 instead ([`auto_chunk`]).
pub const DEFAULT_CHUNK: usize = 16 * 1024;

/// Floor on the chunk size; below this the queue overhead dominates.
const MIN_CHUNK: usize = 1024;

/// Ceiling on the auto-sized chunk: past ~1 MiB per plane the block no
/// longer fits any L2 and splitting finer only helps parallelism.
const MAX_CHUNK: usize = 256 * 1024;

/// One chunk of a batch, dispatched to a persistent worker: shared
/// input planes plus the per-chunk output range `[start, start + len)`
/// this job covers.
struct ChunkJob {
    op: Op,
    /// Kernel tier the owning backend resolved at construction.
    tier: KernelTier,
    inputs: Vec<Arc<Vec<f32>>>,
    start: usize,
    len: usize,
    /// Completion channel of the batch this chunk belongs to.
    done: mpsc::Sender<ChunkResult>,
}

/// A computed chunk on its way back to the batch assembler.
struct ChunkResult {
    start: usize,
    /// Which arena the output buffers must return to.
    worker: usize,
    outs: Vec<Vec<f32>>,
    err: Option<String>,
}

/// Gather one launch window of one input plane from per-request planes
/// into a buffer from the gathering worker's arena (node-local first
/// touch on a pinned crew).
struct GatherJob {
    /// Which input plane this job assembles.
    plane: usize,
    /// The op's pad value for this plane.
    pad: f32,
    /// Per-request planes in concatenation order.
    sources: Vec<Arc<Vec<f32>>>,
    /// Launch size (the buffer is padded up to it).
    size: usize,
    /// Window `[start, start + len)` of the concatenated batch.
    start: usize,
    len: usize,
    done: mpsc::Sender<GatherResult>,
}

/// A gathered plane: the buffer plus the arena it must return to.
struct GatherResult {
    plane: usize,
    worker: usize,
    buf: Vec<f32>,
}

/// Scatter a contiguous range of requests out of the executed launches:
/// the worker allocates the requests' output planes itself (node-local
/// first touch) and fills them from every overlapping launch window.
struct ScatterJob {
    /// All executed launches of the group, shared across scatter jobs.
    launches: Arc<Vec<LaunchOut>>,
    /// `(offset, len)` in the concatenated batch per request in this
    /// job's range.
    spans: Vec<(usize, usize)>,
    /// Index of the first request in the range (for reassembly order).
    first: usize,
    n_out: usize,
    done: mpsc::Sender<ScatterResult>,
}

struct ScatterResult {
    first: usize,
    /// `n_out` planes per request, in range order.
    planes: Vec<Vec<Vec<f32>>>,
}

/// Everything the crew's shared queue carries.
enum WorkerJob {
    Chunk(ChunkJob),
    Gather(GatherJob),
    Scatter(ScatterJob),
}

/// The standing crew: one shared job queue, N long-lived threads,
/// per-worker buffer arenas. Dropping it disconnects the queue and
/// joins every worker.
struct WorkerPool {
    /// `Some` for the pool's whole life; taken in `drop` so the queue
    /// disconnects before the joins.
    job_tx: Option<mpsc::Sender<WorkerJob>>,
    arenas: Arc<WorkerArenas>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads; `None` when one worker (or fewer) is
    /// requested — the serial path needs no crew. Spawn failures
    /// degrade to however many threads came up. When `cpus` is given,
    /// each worker pins itself to that CPU set *before* touching any
    /// memory, so its arena pages land on the owning node.
    fn spawn(workers: usize, cpus: Option<Arc<Vec<usize>>>) -> Option<WorkerPool> {
        if workers <= 1 {
            return None;
        }
        let (job_tx, job_rx) = mpsc::channel::<WorkerJob>();
        let queue = Arc::new(Mutex::new(job_rx));
        let arenas = Arc::new(WorkerArenas::new(workers));
        let mut handles = Vec::with_capacity(workers);
        for me in 0..workers {
            let (q, a, c) = (queue.clone(), arenas.clone(), cpus.clone());
            match std::thread::Builder::new()
                .name(format!("ffgpu-native-worker-{me}"))
                .spawn(move || {
                    if let Some(cpus) = &c {
                        topology::pin_current_thread(cpus);
                    }
                    worker_main(me, q, a)
                }) {
                Ok(h) => handles.push(h),
                Err(_) => break,
            }
        }
        if handles.is_empty() {
            return None;
        }
        Some(WorkerPool { job_tx: Some(job_tx), arenas, handles })
    }

    fn size(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // dropping the sender disconnects the queue; each worker's recv
        // errors out and its loop exits
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A worker's whole life: pull a job, run it, report back, repeat
/// until the queue disconnects.
fn worker_main(
    me: usize, queue: Arc<Mutex<mpsc::Receiver<WorkerJob>>>, arenas: Arc<WorkerArenas>,
) {
    loop {
        // the lock is held across the blocking recv: idle workers queue
        // on the mutex and each arriving job wakes exactly one of them
        let job = match queue.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break,
        };
        match job {
            Ok(WorkerJob::Chunk(job)) => run_chunk(me, job, &arenas),
            Ok(WorkerJob::Gather(job)) => run_gather(me, job, &arenas),
            Ok(WorkerJob::Scatter(job)) => run_scatter(job),
            Err(_) => break,
        }
    }
}

/// Compute one execute chunk into buffers from this worker's arena.
fn run_chunk(me: usize, job: ChunkJob, arenas: &WorkerArenas) {
    let ChunkJob { op, tier, inputs, start, len, done } = job;
    let ins: Vec<&[f32]> = inputs.iter().map(|p| &p[start..start + len]).collect();
    let mut outs: Vec<Vec<f32>> = (0..op.n_out()).map(|_| arenas.take(me, len)).collect();
    let err = {
        let mut windows: Vec<&mut [f32]> =
            outs.iter_mut().map(|v| v.as_mut_slice()).collect();
        simd::dispatch_slices(tier, op.name(), &ins, &mut windows).err()
    };
    drop(ins);
    // release the Arc clones *before* signalling completion, so a
    // caller that drains all chunk results can reclaim its gather
    // buffers through `Arc::try_unwrap` immediately
    drop(inputs);
    let _ = done.send(ChunkResult { start, worker: me, outs, err });
}

/// Gather one plane's launch window into an arena buffer.
fn run_gather(me: usize, job: GatherJob, arenas: &WorkerArenas) {
    let GatherJob { plane, pad, sources, size, start, len, done } = job;
    let mut buf = arenas.take_empty(me);
    gather_window_into(&sources, size, start, len, pad, &mut buf);
    // drop the source Arcs before reporting, mirroring run_chunk
    drop(sources);
    let _ = done.send(GatherResult { plane, worker: me, buf });
}

/// Gather the window `[start, start + len)` of the concatenation of
/// `sources` into `out`, padded to `size` lanes with `pad`.
///
/// This mirrors [`crate::coordinator::batcher::gather_plane_into`]
/// copy-for-copy (same walk, same `extend_from_slice` windows, same
/// `resize` padding), so the parallel stage is bit-identical to the
/// serial one by construction; the parity is pinned by tests here and
/// end-to-end in the coordinator.
pub fn gather_window_into(
    sources: &[Arc<Vec<f32>>], size: usize, start: usize, len: usize, pad: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(size);
    // walk the concatenated space [start, start+len)
    let mut skipped = 0usize;
    for s in sources {
        let rl = s.len();
        if skipped + rl <= start {
            skipped += rl;
            continue;
        }
        let from = start.saturating_sub(skipped);
        let need = (start + len).saturating_sub(skipped.max(start));
        let take = need.min(rl - from);
        out.extend_from_slice(&s[from..from + take]);
        skipped += rl;
        if out.len() >= len {
            break;
        }
    }
    debug_assert_eq!(out.len(), len);
    out.resize(size, pad);
}

/// Build and fill the output planes of one contiguous request range
/// from every overlapping launch window. Allocating the planes *here*
/// (not on the shard thread) is the point: a pinned worker
/// first-touches the reply pages on its own node.
fn run_scatter(job: ScatterJob) {
    let ScatterJob { launches, spans, first, n_out, done } = job;
    let mut planes = Vec::with_capacity(spans.len());
    for &(g, n) in &spans {
        let mut req_planes: Vec<Vec<f32>> = (0..n_out).map(|_| vec![0.0f32; n]).collect();
        for l in launches.iter() {
            // overlap of request [g, g+n) with launch window [start, start+len)
            let lo = g.max(l.start);
            let hi = (g + n).min(l.start + l.len);
            if lo >= hi {
                continue;
            }
            for (oi, plane) in req_planes.iter_mut().enumerate() {
                plane[lo - g..hi - g]
                    .copy_from_slice(&l.outs[oi][lo - l.start..hi - l.start]);
            }
        }
        planes.push(req_planes);
    }
    // drop our launch handle before reporting so the assembler can
    // reclaim the launch buffers via `Arc::try_unwrap` once every
    // scatter result is in
    drop(launches);
    let _ = done.send(ScatterResult { first, planes });
}

/// Native CPU backend: chunked execution over a persistent channel-fed
/// worker crew.
pub struct NativeBackend {
    chunk: usize,
    tier: KernelTier,
    /// NUMA node this backend (and its crew) is pinned to, if any.
    node: Option<usize>,
    /// `None` in single-worker (serial) mode.
    pool: Option<WorkerPool>,
    stats: BackendStats,
}

impl NativeBackend {
    /// `workers == 0` selects one worker per available core; `1` is the
    /// serial (seed-comparable) mode with no crew at all. `chunk == 0`
    /// picks an L2-sized chunk; the kernel tier comes from
    /// [`KernelTier::resolve`] (env var, then CPU detection).
    pub fn new(chunk: usize, workers: usize) -> NativeBackend {
        NativeBackend::with_tier(chunk, workers, None)
    }

    /// [`Self::new`] with an explicit kernel tier (`None` = resolve via
    /// `FFGPU_KERNEL_TIER` / CPU detection). Forcing a tier the host
    /// cannot run fast is allowed — results stay bit-correct.
    pub fn with_tier(
        chunk: usize, workers: usize, tier: Option<KernelTier>,
    ) -> NativeBackend {
        NativeBackend::with_placement(chunk, workers, tier, None)
    }

    /// [`Self::with_tier`] plus NUMA placement. `node: Some(n)` pins
    /// the **calling** thread (backends are built on the shard thread
    /// that owns them) and every crew worker to node `n`'s CPUs, so
    /// shard-thread pool buffers and worker arena buffers alike are
    /// first-touched on the owning node. An unknown node, a single-node
    /// host, or a refused syscall all degrade to no pinning; `None`
    /// performs no placement side effect at all.
    pub fn with_placement(
        chunk: usize, workers: usize, tier: Option<KernelTier>, node: Option<usize>,
    ) -> NativeBackend {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let chunk = if chunk == 0 { auto_chunk() } else { chunk.max(MIN_CHUNK) };
        let cpus: Option<Arc<Vec<usize>>> = node.and_then(|n| {
            Topology::detect().cpus_of(n).map(|c| Arc::new(c.to_vec()))
        });
        if let Some(cpus) = &cpus {
            topology::pin_current_thread(cpus);
        }
        NativeBackend {
            chunk,
            tier: KernelTier::resolve(tier),
            node,
            pool: WorkerPool::spawn(workers, cpus),
            stats: BackendStats::default(),
        }
    }

    /// Live worker threads (1 in serial mode).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map_or(1, WorkerPool::size)
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// The kernel tier every chunk of every batch runs on.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// The NUMA node this backend was asked to pin to (`None` =
    /// unpinned; pinning to an unknown node keeps the label but has no
    /// placement effect).
    pub fn node(&self) -> Option<usize> {
        self.node
    }

    /// Chunk buffers currently parked across the worker arenas (0 in
    /// serial mode) — observability for the arena recycling path.
    pub fn idle_buffers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.arenas.idle())
    }

    fn crew_tx(&self) -> Result<&mpsc::Sender<WorkerJob>, ServiceError> {
        let pool = self.pool.as_ref().ok_or_else(|| {
            ServiceError::Backend("native: no staging crew (workers <= 1)".into())
        })?;
        Ok(pool.job_tx.as_ref().expect("queue lives as long as the pool"))
    }
}

impl KernelBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn ops(&self) -> Vec<Op> {
        Op::ALL.to_vec()
    }

    fn execute(
        &mut self, job: &ExecJob, outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let n = check_outputs("native", job, outputs)?;
        let t0 = Instant::now();
        let chunks = n.div_ceil(self.chunk);
        // parallel only from two *full* chunks up (a batch barely past
        // one chunk would ship a degenerate tail job through the crew)
        let launches = match &self.pool {
            Some(pool) if n >= self.chunk * 2 => {
                let tx = pool.job_tx.as_ref().expect("queue lives as long as the pool");
                let (done_tx, done_rx) = mpsc::channel::<ChunkResult>();
                let mut start = 0usize;
                while start < n {
                    let len = self.chunk.min(n - start);
                    tx.send(WorkerJob::Chunk(ChunkJob {
                        op: job.op(),
                        tier: self.tier,
                        inputs: job.inputs().to_vec(),
                        start,
                        len,
                        done: done_tx.clone(),
                    }))
                    .map_err(|_| {
                        ServiceError::Backend("native worker crew is gone".into())
                    })?;
                    start += len;
                }
                drop(done_tx);
                // assemble the per-chunk output ranges; keep draining
                // even after a failure so every buffer returns home
                let mut failure: Option<String> = None;
                for _ in 0..chunks {
                    let Ok(res) = done_rx.recv() else {
                        failure
                            .get_or_insert_with(|| "native worker died mid-batch".into());
                        break;
                    };
                    match res.err {
                        Some(e) => {
                            failure.get_or_insert(e);
                        }
                        None => {
                            for (o, plane) in outputs.iter_mut().enumerate() {
                                plane[res.start..res.start + res.outs[o].len()]
                                    .copy_from_slice(&res.outs[o]);
                            }
                        }
                    }
                    for b in res.outs {
                        pool.arenas.put(res.worker, b);
                    }
                }
                if let Some(e) = failure {
                    return Err(ServiceError::Backend(e));
                }
                chunks
            }
            // small batches (or serial mode) run inline: a channel
            // round-trip costs more than the kernel at this size
            _ => {
                let ins = job.input_refs();
                simd::dispatch(self.tier, job.op().name(), &ins, outputs)
                    .map_err(ServiceError::Backend)?;
                1
            }
        };
        self.stats.executions += 1;
        self.stats.elements += n as u64;
        self.stats.busy_seconds += t0.elapsed().as_secs_f64();
        Ok(ExecReport { launches, padded_elements: 0 })
    }

    fn kernel_tier(&self) -> Option<KernelTier> {
        Some(self.tier)
    }

    fn staging_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, WorkerPool::size)
    }

    fn stage_gather(
        &mut self, op: Op, sources: &[Vec<Arc<Vec<f32>>>], size: usize, start: usize,
        len: usize,
    ) -> Result<Vec<(usize, Vec<f32>)>, ServiceError> {
        let tx = self.crew_tx()?;
        let n_in = sources.len();
        let (done_tx, done_rx) = mpsc::channel::<GatherResult>();
        for (plane, srcs) in sources.iter().enumerate() {
            tx.send(WorkerJob::Gather(GatherJob {
                plane,
                pad: op.pad_value(plane),
                sources: srcs.clone(),
                size,
                start,
                len,
                done: done_tx.clone(),
            }))
            .map_err(|_| ServiceError::Backend("native worker crew is gone".into()))?;
        }
        drop(done_tx);
        let mut planes: Vec<Option<(usize, Vec<f32>)>> = (0..n_in).map(|_| None).collect();
        for _ in 0..n_in {
            let Ok(res) = done_rx.recv() else {
                return Err(ServiceError::Backend("native worker died mid-gather".into()));
            };
            planes[res.plane] = Some((res.worker, res.buf));
        }
        Ok(planes
            .into_iter()
            .map(|p| p.expect("every gather job reports exactly one plane"))
            .collect())
    }

    fn stage_scatter(
        &mut self, launches: Vec<LaunchOut>, spans: &[(usize, usize)], n_out: usize,
    ) -> Result<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>), ServiceError> {
        let workers = self.staging_workers().max(1);
        let tx = self.crew_tx()?;
        let launches = Arc::new(launches);
        // shard the request list into one contiguous range per worker
        let jobs = workers.min(spans.len().max(1));
        let per = spans.len().div_ceil(jobs).max(1);
        let (done_tx, done_rx) = mpsc::channel::<ScatterResult>();
        let mut sent = 0usize;
        let mut first = 0usize;
        while first < spans.len() {
            let range = &spans[first..(first + per).min(spans.len())];
            tx.send(WorkerJob::Scatter(ScatterJob {
                launches: launches.clone(),
                spans: range.to_vec(),
                first,
                n_out,
                done: done_tx.clone(),
            }))
            .map_err(|_| ServiceError::Backend("native worker crew is gone".into()))?;
            sent += 1;
            first += range.len();
        }
        drop(done_tx);
        let mut results = Vec::with_capacity(sent);
        for _ in 0..sent {
            let Ok(res) = done_rx.recv() else {
                return Err(ServiceError::Backend("native worker died mid-scatter".into()));
            };
            results.push(res);
        }
        results.sort_by_key(|r| r.first);
        let planes: Vec<Vec<Vec<f32>>> =
            results.into_iter().flat_map(|r| r.planes).collect();
        // every worker dropped its launch handle before reporting, so
        // the unwrap succeeds and the launch buffers go home
        let reclaimed = match Arc::try_unwrap(launches) {
            Ok(ls) => ls.into_iter().flat_map(|l| l.outs).collect(),
            Err(_) => Vec::new(),
        };
        Ok((planes, reclaimed))
    }

    fn stage_reclaim(&mut self, worker: usize, buf: Vec<f32>) {
        if let Some(pool) = &self.pool {
            pool.arenas.put(worker, buf);
        }
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            arena_dropped: self.pool.as_ref().map_or(0, |p| p.arenas.dropped()),
            ..self.stats
        }
    }
}

/// Chunk lanes sized so one chunk's working set (inputs + outputs,
/// ~8 planes × 4 bytes for the widest op) fills about 3/4 of the L2
/// cache, rounded to a [`MIN_CHUNK`] multiple and clamped to
/// `[MIN_CHUNK, MAX_CHUNK]`. Falls back to [`DEFAULT_CHUNK`] territory
/// (512 KiB assumed L2) when the cache size cannot be read.
fn auto_chunk() -> usize {
    let l2 = topology::detect_cache_bytes(2).unwrap_or(512 * 1024);
    let lanes = (l2 / 4 * 3) / 32; // 3/4 of L2, 32 B/lane working set
    (lanes / MIN_CHUNK * MIN_CHUNK).clamp(MIN_CHUNK, MAX_CHUNK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload;

    fn run(backend: &mut NativeBackend, op: Op, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let planes = workload::planes_for(op.name(), n, seed);
        let job = ExecJob::new(op, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; op.n_out()];
        backend.execute(&job, &mut outs).unwrap();
        outs
    }

    #[test]
    fn chunked_parallel_matches_single_sweep_bitwise() {
        let mut serial = NativeBackend::new(DEFAULT_CHUNK, 1);
        let mut parallel = NativeBackend::new(MIN_CHUNK, 4);
        for op in [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22, Op::Add] {
            // 9 full chunks + a ragged tail
            let n = MIN_CHUNK * 9 + 137;
            let a = run(&mut serial, op, n, 0xC0DE);
            let b = run(&mut parallel, op, n, 0xC0DE);
            for (pa, pb) in a.iter().zip(&b) {
                for i in 0..n {
                    assert_eq!(
                        pa[i].to_bits(),
                        pb[i].to_bits(),
                        "op={op} lane={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn persistent_workers_survive_consecutive_batches() {
        // the tentpole property: ONE crew serves many batches — no
        // spawn/join between them, answers stay bit-identical
        let mut serial = NativeBackend::new(DEFAULT_CHUNK, 1);
        let mut crew = NativeBackend::new(MIN_CHUNK, 4);
        let workers_before = crew.workers();
        for round in 0..4u64 {
            let n = MIN_CHUNK * (3 + round as usize) + 41 * round as usize;
            let a = run(&mut serial, Op::Mul22, n, 0xBEE5 + round);
            let b = run(&mut crew, Op::Mul22, n, 0xBEE5 + round);
            for i in 0..n {
                assert_eq!(
                    (a[0][i].to_bits(), a[1][i].to_bits()),
                    (b[0][i].to_bits(), b[1][i].to_bits()),
                    "round={round} lane={i}"
                );
            }
        }
        assert_eq!(crew.workers(), workers_before, "crew changed size");
        let st = crew.stats();
        assert_eq!(st.executions, 4, "every batch went through the same backend");
        // chunk buffers were recycled into the worker arenas, not leaked
        assert!(crew.idle_buffers() > 0, "arenas never saw a buffer back");
    }

    #[test]
    fn parallel_path_reports_chunk_launches() {
        let mut b = NativeBackend::new(MIN_CHUNK, 4);
        let n = MIN_CHUNK * 4;
        let planes = workload::planes_for("add22", n, 3);
        let job = ExecJob::new(Op::Add22, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; 2];
        let rep = b.execute(&job, &mut outs).unwrap();
        assert_eq!(rep.launches, 4);
        assert_eq!(rep.padded_elements, 0);
        let st = b.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.elements, n as u64);
    }

    #[test]
    fn small_batches_take_the_serial_path() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 8);
        let planes = workload::planes_for("add22", 100, 5);
        let job = ExecJob::new(Op::Add22, planes).unwrap();
        let mut outs = vec![vec![0.0f32; 100]; 2];
        let rep = b.execute(&job, &mut outs).unwrap();
        assert_eq!(rep.launches, 1);
        assert_eq!(b.idle_buffers(), 0, "serial path must not touch the arenas");
    }

    #[test]
    fn rejects_bad_output_buffers() {
        // input-shape errors die at ExecJob construction now; the
        // backend still rejects mismatched output buffers
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 2);
        assert!(matches!(
            ExecJob::new(Op::Add22, vec![vec![1.0f32; 8]; 2]),
            Err(ServiceError::Arity { .. })
        ));
        let job = ExecJob::new(Op::Add, vec![vec![1.0f32; 8]; 2]).unwrap();
        let mut wrong_count = vec![vec![0.0f32; 8]; 2];
        assert!(matches!(
            b.execute(&job, &mut wrong_count),
            Err(ServiceError::Shape(_))
        ));
        let mut wrong_len = vec![vec![0.0f32; 4]];
        assert!(matches!(
            b.execute(&job, &mut wrong_len),
            Err(ServiceError::Shape(_))
        ));
    }

    #[test]
    fn auto_worker_count_is_positive() {
        let b = NativeBackend::new(0, 0);
        assert!(b.workers() >= 1);
        assert!(b.chunk() >= MIN_CHUNK);
        assert!(b.supports(Op::Add22));
        assert_eq!(b.ops().len(), Op::COUNT);
    }

    #[test]
    fn forced_tiers_agree_bitwise_through_the_backend() {
        use crate::ff::simd::KernelTier;
        // the whole execute pipeline — chunking, crew, arenas — under
        // each tier must reproduce the scalar reference bit-for-bit
        let mut scalar = NativeBackend::with_tier(1 << 20, 1, Some(KernelTier::Scalar));
        for tier in [KernelTier::Blocked, KernelTier::BlockedFma] {
            let mut tiered = NativeBackend::with_tier(MIN_CHUNK, 4, Some(tier));
            assert_eq!(tiered.tier(), tier);
            assert_eq!(tiered.kernel_tier(), Some(tier));
            for op in [Op::Add22, Op::Mul22, Op::Mul12, Op::Div22, Op::Mad22, Op::Mad] {
                let n = MIN_CHUNK * 5 + 77;
                let a = run(&mut scalar, op, n, 0xD00D);
                let b = run(&mut tiered, op, n, 0xD00D);
                for (pa, pb) in a.iter().zip(&b) {
                    for i in 0..n {
                        assert_eq!(
                            pa[i].to_bits(),
                            pb[i].to_bits(),
                            "tier={tier} op={op} lane={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn auto_chunk_is_sane() {
        let c = auto_chunk();
        assert!((MIN_CHUNK..=MAX_CHUNK).contains(&c), "auto chunk {c}");
        assert_eq!(c % MIN_CHUNK, 0, "auto chunk {c} not a MIN_CHUNK multiple");
        // chunk == 0 routes through auto sizing; explicit sizes clamp up
        assert_eq!(NativeBackend::new(0, 1).chunk(), c);
        assert_eq!(NativeBackend::new(17, 1).chunk(), MIN_CHUNK);
    }

    #[test]
    fn execute_planes_convenience_matches_job_path() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 1);
        let planes = workload::planes_for("add", 64, 9);
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut via_planes = vec![vec![0.0f32; 64]];
        b.execute_planes(Op::Add, &refs, &mut via_planes).unwrap();
        let via_job = run(&mut b, Op::Add, 64, 9);
        assert_eq!(via_planes[0], via_job[0]);
    }

    /// The obviously-correct serial gather: concatenate everything,
    /// slice the window, pad to size.
    fn ref_gather(
        sources: &[Arc<Vec<f32>>], size: usize, start: usize, len: usize, pad: f32,
    ) -> Vec<f32> {
        let mut all = Vec::new();
        for s in sources {
            all.extend_from_slice(s);
        }
        let mut out = all[start..start + len].to_vec();
        out.resize(size, pad);
        out
    }

    #[test]
    fn staged_gather_matches_serial_reference_bitwise() {
        let mut b = NativeBackend::new(MIN_CHUNK, 4);
        // request planes of awkward lengths straddling chunk seams
        let lens = [3usize, MIN_CHUNK, 137, MIN_CHUNK * 2 + 1, 1];
        let op = Op::Div22; // pad values differ per plane
        let n_in = op.n_in();
        let mut sources: Vec<Vec<Arc<Vec<f32>>>> = vec![Vec::new(); n_in];
        for (ri, &l) in lens.iter().enumerate() {
            let planes = workload::planes_for(op.name(), l, 7 + ri as u64);
            for (p, plane) in planes.into_iter().enumerate() {
                sources[p].push(Arc::new(plane));
            }
        }
        let total: usize = lens.iter().sum();
        // windows straddling request seams, all with pad lanes or
        // awkward starts; the last one ends mid-batch with padding
        let windows = [
            (total.next_power_of_two(), 0usize, total),
            (MIN_CHUNK, 2, MIN_CHUNK),
            (256, MIN_CHUNK + 100, 256),
            (512, total - 300, 300),
        ];
        for &(size, start, len) in &windows {
            let got = b.stage_gather(op, &sources, size, start, len).unwrap();
            assert_eq!(got.len(), n_in);
            for (plane, (worker, buf)) in got.into_iter().enumerate() {
                let want =
                    ref_gather(&sources[plane], size, start, len, op.pad_value(plane));
                assert_eq!(buf.len(), size);
                for i in 0..size {
                    assert_eq!(
                        buf[i].to_bits(),
                        want[i].to_bits(),
                        "plane={plane} lane={i} window=({size},{start},{len})"
                    );
                }
                b.stage_reclaim(worker, buf);
            }
        }
        assert!(b.idle_buffers() > 0, "gather buffers went back to the arenas");
    }

    #[test]
    fn staged_scatter_reassembles_requests_bitwise() {
        let mut b = NativeBackend::new(MIN_CHUNK, 3);
        // five requests with awkward spans, covered by three launches
        // with padded tails; request 3 straddles both launch seams
        let lens = [5usize, 700, 64, 1200, 31];
        let total: usize = lens.iter().sum();
        let mut spans = Vec::new();
        let mut off = 0usize;
        for &l in &lens {
            spans.push((off, l));
            off += l;
        }
        let reference: Vec<Vec<f32>> = (0..2)
            .map(|o| (0..total).map(|i| (o * 1_000_000 + i) as f32).collect())
            .collect();
        let cuts = [(0usize, 1000usize, 1024usize), (1000, 900, 1024), (1900, 100, 2048)];
        let launches: Vec<LaunchOut> = cuts
            .iter()
            .map(|&(start, len, size)| LaunchOut {
                start,
                len,
                outs: reference
                    .iter()
                    .map(|p| {
                        let mut v = p[start..start + len].to_vec();
                        v.resize(size, -1.0); // pad lanes must never leak
                        v
                    })
                    .collect(),
            })
            .collect();
        let (planes, reclaimed) = b.stage_scatter(launches, &spans, 2).unwrap();
        assert_eq!(planes.len(), lens.len());
        assert_eq!(reclaimed.len(), 6, "all launch buffers reclaimed");
        for (ri, &(g, n)) in spans.iter().enumerate() {
            for o in 0..2 {
                assert_eq!(planes[ri][o].len(), n);
                for i in 0..n {
                    assert_eq!(
                        planes[ri][o][i].to_bits(),
                        reference[o][g + i].to_bits(),
                        "req={ri} plane={o} lane={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn serial_mode_has_no_staging_crew() {
        let mut b = NativeBackend::new(DEFAULT_CHUNK, 1);
        assert_eq!(b.staging_workers(), 0);
        assert!(b.stage_gather(Op::Add, &[], 8, 0, 8).is_err());
        assert!(b.stage_scatter(Vec::new(), &[], 1).is_err());
        // reclaim on a crewless backend is a silent drop
        b.stage_reclaim(0, vec![0.0; 8]);
        assert_eq!(b.idle_buffers(), 0);
    }

    #[test]
    fn placement_degrades_to_unpinned_on_unknown_nodes() {
        // pinning to a node the topology doesn't know is a no-op, not
        // an error — the containerized-host acceptance criterion
        let mut b = NativeBackend::with_placement(MIN_CHUNK, 2, None, Some(9_999));
        assert_eq!(b.node(), Some(9_999));
        assert_eq!(b.staging_workers(), 2);
        let n = MIN_CHUNK * 3;
        let planes = workload::planes_for("add22", n, 11);
        let job = ExecJob::new(Op::Add22, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; 2];
        b.execute(&job, &mut outs).unwrap();
        assert_eq!(NativeBackend::new(0, 1).node(), None);
        assert_eq!(b.stats().arena_dropped, 0);
    }
}
