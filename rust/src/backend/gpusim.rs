//! [`GpuSimBackend`]: the operator catalogue lowered onto the gpusim
//! stream VM.
//!
//! This makes the paper's *non-IEEE arithmetic models* a servable
//! substrate for the first time: the same `add22`/`mul22`/... requests
//! the coordinator serves natively can run under NV35 truncated-add,
//! R300 no-guard-bit, chopped, or IEEE arithmetic, by executing the
//! pre-assembled fragment programs of [`crate::gpusim::shader`].
//!
//! On the `ieee-rn` model the EFT operators (`add12`, `mul12`, `add22`,
//! `mul22`, `mad22`) are **bit-identical** to the native kernels — the
//! cross-backend parity test in `rust/tests/backend_parity.rs` pins
//! that. `split` (FP-only Dekker vs the native mask split) and `div22`
//! (reciprocal-based, as real GPUs did it) are numerically equivalent
//! but not bit-equal, which is itself faithful to the paper.

use super::{
    check_outputs, BackendStats, ExecJob, ExecReport, KernelBackend, Op, ServiceError,
};
use crate::gpusim::shader::{self, programs, Program};
use crate::gpusim::GpuModel;
use std::time::Instant;

/// Stream-VM backend over one GPU arithmetic model.
pub struct GpuSimBackend {
    model: GpuModel,
    programs: Vec<(Op, Program)>,
    /// Reusable f64 staging for input streams (upload side).
    fin: Vec<Vec<f64>>,
    /// Reusable f64 staging for output streams (readback side).
    fout: Vec<Vec<f64>>,
    stats: BackendStats,
}

impl GpuSimBackend {
    pub fn new(model: GpuModel) -> GpuSimBackend {
        let p = model.format.precision();
        let programs: Vec<(Op, Program)> = vec![
            (Op::Add12, programs::add12()),
            (Op::Split, programs::split(p)),
            (Op::Mul12, programs::mul12(p)),
            (Op::Add22, programs::add22()),
            (Op::Mul22, programs::mul22(p)),
            (Op::Div22, programs::div22(p)),
            (Op::Mad22, programs::mad22(p)),
            (Op::Add, programs::base_add()),
            (Op::Mul, programs::base_mul()),
            (Op::Mad, programs::base_mad()),
        ];
        GpuSimBackend {
            model,
            programs,
            fin: Vec::new(),
            fout: Vec::new(),
            stats: BackendStats::default(),
        }
    }

    /// Construct from a model name ("ieee-rn", "nv35", "nv40", "r300",
    /// "chopped").
    pub fn by_name(model: &str) -> Result<GpuSimBackend, ServiceError> {
        GpuModel::by_name(model)
            .map(GpuSimBackend::new)
            .ok_or_else(|| ServiceError::Backend(format!("unknown GPU model '{model}'")))
    }

    pub fn model(&self) -> &GpuModel {
        &self.model
    }
}

impl KernelBackend for GpuSimBackend {
    fn name(&self) -> &'static str {
        "gpusim"
    }

    fn ops(&self) -> Vec<Op> {
        self.programs.iter().map(|(op, _)| *op).collect()
    }

    fn execute(
        &mut self, job: &ExecJob, outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let n = check_outputs("gpusim", job, outputs)?;
        let op = job.op();
        let (n_in, n_out) = op.arity();
        let Some(prog) = self.programs.iter().find(|(p, _)| *p == op) else {
            return Err(ServiceError::Unsupported { backend: "gpusim", op });
        };
        let prog = &prog.1;
        let t0 = Instant::now();
        // upload: widen f32 planes into reusable f64 streams
        while self.fin.len() < n_in {
            self.fin.push(Vec::new());
        }
        for (i, plane) in job.inputs().iter().enumerate() {
            let buf = &mut self.fin[i];
            buf.clear();
            buf.extend(plane.iter().map(|&v| v as f64));
        }
        let in_refs: Vec<&[f64]> = self.fin[..n_in].iter().map(Vec::as_slice).collect();
        while self.fout.len() < n_out {
            self.fout.push(Vec::new());
        }
        for buf in self.fout[..n_out].iter_mut() {
            buf.clear();
            buf.resize(n, 0.0);
        }
        shader::run_into(&self.model, prog, &in_refs, &mut self.fout[..n_out])
            .map_err(|e| ServiceError::Backend(format!("gpusim vm: {e:?}")))?;
        // readback: narrow to f32 output planes
        for (o, plane) in outputs.iter_mut().enumerate() {
            for (dst, &src) in plane.iter_mut().zip(self.fout[o].iter()) {
                *dst = src as f32;
            }
        }
        self.stats.executions += 1;
        self.stats.elements += n as u64;
        self.stats.busy_seconds += t0.elapsed().as_secs_f64();
        Ok(ExecReport { launches: 1, padded_elements: 0 })
    }

    fn stats(&self) -> BackendStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::FF32;
    use crate::harness::workload;

    fn exec(b: &mut GpuSimBackend, op: Op, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let planes = workload::planes_for(op.name(), n, seed);
        let job = super::ExecJob::new(op, planes).unwrap();
        let mut outs = vec![vec![0.0f32; n]; op.n_out()];
        b.execute(&job, &mut outs).unwrap();
        outs
    }

    #[test]
    fn ieee_model_serves_add22_bit_identical_to_scalar() {
        let mut b = GpuSimBackend::by_name("ieee-rn").unwrap();
        let n = 500;
        let planes = workload::planes_for("add22", n, 0x6511);
        let job = super::ExecJob::new(Op::Add22, planes.clone()).unwrap();
        let mut outs = vec![vec![0.0f32; n]; 2];
        b.execute(&job, &mut outs).unwrap();
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!(
                (outs[0][i].to_bits(), outs[1][i].to_bits()),
                (want.hi.to_bits(), want.lo.to_bits()),
                "i={i}"
            );
        }
    }

    #[test]
    fn nv35_model_differs_from_ieee_somewhere() {
        let mut ieee = GpuSimBackend::by_name("ieee-rn").unwrap();
        let mut nv35 = GpuSimBackend::by_name("nv35").unwrap();
        let a = exec(&mut ieee, Op::Add22, 4096, 7);
        let b = exec(&mut nv35, Op::Add22, 4096, 7);
        let diff = a[0]
            .iter()
            .zip(&b[0])
            .chain(a[1].iter().zip(&b[1]))
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        assert!(diff > 0, "NV35 truncated adds should deviate from IEEE");
    }

    #[test]
    fn every_catalog_op_is_served() {
        let mut b = GpuSimBackend::by_name("ieee-rn").unwrap();
        for op in Op::ALL {
            let outs = exec(&mut b, op, 64, 11);
            assert_eq!(outs.len(), op.n_out(), "op {op}");
            assert!(outs[0].iter().any(|&v| v != 0.0), "op {op} wrote zeros");
        }
        let st = b.stats();
        assert_eq!(st.executions, Op::COUNT as u64);
    }

    #[test]
    fn staging_buffers_are_reused() {
        let mut b = GpuSimBackend::by_name("ieee-rn").unwrap();
        exec(&mut b, Op::Add22, 1000, 1);
        let cap0 = b.fin[0].capacity();
        let ptr0 = b.fin[0].as_ptr();
        exec(&mut b, Op::Add22, 900, 2);
        assert_eq!(b.fin[0].capacity(), cap0);
        assert_eq!(b.fin[0].as_ptr(), ptr0, "staging reallocated");
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(matches!(
            GpuSimBackend::by_name("voodoo2"),
            Err(ServiceError::Backend(_))
        ));
    }
}
