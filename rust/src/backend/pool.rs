//! [`BufferPool`]: reusable `Vec<f32>` planes for the dispatch hot path,
//! and [`WorkerArenas`]: one pool **per persistent worker**.
//!
//! The seed coordinator allocated every gather plane and output plane
//! per batch. Each shard thread now owns a pool; buffers cycle through
//! gather → execute → scatter → back to the pool, so steady-state
//! serving performs no plane allocation (capacity grows to the largest
//! batch seen and stays).
//!
//! The persistent native worker crew gets [`WorkerArenas`] instead of
//! one shared pool: each worker takes chunk buffers from *its own*
//! mutex-guarded free-list and the batch assembler returns them there,
//! so workers never contend with each other on a single free-list (a
//! worker's arena mutex is only ever touched by that worker and,
//! briefly, by the assembler handing buffers back).
//!
//! Arenas are also the stack's **NUMA locality anchor**: a pinned
//! worker first-touches every page of a fresh buffer on its own node
//! (the zero-fill in [`BufferPool::take`] faults the pages in), and
//! because buffers only ever return to the arena they came from, a
//! recycled plane never migrates to another worker — or another node.
//!
//! Retention is bounded by **bytes**, not buffer count: after a burst
//! of giant fused batches a count cap would permanently pin dozens of
//! peak-sized planes. Overflow buffers are dropped and counted
//! ([`BufferPool::dropped`]), and the coordinator forwards the counter
//! into service telemetry.

use std::sync::Mutex;

/// Default retained-byte cap per free-list (32 MiB — a handful of
/// top-rung launch planes, enough to keep steady state allocation-free
/// without pinning a burst forever).
pub const DEFAULT_RETAINED_BYTES: usize = 32 << 20;

/// A trivial free-list of `f32` planes. Not thread-safe by design: one
/// pool per shard thread.
#[derive(Debug)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    /// Byte budget for parked capacity; `put` past it drops instead.
    max_retained_bytes: usize,
    /// Capacity bytes currently parked in `free`.
    retained_bytes: usize,
    /// Buffers dropped because the budget was full.
    dropped: u64,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        Self::with_byte_cap(DEFAULT_RETAINED_BYTES)
    }

    /// A pool retaining at most `max_retained_bytes` of parked capacity.
    pub fn with_byte_cap(max_retained_bytes: usize) -> BufferPool {
        BufferPool {
            free: Vec::new(),
            max_retained_bytes,
            retained_bytes: 0,
            dropped: 0,
        }
    }

    fn pop(&mut self) -> Option<Vec<f32>> {
        let v = self.free.pop()?;
        self.retained_bytes = self
            .retained_bytes
            .saturating_sub(v.capacity() * std::mem::size_of::<f32>());
        Some(v)
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// An empty buffer (len 0), ready for `extend`-style gathering.
    pub fn take_empty(&mut self) -> Vec<f32> {
        let mut v = self.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a buffer to the pool; past the byte budget it is dropped
    /// and counted instead of parked.
    pub fn put(&mut self, v: Vec<f32>) {
        let bytes = v.capacity() * std::mem::size_of::<f32>();
        if bytes == 0 {
            return; // zero-capacity buffers are not worth parking
        }
        if self.retained_bytes + bytes <= self.max_retained_bytes {
            self.retained_bytes += bytes;
            self.free.push(v);
        } else {
            self.dropped += 1;
        }
    }

    /// Buffers currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Capacity bytes currently parked.
    pub fn retained_bytes(&self) -> usize {
        self.retained_bytes
    }

    /// Buffers dropped on overflow since creation.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Per-worker buffer arenas for a persistent worker crew: worker `i`
/// takes from arena `i`, and whoever assembles the batch returns each
/// chunk buffer to the arena it came from. No free-list is shared
/// between workers, so the crew never contends on one pool — and on a
/// pinned crew, no buffer ever changes NUMA node.
#[derive(Debug)]
pub struct WorkerArenas {
    arenas: Vec<Mutex<BufferPool>>,
}

impl WorkerArenas {
    /// One arena per worker (at least one), each byte-capped at
    /// [`DEFAULT_RETAINED_BYTES`].
    pub fn new(workers: usize) -> WorkerArenas {
        WorkerArenas {
            arenas: (0..workers.max(1)).map(|_| Mutex::new(BufferPool::new())).collect(),
        }
    }

    /// Number of arenas (== workers).
    pub fn workers(&self) -> usize {
        self.arenas.len()
    }

    /// A zero-filled buffer of exactly `len` elements from `worker`'s
    /// arena.
    pub fn take(&self, worker: usize, len: usize) -> Vec<f32> {
        match self.arenas[worker].lock() {
            Ok(mut pool) => pool.take(len),
            Err(_) => vec![0.0; len], // poisoned arena: degrade to alloc
        }
    }

    /// An empty buffer from `worker`'s arena, ready for gathering.
    pub fn take_empty(&self, worker: usize) -> Vec<f32> {
        match self.arenas[worker].lock() {
            Ok(mut pool) => pool.take_empty(),
            Err(_) => Vec::new(),
        }
    }

    /// Return a buffer to the arena it was taken from.
    pub fn put(&self, worker: usize, v: Vec<f32>) {
        if let Ok(mut pool) = self.arenas[worker].lock() {
            pool.put(v);
        }
    }

    /// Buffers parked across all arenas.
    pub fn idle(&self) -> usize {
        self.arenas
            .iter()
            .map(|a| a.lock().map(|p| p.idle()).unwrap_or(0))
            .sum()
    }

    /// Buffers dropped on overflow across all arenas.
    pub fn dropped(&self) -> u64 {
        self.arenas
            .iter()
            .map(|a| a.lock().map(|p| p.dropped()).unwrap_or(0))
            .sum()
    }

    /// Capacity bytes parked across all arenas.
    pub fn retained_bytes(&self) -> usize {
        self.arenas
            .iter()
            .map(|a| a.lock().map(|p| p.retained_bytes()).unwrap_or(0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_capacity() {
        let mut pool = BufferPool::new();
        let mut v = pool.take(1000);
        v[0] = 42.0;
        let cap = v.capacity();
        let ptr = v.as_ptr();
        pool.put(v);
        assert_eq!(pool.idle(), 1);
        let v2 = pool.take(500);
        assert_eq!(v2.len(), 500);
        assert_eq!(v2.as_ptr(), ptr, "buffer not reused");
        assert!(v2.capacity() >= 500 && v2.capacity() <= cap.max(1000));
        assert!(v2.iter().all(|&x| x == 0.0), "stale data leaked");
    }

    #[test]
    fn take_empty_is_empty_with_capacity() {
        let mut pool = BufferPool::new();
        pool.put(vec![1.0; 256]);
        let v = pool.take_empty();
        assert_eq!(v.len(), 0);
        assert!(v.capacity() >= 256);
    }

    #[test]
    fn retention_is_bounded_by_bytes() {
        // budget of ~two 128-element planes
        let mut pool = BufferPool::with_byte_cap(1024);
        for _ in 0..4 {
            pool.put(Vec::with_capacity(128)); // 512 bytes each
        }
        assert!(pool.retained_bytes() <= 1024);
        assert_eq!(pool.idle(), 2);
        assert_eq!(pool.dropped(), 2, "overflow buffers counted, not parked");
        // taking a buffer frees budget for the next put
        let v = pool.take(128);
        pool.put(v);
        assert_eq!(pool.idle(), 2);
        assert_eq!(pool.dropped(), 2);
        // zero-capacity buffers are neither parked nor counted
        pool.put(Vec::new());
        assert_eq!(pool.idle(), 2);
        assert_eq!(pool.dropped(), 2);
        // a single buffer bigger than the whole budget is never parked
        let mut big = BufferPool::with_byte_cap(64);
        big.put(vec![0.0; 1000]);
        assert_eq!(big.idle(), 0);
        assert_eq!(big.dropped(), 1);
    }

    #[test]
    fn worker_arenas_are_isolated_per_worker() {
        let arenas = WorkerArenas::new(3);
        assert_eq!(arenas.workers(), 3);
        let a = arenas.take(0, 100);
        let ptr = a.as_ptr();
        arenas.put(0, a);
        assert_eq!(arenas.idle(), 1);
        // worker 1 never sees worker 0's buffer
        let b = arenas.take(1, 100);
        assert_ne!(b.as_ptr(), ptr, "arena leaked across workers");
        // worker 0 reuses its own
        let c = arenas.take(0, 50);
        assert_eq!(c.as_ptr(), ptr, "own arena not reused");
        assert!(c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn worker_arenas_never_empty() {
        let arenas = WorkerArenas::new(0);
        assert_eq!(arenas.workers(), 1);
        assert_eq!(arenas.take(0, 8).len(), 8);
        let e = arenas.take_empty(0);
        assert_eq!(e.len(), 0);
    }

    #[test]
    fn worker_arenas_aggregate_drop_counts() {
        let arenas = WorkerArenas::new(2);
        assert_eq!(arenas.dropped(), 0);
        // overflow one arena far past the byte budget
        let huge = DEFAULT_RETAINED_BYTES / std::mem::size_of::<f32>();
        arenas.put(0, vec![0.0; huge]);
        arenas.put(0, vec![0.0; huge]);
        assert!(arenas.dropped() >= 1);
        assert!(arenas.retained_bytes() <= 2 * DEFAULT_RETAINED_BYTES);
    }
}
