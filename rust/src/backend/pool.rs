//! [`BufferPool`]: reusable `Vec<f32>` planes for the dispatch hot path.
//!
//! The seed coordinator allocated every gather plane and output plane
//! per batch. Each shard thread now owns a pool; buffers cycle through
//! gather → execute → scatter → back to the pool, so steady-state
//! serving performs no plane allocation (capacity grows to the largest
//! batch seen and stays).

/// A trivial free-list of `f32` planes. Not thread-safe by design: one
/// pool per shard thread.
#[derive(Debug)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    /// Max buffers retained (bounds memory after a burst of huge batches).
    max_retained: usize,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool { free: Vec::new(), max_retained: 32 }
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// An empty buffer (len 0), ready for `extend`-style gathering.
    pub fn take_empty(&mut self) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a buffer to the pool.
    pub fn put(&mut self, v: Vec<f32>) {
        if self.free.len() < self.max_retained && v.capacity() > 0 {
            self.free.push(v);
        }
    }

    /// Buffers currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_capacity() {
        let mut pool = BufferPool::new();
        let mut v = pool.take(1000);
        v[0] = 42.0;
        let cap = v.capacity();
        let ptr = v.as_ptr();
        pool.put(v);
        assert_eq!(pool.idle(), 1);
        let v2 = pool.take(500);
        assert_eq!(v2.len(), 500);
        assert_eq!(v2.as_ptr(), ptr, "buffer not reused");
        assert!(v2.capacity() >= 500 && v2.capacity() <= cap.max(1000));
        assert!(v2.iter().all(|&x| x == 0.0), "stale data leaked");
    }

    #[test]
    fn take_empty_is_empty_with_capacity() {
        let mut pool = BufferPool::new();
        pool.put(vec![1.0; 256]);
        let v = pool.take_empty();
        assert_eq!(v.len(), 0);
        assert!(v.capacity() >= 256);
    }

    #[test]
    fn retention_is_bounded() {
        let mut pool = BufferPool::new();
        for _ in 0..100 {
            pool.put(vec![0.0; 8]);
        }
        assert!(pool.idle() <= 32);
        // zero-capacity buffers are not worth parking
        pool.put(Vec::new());
        assert!(pool.idle() <= 32);
    }
}
