//! [`BufferPool`]: reusable `Vec<f32>` planes for the dispatch hot path,
//! and [`WorkerArenas`]: one pool **per persistent worker**.
//!
//! The seed coordinator allocated every gather plane and output plane
//! per batch. Each shard thread now owns a pool; buffers cycle through
//! gather → execute → scatter → back to the pool, so steady-state
//! serving performs no plane allocation (capacity grows to the largest
//! batch seen and stays).
//!
//! The persistent native worker crew gets [`WorkerArenas`] instead of
//! one shared pool: each worker takes chunk buffers from *its own*
//! mutex-guarded free-list and the batch assembler returns them there,
//! so workers never contend with each other on a single free-list (a
//! worker's arena mutex is only ever touched by that worker and,
//! briefly, by the assembler handing buffers back).

use std::sync::Mutex;

/// A trivial free-list of `f32` planes. Not thread-safe by design: one
/// pool per shard thread.
#[derive(Debug)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    /// Max buffers retained (bounds memory after a burst of huge batches).
    max_retained: usize,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool { free: Vec::new(), max_retained: 32 }
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// An empty buffer (len 0), ready for `extend`-style gathering.
    pub fn take_empty(&mut self) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a buffer to the pool.
    pub fn put(&mut self, v: Vec<f32>) {
        if self.free.len() < self.max_retained && v.capacity() > 0 {
            self.free.push(v);
        }
    }

    /// Buffers currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

/// Per-worker buffer arenas for a persistent worker crew: worker `i`
/// takes from arena `i`, and whoever assembles the batch returns each
/// chunk buffer to the arena it came from. No free-list is shared
/// between workers, so the crew never contends on one pool.
#[derive(Debug)]
pub struct WorkerArenas {
    arenas: Vec<Mutex<BufferPool>>,
}

impl WorkerArenas {
    /// One arena per worker (at least one).
    pub fn new(workers: usize) -> WorkerArenas {
        WorkerArenas {
            arenas: (0..workers.max(1)).map(|_| Mutex::new(BufferPool::new())).collect(),
        }
    }

    /// Number of arenas (== workers).
    pub fn workers(&self) -> usize {
        self.arenas.len()
    }

    /// A zero-filled buffer of exactly `len` elements from `worker`'s
    /// arena.
    pub fn take(&self, worker: usize, len: usize) -> Vec<f32> {
        match self.arenas[worker].lock() {
            Ok(mut pool) => pool.take(len),
            Err(_) => vec![0.0; len], // poisoned arena: degrade to alloc
        }
    }

    /// Return a buffer to the arena it was taken from.
    pub fn put(&self, worker: usize, v: Vec<f32>) {
        if let Ok(mut pool) = self.arenas[worker].lock() {
            pool.put(v);
        }
    }

    /// Buffers parked across all arenas.
    pub fn idle(&self) -> usize {
        self.arenas
            .iter()
            .map(|a| a.lock().map(|p| p.idle()).unwrap_or(0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_capacity() {
        let mut pool = BufferPool::new();
        let mut v = pool.take(1000);
        v[0] = 42.0;
        let cap = v.capacity();
        let ptr = v.as_ptr();
        pool.put(v);
        assert_eq!(pool.idle(), 1);
        let v2 = pool.take(500);
        assert_eq!(v2.len(), 500);
        assert_eq!(v2.as_ptr(), ptr, "buffer not reused");
        assert!(v2.capacity() >= 500 && v2.capacity() <= cap.max(1000));
        assert!(v2.iter().all(|&x| x == 0.0), "stale data leaked");
    }

    #[test]
    fn take_empty_is_empty_with_capacity() {
        let mut pool = BufferPool::new();
        pool.put(vec![1.0; 256]);
        let v = pool.take_empty();
        assert_eq!(v.len(), 0);
        assert!(v.capacity() >= 256);
    }

    #[test]
    fn retention_is_bounded() {
        let mut pool = BufferPool::new();
        for _ in 0..100 {
            pool.put(vec![0.0; 8]);
        }
        assert!(pool.idle() <= 32);
        // zero-capacity buffers are not worth parking
        pool.put(Vec::new());
        assert!(pool.idle() <= 32);
    }

    #[test]
    fn worker_arenas_are_isolated_per_worker() {
        let arenas = WorkerArenas::new(3);
        assert_eq!(arenas.workers(), 3);
        let a = arenas.take(0, 100);
        let ptr = a.as_ptr();
        arenas.put(0, a);
        assert_eq!(arenas.idle(), 1);
        // worker 1 never sees worker 0's buffer
        let b = arenas.take(1, 100);
        assert_ne!(b.as_ptr(), ptr, "arena leaked across workers");
        // worker 0 reuses its own
        let c = arenas.take(0, 50);
        assert_eq!(c.as_ptr(), ptr, "own arena not reused");
        assert!(c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn worker_arenas_never_empty() {
        let arenas = WorkerArenas::new(0);
        assert_eq!(arenas.workers(), 1);
        assert_eq!(arenas.take(0, 8).len(), 8);
    }
}
