//! Content fingerprinting of request planes — the keying half of the
//! coordinator's result cache
//! ([`crate::coordinator::cache::ResultCache`]).
//!
//! Every catalogue operator is a pure, deterministic function of its
//! input planes (the backend-parity contract: bit-identical in,
//! bit-identical out), so a request's identity is exactly
//! `(op, plane count, per-plane length, per-lane f32 bit pattern)`.
//! [`fingerprint`] folds that tuple into a 64-bit key.
//!
//! **Canonicalization is bitwise, deliberately.** Lanes hash as their
//! raw [`f32::to_bits`] patterns: `-0.0` and `+0.0` key differently,
//! and NaNs key by payload. That is not an accident — the serving
//! contract is bit-identical replies, and `1.0 / -0.0` is `-inf` where
//! `1.0 / 0.0` is `+inf`, so value-level equality would serve wrong
//! signs from cache. Two requests share a key only when a backend
//! would be *required* to produce byte-identical output planes for
//! both. (A 64-bit key can collide in principle; at ~2⁻⁶⁴ per pair
//! this is the standard content-address trade, same as any
//! fingerprinted cache.)
//!
//! The mix is a 4-stripe FNV-1a over 64-bit words (two lanes per
//! word): four independent accumulators take words round-robin, so the
//! multiply latency of one stripe overlaps the next three and a
//! million-lane plane hashes at close to memory speed, then the
//! stripes fold together with two avalanche rounds. Std-only, no
//! dependencies, and **pinned**: the constants and word order below
//! are part of the on-disk/test contract (see
//! `pinned_fingerprint_constant`), so keys are stable across runs,
//! platforms and rebuilds.

use super::op::Op;

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Independent accumulator stripes (see module docs).
const STRIPES: usize = 4;

/// Streaming plane hasher: feed 64-bit words / planes, then
/// [`finish`](PlaneHasher::finish). Word order is part of the pinned
/// contract — callers must not reorder planes.
#[derive(Clone, Debug)]
pub struct PlaneHasher {
    lanes: [u64; STRIPES],
    next: usize,
}

impl Default for PlaneHasher {
    fn default() -> Self {
        PlaneHasher::new()
    }
}

impl PlaneHasher {
    pub fn new() -> PlaneHasher {
        // distinct per-stripe seeds: the offset basis advanced by one
        // FNV step over the stripe index
        let mut lanes = [FNV_OFFSET; STRIPES];
        for k in 1..STRIPES {
            lanes[k] = (lanes[k - 1] ^ k as u64).wrapping_mul(FNV_PRIME);
        }
        PlaneHasher { lanes, next: 0 }
    }

    /// Fold one 64-bit word into the current stripe.
    #[inline]
    pub fn write_u64(&mut self, word: u64) {
        let k = self.next;
        self.lanes[k] = (self.lanes[k] ^ word).wrapping_mul(FNV_PRIME);
        self.next = (k + 1) % STRIPES;
    }

    /// Fold one plane: its length, then its lanes as raw bit patterns
    /// packed two per word (an odd tail lane rides alone — the length
    /// word already disambiguates it from a `[lane, 0.0]` pair).
    pub fn write_plane(&mut self, plane: &[f32]) {
        self.write_u64(plane.len() as u64);
        let mut pairs = plane.chunks_exact(2);
        for pair in &mut pairs {
            let w = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
            self.write_u64(w);
        }
        if let [tail] = pairs.remainder() {
            self.write_u64(tail.to_bits() as u64);
        }
    }

    /// Fold the stripes together and avalanche into the final key.
    pub fn finish(&self) -> u64 {
        let mut h = self.lanes[0];
        for k in 1..STRIPES {
            h = (h ^ self.lanes[k]).wrapping_mul(FNV_PRIME);
        }
        h ^= h >> 32;
        h = h.wrapping_mul(FNV_PRIME);
        h ^ (h >> 29)
    }
}

/// The content key of one request: operator discriminant, plane count,
/// and every plane's shape + lane bit patterns (see module docs for
/// the canonicalization contract).
pub fn fingerprint(op: Op, planes: &[Vec<f32>]) -> u64 {
    let mut h = PlaneHasher::new();
    h.write_u64(op.index() as u64);
    h.write_u64(planes.len() as u64);
    for p in planes {
        h.write_plane(p);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_fingerprint_constant() {
        // the key of this exact request is part of the contract: it
        // must survive rebuilds, platforms and refactors. The input
        // exercises the canonicalization corners — a negative zero
        // lane and a payload-carrying NaN lane.
        let planes = vec![
            vec![1.5, -0.0, f32::from_bits(0x7FC0_0123)],
            vec![0.0, 2.5, -1.0],
        ];
        assert_eq!(fingerprint(Op::Add, &planes), 0x35fa_d9ec_743a_ccbf);
        // and it is deterministic call over call
        assert_eq!(fingerprint(Op::Add, &planes), fingerprint(Op::Add, &planes));
    }

    #[test]
    fn signed_zeros_key_differently() {
        // 1.0 / +0.0 = +inf but 1.0 / -0.0 = -inf: value-level
        // equality would serve the wrong sign from cache
        let pz = fingerprint(Op::Add, &[vec![0.0], vec![1.0]]);
        let nz = fingerprint(Op::Add, &[vec![-0.0], vec![1.0]]);
        assert_ne!(pz, nz);
        // pinned alongside the main constant (same contract)
        assert_eq!(pz, 0xf38e_fe84_44b4_918e);
        assert_eq!(nz, 0xf0a3_5274_ca6a_56c5);
    }

    #[test]
    fn nan_payloads_key_differently() {
        let a = fingerprint(Op::Add, &[vec![f32::from_bits(0x7FC0_0000)], vec![1.0]]);
        let b = fingerprint(Op::Add, &[vec![f32::from_bits(0x7FC0_0001)], vec![1.0]]);
        assert_ne!(a, b);
    }

    #[test]
    fn operator_discriminant_is_keyed() {
        let planes = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_ne!(
            fingerprint(Op::Add, &planes),
            fingerprint(Op::Mul, &planes)
        );
    }

    #[test]
    fn shapes_are_keyed_not_just_content() {
        // same 4 bit patterns, different plane structure
        let wide = fingerprint(Op::Add, &[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let tall = fingerprint(Op::Add22, &[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        assert_ne!(wide, tall);
        // a one-lane plane and the same lane padded with 0.0 (whose
        // bit pattern is all zeros, like the packing's empty half)
        // must not collide: the length word disambiguates
        let lone = fingerprint(Op::Add, &[vec![1.0], vec![1.0]]);
        let padded = fingerprint(Op::Add, &[vec![1.0, 0.0], vec![1.0, 0.0]]);
        assert_ne!(lone, padded);
    }

    #[test]
    fn streaming_hasher_matches_fingerprint() {
        let planes = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let mut h = PlaneHasher::new();
        h.write_u64(Op::Mul.index() as u64);
        h.write_u64(planes.len() as u64);
        for p in &planes {
            h.write_plane(p);
        }
        assert_eq!(h.finish(), fingerprint(Op::Mul, &planes));
    }
}
