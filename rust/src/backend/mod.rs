//! The backend layer: one operator surface over every execution substrate.
//!
//! The paper's evaluation is one experiment run on three substrates —
//! real GPU fragment programs (Table 3), native CPU double-double
//! (Table 4), an exact oracle (Table 5). The seed repo hard-coded that
//! choice as a two-variant enum inside the coordinator; this module
//! makes it a first-class abstraction:
//!
//! * [`KernelBackend`] — the trait: an op catalogue plus
//!   `execute(job, outputs)` over an owned [`ExecJob`] (operator +
//!   `Arc`-shared SoA input planes) into pre-sized output planes, with
//!   cumulative [`BackendStats`];
//! * [`ExecJob`] — the owned-buffer job model: input planes live in
//!   `Arc`s so they can cross into **persistent** worker threads
//!   (scoped borrows cannot outlive one batch, owned jobs can), and a
//!   job is validated once at construction — a job that exists has the
//!   right arity and unragged, non-empty planes;
//! * [`NativeBackend`] — the `ff::vector` kernels, executed in parallel
//!   over fixed-size chunks by a standing crew of channel-fed worker
//!   threads (the "CPU path", multicore with no spawn/join per batch);
//! * [`GpuSimBackend`] — the paper's operators lowered onto the
//!   [`crate::gpusim::shader`] stream VM, so the simulated 2006 GPU
//!   arithmetic models (NV35, R300, ...) are a servable substrate;
//! * [`XlaBackend`] — the PJRT/XLA artifact engine, including the
//!   pad-to-compiled-size launch planning that used to live in the
//!   coordinator (the "GPU path");
//! * [`BackendSpec`] — a `Send + Clone` construction recipe, because
//!   PJRT wrapper types must live on the device thread that builds them;
//! * [`BufferPool`] — reusable `Vec<f32>` planes so the dispatch hot
//!   path performs no per-batch allocation, and [`WorkerArenas`] — one
//!   pool per persistent worker, so the crew never contends on a
//!   single free-list (byte-capped, with drop-on-overflow counters);
//! * [`topology`] — std-only NUMA/cache discovery from sysfs plus the
//!   libc-free `sched_setaffinity` pin, so shard threads, worker crews
//!   and their arenas can be node-local ([`Topology`], [`NumaMode`]);
//! * [`ulp`] — the lane-by-lane ulp-diff kernel the accuracy
//!   observatory ([`crate::coordinator::observatory`]) scores one
//!   substrate's replies against a reference with, pad lanes of fused
//!   launches excluded.
//!
//! The operator surface itself is typed: [`Op`] encodes name, arity and
//! plane counts as a closed enum, so jobs carry an `Op`, not a
//! string — unknown-operator errors can only originate at the parse
//! boundary ([`Op::parse`] and the CLI).
//!
//! The coordinator ([`crate::coordinator::service`]) dispatches purely
//! through `Box<dyn KernelBackend>`; N shard threads each own one
//! instance, and since PR 2 the shard set may be **heterogeneous**
//! (per-shard [`BackendSpec`]s, e.g. native shards plus a
//! `gpusim:nv35` canary) with a pluggable
//! [`crate::coordinator::routing::RoutingPolicy`] deciding placement.

pub mod error;
pub mod fingerprint;
pub mod gpusim;
pub mod native;
pub mod op;
pub mod pool;
pub mod topology;
pub mod ulp;
pub mod xla;

pub use crate::ff::simd::KernelTier;
pub use error::ServiceError;
pub use fingerprint::{fingerprint, PlaneHasher};
pub use gpusim::GpuSimBackend;
pub use native::NativeBackend;
pub use op::Op;
pub use pool::{BufferPool, WorkerArenas};
pub use topology::{NumaMode, Topology};
pub use ulp::UlpDiff;
pub use xla::XlaBackend;

use std::path::PathBuf;
use std::sync::Arc;

/// Catalogue row: one servable elementwise operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpSpec {
    pub name: &'static str,
    /// Number of SoA input planes.
    pub n_in: usize,
    /// Number of SoA output planes.
    pub n_out: usize,
}

/// Every operator the serving stack knows about, with its arity.
/// Mirrors `python/compile/kernels/ff.py::OPS`. Derived row-by-row
/// from [`Op::ALL`] (so `CATALOG[op.index()]` is `op`'s row by
/// construction); a `static` (not `const`) so [`Op::spec`] can hand
/// out `&'static` rows indexed at runtime.
pub static CATALOG: [OpSpec; Op::COUNT] = build_catalog();

const fn build_catalog() -> [OpSpec; Op::COUNT] {
    let mut rows = [OpSpec { name: "", n_in: 0, n_out: 0 }; Op::COUNT];
    let mut i = 0;
    while i < Op::COUNT {
        let op = Op::ALL[i];
        rows[i] = OpSpec { name: op.name(), n_in: op.n_in(), n_out: op.n_out() };
        i += 1;
    }
    rows
}

/// Look an operator up in the catalogue.
pub fn op_spec(op: &str) -> Option<&'static OpSpec> {
    CATALOG.iter().find(|s| s.name == op)
}

/// What one `execute` call did (feeds the coordinator's batch metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecReport {
    /// Substrate launches performed (chunks for native, VM sweeps for
    /// gpusim, artifact executions for xla).
    pub launches: usize,
    /// Lanes launched beyond the useful batch (xla pad-to-artifact-size).
    pub padded_elements: u64,
}

/// Cumulative per-backend counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackendStats {
    pub executions: u64,
    pub elements: u64,
    /// Wall-clock seconds spent inside `execute`.
    pub busy_seconds: f64,
    /// Staging buffers dropped by the worker arenas' byte caps
    /// (backends without a crew report 0).
    pub arena_dropped: u64,
}

/// One executed launch staged for the parallel scatter: the window of
/// the concatenated batch it covered, plus its output planes.
#[derive(Debug)]
pub struct LaunchOut {
    /// Offset of this launch's window in the concatenated batch.
    pub start: usize,
    /// Useful lanes in the window (everything past it is padding).
    pub len: usize,
    /// Output planes, `n_out` of them, each at least `len` long.
    pub outs: Vec<Vec<f32>>,
}

/// An owned, validated execution job: one operator plus its SoA input
/// planes behind `Arc`s.
///
/// This is the unit the whole execution pipeline moves around.
/// `Arc`-shared planes are the property that makes **persistent**
/// worker threads possible: a scoped borrow can serve one batch and
/// must join before `execute` returns, but an `Arc` clone can ride a
/// channel into a long-lived worker, outlive nothing it shouldn't, and
/// cost one refcount bump per chunk. Validation happens once, at
/// construction — a job that exists has the operator's arity, unragged
/// planes, and a non-zero batch length — so backends never re-check
/// inputs on the hot path.
///
/// Cloning a job is cheap (`n_in` refcount bumps); the coordinator
/// builds jobs straight from request planes without copying lanes.
#[derive(Clone, Debug)]
pub struct ExecJob {
    op: Op,
    inputs: Vec<Arc<Vec<f32>>>,
    len: usize,
}

impl ExecJob {
    /// Validate `inputs` against `op` and wrap them (each plane moves
    /// into its own `Arc`; no lane is copied).
    pub fn new(op: Op, inputs: Vec<Vec<f32>>) -> Result<ExecJob, ServiceError> {
        let len = op.validate_planes(&inputs)?;
        Ok(ExecJob { op, inputs: inputs.into_iter().map(Arc::new).collect(), len })
    }

    /// Build a job from planes that are already shared (the
    /// coordinator's path: request planes are `Arc`ed at dispatch).
    pub fn from_shared(
        op: Op, inputs: Vec<Arc<Vec<f32>>>,
    ) -> Result<ExecJob, ServiceError> {
        let refs: Vec<&[f32]> = inputs.iter().map(|p| p.as_slice()).collect();
        let len = op.validate_planes(&refs)?;
        Ok(ExecJob { op, inputs, len })
    }

    pub fn op(&self) -> Op {
        self.op
    }

    /// Elements per plane (the batch length).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false — zero-length jobs fail validation.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The shared input planes (what chunk jobs clone).
    pub fn inputs(&self) -> &[Arc<Vec<f32>>] {
        &self.inputs
    }

    /// Borrowed plane views for serial execution paths.
    pub fn input_refs(&self) -> Vec<&[f32]> {
        self.inputs.iter().map(|p| p.as_slice()).collect()
    }

    /// Unwrap into the shared planes (the coordinator reclaims pooled
    /// gather buffers through `Arc::try_unwrap` after execution).
    pub fn into_inputs(self) -> Vec<Arc<Vec<f32>>> {
        self.inputs
    }
}

/// One execution substrate for the operator catalogue.
///
/// Implementations are *not* required to be `Send`/`Sync` (PJRT wrapper
/// types are neither); the coordinator builds one instance per shard
/// thread from a [`BackendSpec`] and keeps it thread-local.
pub trait KernelBackend {
    /// Short substrate name ("native", "gpusim", "xla").
    fn name(&self) -> &'static str;

    /// The operators this backend can execute right now. The
    /// coordinator publishes this catalogue into the routing-visible
    /// shard state ([`crate::coordinator::routing::ShardMeta`]) when
    /// the shard thread builds its backend, so capability-aware
    /// policies never park an op on a shard that cannot serve it.
    fn ops(&self) -> Vec<Op>;

    /// Whether `op` is servable by this backend.
    fn supports(&self, op: Op) -> bool {
        self.ops().contains(&op)
    }

    /// Execute a validated [`ExecJob`] elementwise into pre-sized
    /// output planes (`outputs.len() == job.op().n_out()`, every plane
    /// the batch length). Backends must fill every output lane on
    /// success. Input-shape errors are unrepresentable here — they die
    /// at [`ExecJob`] construction.
    fn execute(
        &mut self, job: &ExecJob, outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError>;

    /// Validate-and-run convenience over borrowed planes: builds a
    /// one-shot [`ExecJob`] (copying the planes) and executes it. The
    /// harness/test path — the serving path builds jobs once and
    /// reuses them.
    fn execute_planes(
        &mut self, op: Op, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let job = ExecJob::new(op, inputs.iter().map(|p| p.to_vec()).collect())?;
        self.execute(&job, outputs)
    }

    /// The CPU kernel tier this backend runs on, for Melem/s
    /// attribution in telemetry, banners and bench JSON. `None` for
    /// substrates where the concept does not apply (gpusim, XLA).
    fn kernel_tier(&self) -> Option<KernelTier> {
        None
    }

    /// Parallel staging lanes this backend offers the coordinator's
    /// gather/scatter data path. `0` (the default) means no crew: the
    /// coordinator stays on its serial path. A backend advertising
    /// `> 1` must implement [`KernelBackend::stage_gather`] and
    /// [`KernelBackend::stage_scatter`].
    fn staging_workers(&self) -> usize {
        0
    }

    /// Gather the window `[start, start + len)` of each input plane's
    /// concatenation (`sources[plane]` lists the per-request planes in
    /// concatenation order) into launch buffers of `size` lanes, short
    /// windows padded with the op's pad value. Returns per-plane
    /// `(worker, buffer)` pairs where `worker` names the arena the
    /// buffer must go back to via [`KernelBackend::stage_reclaim`].
    ///
    /// Bit-parity contract: the gathered lanes must be byte-identical
    /// to [`crate::coordinator::batcher::gather_plane_into`]'s output
    /// for the same window.
    #[allow(unused_variables)]
    fn stage_gather(
        &mut self, op: Op, sources: &[Vec<Arc<Vec<f32>>>], size: usize, start: usize,
        len: usize,
    ) -> Result<Vec<(usize, Vec<f32>)>, ServiceError> {
        Err(ServiceError::Backend(format!(
            "{}: no staging crew (staging_workers() <= 1)",
            self.name()
        )))
    }

    /// Scatter executed launches back into freshly allocated
    /// per-request output planes, sharded by request range across the
    /// crew. `spans[i]` is request `i`'s `(offset, len)` in the
    /// concatenated batch. Returns the per-request planes (in request
    /// order, `n_out` planes each) plus the launches' output buffers,
    /// reclaimed for the caller's pool.
    #[allow(unused_variables)]
    fn stage_scatter(
        &mut self, launches: Vec<LaunchOut>, spans: &[(usize, usize)], n_out: usize,
    ) -> Result<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>), ServiceError> {
        Err(ServiceError::Backend(format!(
            "{}: no staging crew (staging_workers() <= 1)",
            self.name()
        )))
    }

    /// Return a staging buffer to the worker arena it was gathered
    /// into, closing the node-local recycling loop. Default: drop it.
    #[allow(unused_variables)]
    fn stage_reclaim(&mut self, worker: usize, buf: Vec<f32>) {}

    /// Cumulative counters since construction.
    fn stats(&self) -> BackendStats;
}

/// Validate the output buffers of an execute call against the job;
/// returns the batch length. Input rules were enforced when the
/// [`ExecJob`] was built — only the output-buffer checks remain
/// backend-side.
pub(crate) fn check_outputs(
    backend: &'static str, job: &ExecJob, outputs: &[Vec<f32>],
) -> Result<usize, ServiceError> {
    let (op, n) = (job.op(), job.len());
    if outputs.len() != op.n_out() {
        return Err(ServiceError::Shape(format!(
            "{backend}: '{op}' wants {} output planes, got {}",
            op.n_out(),
            outputs.len()
        )));
    }
    if outputs.iter().any(|p| p.len() != n) {
        return Err(ServiceError::Shape(format!(
            "{backend}: output planes of '{op}' must have the batch length {n}"
        )));
    }
    Ok(n)
}

/// Construction recipe for a backend: cheap to clone, `Send`, turned
/// into a live [`KernelBackend`] *on* the shard thread that owns it.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Native CPU kernels, parallel over `chunk`-sized slices.
    /// `workers == 0` means one worker per available core; `chunk == 0`
    /// picks an L2-sized chunk; `tier: None` resolves the kernel tier
    /// via `FFGPU_KERNEL_TIER` / CPU detection. `node: Some(n)` pins
    /// the owning thread and its worker crew to NUMA node `n`
    /// ([`topology::pin_current_thread`]); `None` leaves placement to
    /// the service-level [`NumaMode`] resolution (or unpinned when
    /// built directly).
    Native { chunk: usize, workers: usize, tier: Option<KernelTier>, node: Option<usize> },
    /// The gpusim stream VM on the named GPU arithmetic model
    /// ("ieee-rn", "nv35", "nv40", "r300", "chopped").
    GpuSim { model: String },
    /// PJRT/XLA artifacts from this directory.
    Xla { artifacts: PathBuf, precompile: bool },
}

impl BackendSpec {
    /// Default native spec (auto worker count, auto L2-sized chunks,
    /// auto kernel tier).
    pub fn native() -> BackendSpec {
        BackendSpec::Native { chunk: 0, workers: 0, tier: None, node: None }
    }

    /// Single-threaded native spec (the seed's serving behaviour).
    pub fn native_single() -> BackendSpec {
        BackendSpec::Native { chunk: 0, workers: 1, tier: None, node: None }
    }

    /// GpuSim spec on the IEEE round-to-nearest model (bit-identical to
    /// native kernels on the parity ops).
    pub fn gpusim_ieee() -> BackendSpec {
        BackendSpec::GpuSim { model: "ieee-rn".to_string() }
    }

    /// Substrate label ("native", "gpusim", "xla").
    pub fn label(&self) -> &'static str {
        match self {
            BackendSpec::Native { .. } => "native",
            BackendSpec::GpuSim { .. } => "gpusim",
            BackendSpec::Xla { .. } => "xla",
        }
    }

    /// Parse a CLI-style backend name: `native`, `native:<workers>`,
    /// `gpusim`, `gpusim:<model>`, `xla` (artifacts from `artifacts`).
    pub fn from_cli(name: &str, artifacts: &std::path::Path) -> Result<BackendSpec, ServiceError> {
        let (head, tail) = match name.split_once(':') {
            Some((h, t)) => (h, Some(t)),
            None => (name, None),
        };
        match head {
            "native" | "cpu" => {
                let workers = match tail {
                    Some(t) => t.parse::<usize>().map_err(|_| {
                        ServiceError::Backend(format!("bad worker count '{t}'"))
                    })?,
                    None => 0,
                };
                Ok(BackendSpec::Native { chunk: 0, workers, tier: None, node: None })
            }
            "gpusim" => Ok(BackendSpec::GpuSim {
                model: tail.unwrap_or("ieee-rn").to_string(),
            }),
            "xla" => Ok(BackendSpec::Xla {
                artifacts: artifacts.to_path_buf(),
                precompile: false,
            }),
            other => Err(ServiceError::Backend(format!("unknown backend '{other}'"))),
        }
    }

    /// The NUMA node this spec pins to (native only; `None` = unpinned).
    pub fn numa_node(&self) -> Option<usize> {
        match self {
            BackendSpec::Native { node, .. } => *node,
            _ => None,
        }
    }

    /// Materialise the backend. Must run on the thread that will own
    /// it — a native spec with a `node` pins the calling thread there.
    pub fn build(&self) -> Result<Box<dyn KernelBackend>, ServiceError> {
        match self {
            BackendSpec::Native { chunk, workers, tier, node } => {
                Ok(Box::new(NativeBackend::with_placement(*chunk, *workers, *tier, *node)))
            }
            BackendSpec::GpuSim { model } => {
                Ok(Box::new(GpuSimBackend::by_name(model)?))
            }
            BackendSpec::Xla { artifacts, precompile } => {
                Ok(Box::new(XlaBackend::new(artifacts, *precompile)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_paper_and_extension_ops() {
        for op in ["add12", "split", "mul12", "add22", "mul22", "div22", "mad22",
                   "add", "mul", "mad"] {
            assert!(op_spec(op).is_some(), "op {op}");
        }
        assert!(op_spec("frobnicate").is_none());
        let s = op_spec("mad22").unwrap();
        assert_eq!((s.n_in, s.n_out), (6, 2));
    }

    #[test]
    fn catalog_rows_mirror_the_typed_enum() {
        for (row, op) in CATALOG.iter().zip(Op::ALL) {
            assert_eq!(row.name, op.name());
            assert_eq!((row.n_in, row.n_out), op.arity(), "{op}");
            assert_eq!(op.spec(), row);
        }
    }

    #[test]
    fn exec_job_validates_at_construction() {
        let job = ExecJob::new(Op::Add, vec![vec![1.0f32; 8], vec![2.0f32; 8]]).unwrap();
        assert_eq!(job.op(), Op::Add);
        assert_eq!(job.len(), 8);
        assert!(!job.is_empty());
        assert_eq!(job.inputs().len(), 2);
        assert_eq!(job.input_refs()[1], &[2.0f32; 8]);

        assert!(matches!(
            ExecJob::new(Op::Add, vec![vec![1.0f32; 8]]),
            Err(ServiceError::Arity { want: 2, got: 1, .. })
        ));
        assert!(matches!(
            ExecJob::new(Op::Add, vec![vec![1.0f32; 8], vec![1.0f32; 4]]),
            Err(ServiceError::RaggedPlanes { plane: 1, want: 8, got: 4, .. })
        ));
        assert!(matches!(
            ExecJob::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { op: Op::Add })
        ));
    }

    #[test]
    fn exec_job_shares_planes_without_copying() {
        let plane = vec![1.0f32; 64];
        let ptr = plane.as_ptr();
        let job = ExecJob::new(Op::Split, vec![plane]).unwrap();
        assert_eq!(job.inputs()[0].as_ptr(), ptr, "plane was copied");
        // a clone is refcount bumps, not lane copies
        let clone = job.clone();
        assert_eq!(clone.inputs()[0].as_ptr(), ptr);
        // shared construction validates too
        let shared = job.into_inputs();
        assert!(ExecJob::from_shared(Op::Split, shared.clone()).is_ok());
        assert!(matches!(
            ExecJob::from_shared(Op::Add, shared),
            Err(ServiceError::Arity { .. })
        ));
    }

    #[test]
    fn check_outputs_accepts_and_rejects() {
        let job = ExecJob::new(Op::Add, vec![vec![1.0f32; 8], vec![2.0f32; 8]]).unwrap();
        let mut outs = vec![vec![0.0f32; 8]];
        assert_eq!(check_outputs("t", &job, &outs).unwrap(), 8);
        outs.push(vec![0.0f32; 8]);
        assert!(matches!(
            check_outputs("t", &job, &outs),
            Err(ServiceError::Shape(_))
        ));
        outs.pop();
        outs[0].truncate(4);
        assert!(matches!(
            check_outputs("t", &job, &outs),
            Err(ServiceError::Shape(_))
        ));
    }

    #[test]
    fn spec_from_cli_parses() {
        let dir = std::path::Path::new("artifacts");
        assert!(matches!(
            BackendSpec::from_cli("native", dir),
            Ok(BackendSpec::Native { workers: 0, .. })
        ));
        assert!(matches!(
            BackendSpec::from_cli("native:4", dir),
            Ok(BackendSpec::Native { workers: 4, .. })
        ));
        match BackendSpec::from_cli("gpusim:nv35", dir) {
            Ok(BackendSpec::GpuSim { model }) => assert_eq!(model, "nv35"),
            other => panic!("{other:?}"),
        }
        assert_eq!(BackendSpec::from_cli("xla", dir).unwrap().label(), "xla");
        assert!(BackendSpec::from_cli("voodoo", dir).is_err());
        assert!(BackendSpec::from_cli("native:lots", dir).is_err());
    }

    #[test]
    fn native_and_gpusim_specs_build() {
        assert_eq!(BackendSpec::native().build().unwrap().name(), "native");
        assert_eq!(BackendSpec::gpusim_ieee().build().unwrap().name(), "gpusim");
        assert!(BackendSpec::GpuSim { model: "voodoo2".into() }.build().is_err());
    }

    #[test]
    fn kernel_tier_reported_by_native_only() {
        // native resolves to a concrete tier; substrates without CPU
        // kernel tiers keep the trait default
        assert!(BackendSpec::native_single().build().unwrap().kernel_tier().is_some());
        assert_eq!(BackendSpec::gpusim_ieee().build().unwrap().kernel_tier(), None);
        // an explicit spec tier survives the build
        let spec = BackendSpec::Native {
            chunk: 0,
            workers: 1,
            tier: Some(KernelTier::Scalar),
            node: None,
        };
        assert_eq!(spec.build().unwrap().kernel_tier(), Some(KernelTier::Scalar));
    }

    #[test]
    fn numa_node_reported_for_native_pins_only() {
        assert_eq!(BackendSpec::native().numa_node(), None);
        assert_eq!(BackendSpec::gpusim_ieee().numa_node(), None);
        let spec = BackendSpec::Native { chunk: 0, workers: 1, tier: None, node: Some(1) };
        assert_eq!(spec.numa_node(), Some(1));
        // building with an unknown node degrades to an unpinned backend
        let spec = BackendSpec::Native { chunk: 0, workers: 2, tier: None, node: Some(9999) };
        assert_eq!(spec.build().unwrap().name(), "native");
    }
}
