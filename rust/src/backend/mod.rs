//! The backend layer: one operator surface over every execution substrate.
//!
//! The paper's evaluation is one experiment run on three substrates —
//! real GPU fragment programs (Table 3), native CPU double-double
//! (Table 4), an exact oracle (Table 5). The seed repo hard-coded that
//! choice as a two-variant enum inside the coordinator; this module
//! makes it a first-class abstraction:
//!
//! * [`KernelBackend`] — the trait: an op catalogue plus
//!   `execute(op, inputs, outputs)` over SoA `f32` planes, with
//!   cumulative [`BackendStats`];
//! * [`NativeBackend`] — the `ff::vector` kernels, executed in parallel
//!   over fixed-size chunks by a scoped-thread worker pool (the
//!   "CPU path", now multicore);
//! * [`GpuSimBackend`] — the paper's operators lowered onto the
//!   [`crate::gpusim::shader`] stream VM, so the simulated 2006 GPU
//!   arithmetic models (NV35, R300, ...) are a servable substrate;
//! * [`XlaBackend`] — the PJRT/XLA artifact engine, including the
//!   pad-to-compiled-size launch planning that used to live in the
//!   coordinator (the "GPU path");
//! * [`BackendSpec`] — a `Send + Clone` construction recipe, because
//!   PJRT wrapper types must live on the device thread that builds them;
//! * [`BufferPool`] — reusable `Vec<f32>` planes so the dispatch hot
//!   path performs no per-batch allocation.
//!
//! The operator surface itself is typed: [`Op`] encodes name, arity and
//! plane counts as a closed enum, so `execute` takes an `Op`, not a
//! string — unknown-operator errors can only originate at the parse
//! boundary ([`Op::parse`], the CLI, the deprecated string shims).
//!
//! The coordinator ([`crate::coordinator::service`]) dispatches purely
//! through `Box<dyn KernelBackend>`; N shard threads each own one
//! instance, and since PR 2 the shard set may be **heterogeneous**
//! (per-shard [`BackendSpec`]s, e.g. native shards plus a
//! `gpusim:nv35` canary) with a pluggable
//! [`crate::coordinator::routing::RoutingPolicy`] deciding placement.

pub mod error;
pub mod gpusim;
pub mod native;
pub mod op;
pub mod pool;
pub mod xla;

pub use error::ServiceError;
pub use gpusim::GpuSimBackend;
pub use native::NativeBackend;
pub use op::Op;
pub use pool::BufferPool;
pub use xla::XlaBackend;

use std::path::PathBuf;

/// Catalogue row: one servable elementwise operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpSpec {
    pub name: &'static str,
    /// Number of SoA input planes.
    pub n_in: usize,
    /// Number of SoA output planes.
    pub n_out: usize,
}

/// Every operator the serving stack knows about, with its arity.
/// Mirrors `python/compile/kernels/ff.py::OPS`. Derived row-by-row
/// from [`Op::ALL`] (so `CATALOG[op.index()]` is `op`'s row by
/// construction); a `static` (not `const`) so [`Op::spec`] can hand
/// out `&'static` rows indexed at runtime.
pub static CATALOG: [OpSpec; Op::COUNT] = build_catalog();

const fn build_catalog() -> [OpSpec; Op::COUNT] {
    let mut rows = [OpSpec { name: "", n_in: 0, n_out: 0 }; Op::COUNT];
    let mut i = 0;
    while i < Op::COUNT {
        let op = Op::ALL[i];
        rows[i] = OpSpec { name: op.name(), n_in: op.n_in(), n_out: op.n_out() };
        i += 1;
    }
    rows
}

/// Look an operator up in the catalogue.
pub fn op_spec(op: &str) -> Option<&'static OpSpec> {
    CATALOG.iter().find(|s| s.name == op)
}

/// What one `execute` call did (feeds the coordinator's batch metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecReport {
    /// Substrate launches performed (chunks for native, VM sweeps for
    /// gpusim, artifact executions for xla).
    pub launches: usize,
    /// Lanes launched beyond the useful batch (xla pad-to-artifact-size).
    pub padded_elements: u64,
}

/// Cumulative per-backend counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackendStats {
    pub executions: u64,
    pub elements: u64,
    /// Wall-clock seconds spent inside `execute`.
    pub busy_seconds: f64,
}

/// One execution substrate for the operator catalogue.
///
/// Implementations are *not* required to be `Send`/`Sync` (PJRT wrapper
/// types are neither); the coordinator builds one instance per shard
/// thread from a [`BackendSpec`] and keeps it thread-local.
pub trait KernelBackend {
    /// Short substrate name ("native", "gpusim", "xla").
    fn name(&self) -> &'static str;

    /// The operators this backend can execute right now. The
    /// coordinator publishes this catalogue into the routing-visible
    /// shard state ([`crate::coordinator::routing::ShardMeta`]) when
    /// the shard thread builds its backend, so capability-aware
    /// policies never park an op on a shard that cannot serve it.
    fn ops(&self) -> Vec<Op>;

    /// Whether `op` is servable by this backend.
    fn supports(&self, op: Op) -> bool {
        self.ops().contains(&op)
    }

    /// Execute `op` elementwise over SoA input planes into pre-sized
    /// output planes (`outputs.len() == op.n_out()`, every plane the
    /// batch length). Backends must fill every output lane on success.
    fn execute(
        &mut self, op: Op, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError>;

    /// Cumulative counters since construction.
    fn stats(&self) -> BackendStats;
}

/// Validate an execute call against the operator's arity; returns the
/// batch length. Input rules are [`Op::validate_planes`] (the single
/// source); only the output-buffer checks are backend-side specifics.
pub(crate) fn check_shapes(
    backend: &'static str, op: Op, inputs: &[&[f32]], outputs: &[Vec<f32>],
) -> Result<usize, ServiceError> {
    let n = op.validate_planes(inputs)?;
    if outputs.len() != op.n_out() {
        return Err(ServiceError::Shape(format!(
            "{backend}: '{op}' wants {} output planes, got {}",
            op.n_out(),
            outputs.len()
        )));
    }
    if outputs.iter().any(|p| p.len() != n) {
        return Err(ServiceError::Shape(format!(
            "{backend}: output planes of '{op}' must have the batch length {n}"
        )));
    }
    Ok(n)
}

/// Construction recipe for a backend: cheap to clone, `Send`, turned
/// into a live [`KernelBackend`] *on* the shard thread that owns it.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Native `ff::vector` kernels, parallel over `chunk`-sized slices.
    /// `workers == 0` means one worker per available core.
    Native { chunk: usize, workers: usize },
    /// The gpusim stream VM on the named GPU arithmetic model
    /// ("ieee-rn", "nv35", "nv40", "r300", "chopped").
    GpuSim { model: String },
    /// PJRT/XLA artifacts from this directory.
    Xla { artifacts: PathBuf, precompile: bool },
}

impl BackendSpec {
    /// Default native spec (auto worker count, 16k-element chunks).
    pub fn native() -> BackendSpec {
        BackendSpec::Native { chunk: native::DEFAULT_CHUNK, workers: 0 }
    }

    /// Single-threaded native spec (the seed's serving behaviour).
    pub fn native_single() -> BackendSpec {
        BackendSpec::Native { chunk: native::DEFAULT_CHUNK, workers: 1 }
    }

    /// GpuSim spec on the IEEE round-to-nearest model (bit-identical to
    /// native kernels on the parity ops).
    pub fn gpusim_ieee() -> BackendSpec {
        BackendSpec::GpuSim { model: "ieee-rn".to_string() }
    }

    /// Substrate label ("native", "gpusim", "xla").
    pub fn label(&self) -> &'static str {
        match self {
            BackendSpec::Native { .. } => "native",
            BackendSpec::GpuSim { .. } => "gpusim",
            BackendSpec::Xla { .. } => "xla",
        }
    }

    /// Parse a CLI-style backend name: `native`, `native:<workers>`,
    /// `gpusim`, `gpusim:<model>`, `xla` (artifacts from `artifacts`).
    pub fn from_cli(name: &str, artifacts: &std::path::Path) -> Result<BackendSpec, ServiceError> {
        let (head, tail) = match name.split_once(':') {
            Some((h, t)) => (h, Some(t)),
            None => (name, None),
        };
        match head {
            "native" | "cpu" => {
                let workers = match tail {
                    Some(t) => t.parse::<usize>().map_err(|_| {
                        ServiceError::Backend(format!("bad worker count '{t}'"))
                    })?,
                    None => 0,
                };
                Ok(BackendSpec::Native { chunk: native::DEFAULT_CHUNK, workers })
            }
            "gpusim" => Ok(BackendSpec::GpuSim {
                model: tail.unwrap_or("ieee-rn").to_string(),
            }),
            "xla" => Ok(BackendSpec::Xla {
                artifacts: artifacts.to_path_buf(),
                precompile: false,
            }),
            other => Err(ServiceError::Backend(format!("unknown backend '{other}'"))),
        }
    }

    /// Materialise the backend. Must run on the thread that will own it.
    pub fn build(&self) -> Result<Box<dyn KernelBackend>, ServiceError> {
        match self {
            BackendSpec::Native { chunk, workers } => {
                Ok(Box::new(NativeBackend::new(*chunk, *workers)))
            }
            BackendSpec::GpuSim { model } => {
                Ok(Box::new(GpuSimBackend::by_name(model)?))
            }
            BackendSpec::Xla { artifacts, precompile } => {
                Ok(Box::new(XlaBackend::new(artifacts, *precompile)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_paper_and_extension_ops() {
        for op in ["add12", "split", "mul12", "add22", "mul22", "div22", "mad22",
                   "add", "mul", "mad"] {
            assert!(op_spec(op).is_some(), "op {op}");
        }
        assert!(op_spec("frobnicate").is_none());
        let s = op_spec("mad22").unwrap();
        assert_eq!((s.n_in, s.n_out), (6, 2));
    }

    #[test]
    fn catalog_rows_mirror_the_typed_enum() {
        for (row, op) in CATALOG.iter().zip(Op::ALL) {
            assert_eq!(row.name, op.name());
            assert_eq!((row.n_in, row.n_out), op.arity(), "{op}");
            assert_eq!(op.spec(), row);
        }
    }

    #[test]
    fn check_shapes_accepts_and_rejects() {
        let a = vec![1.0f32; 8];
        let b = vec![2.0f32; 8];
        let ins: Vec<&[f32]> = vec![&a, &b];
        let mut outs = vec![vec![0.0f32; 8]];
        let n = check_shapes("t", Op::Add, &ins, &outs).unwrap();
        assert_eq!(n, 8);

        assert!(matches!(
            check_shapes("t", Op::Add, &ins[..1], &outs),
            Err(ServiceError::Arity { .. })
        ));
        let short = vec![1.0f32; 4];
        let ragged: Vec<&[f32]> = vec![&a, &short];
        assert!(matches!(
            check_shapes("t", Op::Add, &ragged, &outs),
            Err(ServiceError::RaggedPlanes { plane: 1, want: 8, got: 4, .. })
        ));
        outs[0].truncate(4);
        assert!(matches!(
            check_shapes("t", Op::Add, &ins, &outs),
            Err(ServiceError::Shape(_))
        ));
        let empty: Vec<&[f32]> = vec![&[], &[]];
        assert!(matches!(
            check_shapes("t", Op::Add, &empty, &outs),
            Err(ServiceError::EmptyBatch { op: Op::Add })
        ));
    }

    #[test]
    fn spec_from_cli_parses() {
        let dir = std::path::Path::new("artifacts");
        assert!(matches!(
            BackendSpec::from_cli("native", dir),
            Ok(BackendSpec::Native { workers: 0, .. })
        ));
        assert!(matches!(
            BackendSpec::from_cli("native:4", dir),
            Ok(BackendSpec::Native { workers: 4, .. })
        ));
        match BackendSpec::from_cli("gpusim:nv35", dir) {
            Ok(BackendSpec::GpuSim { model }) => assert_eq!(model, "nv35"),
            other => panic!("{other:?}"),
        }
        assert_eq!(BackendSpec::from_cli("xla", dir).unwrap().label(), "xla");
        assert!(BackendSpec::from_cli("voodoo", dir).is_err());
        assert!(BackendSpec::from_cli("native:lots", dir).is_err());
    }

    #[test]
    fn native_and_gpusim_specs_build() {
        assert_eq!(BackendSpec::native().build().unwrap().name(), "native");
        assert_eq!(BackendSpec::gpusim_ieee().build().unwrap().name(), "gpusim");
        assert!(BackendSpec::GpuSim { model: "voodoo2".into() }.build().is_err());
    }
}
