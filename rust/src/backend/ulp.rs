//! The ulp-diff kernel: lane-by-lane comparison of one backend's
//! output planes against a reference, in units in the last place.
//!
//! This is the measurement core of the accuracy observatory
//! ([`crate::coordinator::observatory`]): the observatory mirrors live
//! traffic onto a native (correctly rounded) reference backend and one
//! backend per simulated GPU model, then calls [`diff_outputs`] on each
//! aligned output slice. The paper reports exactly this quantity —
//! Table 2 is ulp-error intervals per arithmetic model, Table 5 is max
//! relative error per operator — so the kernel produces both at once.
//!
//! **The ulp error of a lane.** Output planes are combined into one
//! value per lane the way the float-float format defines it
//! (`hi + lo` in `f64` for two-plane operators, the single plane
//! otherwise); the error is `(got − reference) / ulp`, where the ulp
//! unit is [`crate::util::ulp_f32`] of whichever *high word* has the
//! larger magnitude. Taking the larger-magnitude side keeps the unit
//! stable under flush-to-zero models: a subnormal reference flushed to
//! zero by the model is measured in the reference's (subnormal-range)
//! ulp, not in the degenerate ulp of zero.
//!
//! **Conventions.**
//! * Signed zero: `-0.0` and `+0.0` are numerically equal, so a model
//!   that flips the sign of a zero scores 0 ulp (the paper's harness
//!   compares values, not bit patterns).
//! * Non-finite lanes (either side NaN/inf) are excluded from the
//!   error statistics and counted separately in
//!   [`UlpDiff::non_finite`] — one anomalous lane must not turn the
//!   whole interval into NaN.
//! * Relative error is skipped where the reference is exactly zero
//!   (undefined; the Table 5 harness skips those samples too).
//! * **Pad-lane exclusion**: only `valid` lanes starting at `offset`
//!   are compared. The observatory packs mirrored requests into padded
//!   fused launches, and padding lanes compute on neutral fill values
//!   ([`crate::backend::Op::pad_value`]) — their "errors" are
//!   artefacts of the packing, never of the arithmetic under test, so
//!   they must not reach the statistics.
//!
//! # Examples
//!
//! ```
//! use ffgpu::backend::{ulp, Op};
//!
//! // reference lane 1 is 2.0; the model came back one f32 step high
//! let reference = vec![vec![1.0f32, 2.0], vec![0.0, 0.0]];
//! let got = vec![vec![1.0f32, f32::from_bits(2.0f32.to_bits() + 1)], vec![0.0, 0.0]];
//! let d = ulp::diff_outputs(Op::Add22, &reference, &got, 0, 2);
//! assert_eq!(d.lanes, 2);
//! assert!((d.max_ulp - 1.0).abs() < 1e-12);
//! assert_eq!(d.worst_lane, Some(1));
//! ```

use super::op::Op;
use crate::util::ulp_f32;

/// Lane-by-lane error statistics of one diffed output slice.
///
/// The zero value (via `Default`) is the empty diff: no lanes, all
/// statistics zero, no worst lane.
#[derive(Clone, Copy, Debug, Default)]
pub struct UlpDiff {
    /// Finite lanes compared (pad lanes and non-finite lanes excluded).
    pub lanes: u64,
    /// Lanes where either side was NaN/inf — counted, not scored.
    pub non_finite: u64,
    /// Most negative signed ulp error observed (0.0 when no lanes).
    pub min_ulp: f64,
    /// Most positive signed ulp error observed (0.0 when no lanes).
    pub max_ulp: f64,
    /// Sum of |ulp error| over the compared lanes (for the mean).
    pub sum_abs_ulp: f64,
    /// Largest relative error |err / reference| (reference ≠ 0 lanes).
    pub max_rel: f64,
    /// Index (relative to the diffed slice) of the worst-|ulp| lane.
    pub worst_lane: Option<usize>,
    /// Signed ulp error at [`UlpDiff::worst_lane`].
    pub worst_ulp: f64,
    /// Relative error at [`UlpDiff::worst_lane`] (0.0 when undefined).
    pub worst_rel: f64,
}

impl UlpDiff {
    /// Mean |ulp error| over the compared lanes (0.0 when no lanes).
    pub fn mean_abs_ulp(&self) -> f64 {
        if self.lanes == 0 {
            0.0
        } else {
            self.sum_abs_ulp / self.lanes as f64
        }
    }

    /// Largest |ulp error| observed (max of |min|, |max|).
    pub fn worst_abs_ulp(&self) -> f64 {
        self.min_ulp.abs().max(self.max_ulp.abs())
    }

    /// Whether every compared lane matched the reference exactly.
    pub fn is_exact(&self) -> bool {
        self.lanes > 0 && self.min_ulp == 0.0 && self.max_ulp == 0.0
    }
}

/// Combine one lane of SoA output planes into its value plus the high
/// word the ulp unit derives from: `hi + lo` for the two-plane
/// (float-float) operators, the plane itself for the `f32` baselines.
#[inline]
fn lane_value(planes: &[Vec<f32>], i: usize) -> (f64, f32) {
    let hi = planes[0][i];
    if planes.len() >= 2 {
        (hi as f64 + planes[1][i] as f64, hi)
    } else {
        (hi as f64, hi)
    }
}

/// Diff `valid` lanes of `got` against `reference`, starting at
/// `offset` into both plane sets. Lanes outside `[offset,
/// offset + valid)` — the padding of a fused launch, or neighbouring
/// requests in the same launch — are never read into the statistics.
///
/// Both plane sets must have `op.n_out()` planes of at least
/// `offset + valid` lanes.
pub fn diff_outputs(
    op: Op, reference: &[Vec<f32>], got: &[Vec<f32>], offset: usize, valid: usize,
) -> UlpDiff {
    debug_assert_eq!(reference.len(), op.n_out());
    debug_assert_eq!(got.len(), op.n_out());
    debug_assert!(reference.iter().chain(got).all(|p| p.len() >= offset + valid));
    let mut d = UlpDiff::default();
    let mut worst_abs = 0.0f64;
    for lane in 0..valid {
        let i = offset + lane;
        let (rv, rh) = lane_value(reference, i);
        let (gv, gh) = lane_value(got, i);
        if !rv.is_finite() || !gv.is_finite() {
            d.non_finite += 1;
            continue;
        }
        let err = gv - rv;
        // unit from the larger-magnitude high word: stable when a
        // flush-to-zero model zeroed one side
        let unit = ulp_f32(if gh.abs() >= rh.abs() { gh } else { rh });
        let ulps = err / unit;
        let rel = if rv != 0.0 { (err / rv).abs() } else { 0.0 };
        if d.lanes == 0 {
            d.min_ulp = ulps;
            d.max_ulp = ulps;
        } else {
            d.min_ulp = d.min_ulp.min(ulps);
            d.max_ulp = d.max_ulp.max(ulps);
        }
        d.lanes += 1;
        d.sum_abs_ulp += ulps.abs();
        if rv != 0.0 {
            d.max_rel = d.max_rel.max(rel);
        }
        if d.worst_lane.is_none() || ulps.abs() > worst_abs {
            worst_abs = ulps.abs();
            d.worst_lane = Some(lane);
            d.worst_ulp = ulps;
            d.worst_rel = rel;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_plane(vals: &[f32]) -> Vec<Vec<f32>> {
        vec![vals.to_vec()]
    }

    #[test]
    fn identical_outputs_are_exact() {
        let r = vec![vec![1.0f32, -2.5, 3.25], vec![1e-9, 0.0, -1e-10]];
        let d = diff_outputs(Op::Add22, &r, &r.clone(), 0, 3);
        assert_eq!(d.lanes, 3);
        assert!(d.is_exact());
        assert_eq!(d.mean_abs_ulp(), 0.0);
        assert_eq!(d.max_rel, 0.0);
        assert_eq!(d.non_finite, 0);
    }

    #[test]
    fn one_step_error_is_one_ulp() {
        let r = one_plane(&[4.0]);
        let g = one_plane(&[f32::from_bits(4.0f32.to_bits() + 1)]);
        let d = diff_outputs(Op::Add, &r, &g, 0, 1);
        assert!((d.max_ulp - 1.0).abs() < 1e-12, "{d:?}");
        assert_eq!(d.min_ulp, d.max_ulp);
        assert_eq!(d.worst_lane, Some(0));
        assert!((d.worst_ulp - 1.0).abs() < 1e-12);
        // relative error of 1 ulp at 4.0 = 2^-21 / 4 = 2^-23
        assert!((d.max_rel.log2() + 23.0).abs() < 1e-9, "{}", d.max_rel);
    }

    #[test]
    fn signed_zero_is_not_an_error() {
        // a model that returns -0.0 where the reference has +0.0 (and
        // vice versa) is numerically exact
        let r = vec![vec![0.0f32, -0.0], vec![0.0, 0.0]];
        let g = vec![vec![-0.0f32, 0.0], vec![-0.0, -0.0]];
        let d = diff_outputs(Op::Add22, &r, &g, 0, 2);
        assert_eq!(d.lanes, 2);
        assert!(d.is_exact(), "{d:?}");
        assert_eq!(d.max_rel, 0.0);
    }

    #[test]
    fn subnormal_flush_is_measured_in_subnormal_ulps() {
        // the reference keeps a 5-step subnormal; a flush-to-zero model
        // returns 0.0. The unit comes from the larger-magnitude side
        // (the reference), so the error is exactly -5 subnormal steps,
        // not an infinity from ulp(0).
        let sub = f32::from_bits(5);
        let r = one_plane(&[sub]);
        let g = one_plane(&[0.0]);
        let d = diff_outputs(Op::Add, &r, &g, 0, 1);
        assert!((d.min_ulp + 5.0).abs() < 1e-9, "{d:?}");
        assert_eq!(d.worst_lane, Some(0));
        // the flush is 100% relative error
        assert!((d.max_rel - 1.0).abs() < 1e-12);
        // and in the other direction (model manufactures a subnormal)
        // the unit still comes from the non-zero side
        let d = diff_outputs(Op::Add, &g, &r, 0, 1);
        assert!((d.max_ulp - 5.0).abs() < 1e-9, "{d:?}");
        // reference is zero there: relative error undefined, skipped
        assert_eq!(d.max_rel, 0.0);
    }

    #[test]
    fn pad_lanes_are_excluded() {
        // lanes 2.. are fused-launch padding filled with garbage on the
        // "got" side; only the 2 valid lanes may reach the statistics
        let r = vec![vec![1.0f32, 2.0, 0.0, 0.0], vec![0.0; 4]];
        let g = vec![vec![1.0f32, 2.0, 7777.0, -1e30], vec![0.0; 4]];
        let d = diff_outputs(Op::Add22, &r, &g, 0, 2);
        assert_eq!(d.lanes, 2);
        assert!(d.is_exact(), "pad lanes leaked into the diff: {d:?}");
    }

    #[test]
    fn offset_slices_align_per_request() {
        // two requests fused into one launch: request B occupies lanes
        // [2, 4) and only its own lanes are diffed
        let r = one_plane(&[1.0, 1.0, 8.0, 16.0]);
        let mut gv = r[0].clone();
        gv[0] = 999.0; // request A's error must not show up
        gv[2] = f32::from_bits(8.0f32.to_bits() + 2);
        let g = one_plane(&gv);
        let d = diff_outputs(Op::Add, &r, &g, 2, 2);
        assert_eq!(d.lanes, 2);
        assert!((d.max_ulp - 2.0).abs() < 1e-12, "{d:?}");
        // worst lane is reported relative to the request slice
        assert_eq!(d.worst_lane, Some(0));
    }

    #[test]
    fn non_finite_lanes_are_counted_not_scored() {
        let r = one_plane(&[1.0, f32::NAN, f32::INFINITY, 2.0]);
        let g = one_plane(&[1.0, 1.0, f32::INFINITY, 2.0]);
        let d = diff_outputs(Op::Add, &r, &g, 0, 4);
        assert_eq!(d.lanes, 2, "{d:?}");
        assert_eq!(d.non_finite, 2);
        assert!(d.is_exact());
        assert!(d.max_ulp.is_finite() && d.min_ulp.is_finite());
    }

    #[test]
    fn worst_lane_tracks_the_largest_magnitude() {
        let r = one_plane(&[1.0, 1.0, 1.0]);
        let g = one_plane(&[
            f32::from_bits(1.0f32.to_bits() + 1),
            f32::from_bits(1.0f32.to_bits() - 3), // 3 steps low (below 1.0 the step halves)
            1.0,
        ]);
        let d = diff_outputs(Op::Add, &r, &g, 0, 3);
        assert_eq!(d.worst_lane, Some(1));
        assert!(d.worst_ulp < 0.0);
        assert!(d.worst_abs_ulp() >= 1.0);
        assert!(d.mean_abs_ulp() > 0.0);
    }
}
