//! [`XlaBackend`]: PJRT/XLA artifacts behind the [`KernelBackend`] trait.
//!
//! Wraps [`crate::runtime::Runtime`] and owns the pad-to-compiled-size
//! launch planning that used to be inlined in the coordinator: AOT
//! compilation fixes stream lengths, so a batch is split over the
//! compiled sizes ([`crate::coordinator::batcher::plan`]), each launch
//! staged into pooled padded planes, executed, and copied back into the
//! caller's output planes.
//!
//! Construction goes through [`crate::runtime::Runtime::new`], which
//! requires the `xla` cargo feature (and an artifacts directory from
//! `make artifacts`); without either, `XlaBackend::new` returns a
//! [`ServiceError::Backend`] and the coordinator reports a clean
//! startup failure.

use super::pool::BufferPool;
use super::{
    check_outputs, BackendStats, ExecJob, ExecReport, KernelBackend, Op, ServiceError,
};
use crate::coordinator::batcher;
use crate::runtime::Runtime;
use std::path::Path;
use std::time::Instant;

/// PJRT artifact backend. Not `Send`: build it on the shard thread.
pub struct XlaBackend {
    rt: Runtime,
    pool: BufferPool,
    stats: BackendStats,
}

impl XlaBackend {
    pub fn new(artifacts: &Path, precompile: bool) -> Result<XlaBackend, ServiceError> {
        let rt = Runtime::new(artifacts).map_err(ServiceError::Backend)?;
        if precompile {
            let names: Vec<String> = rt
                .manifest()
                .entries
                .iter()
                .filter(|e| e.kind == "stream")
                .map(|e| e.name.clone())
                .collect();
            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
            rt.precompile(&refs).map_err(ServiceError::Backend)?;
        }
        Ok(XlaBackend { rt, pool: BufferPool::new(), stats: BackendStats::default() })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Compiled stream sizes for `op`, ascending.
    fn sizes_for(&self, op: Op) -> Vec<usize> {
        self.rt
            .manifest()
            .by_op(op.name())
            .iter()
            .filter(|e| e.kind == "stream")
            .map(|e| e.n)
            .collect()
    }
}

impl KernelBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn ops(&self) -> Vec<Op> {
        Op::ALL
            .into_iter()
            .filter(|&op| !self.sizes_for(op).is_empty())
            .collect()
    }

    fn execute(
        &mut self, job: &ExecJob, outputs: &mut [Vec<f32>],
    ) -> Result<ExecReport, ServiceError> {
        let n = check_outputs("xla", job, outputs)?;
        let op = job.op();
        let sizes = self.sizes_for(op);
        let Some(plan) = batcher::plan(n, &sizes) else {
            return Err(ServiceError::Unsupported { backend: "xla", op });
        };
        let t0 = Instant::now();
        let mut padded = 0u64;
        for l in &plan {
            let name = format!("{op}_n{}", l.size);
            // stage each input window into a pooled, padded plane
            let mut staged: Vec<Vec<f32>> = Vec::with_capacity(op.n_in());
            for (p, plane) in job.inputs().iter().enumerate() {
                let mut buf = self.pool.take_empty();
                buf.extend_from_slice(&plane[l.start..l.start + l.len]);
                buf.resize(l.size, op.pad_value(p));
                staged.push(buf);
            }
            let staged_refs: Vec<&[f32]> = staged.iter().map(Vec::as_slice).collect();
            let result = self.rt.execute(&name, &staged_refs);
            drop(staged_refs);
            // recycle the staging planes before any error can propagate,
            // so launch failures don't drain the pool
            for buf in staged {
                self.pool.put(buf);
            }
            let outs = result.map_err(ServiceError::Backend)?;
            if outs.len() != op.n_out() {
                return Err(ServiceError::Backend(format!(
                    "{name}: expected {} output planes, got {}",
                    op.n_out(),
                    outs.len()
                )));
            }
            for (o, plane) in outs.iter().enumerate() {
                outputs[o][l.start..l.start + l.len].copy_from_slice(&plane[..l.len]);
            }
            padded += (l.size - l.len) as u64;
        }
        self.stats.executions += 1;
        self.stats.elements += n as u64;
        self.stats.busy_seconds += t0.elapsed().as_secs_f64();
        Ok(ExecReport { launches: plan.len(), padded_elements: padded })
    }

    fn stats(&self) -> BackendStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_without_artifacts_fails_cleanly() {
        let err = XlaBackend::new(Path::new("/nonexistent/artifacts"), false)
            .err()
            .expect("must fail without artifacts");
        assert!(matches!(err, ServiceError::Backend(_)));
    }
}
