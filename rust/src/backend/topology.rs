//! CPU topology discovery and thread placement for the NUMA-aware
//! data path.
//!
//! The paper's premise is that float-float streams are bandwidth-bound
//! (the NV35/R300 operators saturate memory, not ALUs), so on a
//! multi-socket or chiplet host the serving stack lives or dies by
//! *where* its staging buffers land. This module is the std-only
//! locality layer the rest of the stack consumes:
//!
//! * [`Topology`] — NUMA nodes and their CPU lists, discovered from
//!   sysfs (`/sys/devices/system/node/node*/cpulist`) plus L2/L3 cache
//!   sizes, degrading to a single synthetic node on macOS, containers
//!   with masked sysfs, or unparsable trees — pinning becomes a no-op,
//!   never an error;
//! * [`pin_current_thread`] — `sched_setaffinity` as a **raw syscall**
//!   (no libc dependency) on Linux x86_64/aarch64, a no-op returning
//!   `false` everywhere else;
//! * [`NumaMode`] — the `--numa` / `FFGPU_NUMA` placement selector the
//!   coordinator resolves per shard (explicit
//!   [`crate::backend::BackendSpec::Native`] `node` pins always win).
//!
//! Discovery is fixture-testable: [`Topology::from_sysfs_root`] and
//! [`cache_bytes_from`] take the directory to scan, so the parsers run
//! against synthetic trees in tests regardless of the build host.

use super::error::ServiceError;
use std::path::Path;

/// Where Linux exposes NUMA nodes.
pub const SYSFS_NODE_DIR: &str = "/sys/devices/system/node";

/// Where Linux exposes cpu0's cache hierarchy.
pub const SYSFS_CACHE_DIR: &str = "/sys/devices/system/cpu/cpu0/cache";

/// One NUMA node: its id and the CPUs that live on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    pub id: usize,
    /// Sorted, deduplicated CPU ids from the node's `cpulist`.
    pub cpus: Vec<usize>,
}

/// The machine's CPU topology as the serving stack sees it: one or
/// more NUMA nodes plus the cache sizes chunk auto-sizing reads.
///
/// Always usable: when sysfs is missing or malformed,
/// [`Topology::fallback`] synthesises a single node holding every
/// available CPU, on which placement ([`Topology::assign`]) is a
/// no-op — containerized and single-socket hosts serve identically to
/// the pre-NUMA stack.
#[derive(Clone, Debug)]
pub struct Topology {
    nodes: Vec<NumaNode>,
    l2_bytes: Option<usize>,
    l3_bytes: Option<usize>,
    from_sysfs: bool,
}

impl Topology {
    /// Discover the host topology: sysfs nodes when readable, the
    /// single-node fallback otherwise; cache sizes are best-effort.
    pub fn detect() -> Topology {
        let mut t = Topology::from_sysfs_root(Path::new(SYSFS_NODE_DIR))
            .unwrap_or_else(Topology::fallback);
        t.l2_bytes = detect_cache_bytes(2);
        t.l3_bytes = detect_cache_bytes(3);
        t
    }

    /// Parse a sysfs-style node directory (a directory holding
    /// `node<N>/cpulist` entries). Returns `None` when the directory
    /// is unreadable or yields no valid node — callers degrade to
    /// [`Topology::fallback`]. Nodes with a missing or malformed
    /// `cpulist` are skipped rather than invented.
    pub fn from_sysfs_root(node_dir: &Path) -> Option<Topology> {
        let entries = std::fs::read_dir(node_dir).ok()?;
        let mut nodes = Vec::new();
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(id_str) = name.strip_prefix("node") else { continue };
            let Ok(id) = id_str.parse::<usize>() else { continue };
            let Ok(list) = std::fs::read_to_string(e.path().join("cpulist")) else {
                continue;
            };
            if let Some(cpus) = parse_cpulist(&list) {
                nodes.push(NumaNode { id, cpus });
            }
        }
        if nodes.is_empty() {
            return None;
        }
        nodes.sort_by_key(|n| n.id);
        Some(Topology { nodes, l2_bytes: None, l3_bytes: None, from_sysfs: true })
    }

    /// The single-node degradation: node 0 holds every available CPU.
    pub fn fallback() -> Topology {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Topology {
            nodes: vec![NumaNode { id: 0, cpus: (0..n).collect() }],
            l2_bytes: None,
            l3_bytes: None,
            from_sysfs: false,
        }
    }

    /// The discovered nodes, ascending by id (never empty).
    pub fn nodes(&self) -> &[NumaNode] {
        &self.nodes
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Whether this host has no placement decision to make.
    pub fn is_single_node(&self) -> bool {
        self.nodes.len() <= 1
    }

    /// Whether the topology came from sysfs (vs the synthetic fallback).
    pub fn from_sysfs(&self) -> bool {
        self.from_sysfs
    }

    /// CPU list of node `id`; `None` for unknown ids (pinning to an
    /// unknown node degrades to no pin).
    pub fn cpus_of(&self, id: usize) -> Option<&[usize]> {
        self.nodes.iter().find(|n| n.id == id).map(|n| n.cpus.as_slice())
    }

    /// Round-robin node assignment for shard `shard`: `None` on
    /// single-node hosts (no decision to make), otherwise the shard's
    /// home node in discovery order.
    pub fn assign(&self, shard: usize) -> Option<usize> {
        if self.is_single_node() {
            None
        } else {
            Some(self.nodes[shard % self.nodes.len()].id)
        }
    }

    /// L2 data-cache size in bytes, when sysfs reported one.
    pub fn l2_bytes(&self) -> Option<usize> {
        self.l2_bytes
    }

    /// L3 cache size in bytes, when sysfs reported one.
    pub fn l3_bytes(&self) -> Option<usize> {
        self.l3_bytes
    }
}

/// Parse a sysfs `cpulist`: comma-separated CPU ids and inclusive
/// ranges (`"0-3,8-11"`, `"0"`, `"2,5"`). Returns `None` on empty or
/// malformed input (reversed ranges, non-numeric entries) — a node
/// with an unparsable list is skipped, never guessed at.
pub fn parse_cpulist(s: &str) -> Option<Vec<usize>> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        match part.split_once('-') {
            Some((a, b)) => {
                let a = a.trim().parse::<usize>().ok()?;
                let b = b.trim().parse::<usize>().ok()?;
                // a reversed or absurdly wide range is corrupt input,
                // not a 65k-CPU machine
                if a > b || b - a >= 1 << 16 {
                    return None;
                }
                cpus.extend(a..=b);
            }
            None => cpus.push(part.parse::<usize>().ok()?),
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    Some(cpus)
}

/// Cache size in bytes for `level` via cpu0's sysfs hierarchy (Linux;
/// `None` elsewhere — std exposes no cache geometry).
pub fn detect_cache_bytes(level: usize) -> Option<usize> {
    if cfg!(target_os = "linux") {
        cache_bytes_from(Path::new(SYSFS_CACHE_DIR), level)
    } else {
        None
    }
}

/// Scan a sysfs-style cache directory (`index<N>` subdirectories with
/// `level`/`type`/`size` files) for the first data or unified cache at
/// `level` and parse its size.
pub fn cache_bytes_from(cache_dir: &Path, level: usize) -> Option<usize> {
    let entries = std::fs::read_dir(cache_dir).ok()?;
    for e in entries.flatten() {
        let p = e.path();
        let lv = std::fs::read_to_string(p.join("level"))
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok());
        if lv != Some(level) {
            continue;
        }
        let ty = std::fs::read_to_string(p.join("type")).unwrap_or_default();
        if ty.trim() == "Instruction" {
            continue;
        }
        if let Some(b) = std::fs::read_to_string(p.join("size"))
            .ok()
            .and_then(|s| parse_cache_size(s.trim()))
        {
            return Some(b);
        }
    }
    None
}

/// Parse sysfs cache sizes: `"512K"`, `"1M"`, `"1024"` (bytes).
pub fn parse_cache_size(s: &str) -> Option<usize> {
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|n| n * mult)
}

/// NUMA placement selector (`--numa` / `FFGPU_NUMA`), resolved per
/// service start. Explicit per-shard
/// [`crate::backend::BackendSpec::Native`] `node` pins override it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NumaMode {
    /// Round-robin native shards across the detected nodes; a no-op on
    /// single-node hosts. The default.
    #[default]
    Auto,
    /// No pinning anywhere (the pre-NUMA behaviour).
    Off,
    /// Pin every native shard to one node.
    Node(usize),
}

impl NumaMode {
    /// `FFGPU_NUMA` (`auto` | `off` | `<node>`); unset or unparsable
    /// degrades to [`NumaMode::Auto`] — the env path never fails a
    /// service start.
    pub fn from_env() -> NumaMode {
        match std::env::var("FFGPU_NUMA") {
            Ok(s) => NumaMode::from_cli(&s).unwrap_or(NumaMode::Auto),
            Err(_) => NumaMode::Auto,
        }
    }

    /// Strict parse for the `--numa` flag: `auto`, `off`/`none`, or a
    /// node id.
    pub fn from_cli(s: &str) -> Result<NumaMode, ServiceError> {
        match s.trim() {
            "" | "auto" => Ok(NumaMode::Auto),
            "off" | "none" => Ok(NumaMode::Off),
            other => other.parse::<usize>().map(NumaMode::Node).map_err(|_| {
                ServiceError::Backend(format!(
                    "bad numa mode '{other}' (try auto, off, or a node id)"
                ))
            }),
        }
    }

    /// Human-readable form for banners.
    pub fn describe(&self) -> String {
        match self {
            NumaMode::Auto => "auto".to_string(),
            NumaMode::Off => "off".to_string(),
            NumaMode::Node(n) => format!("node{n}"),
        }
    }
}

/// Pin the calling thread to `cpus` with a raw `sched_setaffinity`
/// syscall (pid 0 = this thread) — no libc. Returns whether the kernel
/// accepted the mask; `false` (and no side effect) on non-Linux
/// targets, unsupported architectures, an empty/out-of-range CPU set,
/// or a kernel refusal (e.g. a cgroup cpuset that excludes the mask).
/// Callers treat `false` as "serve unpinned", never as an error.
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    pin_impl(cpus)
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn pin_impl(cpus: &[usize]) -> bool {
    // 16 × u64 = 1024 CPUs, the kernel's historical cpu_set_t width
    const MASK_WORDS: usize = 16;
    let mut mask = [0u64; MASK_WORDS];
    let mut any = false;
    for &c in cpus {
        if c < MASK_WORDS * 64 {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return false;
    }
    #[cfg(target_arch = "x86_64")]
    const SYS_SCHED_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "aarch64")]
    const SYS_SCHED_SETAFFINITY: usize = 122;
    let ret: isize;
    // SAFETY: the syscall reads MASK_WORDS*8 bytes from `mask`, which
    // outlives the call; pid 0 targets only the calling thread, so no
    // other thread's state is touched. asm! without `nomem` already
    // tells the compiler memory may be read.
    unsafe {
        #[cfg(target_arch = "x86_64")]
        std::arch::asm!(
            "syscall",
            inlateout("rax") SYS_SCHED_SETAFFINITY as isize => ret,
            in("rdi") 0usize,
            in("rsi") MASK_WORDS * 8,
            in("rdx") mask.as_ptr() as usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        #[cfg(target_arch = "aarch64")]
        std::arch::asm!(
            "svc 0",
            in("x8") SYS_SCHED_SETAFFINITY,
            inlateout("x0") 0usize => ret,
            in("x1") MASK_WORDS * 8,
            in("x2") mask.as_ptr() as usize,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_impl(_cpus: &[usize]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// A throwaway sysfs-shaped fixture tree under the system temp dir
    /// (std-only: no tempfile crate in the image). Unique per test via
    /// pid + a process-wide counter; removed on drop.
    struct Fixture {
        root: PathBuf,
    }

    impl Fixture {
        fn new(tag: &str) -> Fixture {
            static SEQ: AtomicUsize = AtomicUsize::new(0);
            let n = SEQ.fetch_add(1, Ordering::Relaxed);
            let root = std::env::temp_dir().join(format!(
                "ffgpu-topo-{}-{tag}-{n}",
                std::process::id()
            ));
            std::fs::create_dir_all(&root).unwrap();
            Fixture { root }
        }

        fn write(&self, rel: &str, contents: &str) {
            let p = self.root.join(rel);
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(p, contents).unwrap();
        }
    }

    impl Drop for Fixture {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }

    #[test]
    fn cpulist_parses_ids_ranges_and_mixes() {
        assert_eq!(parse_cpulist("0"), Some(vec![0]));
        assert_eq!(parse_cpulist("0-3"), Some(vec![0, 1, 2, 3]));
        assert_eq!(
            parse_cpulist("0-3,8-11"),
            Some(vec![0, 1, 2, 3, 8, 9, 10, 11])
        );
        assert_eq!(parse_cpulist(" 2, 5 ,7\n"), Some(vec![2, 5, 7]));
        // overlaps dedup, order normalises
        assert_eq!(parse_cpulist("4-6,5,0"), Some(vec![0, 4, 5, 6]));
    }

    #[test]
    fn cpulist_rejects_malformed_input() {
        assert_eq!(parse_cpulist(""), None);
        assert_eq!(parse_cpulist("  \n"), None);
        assert_eq!(parse_cpulist("a-b"), None);
        assert_eq!(parse_cpulist("3-1"), None, "reversed range");
        assert_eq!(parse_cpulist("0-99999999"), None, "absurd width");
        assert_eq!(parse_cpulist("1,,3"), None);
        assert_eq!(parse_cpulist("1;3"), None);
    }

    #[test]
    fn multi_node_fixture_tree_discovers_both_nodes() {
        let fx = Fixture::new("multi");
        fx.write("node0/cpulist", "0-3\n");
        fx.write("node1/cpulist", "4-7\n");
        // decoys the scanner must ignore
        fx.write("possible", "0-7\n");
        fx.write("nodeX/cpulist", "0\n");
        let t = Topology::from_sysfs_root(&fx.root).unwrap();
        assert!(t.from_sysfs());
        assert_eq!(t.node_count(), 2);
        assert!(!t.is_single_node());
        assert_eq!(t.cpus_of(0), Some(&[0, 1, 2, 3][..]));
        assert_eq!(t.cpus_of(1), Some(&[4, 5, 6, 7][..]));
        assert_eq!(t.cpus_of(7), None);
        // round-robin shard placement alternates nodes
        assert_eq!(t.assign(0), Some(0));
        assert_eq!(t.assign(1), Some(1));
        assert_eq!(t.assign(2), Some(0));
        assert_eq!(t.assign(5), Some(1));
    }

    #[test]
    fn single_node_fixture_assigns_nothing() {
        let fx = Fixture::new("single");
        fx.write("node0/cpulist", "0-15\n");
        let t = Topology::from_sysfs_root(&fx.root).unwrap();
        assert!(t.is_single_node());
        assert_eq!(t.assign(0), None, "single node: placement is a no-op");
        assert_eq!(t.assign(3), None);
        assert_eq!(t.cpus_of(0).unwrap().len(), 16);
    }

    #[test]
    fn missing_and_malformed_trees_degrade_cleanly() {
        // nonexistent directory: no topology at all
        let gone = std::env::temp_dir().join("ffgpu-topo-definitely-missing");
        assert!(Topology::from_sysfs_root(&gone).is_none());
        // a node dir without a cpulist file is skipped; if nothing
        // remains, discovery reports None rather than a phantom node
        let fx = Fixture::new("empty");
        std::fs::create_dir_all(fx.root.join("node0")).unwrap();
        assert!(Topology::from_sysfs_root(&fx.root).is_none());
        // malformed cpulist on one node: that node is skipped, the
        // valid one survives
        let fx = Fixture::new("mixed");
        fx.write("node0/cpulist", "0-3,8-11\n");
        fx.write("node1/cpulist", "7-2\n");
        let t = Topology::from_sysfs_root(&fx.root).unwrap();
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.cpus_of(0), Some(&[0, 1, 2, 3, 8, 9, 10, 11][..]));
    }

    #[test]
    fn fallback_is_one_node_with_every_cpu() {
        let t = Topology::fallback();
        assert!(t.is_single_node());
        assert!(!t.from_sysfs());
        assert_eq!(t.nodes()[0].id, 0);
        assert!(!t.nodes()[0].cpus.is_empty());
        assert_eq!(t.assign(0), None);
        // detect() never panics and always yields at least one node —
        // the containerized-host acceptance criterion
        let d = Topology::detect();
        assert!(d.node_count() >= 1);
    }

    #[test]
    fn cache_fixture_tree_parses_data_and_unified_levels() {
        let fx = Fixture::new("cache");
        fx.write("index0/level", "1\n");
        fx.write("index0/type", "Data\n");
        fx.write("index0/size", "32K\n");
        fx.write("index1/level", "1\n");
        fx.write("index1/type", "Instruction\n");
        fx.write("index1/size", "64K\n");
        fx.write("index2/level", "2\n");
        fx.write("index2/type", "Unified\n");
        fx.write("index2/size", "1M\n");
        fx.write("index3/level", "3\n");
        fx.write("index3/type", "Unified\n");
        fx.write("index3/size", "32M\n");
        assert_eq!(cache_bytes_from(&fx.root, 1), Some(32 * 1024), "skip icache");
        assert_eq!(cache_bytes_from(&fx.root, 2), Some(1024 * 1024));
        assert_eq!(cache_bytes_from(&fx.root, 3), Some(32 * 1024 * 1024));
        assert_eq!(cache_bytes_from(&fx.root, 4), None);
    }

    #[test]
    fn cache_size_parsing() {
        assert_eq!(parse_cache_size("512K"), Some(512 * 1024));
        assert_eq!(parse_cache_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_cache_size("2048k"), Some(2048 * 1024));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("big"), None);
    }

    #[test]
    fn numa_mode_parses_and_describes() {
        assert_eq!(NumaMode::from_cli("auto").unwrap(), NumaMode::Auto);
        assert_eq!(NumaMode::from_cli("").unwrap(), NumaMode::Auto);
        assert_eq!(NumaMode::from_cli("off").unwrap(), NumaMode::Off);
        assert_eq!(NumaMode::from_cli("none").unwrap(), NumaMode::Off);
        assert_eq!(NumaMode::from_cli("1").unwrap(), NumaMode::Node(1));
        assert!(NumaMode::from_cli("sideways").is_err());
        assert_eq!(NumaMode::default(), NumaMode::Auto);
        assert_eq!(NumaMode::Auto.describe(), "auto");
        assert_eq!(NumaMode::Node(2).describe(), "node2");
    }

    #[test]
    fn pinning_is_a_safe_no_op_on_degenerate_masks() {
        // empty and out-of-range sets are refused without a syscall
        assert!(!pin_current_thread(&[]));
        assert!(!pin_current_thread(&[100_000]));
        // a real mask either pins or is refused by the kernel/cgroup —
        // both are acceptable; the call must simply not crash or hang
        let t = Topology::detect();
        let _ = pin_current_thread(&t.nodes()[0].cpus);
    }
}
