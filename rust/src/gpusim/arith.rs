//! Parameterised soft floating-point arithmetic.
//!
//! Every operation takes an [`OpRounding`] describing the datapath the
//! way 2006 GPU ALUs differed from IEEE:
//!
//! * `guard_bits` — how many extra low bits the datapath keeps while
//!   aligning/accumulating. **0 guard bits** is the ATI R300 behaviour
//!   that breaks Sterbenz subtraction (paper Table 2, §4.1); **1 guard
//!   bit** is what the paper assumes for Nvidia.
//! * `sticky` — whether bits shifted past the guards are OR-ed into a
//!   sticky bit (IEEE needs it for correct rounding; GPUs of the era
//!   dropped it).
//! * `mode` — final rounding: truncate (chopped), round-to-nearest-even,
//!   or round-to-nearest-away.
//!
//! Values are [`SoftFp`]: sign/exponent/normalised-mantissa triples in a
//! given [`Format`]. All arithmetic is integer-exact inside the declared
//! datapath — no hidden f64 shortcuts — so the simulated error intervals
//! are *consequences of the datapath*, as on the real chips.

use super::format::Format;

/// Final rounding of the kept bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// Chop: discard kept guard bits (round toward zero).
    Truncate,
    /// Round to nearest, ties to even.
    NearestEven,
    /// Round to nearest, ties away from zero.
    NearestAway,
}

/// Datapath description for one operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpRounding {
    pub guard_bits: u32,
    pub sticky: bool,
    pub mode: RoundMode,
}

impl OpRounding {
    /// IEEE-correct rounding: effectively infinite guard via sticky + RNE.
    pub const IEEE: OpRounding =
        OpRounding { guard_bits: 2, sticky: true, mode: RoundMode::NearestEven };
    /// Pure chopping with no guard (worst 2006 GPU behaviour).
    pub const CHOP: OpRounding =
        OpRounding { guard_bits: 0, sticky: false, mode: RoundMode::Truncate };
    /// One guard bit then truncate — the paper's Nvidia addition model.
    pub const GUARD_TRUNC: OpRounding =
        OpRounding { guard_bits: 1, sticky: false, mode: RoundMode::Truncate };
}

/// A soft floating-point value in some [`Format`].
///
/// Invariants (enforced by constructors): either `mant == 0` (zero) or
/// `2^(p-1) <= mant < 2^p` with `value = ±mant · 2^(exp - p + 1)`.
/// Saturated overflow is represented by the max finite value when the
/// format has no specials; `inf` is a flag otherwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SoftFp {
    pub negative: bool,
    pub exp: i32,
    pub mant: u64,
    pub inf: bool,
}

impl SoftFp {
    pub const fn zero() -> Self {
        SoftFp { negative: false, exp: 0, mant: 0, inf: false }
    }

    pub fn is_zero(&self) -> bool {
        self.mant == 0 && !self.inf
    }

    /// Quantize an `f64` into the format: round mantissa to p bits (RNE),
    /// clamp exponent, flush subnormals. This is the "upload a texel"
    /// conversion.
    pub fn from_f64(v: f64, fmt: Format) -> Self {
        if v == 0.0 || v.is_nan() {
            return Self::zero();
        }
        if v.is_infinite() {
            return Self::saturate(v < 0.0, fmt);
        }
        let negative = v < 0.0;
        let a = v.abs();
        let e = a.log2().floor() as i32;
        let p = fmt.precision();
        // mantissa as integer in [2^(p-1), 2^p)
        let scaled = a / pow2(e) * pow2(p as i32 - 1);
        let mut mant = scaled.round() as u64;
        let mut exp = e;
        if mant == 1 << p {
            exp += 1;
            // keep normalised: mant back to p bits
            mant = 1 << (p - 1);
        }
        if exp > fmt.emax() {
            return Self::saturate(negative, fmt);
        }
        if exp < fmt.emin() {
            return Self::zero(); // flush (all GPU formats flush)
        }
        SoftFp { negative, exp, mant, inf: false }
    }

    pub fn from_f32(v: f32, fmt: Format) -> Self {
        Self::from_f64(v as f64, fmt)
    }

    /// Exact value as f64 (p <= 24 and |exp| <= 128: always exact).
    pub fn to_f64(&self, fmt: Format) -> f64 {
        if self.inf {
            return if self.negative { f64::NEG_INFINITY } else { f64::INFINITY };
        }
        if self.mant == 0 {
            return 0.0;
        }
        let p = fmt.precision() as i32;
        let v = self.mant as f64 * pow2(self.exp - p + 1);
        if self.negative { -v } else { v }
    }

    fn saturate(negative: bool, fmt: Format) -> Self {
        if fmt.has_specials {
            SoftFp { negative, exp: 0, mant: 0, inf: true }
        } else {
            let p = fmt.precision();
            SoftFp { negative, exp: fmt.emax(), mant: (1 << p) - 1, inf: false }
        }
    }

    /// One ulp of this value, as f64 (for error measurement).
    pub fn ulp(&self, fmt: Format) -> f64 {
        let p = fmt.precision() as i32;
        pow2(self.exp - p + 1)
    }
}

fn pow2(e: i32) -> f64 {
    (e as f64).exp2()
}

/// Addition/subtraction in the described datapath.
///
/// Alignment keeps `guard_bits` extra bits of the smaller operand
/// (plus a sticky OR if configured); everything below is **discarded
/// before the add**, exactly like a narrow ALU. The final result is
/// rounded to p bits with `mode`.
pub fn add(a: SoftFp, b: SoftFp, fmt: Format, r: OpRounding) -> SoftFp {
    if a.inf || b.inf {
        // saturating semantics are enough for our workloads
        return if a.inf { a } else { b };
    }
    if a.is_zero() {
        return b;
    }
    if b.is_zero() {
        return a;
    }
    let p = fmt.precision();
    // Effective extra resolution: the guard bits, plus one sticky
    // position when the datapath keeps a sticky bit. Folding the sticky
    // OR into the LSB is the classic G/R/S construction and makes
    // effective subtraction borrow correctly.
    let g = r.guard_bits + r.sticky as u32;
    // order by magnitude (exp, then mant)
    let (big, small) = if (a.exp, a.mant) >= (b.exp, b.mant) { (a, b) } else { (b, a) };
    let shift = (big.exp - small.exp) as u32;

    // Work at resolution 2^(big.exp - p + 1 - g): big scaled by 2^g.
    let effective_sub = big.negative != small.negative;
    let big_m = (big.mant as u128) << g;
    let small_m = if shift <= g {
        (small.mant as u128) << (g - shift)
    } else {
        let drop = shift - g; // bits discarded before the add
        let (kept, lost_any) = if drop >= 64 {
            (0u128, small.mant != 0)
        } else {
            ((small.mant as u128) >> drop, small.mant & ((1u64 << drop) - 1) != 0)
        };
        if r.sticky && lost_any {
            match r.mode {
                // RNE/RNA: classic sticky-OR into the lowest kept bit
                RoundMode::NearestEven | RoundMode::NearestAway => kept | 1,
                // ideal chop (round toward zero of the *exact* result):
                // on effective subtraction the small operand must round
                // *up* in magnitude so the difference floors correctly
                RoundMode::Truncate => kept + effective_sub as u128,
            }
        } else {
            kept
        }
    };

    let (sum, negative) = if big.negative == small.negative {
        (big_m + small_m, big.negative)
    } else if big_m >= small_m {
        (big_m - small_m, big.negative)
    } else {
        (small_m - big_m, small.negative)
    };

    if sum == 0 {
        return SoftFp::zero();
    }

    // normalise: sum has some bit-length L; target p bits.
    let l = 128 - sum.leading_zeros();
    let exp = big.exp + l as i32 - (p + g) as i32;
    round_to_format(negative, sum, l, exp, false, fmt, r.mode)
}

/// Subtraction.
pub fn sub(a: SoftFp, b: SoftFp, fmt: Format, r: OpRounding) -> SoftFp {
    let nb = SoftFp { negative: !b.negative, ..b };
    add(a, nb, fmt, r)
}

/// Multiplication: exact 2p-bit product, then the datapath keeps
/// `guard_bits` bits beyond p (sticky optional) and rounds.
pub fn mul(a: SoftFp, b: SoftFp, fmt: Format, r: OpRounding) -> SoftFp {
    if a.inf || b.inf {
        return SoftFp { negative: a.negative != b.negative, exp: 0, mant: 0, inf: true };
    }
    if a.is_zero() || b.is_zero() {
        return SoftFp::zero();
    }
    let p = fmt.precision();
    let prod = (a.mant as u128) * (b.mant as u128); // 2p or 2p-1 bits
    let l = 128 - prod.leading_zeros();
    let exp = a.exp + b.exp + l as i32 - (2 * p) as i32 + 1;
    // emulate a datapath that only *sees* p + guard (+ sticky) bits:
    let keep = p + r.guard_bits + r.sticky as u32;
    let (seen, seen_len) = if l > keep {
        let drop = l - keep;
        let mut kept = prod >> drop;
        let lost = prod & ((1u128 << drop) - 1);
        if r.sticky && lost != 0 {
            kept |= 1;
        }
        (kept, keep)
    } else {
        (prod, l)
    };
    round_to_format(a.negative != b.negative, seen, seen_len, exp, false, fmt, r.mode)
}

/// Reciprocal: GPUs used table+Newton units producing a faithful (not
/// correctly rounded) reciprocal. We model it as the exactly-computed
/// reciprocal kept to `p + guard` bits, then rounded with `mode`.
pub fn recip(b: SoftFp, fmt: Format, r: OpRounding) -> SoftFp {
    if b.inf {
        return SoftFp::zero();
    }
    if b.is_zero() {
        return SoftFp::saturate(b.negative, fmt);
    }
    let p = fmt.precision();
    let keep = p + r.guard_bits + r.sticky as u32;
    // 1/b = 2^k / mant with k chosen so the quotient has `keep+1` bits
    // quotient q = floor(2^s / mant), s = keep + bits(mant)
    let s = keep + p; // mant has exactly p bits
    let num: u128 = 1u128 << s;
    let mut q = num / b.mant as u128;
    let rem = num % b.mant as u128;
    if r.sticky && rem != 0 {
        q |= 1;
    }
    let l = 128 - q.leading_zeros();
    // value = q · 2^(-s) · 2^(-(exp - p + 1))  =>  exponent algebra:
    let exp = -(b.exp - p as i32 + 1) - s as i32 + l as i32 - 1;
    round_to_format(b.negative, q, l, exp, false, fmt, r.mode)
}

/// Division as the paper observed GPUs do it: reciprocal **then**
/// multiply — two roundings, hence Table 2's division intervals
/// exceeding ±1 ulp.
pub fn div(a: SoftFp, b: SoftFp, fmt: Format, r_recip: OpRounding, r_mul: OpRounding) -> SoftFp {
    let rb = recip(b, fmt, r_recip);
    mul(a, rb, fmt, r_mul)
}

/// Round a normalised intermediate (`mant_ext` with `len` significant
/// bits, value `± mant_ext · 2^(exp - len + 1)` … conceptually) to the
/// format's p bits with the given mode, then clamp/flush to the format.
fn round_to_format(
    negative: bool, mant_ext: u128, len: u32, exp: i32, sticky_below: bool,
    fmt: Format, mode: RoundMode,
) -> SoftFp {
    debug_assert!(mant_ext != 0);
    let p = fmt.precision();
    let (mut mant, mut exp) = if len > p {
        let drop = len - p;
        let kept = (mant_ext >> drop) as u64;
        let dropped = mant_ext & ((1u128 << drop) - 1);
        let half = 1u128 << (drop - 1);
        let increment = match mode {
            RoundMode::Truncate => false,
            RoundMode::NearestEven => {
                dropped > half
                    || (dropped == half && (sticky_below || kept & 1 == 1))
            }
            RoundMode::NearestAway => dropped > half || (dropped == half),
        };
        let m = kept + increment as u64;
        (m, exp)
    } else {
        ((mant_ext as u64) << (p - len), exp)
    };
    // post-round carry
    if mant == 1 << p {
        exp += 1;
        mant = 1 << (p - 1);
    }
    if mant == 0 {
        return SoftFp::zero();
    }
    if exp > fmt.emax() {
        return SoftFp::saturate(negative, fmt);
    }
    if exp < fmt.emin() {
        return SoftFp::zero(); // flush
    }
    SoftFp { negative, exp, mant, inf: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const F: Format = Format::NV32;

    fn sf(v: f64) -> SoftFp {
        SoftFp::from_f64(v, F)
    }

    #[test]
    fn roundtrip_f32_values() {
        let mut rng = Rng::new(81);
        for _ in 0..100_000 {
            let v = rng.spread_f32(-100, 100);
            assert_eq!(sf(v as f64).to_f64(F), v as f64, "v={v}");
        }
    }

    #[test]
    fn ieee_add_matches_hardware_f32() {
        let mut rng = Rng::new(82);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let got = add(sf(a as f64), sf(b as f64), F, OpRounding::IEEE).to_f64(F);
            let want = (a + b) as f64;
            assert_eq!(got, want, "a={a} b={b}");
        }
    }

    #[test]
    fn ieee_mul_matches_hardware_f32() {
        let mut rng = Rng::new(83);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let got = mul(sf(a as f64), sf(b as f64), F, OpRounding::IEEE).to_f64(F);
            let want = (a * b) as f64;
            assert_eq!(got, want, "a={a} b={b}");
        }
    }

    #[test]
    fn ideal_chop_add_error_interval() {
        // ideal chopping (wide datapath + truncate): error in (-1, 0] ulp
        // and |result| <= |exact| (paper Table 2 "Chopped" column)
        let chop = OpRounding { guard_bits: 8, sticky: true, mode: RoundMode::Truncate };
        let mut rng = Rng::new(84);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-8, 8) as f64;
            let b = rng.spread_f32(-8, 8) as f64;
            let r = add(sf(a), sf(b), F, chop);
            // magnitude convention: chop error in (-1, 0]
            let e = (r.to_f64(F).abs() - (a + b).abs()) / r.ulp(F);
            assert!(e <= 0.0 && e > -1.0, "a={a} b={b} e={e}");
            // truncation moves toward zero
            assert!(r.to_f64(F).abs() <= (a + b).abs(), "a={a} b={b}");
        }
    }

    #[test]
    fn no_guard_chop_add_error_can_go_positive() {
        // the *hardware* no-guard chop (OpRounding::CHOP) pre-truncates
        // the aligned operand, so effective subtraction can overshoot:
        // error spans (-1, 1) — this is the R300 subtraction row.
        let mut rng = Rng::new(184);
        let mut saw_positive = false;
        for _ in 0..200_000 {
            let a = rng.spread_f32(-8, 8) as f64;
            let b = rng.spread_f32(-8, 8) as f64;
            // same-binade results only: once the result drops a binade, a
            // no-guard adder's error in result-ulps is unbounded
            // (Goldberg) — the bounded (-1, 1) claim is for the rounding
            // behaviour itself
            let scale = a.abs().max(b.abs());
            if (a + b).abs().log2().floor() != scale.log2().floor() {
                continue;
            }
            let r = add(sf(a), sf(b), F, OpRounding::CHOP);
            let e = (r.to_f64(F).abs() - (a + b).abs()) / r.ulp(F);
            assert!(e.abs() < 1.0 + 1e-9, "a={a} b={b} e={e}");
            if e > 0.25 {
                saw_positive = true;
            }
        }
        assert!(saw_positive, "expected positive errors from pre-truncation");
    }

    #[test]
    fn guard_bit_preserves_sterbenz() {
        // y/2 <= x <= 2y  =>  x - y exact with >= 1 guard bit
        let mut rng = Rng::new(85);
        for _ in 0..100_000 {
            let y = rng.spread_f32(-8, 8).abs() as f64;
            let x = y * rng.uniform(0.5, 2.0);
            let xs = sf(x);
            let ys = sf(y);
            let r = sub(xs, ys, F, OpRounding::GUARD_TRUNC);
            let want = xs.to_f64(F) - ys.to_f64(F);
            assert_eq!(r.to_f64(F), want, "x={x} y={y}");
        }
    }

    #[test]
    fn no_guard_bit_breaks_sterbenz() {
        // R300-style g=0: find a case where Sterbenz fails
        let x = sf(1.0 + 2f64.powi(-23)); // 1 + ulp
        let y = sf(2f64.powi(-23) * 1.5); // needs alignment shift of 23
        let r = sub(x, y, F, OpRounding::CHOP);
        let want = x.to_f64(F) - y.to_f64(F);
        // with the half-ulp tail discarded pre-subtract, result is wrong
        assert_ne!(r.to_f64(F), want);
    }

    #[test]
    fn mul_guard_trunc_is_faithful() {
        // |error| < 1 ulp and the two neighbours bracket the true product
        let mut rng = Rng::new(86);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-8, 8) as f64;
            let b = rng.spread_f32(-8, 8) as f64;
            let r = mul(sf(a), sf(b), F,
                        OpRounding { guard_bits: 1, sticky: false, mode: RoundMode::NearestEven });
            let err = (r.to_f64(F) - a * b) / r.ulp(F);
            assert!(err.abs() < 1.0, "a={a} b={b} err={err}");
        }
    }

    #[test]
    fn recip_then_mul_div_is_worse_than_one_ulp_sometimes() {
        let mut rng = Rng::new(87);
        let r_op = OpRounding::GUARD_TRUNC;
        let mut worst: f64 = 0.0;
        for _ in 0..200_000 {
            let a = rng.spread_f32(-8, 8) as f64;
            let b = rng.spread_f32(-8, 8) as f64;
            let q = div(sf(a), sf(b), F, r_op, r_op);
            let err = (q.to_f64(F) - a / b) / q.ulp(F);
            worst = worst.max(err.abs());
        }
        assert!(worst > 1.0, "double rounding should exceed 1 ulp, worst={worst}");
        assert!(worst < 4.0, "but stay small, worst={worst}");
    }

    #[test]
    fn ati24_quantizes_to_17_bits() {
        let v = std::f32::consts::PI as f64;
        let q = SoftFp::from_f64(v, Format::ATI24);
        let back = q.to_f64(Format::ATI24);
        // 17-bit precision: relative error <= 2^-17
        assert!(((back - v) / v).abs() <= 2f64.powi(-17));
        assert_ne!(back, v);
    }

    #[test]
    fn saturation_without_specials() {
        let big = SoftFp::from_f64(1e30, Format::ATI24);
        assert!(!big.inf);
        assert_eq!(big.exp, Format::ATI24.emax());
        let nv = SoftFp::from_f64(1e300, Format::NV32);
        assert!(nv.inf);
    }

    #[test]
    fn subnormals_flush_to_zero() {
        let tiny = SoftFp::from_f64(1e-40, Format::NV32); // subnormal in f32
        assert!(tiny.is_zero());
        // but IEEE32 format keeps... also flushes? IEEE32 has flush_subnormals=false,
        // but our SoftFp doesn't model subnormals; from_f64 flushes below emin.
        // Document: IEEE32 reference is used for normal-range tests only.
    }

    #[test]
    fn add_commutes() {
        let mut rng = Rng::new(88);
        for r in [OpRounding::IEEE, OpRounding::CHOP, OpRounding::GUARD_TRUNC] {
            for _ in 0..20_000 {
                let a = sf(rng.spread_f32(-8, 8) as f64);
                let b = sf(rng.spread_f32(-8, 8) as f64);
                assert_eq!(add(a, b, F, r), add(b, a, F, r));
            }
        }
    }

    #[test]
    fn exact_cancellation_gives_zero() {
        let a = sf(3.25);
        let r = sub(a, a, F, OpRounding::CHOP);
        assert!(r.is_zero());
    }
}
