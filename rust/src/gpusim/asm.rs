//! Mini-Brook assembler: parse textual fragment programs into
//! [`super::shader::Program`]s.
//!
//! The paper wrote its operators in Brook and then *hand-corrected the
//! generated fragment assembly* when the DirectX backend miscompiled the
//! EFT patterns (§5). This module gives that workflow a concrete form:
//! operators can be authored/inspected as assembly text, round-tripped,
//! and executed on any [`super::models::GpuModel`].
//!
//! Grammar (one instruction per line, `;` comments):
//!
//! ```text
//! ; add12 fragment program
//! in    2                 ; number of input streams
//! out   2                 ; number of output streams
//! ldin  r0, s0            ; r0 = input_stream[0]
//! ldc   r1, 4097.0        ; r1 = constant
//! add   r2, r0, r1
//! sub   r3, r2, r0
//! mul   r4, r0, r1
//! mad   r5, r0, r1, r2    ; r5 = round(round(r0*r1) + r2)
//! rcp   r6, r0
//! mov   r7, r6
//! stout s0, r2            ; output_stream[0] = r2
//! ```

use super::shader::{Instr, Program};

/// Assembly parse error: line number (1-based) + message.
#[derive(Debug, PartialEq, Eq)]
pub struct AsmError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error on line {}: {}", self.line, self.message)
    }
}

fn err(line: usize, m: impl Into<String>) -> AsmError {
    AsmError { line, message: m.into() }
}

fn reg(tok: &str, line: usize) -> Result<u8, AsmError> {
    tok.strip_prefix('r')
        .and_then(|n| n.parse::<u8>().ok())
        .filter(|&n| n < 32)
        .ok_or_else(|| err(line, format!("bad register '{tok}' (r0..r31)")))
}

fn stream(tok: &str, line: usize) -> Result<u8, AsmError> {
    tok.strip_prefix('s')
        .and_then(|n| n.parse::<u8>().ok())
        .ok_or_else(|| err(line, format!("bad stream '{tok}' (s0..)")))
}

/// Assemble a textual fragment program.
pub fn assemble(name: &str, text: &str) -> Result<Program, AsmError> {
    let mut n_in: Option<usize> = None;
    let mut n_out: Option<usize> = None;
    let mut code = Vec::new();

    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = raw.split(';').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let cleaned = line.replace(',', " ");
        let toks: Vec<&str> = cleaned.split_whitespace().collect();
        let args = &toks[1..];
        let want = |n: usize| -> Result<(), AsmError> {
            if args.len() == n {
                Ok(())
            } else {
                Err(err(line_no, format!("'{}' wants {n} operands, got {}", toks[0], args.len())))
            }
        };
        match toks[0] {
            "in" => {
                want(1)?;
                n_in = Some(args[0].parse().map_err(|_| err(line_no, "bad count"))?);
            }
            "out" => {
                want(1)?;
                n_out = Some(args[0].parse().map_err(|_| err(line_no, "bad count"))?);
            }
            "ldin" => {
                want(2)?;
                code.push(Instr::LoadIn { dst: reg(args[0], line_no)?, src: stream(args[1], line_no)? });
            }
            "ldc" => {
                want(2)?;
                let value = args[1].parse::<f64>().map_err(|_| err(line_no, "bad constant"))?;
                code.push(Instr::LoadConst { dst: reg(args[0], line_no)?, value });
            }
            "stout" => {
                want(2)?;
                code.push(Instr::StoreOut { dst: stream(args[0], line_no)?, src: reg(args[1], line_no)? });
            }
            "mov" => {
                want(2)?;
                code.push(Instr::Mov { dst: reg(args[0], line_no)?, src: reg(args[1], line_no)? });
            }
            "add" | "sub" | "mul" => {
                want(3)?;
                let (dst, a, b) = (reg(args[0], line_no)?, reg(args[1], line_no)?, reg(args[2], line_no)?);
                code.push(match toks[0] {
                    "add" => Instr::Add { dst, a, b },
                    "sub" => Instr::Sub { dst, a, b },
                    _ => Instr::Mul { dst, a, b },
                });
            }
            "mad" => {
                want(4)?;
                code.push(Instr::Mad {
                    dst: reg(args[0], line_no)?,
                    a: reg(args[1], line_no)?,
                    b: reg(args[2], line_no)?,
                    c: reg(args[3], line_no)?,
                });
            }
            "rcp" => {
                want(2)?;
                code.push(Instr::Rcp { dst: reg(args[0], line_no)?, a: reg(args[1], line_no)? });
            }
            other => return Err(err(line_no, format!("unknown mnemonic '{other}'"))),
        }
    }

    Ok(Program {
        name: name.to_string(),
        n_in: n_in.ok_or_else(|| err(0, "missing 'in' directive"))?,
        n_out: n_out.ok_or_else(|| err(0, "missing 'out' directive"))?,
        code,
    })
}

/// Disassemble a program back to text (round-trip format).
pub fn disassemble(p: &Program) -> String {
    let mut s = format!("; {}\nin    {}\nout   {}\n", p.name, p.n_in, p.n_out);
    for ins in &p.code {
        let line = match *ins {
            Instr::LoadIn { dst, src } => format!("ldin  r{dst}, s{src}"),
            Instr::LoadConst { dst, value } => format!("ldc   r{dst}, {value}"),
            Instr::StoreOut { dst, src } => format!("stout s{dst}, r{src}"),
            Instr::Mov { dst, src } => format!("mov   r{dst}, r{src}"),
            Instr::Add { dst, a, b } => format!("add   r{dst}, r{a}, r{b}"),
            Instr::Sub { dst, a, b } => format!("sub   r{dst}, r{a}, r{b}"),
            Instr::Mul { dst, a, b } => format!("mul   r{dst}, r{a}, r{b}"),
            Instr::Mad { dst, a, b, c } => format!("mad   r{dst}, r{a}, r{b}, r{c}"),
            Instr::Rcp { dst, a } => format!("rcp   r{dst}, r{a}"),
        };
        s.push_str(&line);
        s.push('\n');
    }
    s
}

/// The paper's Add12 as assembly text (the form §5's hand-corrections
/// were applied in).
pub const ADD12_ASM: &str = "\
; Add12 — Knuth two-sum, branch-free (paper Th. 2)
in    2
out   2
ldin  r0, s0        ; a
ldin  r1, s1        ; b
add   r2, r0, r1    ; s = a + b
sub   r3, r2, r0    ; bb = s - a
sub   r4, r2, r3    ; s - bb
sub   r4, r0, r4    ; a - (s - bb)   <- the sequence DirectX folded (§5)
sub   r5, r1, r3    ; b - bb
add   r6, r4, r5    ; err
stout s0, r2
stout s1, r6
";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{algorithms, shader, GpuModel};
    use crate::util::Rng;

    #[test]
    fn assembles_add12_and_matches_algorithm() {
        let prog = assemble("add12", ADD12_ASM).unwrap();
        assert_eq!(prog.n_in, 2);
        assert_eq!(prog.n_out, 2);
        assert_eq!(prog.flops(), 6);
        let m = GpuModel::NV35;
        let mut rng = Rng::new(141);
        let a: Vec<f64> = (0..256).map(|_| rng.spread_f32(-8, 8) as f64).collect();
        let b: Vec<f64> = (0..256).map(|_| rng.spread_f32(-8, 8) as f64).collect();
        let out = shader::run(&m, &prog, &[&a, &b]).unwrap();
        for i in 0..a.len() {
            let (s, e) = algorithms::add12(&m, m.quantize(a[i]), m.quantize(b[i]));
            assert_eq!(out[0][i], m.to_f64(s));
            assert_eq!(out[1][i], m.to_f64(e));
        }
    }

    #[test]
    fn roundtrip_disassemble_assemble() {
        let progs = [
            shader::programs::add12(),
            shader::programs::add22(),
            shader::programs::mul12(24),
            shader::programs::base_mad(),
        ];
        let m = GpuModel::NV35;
        let mut rng = Rng::new(142);
        for p in progs {
            let text = disassemble(&p);
            let p2 = assemble(&p.name, &text).unwrap();
            assert_eq!(p2.flops(), p.flops(), "{}", p.name);
            // behavioural equality on random streams
            let inputs: Vec<Vec<f64>> = (0..p.n_in)
                .map(|_| (0..64).map(|_| rng.spread_f32(-6, 6) as f64).collect())
                .collect();
            let refs: Vec<&[f64]> = inputs.iter().map(Vec::as_slice).collect();
            let o1 = shader::run(&m, &p, &refs).unwrap();
            let o2 = shader::run(&m, &p2, &refs).unwrap();
            assert_eq!(o1, o2, "{}", p.name);
        }
    }

    #[test]
    fn parse_errors_are_located() {
        let e = assemble("x", "in 2\nout 1\nfrobnicate r0\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.to_string().contains("frobnicate"));
        let e = assemble("x", "in 2\nout 1\nadd r0, r1\n").unwrap_err();
        assert_eq!(e.line, 3);
        let e = assemble("x", "in 2\nout 1\nadd r99, r0, r1\n").unwrap_err();
        assert!(e.message.contains("register"));
        let e = assemble("x", "add r0, r1, r2\n").unwrap_err();
        assert!(e.message.contains("'in' directive"));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let p = assemble("t", "; hi\n\nin 1\nout 1\nldin r0, s0 ; load\nstout s0, r0\n")
            .unwrap();
        assert_eq!(p.code.len(), 2);
    }
}
