//! Storage formats of the paper's Table 1.
//!
//! | Format name   | Sign | Exponent | Mantissa | Specials |
//! |---------------|------|----------|----------|----------|
//! | Nvidia 16-bit |  1   |    5     |    10    | yes      |
//! | Nvidia 32-bit |  1   |    8     |    23    | yes      |
//! | ATI 16-bit    |  1   |    5     |    10    | no       |
//! | ATI 24-bit    |  1   |    7     |    16    | no       |
//! | ATI 32-bit    |  1   |    8     |    23    | ?        |
//!
//! A format fixes *storage*; the per-operation rounding behaviour lives
//! in [`super::models::GpuModel`]. Subnormals are flushed to zero on all
//! GPU formats (paper §1.2: "denormal number which are typically flushed
//! to zero").

/// A binary floating-point storage format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Format {
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Explicit mantissa (fraction) bits — precision is `mant_bits + 1`.
    pub mant_bits: u32,
    /// Whether Inf/NaN are representable (Table 1 "support for special
    /// values"). When false, overflow saturates to the max finite value.
    pub has_specials: bool,
    /// Flush subnormal results (and inputs) to zero.
    pub flush_subnormals: bool,
}

impl Format {
    /// Nvidia 32-bit (the paper's main target: NV3x/NV4x `float`).
    pub const NV32: Format =
        Format { exp_bits: 8, mant_bits: 23, has_specials: true, flush_subnormals: true };
    /// Nvidia 16-bit `half`.
    pub const NV16: Format =
        Format { exp_bits: 5, mant_bits: 10, has_specials: true, flush_subnormals: true };
    /// ATI 16-bit.
    pub const ATI16: Format =
        Format { exp_bits: 5, mant_bits: 10, has_specials: false, flush_subnormals: true };
    /// ATI 24-bit (R300 internal compute format).
    pub const ATI24: Format =
        Format { exp_bits: 7, mant_bits: 16, has_specials: false, flush_subnormals: true };
    /// ATI 32-bit (X1k storage format).
    pub const ATI32: Format =
        Format { exp_bits: 8, mant_bits: 23, has_specials: false, flush_subnormals: true };
    /// IEEE binary32 with subnormals (CPU reference).
    pub const IEEE32: Format =
        Format { exp_bits: 8, mant_bits: 23, has_specials: true, flush_subnormals: false };

    /// Precision p in bits (including the implicit leading 1).
    pub const fn precision(&self) -> u32 {
        self.mant_bits + 1
    }

    /// Exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Maximum unbiased exponent of a finite value.
    pub const fn emax(&self) -> i32 {
        if self.has_specials {
            (1 << (self.exp_bits - 1)) - 1 // top code reserved for inf/nan
        } else {
            1 << (self.exp_bits - 1) // all codes are finite
        }
    }

    /// Minimum unbiased exponent of a normal value.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    /// Table 1 row name.
    pub fn name(&self) -> &'static str {
        match (self.exp_bits, self.mant_bits, self.has_specials, self.flush_subnormals) {
            (8, 23, true, true) => "Nvidia 32-bit",
            (5, 10, true, true) => "Nvidia 16-bit",
            (5, 10, false, true) => "ATI 16-bit",
            (7, 16, false, true) => "ATI 24-bit",
            (8, 23, false, true) => "ATI 32-bit",
            (8, 23, true, false) => "IEEE binary32",
            _ => "custom",
        }
    }

    /// All Table 1 formats, for `ffgpu info --formats`.
    pub fn table1() -> Vec<Format> {
        vec![Self::NV16, Self::NV32, Self::ATI16, Self::ATI24, Self::ATI32]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nv32_matches_binary32_geometry() {
        assert_eq!(Format::NV32.precision(), 24);
        assert_eq!(Format::NV32.bias(), 127);
        assert_eq!(Format::NV32.emax(), 127);
        assert_eq!(Format::NV32.emin(), -126);
    }

    #[test]
    fn ati24_geometry() {
        assert_eq!(Format::ATI24.precision(), 17);
        assert_eq!(Format::ATI24.bias(), 63);
        // no specials: full exponent range is finite
        assert_eq!(Format::ATI24.emax(), 64);
    }

    #[test]
    fn half_precision_geometry() {
        assert_eq!(Format::NV16.precision(), 11);
        assert_eq!(Format::NV16.bias(), 15);
        assert_eq!(Format::NV16.emin(), -14);
    }

    #[test]
    fn table1_has_five_rows_with_names() {
        let t = Format::table1();
        assert_eq!(t.len(), 5);
        let names: Vec<_> = t.iter().map(|f| f.name()).collect();
        assert!(names.contains(&"Nvidia 32-bit"));
        assert!(names.contains(&"ATI 24-bit"));
        assert!(!names.contains(&"custom"));
    }
}
