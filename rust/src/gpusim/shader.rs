//! Mini-Brook: a branch-free stream VM over the simulated GPU arithmetic.
//!
//! The paper implements its operators as Brook kernels — fragment
//! programs applied pointwise to streams (Figure 1's programmable pixel
//! units). This module is that execution model: a register machine with
//! **no control flow** (the instruction set simply has no branch — the
//! property §4 insists on: "we should avoid tests even at the expense of
//! extra computations"), running one program over SoA input streams.
//!
//! The float-float operators are provided as pre-assembled programs
//! ([`programs`]); the integration tests check them against
//! [`super::algorithms`] op-for-op.

use super::arith::SoftFp;
use super::models::GpuModel;

/// Register index.
pub type Reg = u8;

/// Branch-free instruction set of the stream VM (a faithful subset of
/// 2006 fragment-program arithmetic: MOV/ADD/SUB/MUL/MAD/RCP).
#[derive(Clone, Copy, Debug)]
pub enum Instr {
    /// `r[dst] = input_stream[src][i]`
    LoadIn { dst: Reg, src: u8 },
    /// `r[dst] = constant`
    LoadConst { dst: Reg, value: f64 },
    /// `output_stream[dst][i] = r[src]`
    StoreOut { dst: u8, src: Reg },
    Mov { dst: Reg, src: Reg },
    Add { dst: Reg, a: Reg, b: Reg },
    Sub { dst: Reg, a: Reg, b: Reg },
    Mul { dst: Reg, a: Reg, b: Reg },
    /// Fused in sequence on this era of hardware: round(round(a*b) + c).
    Mad { dst: Reg, a: Reg, b: Reg, c: Reg },
    /// Reciprocal (the unit GPUs build division from).
    Rcp { dst: Reg, a: Reg },
}

/// A fragment program: straight-line code, `n_in` input streams,
/// `n_out` output streams.
#[derive(Clone, Debug)]
pub struct Program {
    pub name: String,
    pub n_in: usize,
    pub n_out: usize,
    pub code: Vec<Instr>,
}

impl Program {
    /// Number of arithmetic instructions (the paper's op-count economics:
    /// Add12 = 6 ops, Mul12 = 17 ops with splits, etc.).
    pub fn flops(&self) -> usize {
        self.code
            .iter()
            .filter(|i| matches!(i,
                Instr::Add { .. } | Instr::Sub { .. } | Instr::Mul { .. }
                | Instr::Mad { .. } | Instr::Rcp { .. }))
            .count()
    }
}

/// Execution error.
#[derive(Debug, PartialEq, Eq)]
pub enum VmError {
    BadStreamIndex,
    LengthMismatch,
}

/// Run `prog` elementwise over `inputs` on the given GPU model.
///
/// Streams are `f64` views quantized into the model's format on load —
/// exactly Brook's `streamRead` upload semantics.
pub fn run(
    model: &GpuModel, prog: &Program, inputs: &[&[f64]],
) -> Result<Vec<Vec<f64>>, VmError> {
    let n = inputs.first().map_or(0, |s| s.len());
    let mut outputs = vec![vec![0.0f64; n]; prog.n_out];
    run_into(model, prog, inputs, &mut outputs)?;
    Ok(outputs)
}

/// Allocation-free variant of [`run`]: writes into caller-provided
/// output streams (each pre-sized to the input length). The backend
/// layer uses this to keep staging buffers warm across batches.
pub fn run_into(
    model: &GpuModel, prog: &Program, inputs: &[&[f64]], outputs: &mut [Vec<f64>],
) -> Result<(), VmError> {
    if inputs.len() != prog.n_in {
        return Err(VmError::BadStreamIndex);
    }
    let n = inputs.first().map_or(0, |s| s.len());
    if inputs.iter().any(|s| s.len() != n) {
        return Err(VmError::LengthMismatch);
    }
    if outputs.len() != prog.n_out || outputs.iter().any(|s| s.len() != n) {
        return Err(VmError::LengthMismatch);
    }
    let mut regs = [SoftFp::zero(); 32];
    for i in 0..n {
        for ins in &prog.code {
            match *ins {
                Instr::LoadIn { dst, src } => {
                    let s = inputs.get(src as usize).ok_or(VmError::BadStreamIndex)?;
                    regs[dst as usize] = model.quantize(s[i]);
                }
                Instr::LoadConst { dst, value } => {
                    regs[dst as usize] = model.quantize(value);
                }
                Instr::StoreOut { dst, src } => {
                    let out =
                        outputs.get_mut(dst as usize).ok_or(VmError::BadStreamIndex)?;
                    out[i] = model.to_f64(regs[src as usize]);
                }
                Instr::Mov { dst, src } => regs[dst as usize] = regs[src as usize],
                Instr::Add { dst, a, b } => {
                    regs[dst as usize] = model.add(regs[a as usize], regs[b as usize])
                }
                Instr::Sub { dst, a, b } => {
                    regs[dst as usize] = model.sub(regs[a as usize], regs[b as usize])
                }
                Instr::Mul { dst, a, b } => {
                    regs[dst as usize] = model.mul(regs[a as usize], regs[b as usize])
                }
                Instr::Mad { dst, a, b, c } => {
                    regs[dst as usize] =
                        model.mad(regs[a as usize], regs[b as usize], regs[c as usize])
                }
                Instr::Rcp { dst, a } => {
                    regs[dst as usize] =
                        super::arith::recip(regs[a as usize], model.format, model.recip)
                }
            }
        }
    }
    Ok(())
}

/// Pre-assembled fragment programs for the paper's operators.
pub mod programs {
    use super::*;

    /// Add12: streams (a, b) -> (s, err). 6 arithmetic ops, branch-free.
    pub fn add12() -> Program {
        use Instr::*;
        Program {
            name: "add12".into(),
            n_in: 2,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },            // a
                LoadIn { dst: 1, src: 1 },            // b
                Add { dst: 2, a: 0, b: 1 },           // s = a + b
                Sub { dst: 3, a: 2, b: 0 },           // bb = s - a
                Sub { dst: 4, a: 2, b: 3 },           // s - bb
                Sub { dst: 4, a: 0, b: 4 },           // a - (s - bb)
                Sub { dst: 5, a: 1, b: 3 },           // b - bb
                Add { dst: 6, a: 4, b: 5 },           // err
                StoreOut { dst: 0, src: 2 },
                StoreOut { dst: 1, src: 6 },
            ],
        }
    }

    /// SPLIT for precision p: stream (a) -> (hi, lo). FP-only Dekker.
    pub fn split(p: u32) -> Program {
        use Instr::*;
        let s = p.div_ceil(2);
        Program {
            name: format!("split{s}"),
            n_in: 1,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },                             // a
                LoadConst { dst: 1, value: ((1u64 << s) + 1) as f64 }, // 2^s+1
                Mul { dst: 2, a: 1, b: 0 },                            // c
                Sub { dst: 3, a: 2, b: 0 },                            // a_big
                Sub { dst: 4, a: 2, b: 3 },                            // hi
                Sub { dst: 5, a: 0, b: 4 },                            // lo
                StoreOut { dst: 0, src: 4 },
                StoreOut { dst: 1, src: 5 },
            ],
        }
    }

    /// Mul12: streams (a, b) -> (x, y).
    pub fn mul12(p: u32) -> Program {
        use Instr::*;
        let s = p.div_ceil(2);
        let splitter = ((1u64 << s) + 1) as f64;
        Program {
            name: "mul12".into(),
            n_in: 2,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },              // a
                LoadIn { dst: 1, src: 1 },              // b
                Mul { dst: 2, a: 0, b: 1 },             // x
                LoadConst { dst: 3, value: splitter },
                // split a -> r4 hi, r5 lo
                Mul { dst: 4, a: 3, b: 0 },
                Sub { dst: 5, a: 4, b: 0 },
                Sub { dst: 4, a: 4, b: 5 },
                Sub { dst: 5, a: 0, b: 4 },
                // split b -> r6 hi, r7 lo
                Mul { dst: 6, a: 3, b: 1 },
                Sub { dst: 7, a: 6, b: 1 },
                Sub { dst: 6, a: 6, b: 7 },
                Sub { dst: 7, a: 1, b: 6 },
                // error chain
                Mul { dst: 8, a: 4, b: 6 },             // ahi*bhi
                Sub { dst: 8, a: 2, b: 8 },             // err1
                Mul { dst: 9, a: 5, b: 6 },             // alo*bhi
                Sub { dst: 8, a: 8, b: 9 },             // err2
                Mul { dst: 9, a: 4, b: 7 },             // ahi*blo
                Sub { dst: 8, a: 8, b: 9 },             // err3
                Mul { dst: 9, a: 5, b: 7 },             // alo*blo
                Sub { dst: 9, a: 9, b: 8 },             // y
                StoreOut { dst: 0, src: 2 },
                StoreOut { dst: 1, src: 9 },
            ],
        }
    }

    /// Add22: streams (ah, al, bh, bl) -> (rh, rl).
    pub fn add22() -> Program {
        use Instr::*;
        Program {
            name: "add22".into(),
            n_in: 4,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },   // ah
                LoadIn { dst: 1, src: 1 },   // al
                LoadIn { dst: 2, src: 2 },   // bh
                LoadIn { dst: 3, src: 3 },   // bl
                // add12(ah, bh) -> r4 s, r5 err
                Add { dst: 4, a: 0, b: 2 },
                Sub { dst: 5, a: 4, b: 0 },
                Sub { dst: 6, a: 4, b: 5 },
                Sub { dst: 6, a: 0, b: 6 },
                Sub { dst: 7, a: 2, b: 5 },
                Add { dst: 5, a: 6, b: 7 },
                // te = (al + bl) + se
                Add { dst: 8, a: 1, b: 3 },
                Add { dst: 8, a: 8, b: 5 },
                // fast_add12(s, te)
                Add { dst: 9, a: 4, b: 8 },
                Sub { dst: 10, a: 9, b: 4 },
                Sub { dst: 10, a: 8, b: 10 },
                StoreOut { dst: 0, src: 9 },
                StoreOut { dst: 1, src: 10 },
            ],
        }
    }

    /// Mul22: streams (ah, al, bh, bl) -> (rh, rl).
    ///
    /// Mirrors the native `ff::vector::mul22` op-for-op: Dekker
    /// two-product of the high words (FP-only split, splitting point
    /// `ceil(p/2)`), cross terms accumulated in one add each, renormalise
    /// with fast-two-sum. Under the IEEE model this is bit-identical to
    /// the native kernel (the two-product is an EFT either way).
    pub fn mul22(p: u32) -> Program {
        use Instr::*;
        let s = p.div_ceil(2);
        let splitter = ((1u64 << s) + 1) as f64;
        Program {
            name: "mul22".into(),
            n_in: 4,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },              // ah
                LoadIn { dst: 1, src: 1 },              // al
                LoadIn { dst: 2, src: 2 },              // bh
                LoadIn { dst: 3, src: 3 },              // bl
                // two_prod(ah, bh) -> r4 = x, r11 = y
                Mul { dst: 4, a: 0, b: 2 },             // x = ah*bh
                LoadConst { dst: 5, value: splitter },
                // split ah -> r6 hi, r7 lo
                Mul { dst: 6, a: 5, b: 0 },
                Sub { dst: 7, a: 6, b: 0 },
                Sub { dst: 6, a: 6, b: 7 },
                Sub { dst: 7, a: 0, b: 6 },
                // split bh -> r8 hi, r9 lo
                Mul { dst: 8, a: 5, b: 2 },
                Sub { dst: 9, a: 8, b: 2 },
                Sub { dst: 8, a: 8, b: 9 },
                Sub { dst: 9, a: 2, b: 8 },
                // error chain
                Mul { dst: 10, a: 6, b: 8 },            // ahi*bhi
                Sub { dst: 10, a: 4, b: 10 },           // err1
                Mul { dst: 11, a: 7, b: 8 },            // alo*bhi
                Sub { dst: 10, a: 10, b: 11 },          // err2
                Mul { dst: 11, a: 6, b: 9 },            // ahi*blo
                Sub { dst: 10, a: 10, b: 11 },          // err3
                Mul { dst: 11, a: 7, b: 9 },            // alo*blo
                Sub { dst: 11, a: 11, b: 10 },          // y
                // cross terms: pl = y + (ah*bl + al*bh)
                Mul { dst: 12, a: 0, b: 3 },            // ah*bl
                Mul { dst: 13, a: 1, b: 2 },            // al*bh
                Add { dst: 12, a: 12, b: 13 },
                Add { dst: 11, a: 11, b: 12 },          // pl
                // fast_two_sum(x, pl)
                Add { dst: 14, a: 4, b: 11 },
                Sub { dst: 15, a: 14, b: 4 },
                Sub { dst: 15, a: 11, b: 15 },
                StoreOut { dst: 0, src: 14 },
                StoreOut { dst: 1, src: 15 },
            ],
        }
    }

    /// Div22: streams (ah, al, bh, bl) -> (rh, rl).
    ///
    /// GPUs of this era have no divider; division is reciprocal +
    /// multiply (the paper's §1.2 observation), so `q1 = ah · rcp(bh)`
    /// and the residual correction also multiplies by the reciprocal.
    /// Numerically equivalent to the native `div22` but **not**
    /// bit-identical even under IEEE arithmetic (two roundings where the
    /// CPU has one exact division).
    pub fn div22(p: u32) -> Program {
        use Instr::*;
        let s = p.div_ceil(2);
        let splitter = ((1u64 << s) + 1) as f64;
        Program {
            name: "div22".into(),
            n_in: 4,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },              // ah
                LoadIn { dst: 1, src: 1 },              // al
                LoadIn { dst: 2, src: 2 },              // bh
                LoadIn { dst: 3, src: 3 },              // bl
                Rcp { dst: 4, a: 2 },                   // rb = rcp(bh)
                Mul { dst: 5, a: 0, b: 4 },             // q1 = ah * rb
                // two_prod(q1, bh) -> r6 = th, r13 = tl
                Mul { dst: 6, a: 5, b: 2 },
                LoadConst { dst: 7, value: splitter },
                Mul { dst: 8, a: 7, b: 5 },             // split q1
                Sub { dst: 9, a: 8, b: 5 },
                Sub { dst: 8, a: 8, b: 9 },
                Sub { dst: 9, a: 5, b: 8 },
                Mul { dst: 10, a: 7, b: 2 },            // split bh
                Sub { dst: 11, a: 10, b: 2 },
                Sub { dst: 10, a: 10, b: 11 },
                Sub { dst: 11, a: 2, b: 10 },
                Mul { dst: 12, a: 8, b: 10 },
                Sub { dst: 12, a: 6, b: 12 },           // err1
                Mul { dst: 13, a: 9, b: 10 },
                Sub { dst: 12, a: 12, b: 13 },          // err2
                Mul { dst: 13, a: 8, b: 11 },
                Sub { dst: 12, a: 12, b: 13 },          // err3
                Mul { dst: 13, a: 9, b: 11 },
                Sub { dst: 13, a: 13, b: 12 },          // tl
                // r = (((ah - th) - tl) + al - q1*bl) * rb
                Sub { dst: 14, a: 0, b: 6 },
                Sub { dst: 14, a: 14, b: 13 },
                Add { dst: 14, a: 14, b: 1 },
                Mul { dst: 15, a: 5, b: 3 },
                Sub { dst: 14, a: 14, b: 15 },
                Mul { dst: 14, a: 14, b: 4 },
                // fast_two_sum(q1, r)
                Add { dst: 16, a: 5, b: 14 },
                Sub { dst: 17, a: 16, b: 5 },
                Sub { dst: 17, a: 14, b: 17 },
                StoreOut { dst: 0, src: 16 },
                StoreOut { dst: 1, src: 17 },
            ],
        }
    }

    /// Mad22: streams (ah, al, bh, bl, ch, cl) -> (rh, rl), computed as
    /// `add22(mul22(a, b), c)` exactly like the native kernel.
    pub fn mad22(p: u32) -> Program {
        use Instr::*;
        let s = p.div_ceil(2);
        let splitter = ((1u64 << s) + 1) as f64;
        Program {
            name: "mad22".into(),
            n_in: 6,
            n_out: 2,
            code: vec![
                LoadIn { dst: 0, src: 0 },              // ah
                LoadIn { dst: 1, src: 1 },              // al
                LoadIn { dst: 2, src: 2 },              // bh
                LoadIn { dst: 3, src: 3 },              // bl
                LoadIn { dst: 4, src: 4 },              // ch
                LoadIn { dst: 5, src: 5 },              // cl
                // ---- mul22(a, b) -> r16 = ph, r17 = pl
                Mul { dst: 6, a: 0, b: 2 },             // x = ah*bh
                LoadConst { dst: 7, value: splitter },
                Mul { dst: 8, a: 7, b: 0 },             // split ah
                Sub { dst: 9, a: 8, b: 0 },
                Sub { dst: 8, a: 8, b: 9 },
                Sub { dst: 9, a: 0, b: 8 },
                Mul { dst: 10, a: 7, b: 2 },            // split bh
                Sub { dst: 11, a: 10, b: 2 },
                Sub { dst: 10, a: 10, b: 11 },
                Sub { dst: 11, a: 2, b: 10 },
                Mul { dst: 12, a: 8, b: 10 },
                Sub { dst: 12, a: 6, b: 12 },           // err1
                Mul { dst: 13, a: 9, b: 10 },
                Sub { dst: 12, a: 12, b: 13 },          // err2
                Mul { dst: 13, a: 8, b: 11 },
                Sub { dst: 12, a: 12, b: 13 },          // err3
                Mul { dst: 13, a: 9, b: 11 },
                Sub { dst: 13, a: 13, b: 12 },          // y
                Mul { dst: 14, a: 0, b: 3 },            // ah*bl
                Mul { dst: 15, a: 1, b: 2 },            // al*bh
                Add { dst: 14, a: 14, b: 15 },
                Add { dst: 13, a: 13, b: 14 },          // pl
                Add { dst: 16, a: 6, b: 13 },           // fast_two_sum
                Sub { dst: 17, a: 16, b: 6 },
                Sub { dst: 17, a: 13, b: 17 },
                // ---- add22(p, c): two_sum(ph, ch) -> r18 s, r19 se
                Add { dst: 18, a: 16, b: 4 },
                Sub { dst: 19, a: 18, b: 16 },          // bb
                Sub { dst: 20, a: 18, b: 19 },          // s - bb
                Sub { dst: 20, a: 16, b: 20 },          // ph - (s - bb)
                Sub { dst: 21, a: 4, b: 19 },           // ch - bb
                Add { dst: 19, a: 20, b: 21 },          // se
                // te = (pl + cl) + se
                Add { dst: 22, a: 17, b: 5 },
                Add { dst: 22, a: 22, b: 19 },
                // fast_two_sum(s, te)
                Add { dst: 23, a: 18, b: 22 },
                Sub { dst: 24, a: 23, b: 18 },
                Sub { dst: 24, a: 22, b: 24 },
                StoreOut { dst: 0, src: 23 },
                StoreOut { dst: 1, src: 24 },
            ],
        }
    }

    /// Baseline single add: (a, b) -> (r).
    pub fn base_add() -> Program {
        use Instr::*;
        Program {
            name: "add".into(),
            n_in: 2,
            n_out: 1,
            code: vec![
                LoadIn { dst: 0, src: 0 },
                LoadIn { dst: 1, src: 1 },
                Add { dst: 2, a: 0, b: 1 },
                StoreOut { dst: 0, src: 2 },
            ],
        }
    }

    /// Baseline single mul: (a, b) -> (r).
    pub fn base_mul() -> Program {
        use Instr::*;
        Program {
            name: "mul".into(),
            n_in: 2,
            n_out: 1,
            code: vec![
                LoadIn { dst: 0, src: 0 },
                LoadIn { dst: 1, src: 1 },
                Mul { dst: 2, a: 0, b: 1 },
                StoreOut { dst: 0, src: 2 },
            ],
        }
    }

    /// Baseline MAD: (a, b, c) -> (a*b + c).
    pub fn base_mad() -> Program {
        use Instr::*;
        Program {
            name: "mad".into(),
            n_in: 3,
            n_out: 1,
            code: vec![
                LoadIn { dst: 0, src: 0 },
                LoadIn { dst: 1, src: 1 },
                LoadIn { dst: 2, src: 2 },
                Mad { dst: 3, a: 0, b: 1, c: 2 },
                StoreOut { dst: 0, src: 3 },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::algorithms;
    use crate::util::Rng;

    #[test]
    fn add12_program_matches_algorithm() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(121);
        let a: Vec<f64> = (0..512).map(|_| rng.spread_f32(-10, 10) as f64).collect();
        let b: Vec<f64> = (0..512).map(|_| rng.spread_f32(-10, 10) as f64).collect();
        let out = run(&m, &programs::add12(), &[&a, &b]).unwrap();
        for i in 0..a.len() {
            let (s, e) = algorithms::add12(&m, m.quantize(a[i]), m.quantize(b[i]));
            assert_eq!(out[0][i], m.to_f64(s), "i={i}");
            assert_eq!(out[1][i], m.to_f64(e), "i={i}");
        }
    }

    #[test]
    fn mul12_program_matches_algorithm() {
        let m = GpuModel::NV35;
        let p = m.format.precision();
        let mut rng = Rng::new(122);
        let a: Vec<f64> = (0..512).map(|_| rng.spread_f32(-8, 8) as f64).collect();
        let b: Vec<f64> = (0..512).map(|_| rng.spread_f32(-8, 8) as f64).collect();
        let out = run(&m, &programs::mul12(p), &[&a, &b]).unwrap();
        for i in 0..a.len() {
            let (x, y) = algorithms::mul12(&m, m.quantize(a[i]), m.quantize(b[i]));
            assert_eq!(out[0][i], m.to_f64(x), "i={i}");
            assert_eq!(out[1][i], m.to_f64(y), "i={i}");
        }
    }

    #[test]
    fn add22_program_matches_algorithm() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(123);
        let n = 256;
        let mk = |rng: &mut Rng| -> (Vec<f64>, Vec<f64>) {
            let hi: Vec<f64> = (0..n).map(|_| rng.spread_f32(-8, 8) as f64).collect();
            let lo: Vec<f64> =
                hi.iter().map(|&h| h * 2f64.powi(-25) * rng.uniform(-1.0, 1.0)).collect();
            (hi, lo)
        };
        let (ah, al) = mk(&mut rng);
        let (bh, bl) = mk(&mut rng);
        let out = run(&m, &programs::add22(), &[&ah, &al, &bh, &bl]).unwrap();
        for i in 0..n {
            let r = algorithms::add22(
                &m,
                (m.quantize(ah[i]), m.quantize(al[i])),
                (m.quantize(bh[i]), m.quantize(bl[i])),
            );
            assert_eq!(out[0][i], m.to_f64(r.0), "i={i}");
            assert_eq!(out[1][i], m.to_f64(r.1), "i={i}");
        }
    }

    /// The IEEE-configured VM must reproduce the native f32 kernels
    /// bit-for-bit for the EFT-based operators (the property the
    /// cross-backend parity test in `rust/tests/` depends on).
    #[test]
    fn ieee_mul22_and_mad22_programs_match_native_kernels() {
        use crate::ff::FF32;
        let m = GpuModel::IEEE;
        let p = m.format.precision();
        let mut rng = Rng::new(124);
        let n = 512;
        let mut planes: Vec<Vec<f64>> = vec![Vec::with_capacity(n); 6];
        for _ in 0..n {
            for pair in 0..3 {
                let (hi, lo) = rng.ff_pair(-8, 8);
                planes[2 * pair].push(hi as f64);
                planes[2 * pair + 1].push(lo as f64);
            }
        }
        let refs: Vec<&[f64]> = planes.iter().map(Vec::as_slice).collect();

        let out = run(&m, &programs::mul22(p), &refs[..4]).unwrap();
        for i in 0..n {
            let a = FF32::from_parts(planes[0][i] as f32, planes[1][i] as f32);
            let b = FF32::from_parts(planes[2][i] as f32, planes[3][i] as f32);
            let want = a * b;
            assert_eq!(out[0][i], want.hi as f64, "mul22 hi i={i}");
            assert_eq!(out[1][i], want.lo as f64, "mul22 lo i={i}");
        }

        let out = run(&m, &programs::mad22(p), &refs).unwrap();
        for i in 0..n {
            let a = FF32::from_parts(planes[0][i] as f32, planes[1][i] as f32);
            let b = FF32::from_parts(planes[2][i] as f32, planes[3][i] as f32);
            let c = FF32::from_parts(planes[4][i] as f32, planes[5][i] as f32);
            let want = a.mul22(b).add22(c);
            assert_eq!(out[0][i], want.hi as f64, "mad22 hi i={i}");
            assert_eq!(out[1][i], want.lo as f64, "mad22 lo i={i}");
        }
    }

    #[test]
    fn div22_program_is_accurate_not_bitexact() {
        use crate::ff::FF32;
        let m = GpuModel::IEEE;
        let p = m.format.precision();
        let mut rng = Rng::new(125);
        let n = 256;
        let mut planes: Vec<Vec<f64>> = vec![Vec::with_capacity(n); 4];
        for _ in 0..n {
            for pair in 0..2 {
                let (mut hi, lo) = rng.ff_pair(-6, 6);
                if pair == 1 && hi.abs() < 1e-3 {
                    hi += 1.0f32.copysign(hi);
                }
                planes[2 * pair].push(hi as f64);
                planes[2 * pair + 1].push(lo as f64);
            }
        }
        let refs: Vec<&[f64]> = planes.iter().map(Vec::as_slice).collect();
        let out = run(&m, &programs::div22(p), &refs).unwrap();
        for i in 0..n {
            let a = FF32::from_parts(planes[0][i] as f32, planes[1][i] as f32);
            let b = FF32::from_parts(planes[2][i] as f32, planes[3][i] as f32);
            let want = (a / b).to_f64();
            let got = out[0][i] + out[1][i];
            let rel = if want == 0.0 { got.abs() } else { ((got - want) / want).abs() };
            // recip-based division: a few ulps beyond the CPU result
            assert!(rel < 2f64.powi(-38), "i={i} rel={rel:e}");
        }
    }

    #[test]
    fn run_into_matches_run_and_checks_shapes() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(126);
        let a: Vec<f64> = (0..64).map(|_| rng.spread_f32(-6, 6) as f64).collect();
        let b: Vec<f64> = (0..64).map(|_| rng.spread_f32(-6, 6) as f64).collect();
        let prog = programs::add12();
        let want = run(&m, &prog, &[&a, &b]).unwrap();
        let mut out = vec![vec![0.0f64; 64]; 2];
        run_into(&m, &prog, &[&a, &b], &mut out).unwrap();
        assert_eq!(out, want);
        // wrong output arity / length are rejected
        let mut bad = vec![vec![0.0f64; 64]; 1];
        assert_eq!(
            run_into(&m, &prog, &[&a, &b], &mut bad),
            Err(VmError::LengthMismatch)
        );
        let mut short = vec![vec![0.0f64; 32]; 2];
        assert_eq!(
            run_into(&m, &prog, &[&a, &b], &mut short),
            Err(VmError::LengthMismatch)
        );
    }

    #[test]
    fn flop_counts_match_paper_economics() {
        // paper: branch-free Add12 = 6 ops; Add22 = Add12 + 3 + fast(3) = 11
        assert_eq!(programs::add12().flops(), 6);
        assert_eq!(programs::add22().flops(), 11);
        assert_eq!(programs::base_add().flops(), 1);
        // Mul12 = 1 mul + 2 splits(3 ops + const mul each = 4) + 7 chain = 16..17
        let p = Format::NV32.precision();
        assert!(programs::mul12(p).flops() >= 16);
    }

    #[test]
    fn errors_on_bad_wiring() {
        let m = GpuModel::NV35;
        let a = vec![1.0f64; 4];
        assert_eq!(run(&m, &programs::add12(), &[&a]).unwrap_err(),
                   VmError::BadStreamIndex);
        let b = vec![1.0f64; 3];
        assert_eq!(run(&m, &programs::add12(), &[&a, &b]).unwrap_err(),
                   VmError::LengthMismatch);
    }

    use crate::gpusim::Format;
}
