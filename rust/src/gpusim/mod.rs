//! GPU arithmetic simulator — the substrate replacing the paper's
//! 2006-era graphics hardware (DESIGN.md substitution table).
//!
//! The paper's entire soundness story rests on *which* non-IEEE rounding
//! a GPU performs: Table 2 characterises ATI R300 and Nvidia NV35 with a
//! Paranoia-derived tool, and §4 proves Add12/Split/Mul12 correct under
//! "faithful rounding + guard bit" (the Nvidia behaviour). Since that
//! hardware no longer exists, we rebuild its arithmetic bit-level:
//!
//! * [`format`] — storage formats of the paper's Table 1 (sign/exponent/
//!   mantissa widths, specials support, subnormal flushing);
//! * [`arith`] — parameterised soft-float add/sub/mul/recip/div with
//!   explicit guard-bit count, sticky-bit, and rounding mode — the knobs
//!   that distinguish R300 from NV35 from IEEE;
//! * [`models`] — named GPU profiles (R300, NV35, NV40, IEEE-RN,
//!   truncation) matching Table 2's observed error intervals;
//! * [`algorithms`] — the paper's §4 algorithms executed *on the
//!   simulated arithmetic*: validates Theorems 1–6 under GPU conditions
//!   (and shows Add12 failing on R300, which has no guard bit — the
//!   negative result the paper's §6.1 anomaly hints at);
//! * [`shader`] — a mini-Brook stream VM: branch-free register programs
//!   applied to SoA streams, the form the paper's fragment programs take
//!   (Figure 1's programmable units, §5's Brook implementation);
//! * [`paranoia`] — the measurement harness regenerating Table 2.

pub mod algorithms;
pub mod asm;
pub mod arith;
pub mod format;
pub mod models;
pub mod paranoia;
pub mod shader;

pub use arith::SoftFp;
pub use format::Format;
pub use models::GpuModel;
