//! The paper's §4 algorithms executed on **simulated GPU arithmetic** —
//! this is where Theorems 1–6 are validated under the conditions the
//! paper actually claims them for (faithful rounding + guard bit), and
//! where the R300 counterexamples live.
//!
//! Everything is written against a [`GpuModel`], so the same code runs
//! under IEEE, chopped, R300 and NV35 arithmetic. The float-float pair
//! is `(hi, lo)` of [`SoftFp`].

use super::arith::SoftFp;
use super::models::GpuModel;

/// Float-float value in simulated arithmetic.
pub type FfSim = (SoftFp, SoftFp);

/// Add12 (paper Th. 2), branch-free 6-op form, on the model's adder.
pub fn add12(m: &GpuModel, a: SoftFp, b: SoftFp) -> FfSim {
    let s = m.add(a, b);
    let bb = m.sub(s, a);
    let err = m.add(m.sub(a, m.sub(s, bb)), m.sub(b, bb));
    (s, err)
}

/// Fast-two-sum (3 ops), requires |a| >= |b|.
pub fn fast_add12(m: &GpuModel, a: SoftFp, b: SoftFp) -> FfSim {
    let s = m.add(a, b);
    let err = m.sub(b, m.sub(s, a));
    (s, err)
}

/// SPLIT (paper Th. 3) — the FP-only Dekker sequence, verbatim, with
/// splitting point s = ceil(p/2) for the model's format.
pub fn split(m: &GpuModel, a: SoftFp) -> FfSim {
    let p = m.format.precision();
    let s = p.div_ceil(2);
    let splitter = m.quantize(((1u64 << s) + 1) as f64);
    let c = m.mul(splitter, a);
    let a_big = m.sub(c, a);
    let a_hi = m.sub(c, a_big);
    let a_lo = m.sub(a, a_hi);
    (a_hi, a_lo)
}

/// Mul12 (paper Th. 4): exact product as (x, y), FP-only sequence.
pub fn mul12(m: &GpuModel, a: SoftFp, b: SoftFp) -> FfSim {
    let x = m.mul(a, b);
    let (a_hi, a_lo) = split(m, a);
    let (b_hi, b_lo) = split(m, b);
    let err1 = m.sub(x, m.mul(a_hi, b_hi));
    let err2 = m.sub(err1, m.mul(a_lo, b_hi));
    let err3 = m.sub(err2, m.mul(a_hi, b_lo));
    let y = m.sub(m.mul(a_lo, b_lo), err3);
    (x, y)
}

/// Add22 (paper Th. 5), branch-free GPU variant.
pub fn add22(m: &GpuModel, a: FfSim, b: FfSim) -> FfSim {
    let (sh, se) = add12(m, a.0, b.0);
    let te = m.add(m.add(a.1, b.1), se);
    fast_add12(m, sh, te)
}

/// Mul22 (paper Th. 6).
pub fn mul22(m: &GpuModel, a: FfSim, b: FfSim) -> FfSim {
    let (ph, pl) = mul12(m, a.0, b.0);
    let cross = m.add(m.mul(a.0, b.1), m.mul(a.1, b.0));
    let pl = m.add(pl, cross);
    fast_add12(m, ph, pl)
}

/// Exact f64 value of a simulated float-float pair.
pub fn to_f64(m: &GpuModel, v: FfSim) -> f64 {
    m.to_f64(v.0) + m.to_f64(v.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Random SoftFp in the model's format within a safe exponent range.
    fn rand_fp(m: &GpuModel, rng: &mut Rng, lo: i32, hi: i32) -> SoftFp {
        m.quantize(rng.spread_f32(lo, hi) as f64)
    }

    // ---- Theorem 1 (Sterbenz) ---------------------------------------

    #[test]
    fn th1_sterbenz_holds_on_nv35() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(101);
        for _ in 0..100_000 {
            let y = m.quantize(rng.spread_f32(-6, 6).abs() as f64);
            let x = m.quantize(m.to_f64(y) * rng.uniform(0.5, 2.0));
            let r = m.sub(x, y);
            assert_eq!(m.to_f64(r), m.to_f64(x) - m.to_f64(y), "Sterbenz violated");
        }
    }

    #[test]
    fn th1_sterbenz_fails_on_r300() {
        // without a guard bit there exist x,y with y/2<=x<=2y and inexact x-y
        let m = GpuModel::R300;
        let mut rng = Rng::new(102);
        let mut violations = 0u32;
        for _ in 0..100_000 {
            let y = m.quantize(rng.spread_f32(-6, 6).abs() as f64);
            let x = m.quantize(m.to_f64(y) * rng.uniform(0.5, 2.0));
            if m.to_f64(m.sub(x, y)) != m.to_f64(x) - m.to_f64(y) {
                violations += 1;
            }
        }
        assert!(violations > 0, "expected Sterbenz violations on R300");
    }

    // ---- Theorem 2 (Add12) ------------------------------------------

    #[test]
    fn th2_add12_exact_on_ieee() {
        let m = GpuModel::IEEE;
        let mut rng = Rng::new(103);
        for _ in 0..100_000 {
            let a = rand_fp(&m, &mut rng, -12, 12);
            let b = rand_fp(&m, &mut rng, -12, 12);
            let (s, r) = add12(&m, a, b);
            assert_eq!(m.to_f64(s) + m.to_f64(r), m.to_f64(a) + m.to_f64(b));
        }
    }

    #[test]
    fn th2_add12_on_nv35_and_the_6_1_anomaly() {
        // The paper §6.1: Add12 measured at 2^-48 (not exact) on real
        // hardware, traced to sums of opposite-sign values with
        // non-overlapping mantissas. Truncated-with-guard addition shows
        // exactly that: near-exactness with rare small residuals.
        let m = GpuModel::NV35;
        let mut rng = Rng::new(104);
        let mut max_rel: f64 = 0.0;
        let mut inexact = 0u64;
        for _ in 0..200_000 {
            let a = rand_fp(&m, &mut rng, -12, 12);
            let b = rand_fp(&m, &mut rng, -12, 12);
            let (s, r) = add12(&m, a, b);
            let got = m.to_f64(s) + m.to_f64(r);
            let want = m.to_f64(a) + m.to_f64(b);
            if got != want && want != 0.0 {
                inexact += 1;
                max_rel = max_rel.max(((got - want) / want).abs());
            }
        }
        // truncation (not RN) leaks sub-ulp residuals in rare cases, but
        // the representable error must stay below ~2^-44 of the sum
        if inexact > 0 {
            assert!(max_rel < 2f64.powi(-40), "max_rel=2^{}", max_rel.log2());
        }
        // and the overwhelming majority is exact
        assert!(inexact < 200_000 / 50, "inexact={inexact}");
    }

    // ---- Theorem 3 (Split) ------------------------------------------

    #[test]
    fn th3_split_exact_on_nv35() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(105);
        for _ in 0..100_000 {
            let a = rand_fp(&m, &mut rng, -12, 12);
            let (hi, lo) = split(&m, a);
            assert_eq!(m.to_f64(hi) + m.to_f64(lo), m.to_f64(a), "split not exact");
            // hi fits p - s bits: check via ulp granularity
            if !hi.is_zero() {
                let p = m.format.precision();
                let s = p.div_ceil(2);
                let granule = 2f64.powi(hi.exp - (p - s) as i32 + 1);
                let q = m.to_f64(hi) / granule;
                assert_eq!(q, q.round(), "hi has too many bits");
            }
        }
    }

    #[test]
    fn th3_split_exact_on_ati24() {
        // Th. 3 only needs Sterbenz-exactness of lines 3-4 *given* the
        // guard bit; on R300 (no guard) splits can break — but on a
        // guard-bit model with ATI24's 17-bit precision it must hold.
        let m = GpuModel {
            name: "ati24-guarded",
            format: crate::gpusim::Format::ATI24,
            ..GpuModel::NV35
        };
        let mut rng = Rng::new(106);
        for _ in 0..50_000 {
            let a = rand_fp(&m, &mut rng, -8, 8);
            let (hi, lo) = split(&m, a);
            assert_eq!(m.to_f64(hi) + m.to_f64(lo), m.to_f64(a));
        }
    }

    // ---- Theorem 4 (Mul12) ------------------------------------------

    #[test]
    fn th4_mul12_exact_on_ieee() {
        let m = GpuModel::IEEE;
        let mut rng = Rng::new(107);
        for _ in 0..100_000 {
            let a = rand_fp(&m, &mut rng, -10, 10);
            let b = rand_fp(&m, &mut rng, -10, 10);
            let (x, y) = mul12(&m, a, b);
            assert_eq!(m.to_f64(x) + m.to_f64(y), m.to_f64(a) * m.to_f64(b));
        }
    }

    #[test]
    fn th4_mul12_error_bounded_on_nv35() {
        // With faithful (not correctly-rounded) mul, Mul12 is exact
        // whenever the error term is representable; residuals bounded by
        // ~2^-44 relative (the paper's measured "(exact)" row tolerance).
        let m = GpuModel::NV35;
        let mut rng = Rng::new(108);
        let mut max_rel: f64 = 0.0;
        for _ in 0..200_000 {
            let a = rand_fp(&m, &mut rng, -10, 10);
            let b = rand_fp(&m, &mut rng, -10, 10);
            let (x, y) = mul12(&m, a, b);
            let got = m.to_f64(x) + m.to_f64(y);
            let want = m.to_f64(a) * m.to_f64(b);
            if want != 0.0 {
                max_rel = max_rel.max(((got - want) / want).abs());
            }
        }
        assert!(max_rel <= 2f64.powi(-43), "max_rel=2^{:.1}", max_rel.log2());
    }

    // ---- Theorems 5-6 (Add22 / Mul22) --------------------------------

    fn rand_ff(m: &GpuModel, rng: &mut Rng) -> (FfSim, f64) {
        let hi = rand_fp(m, rng, -10, 10);
        // lo scaled well below ulp(hi)
        let scale = 2f64.powi(-(m.format.precision() as i32));
        let lo = m.quantize(m.to_f64(hi) * scale * rng.uniform(-0.5, 0.5));
        ((hi, lo), m.to_f64(hi) + m.to_f64(lo))
    }

    #[test]
    fn th5_add22_bound_on_nv35() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(109);
        for _ in 0..100_000 {
            let (a, a64) = rand_ff(&m, &mut rng);
            let (b, b64) = rand_ff(&m, &mut rng);
            let r = add22(&m, a, b);
            let want = a64 + b64;
            let err = (to_f64(&m, r) - want).abs();
            // paper Th. 5 bound, with one guard factor for truncation
            let bound = (2f64.powi(-22) * (m.to_f64(a.1) + m.to_f64(b.1)).abs())
                .max(2f64.powi(-42) * want.abs());
            assert!(err <= bound + 1e-300, "err={err:e} bound={bound:e}");
        }
    }

    #[test]
    fn th6_mul22_bound_on_nv35() {
        let m = GpuModel::NV35;
        let mut rng = Rng::new(110);
        let mut max_rel: f64 = 0.0;
        for _ in 0..100_000 {
            let (a, a64) = rand_ff(&m, &mut rng);
            let (b, b64) = rand_ff(&m, &mut rng);
            let r = mul22(&m, a, b);
            let want = a64 * b64;
            if want != 0.0 {
                max_rel = max_rel.max(((to_f64(&m, r) - want) / want).abs());
            }
        }
        // paper Th. 6: eps <= 2^-44; truncated adders cost ~1 bit
        assert!(max_rel <= 2f64.powi(-42), "max_rel=2^{:.1}", max_rel.log2());
    }

    #[test]
    fn add22_degrades_on_r300() {
        // the paper's §6.1 bad Add22 accuracy (-33.7) is caused by the
        // guard-bit-free adder; R300-sim must show clearly worse errors
        // than NV35-sim
        let nv = GpuModel::NV35;
        let ati = GpuModel::R300;
        let mut rng = Rng::new(111);
        let (mut worst_nv, mut worst_ati) = (0.0f64, 0.0f64);
        for _ in 0..100_000 {
            let a64 = rng.normal() * rng.uniform(-6.0, 6.0).exp2();
            let b64 = rng.normal() * rng.uniform(-6.0, 6.0).exp2();
            for (m, worst) in [(&nv, &mut worst_nv), (&ati, &mut worst_ati)] {
                let mk = |v: f64| {
                    let hi = m.quantize(v);
                    let lo = m.quantize(v - m.to_f64(hi));
                    (hi, lo)
                };
                let r = add22(m, mk(a64), mk(b64));
                let want = (m.to_f64(mk(a64).0) + m.to_f64(mk(a64).1))
                    + (m.to_f64(mk(b64).0) + m.to_f64(mk(b64).1));
                if want != 0.0 {
                    *worst = worst.max(((to_f64(m, r) - want) / want).abs());
                }
            }
        }
        assert!(worst_ati > worst_nv, "ati={worst_ati:e} nv={worst_nv:e}");
    }
}
