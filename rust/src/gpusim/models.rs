//! Named GPU arithmetic profiles (the rows and columns of Table 2).
//!
//! A [`GpuModel`] bundles a storage [`Format`] with one [`OpRounding`]
//! per operator class. The presets reproduce the *behaviour classes* the
//! paper measured; the exact interval endpoints of Table 2 are
//! chip-specific analogue of e.g. the NV35's internal mul datapath, so
//! comparisons are by class (exact / chopped / faithful / beyond
//! 1 ulp for div), not fourth-decimal endpoints.

use super::arith::{self, OpRounding, RoundMode, SoftFp};
use super::format::Format;

/// One GPU's arithmetic personality.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub name: &'static str,
    pub format: Format,
    /// Addition/subtraction datapath.
    pub add: OpRounding,
    /// Multiplication datapath.
    pub mul: OpRounding,
    /// Reciprocal unit (division = recip + mul, as the paper observes).
    pub recip: OpRounding,
    /// True when the adder keeps a guard bit (the paper's key Nvidia
    /// assumption, Th. 1–2).
    pub has_guard_bit: bool,
}

impl GpuModel {
    /// IEEE-754 round-to-nearest reference ("Exact rounding" column).
    pub const IEEE: GpuModel = GpuModel {
        name: "ieee-rn",
        format: Format::NV32,
        add: OpRounding::IEEE,
        mul: OpRounding::IEEE,
        recip: OpRounding::IEEE,
        has_guard_bit: true,
    };

    /// Theoretical chopped arithmetic ("Chopped" column: (-1, 0]).
    pub const CHOPPED: GpuModel = GpuModel {
        name: "chopped",
        format: Format::NV32,
        add: OpRounding { guard_bits: 8, sticky: true, mode: RoundMode::Truncate },
        mul: OpRounding { guard_bits: 8, sticky: true, mode: RoundMode::Truncate },
        recip: OpRounding { guard_bits: 8, sticky: true, mode: RoundMode::Truncate },
        has_guard_bit: true,
    };

    /// ATI R300: 24-bit internal format, **no guard bit** on the adder
    /// (subtraction error spans (-1, 1)), faithful multiplier.
    pub const R300: GpuModel = GpuModel {
        name: "r300",
        format: Format::ATI24,
        add: OpRounding { guard_bits: 0, sticky: false, mode: RoundMode::Truncate },
        mul: OpRounding { guard_bits: 2, sticky: false, mode: RoundMode::NearestEven },
        recip: OpRounding { guard_bits: 1, sticky: false, mode: RoundMode::NearestEven },
        has_guard_bit: false,
    };

    /// Nvidia NV35: 32-bit format, truncated addition **with a guard
    /// bit** (subtraction error within (-0.75, 0.75) in the paper's
    /// measurement), faithful multiplier.
    pub const NV35: GpuModel = GpuModel {
        name: "nv35",
        format: Format::NV32,
        add: OpRounding::GUARD_TRUNC,
        mul: OpRounding { guard_bits: 1, sticky: false, mode: RoundMode::NearestEven },
        recip: OpRounding { guard_bits: 1, sticky: false, mode: RoundMode::NearestEven },
        has_guard_bit: true,
    };

    /// Nvidia NV40/G70 (7800GTX, the paper's benchmark GPU): same
    /// arithmetic class as NV35 — the paper's §4.1 assumption "GPUs have
    /// a guard bit for the addition/subtraction with a faithful
    /// rounding … the case with latest Nvidia chips".
    pub const NV40: GpuModel = GpuModel { name: "nv40", ..Self::NV35 };

    /// All models the paranoia harness characterises.
    pub fn all() -> Vec<GpuModel> {
        vec![Self::IEEE, Self::CHOPPED, Self::R300, Self::NV35, Self::NV40]
    }

    pub fn by_name(name: &str) -> Option<GpuModel> {
        Self::all().into_iter().find(|m| m.name == name)
    }

    // Operator wrappers ----------------------------------------------------

    pub fn quantize(&self, v: f64) -> SoftFp {
        SoftFp::from_f64(v, self.format)
    }

    pub fn to_f64(&self, v: SoftFp) -> f64 {
        v.to_f64(self.format)
    }

    pub fn add(&self, a: SoftFp, b: SoftFp) -> SoftFp {
        arith::add(a, b, self.format, self.add)
    }

    pub fn sub(&self, a: SoftFp, b: SoftFp) -> SoftFp {
        arith::sub(a, b, self.format, self.add)
    }

    pub fn mul(&self, a: SoftFp, b: SoftFp) -> SoftFp {
        arith::mul(a, b, self.format, self.mul)
    }

    pub fn div(&self, a: SoftFp, b: SoftFp) -> SoftFp {
        arith::div(a, b, self.format, self.recip, self.mul)
    }

    /// Multiply-accumulate as two chained ops (the MAD unit of the 7800
    /// pixel shader — §1.1 — rounds between the stages on this era of
    /// hardware).
    pub fn mad(&self, a: SoftFp, b: SoftFp, c: SoftFp) -> SoftFp {
        self.add(self.mul(a, b), c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ieee_model_matches_f32_ops() {
        let m = GpuModel::IEEE;
        let mut rng = Rng::new(91);
        for _ in 0..50_000 {
            let a = rng.spread_f32(-20, 20);
            let b = rng.spread_f32(-20, 20);
            assert_eq!(m.to_f64(m.add(m.quantize(a as f64), m.quantize(b as f64))),
                       (a + b) as f64);
            assert_eq!(m.to_f64(m.mul(m.quantize(a as f64), m.quantize(b as f64))),
                       (a * b) as f64);
        }
    }

    #[test]
    fn r300_uses_24bit_storage() {
        let m = GpuModel::R300;
        let v = m.quantize(std::f32::consts::PI as f64);
        // 17-bit significand
        assert!(v.mant < 1 << 17);
        assert!(v.mant >= 1 << 16);
    }

    #[test]
    fn chopped_matches_paper_interval_class() {
        let m = GpuModel::CHOPPED;
        let mut rng = Rng::new(92);
        for _ in 0..50_000 {
            let a = rng.spread_f32(-8, 8) as f64;
            let b = rng.spread_f32(-8, 8) as f64;
            let scale = a.abs().max(b.abs());
            if (a + b) == 0.0 || (a + b).abs().log2().floor() != scale.log2().floor() {
                continue; // rounding probe, not a cancellation probe
            }
            let r = m.add(m.quantize(a), m.quantize(b));
            // magnitude convention (paper "Chopped" column): (-1, 0]
            let e = (m.to_f64(r).abs() - (a + b).abs()) / r.ulp(m.format);
            assert!(e <= 1e-9 && e > -1.0, "a={a} b={b} e={e}");
        }
    }

    #[test]
    fn all_models_have_unique_names() {
        let names: Vec<_> = GpuModel::all().iter().map(|m| m.name).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), 5);
        assert!(GpuModel::by_name("nv35").is_some());
        assert!(GpuModel::by_name("voodoo2").is_none());
    }

    #[test]
    fn mad_is_mul_then_add() {
        let m = GpuModel::NV35;
        let a = m.quantize(1.5);
        let b = m.quantize(2.25);
        let c = m.quantize(-3.0);
        assert_eq!(m.mad(a, b, c), m.add(m.mul(a, b), c));
    }
}
