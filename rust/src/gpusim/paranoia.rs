//! GPU Paranoia — regenerates the paper's Table 2.
//!
//! The paper ran Hillesland & Lastra's "GPU floating-point paranoia"
//! tool [14] to measure signed relative-error intervals (in ulps of the
//! result) for ⊕ ⊖ ⊗ ⊘ on real chips. This module performs the same
//! measurement against the simulated models: directed stress patterns
//! (operands engineered to maximise alignment loss) plus a large random
//! sweep, reporting `[min, max]` error in ulps per operation.

use super::models::GpuModel;
use crate::util::Rng;

/// Measured signed error interval (units: ulp of the rounded result).
#[derive(Clone, Copy, Debug, Default)]
pub struct Interval {
    pub min: f64,
    pub max: f64,
}

impl Interval {
    fn absorb(&mut self, e: f64) {
        if e < self.min {
            self.min = e;
        }
        if e > self.max {
            self.max = e;
        }
    }
}

/// One Table 2 row for one model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParanoiaRow {
    pub add: Interval,
    pub sub: Interval,
    pub mul: Interval,
    pub div: Interval,
}

/// Signed error of one simulated op against the exact real result, in
/// ulps of the simulated result. Like classic Paranoia (and hence the
/// paper's Table 2), operands are probed **positive**, so chopping an
/// addition gives (-1, 0] while a subtraction — whose result carries
/// either sign — spans (-1, 1).
fn ulp_err(model: &GpuModel, got: super::arith::SoftFp, exact: f64) -> f64 {
    let g = model.to_f64(got);
    if !g.is_finite() || !exact.is_finite() {
        return 0.0;
    }
    let ulp = got.ulp(model.format);
    if ulp == 0.0 {
        return 0.0;
    }
    (g - exact) / ulp
}

/// Run the paranoia measurement for one model.
///
/// `samples` random pairs per op plus directed patterns; the paper used
/// the Hillesland tool's directed search, we use both.
pub fn run(model: &GpuModel, samples: usize, seed: u64) -> ParanoiaRow {
    let mut row = ParanoiaRow::default();
    let mut rng = Rng::new(seed);

    // directed patterns: worst alignment cases x near-1 multipliers
    let p = model.format.precision() as i32;
    let mut directed: Vec<(f64, f64)> = Vec::new();
    for sh in 0..=(p + 2) {
        for frac in [1.0, 1.5, 1.25, 1.75, 1.0 + 2f64.powi(1 - p)] {
            for s2 in [1.0, -1.0] {
                directed.push((frac, s2 * (1.0 + 2f64.powi(1 - p)) * 2f64.powi(-sh)));
                directed.push((frac * (1.0 - 2f64.powi(1 - p)), s2 * 2f64.powi(-sh)));
            }
        }
    }

    // Like the Hillesland/Lastra tool (and the original Paranoia), the
    // probe patterns characterise the *rounding* of each unit. For +/-
    // that means same-binade results only: once the result drops a
    // binade below the larger operand, the error in result-ulps measures
    // alignment loss, not rounding, and is unbounded on any no-guard
    // adder (Goldberg §"guard digits").
    let same_binade = |r: f64, scale: f64| -> bool {
        r != 0.0 && r.abs().log2().floor() == scale.log2().floor()
    };
    let probe = |a: f64, b: f64, row: &mut ParanoiaRow| {
        // Paranoia probes positive operands (subtraction results still
        // carry both signs, which is where Table 2's (-1, 1) rows come
        // from).
        let qa = model.quantize(a.abs());
        let qb = model.quantize(b.abs());
        let (a, b) = (model.to_f64(qa), model.to_f64(qb));
        if a == 0.0 || b == 0.0 {
            return;
        }
        let scale = a.max(b);
        row.add.absorb(ulp_err(model, model.add(qa, qb), a + b));
        if same_binade(a - b, scale) {
            row.sub.absorb(ulp_err(model, model.sub(qa, qb), a - b));
        }
        row.mul.absorb(ulp_err(model, model.mul(qa, qb), a * b));
        row.div.absorb(ulp_err(model, model.div(qa, qb), a / b));
    };

    for &(a, b) in &directed {
        probe(a, b, &mut row);
        probe(b, a, &mut row);
    }
    for _ in 0..samples {
        let a = rng.spread_f32(-12, 12) as f64;
        let b = rng.spread_f32(-12, 12) as f64;
        probe(a, b, &mut row);
    }
    row
}

/// Paper's Table 2 reference values (for the comparison printout).
pub fn paper_reference() -> Vec<(&'static str, [f64; 8])> {
    vec![
        // op rows: [exact_min, exact_max, chopped_min, chopped_max,
        //           r300_min, r300_max, nv35_min, nv35_max]
        ("Addition", [-0.5, 0.5, -1.0, 0.0, -1.0, 0.0, -1.0, 0.0]),
        ("Subtraction", [-0.5, 0.5, -1.0, 1.0, -1.0, 1.0, -0.75, 0.75]),
        ("Multiplication", [-0.5, 0.5, -1.0, 0.0, -0.989, 0.125, -0.782, 0.625]),
        ("Division", [-0.5, 0.5, -1.0, 0.0, -2.869, 0.094, -1.199, 1.375]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn within(i: Interval, lo: f64, hi: f64) -> bool {
        i.min >= lo - 1e-9 && i.max <= hi + 1e-9
    }

    #[test]
    fn ieee_model_is_exactly_rounded() {
        let row = run(&GpuModel::IEEE, 50_000, 1);
        for i in [row.add, row.sub, row.mul] {
            assert!(within(i, -0.5, 0.5), "{i:?}");
            // and the interval is actually exercised
            assert!(i.min < -0.4 && i.max > 0.4, "{i:?}");
        }
        // The IEEE model's division still goes recip+mul (the GPU
        // datapath); two correct roundings compound to ~1.5 ulp worst
        // case, so "exact" applies to + - x only — exactly why the
        // paper's Table 2 shows division worse on every GPU.
        assert!(row.div.min >= -1.6 && row.div.max <= 1.6, "{:?}", row.div);
    }

    #[test]
    fn chopped_model_matches_paper_column() {
        let row = run(&GpuModel::CHOPPED, 50_000, 2);
        // paper: addition (-1, 0], multiplication (-1, 0]
        assert!(within(row.add, -1.0, 0.0), "{:?}", row.add);
        assert!(within(row.mul, -1.0, 0.0), "{:?}", row.mul);
        // subtraction (-1, 1)
        assert!(within(row.sub, -1.0, 1.0), "{:?}", row.sub);
        assert!(row.sub.min < -0.5 && row.sub.max > 0.5, "{:?}", row.sub);
    }

    #[test]
    fn r300_sub_spans_both_signs_beyond_half() {
        let row = run(&GpuModel::R300, 50_000, 3);
        // no guard bit: subtraction error approaches +-1 ulp
        assert!(row.sub.min < -0.9 && row.sub.max > 0.9, "{:?}", row.sub);
        // addition truncated: (-1, 0]
        assert!(within(row.add, -1.0, 0.0), "{:?}", row.add);
    }

    #[test]
    fn nv35_guard_bit_narrows_subtraction() {
        let row = run(&GpuModel::NV35, 50_000, 4);
        // guard bit: |sub error| strictly below 1 ulp (paper: 0.75)
        assert!(within(row.sub, -1.0, 1.0), "{:?}", row.sub);
        assert!(row.sub.min > -1.0 && row.sub.max < 1.0, "{:?}", row.sub);
        // faithful mul: |err| < 1
        assert!(within(row.mul, -1.0, 1.0), "{:?}", row.mul);
        // division via recip+mul: exceeds 1 ulp
        assert!(row.div.min < -1.0 || row.div.max > 1.0, "{:?}", row.div);
    }

    #[test]
    fn nv35_sub_tighter_than_r300() {
        let nv = run(&GpuModel::NV35, 30_000, 5);
        let ati = run(&GpuModel::R300, 30_000, 5);
        let span_nv = nv.sub.max - nv.sub.min;
        let span_ati = ati.sub.max - ati.sub.min;
        assert!(span_nv < span_ati, "nv={span_nv} ati={span_ati}");
    }

    #[test]
    fn paper_reference_shape() {
        let r = paper_reference();
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, "Addition");
    }
}
