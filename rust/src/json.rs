//! Minimal JSON parser and emitter — substrate for reading
//! `artifacts/manifest.json` and for the wire protocol's control
//! frames ([`crate::net`]).
//!
//! The image vendors no serde/serde_json, so this is a small, strict
//! recursive-descent parser covering the JSON the AOT pipeline emits
//! (objects, arrays, strings with escapes, numbers, bools, null), plus
//! a matching [`Value::render`] emitter (`parse(v.render()) == v` for
//! every finite value). Not a general-purpose library: no trailing
//! commas, no comments, UTF-8 only.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Integer accessor for wire fields carried as JSON numbers (ids,
    /// counts, millisecond budgets). f64 represents every integer only
    /// below 2^53, so values at or above that are rejected — a wire id
    /// that would silently alias through the Number round-trip (and
    /// mis-correlate replies) fails typed instead.
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 && *n < MAX_EXACT => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Emit this value as a compact JSON document such that
    /// `parse(&v.render()) == v` for every finite value. Non-finite
    /// numbers (which JSON cannot represent) render as `null`; integral
    /// numbers within the exactly-representable range render without a
    /// fractional part, so `u64` wire fields round-trip through
    /// [`Value::as_u64`].
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Number(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::String(s) => escape_into(s, out),
            Value::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Value::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build an object value from key/value pairs — the emitter-side
/// convenience the wire codecs use (`BTreeMap` construction inline is
/// noisy).
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> ParseError {
        ParseError { offset: self.pos, message: m.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::String(self.string()?)),
            b't' => self.keyword("true", Value::Bool(true)),
            b'f' => self.keyword("false", Value::Bool(false)),
            b'n' => self.keyword("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn keyword(&mut self, kw: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| ParseError { offset: start, message: format!("bad number '{s}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "format": "hlo-text-v1",
          "entries": [
            {"name": "add_n4096", "op": "add", "n": 4096, "n_in": 2,
             "n_out": 1, "file": "add_n4096.hlo.txt", "hlo_bytes": 1234,
             "in_shapes": [[4096],[4096]], "lower_seconds": 0.03}
          ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text-v1");
        let entries = v.get("entries").unwrap().as_array().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("n").unwrap().as_usize().unwrap(), 4096);
        assert_eq!(
            entries[0].get("in_shapes").unwrap().as_array().unwrap()[0]
                .as_array().unwrap()[0].as_usize().unwrap(),
            4096
        );
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("-12.5e2").unwrap(), Value::Number(-1250.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse(r#""héllo ∞""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∞");
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"[1, [2, [3, {"k": [4]}]], {}]"#).unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] extra").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_reports_offset() {
        let e = parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
        assert!(e.to_string().contains("byte 4"));
    }

    #[test]
    fn render_round_trips() {
        let docs = [
            "null",
            "true",
            "[1,2.5,-3]",
            r#"{"a":[{"b":"c"},null],"d":false}"#,
            r#""quote \" backslash \\ newline \n tab \t""#,
            r#"{"id":9007199254740992}"#,
            r#""héllo ∞""#,
        ];
        for doc in docs {
            let v = parse(doc).unwrap();
            assert_eq!(parse(&v.render()).unwrap(), v, "doc: {doc}");
        }
    }

    #[test]
    fn render_integers_without_fraction() {
        let v = obj(vec![("id", Value::Number(12345.0))]);
        assert_eq!(v.render(), r#"{"id":12345}"#);
        assert_eq!(parse(&v.render()).unwrap().get("id").unwrap().as_u64(), Some(12345));
    }

    #[test]
    fn render_control_chars_escaped() {
        let v = Value::String("a\u{1}b".into());
        assert_eq!(v.render(), "\"a\\u0001b\"");
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn render_non_finite_as_null() {
        assert_eq!(Value::Number(f64::NAN).render(), "null");
        assert_eq!(Value::Number(f64::INFINITY).render(), "null");
    }

    #[test]
    fn as_u64_rejects_non_integers() {
        assert_eq!(Value::Number(1.5).as_u64(), None);
        assert_eq!(Value::Number(-1.0).as_u64(), None);
        assert_eq!(Value::Number(42.0).as_u64(), Some(42));
        assert_eq!(Value::String("42".into()).as_u64(), None);
    }

    #[test]
    fn as_u64_rejects_beyond_exact_f64_range() {
        // 2^53 - 1 is the last integer every neighbour of which f64
        // still distinguishes; from 2^53 up, distinct u64 ids alias
        let max_exact = (1u64 << 53) - 1;
        assert_eq!(Value::Number(max_exact as f64).as_u64(), Some(max_exact));
        assert_eq!(Value::Number((1u64 << 53) as f64).as_u64(), None);
        // 2^53 + 1 parses to the f64 2^53 — must not yield a wrong id
        assert_eq!(parse("9007199254740993").unwrap().as_u64(), None);
        assert_eq!(Value::Number(1e18).as_u64(), None);
    }
}
