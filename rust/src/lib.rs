//! # ffgpu — float-float operators on a stream processor
//!
//! Production reproduction of *"Implementation of float-float operators on
//! graphics hardware"* (Guillaume Da Graça, David Defour, 2006): a 44-bit
//! "single-single" floating-point format built from pairs of `f32`s, the
//! error-free transformations it rests on (Add12 / Split / Mul12), the
//! float-float operators (Add22 / Mul22 and the §7 extensions), plus every
//! substrate the paper's evaluation needs:
//!
//! * [`ff`] — the numeric format itself on native IEEE-754 hardware
//!   (scalar [`ff::FF32`], SoA vector ops, double-double comparator,
//!   compensated algorithms, and the tiered SIMD/FMA kernel engine
//!   [`ff::simd`]: scalar / lane-blocked / FMA kernels selected per
//!   CPU via [`ff::KernelTier`], bit-identical on the servable
//!   domain);
//! * [`gpusim`] — a software model of 2006-era GPU arithmetic
//!   (configurable formats of the paper's Table 1, rounding behaviours of
//!   Table 2, a mini-Brook stream VM) used to validate the paper's
//!   theorems under *non-IEEE* arithmetic and to regenerate Table 2;
//! * [`mp`] — an arbitrary-precision binary float (mini-MPFR), the
//!   accuracy oracle for Table 5;
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled XLA
//!   artifacts produced by `python/compile` (the "GPU path" of Table 3;
//!   needs the `xla` cargo feature, stubbed otherwise);
//! * [`backend`] — the **execution-substrate layer**: the typed
//!   operator catalogue ([`backend::Op`]), the owned-buffer job model
//!   ([`backend::ExecJob`]: `Arc`-shared input planes, validated at
//!   construction), one [`backend::KernelBackend`] trait over both,
//!   with native multicore ([`backend::NativeBackend`] — a persistent
//!   channel-fed worker crew with per-worker
//!   [`backend::WorkerArenas`], no spawn/join per batch, running the
//!   [`backend::KernelTier`] resolved at construction over L2-sized
//!   chunks),
//!   simulated-GPU ([`backend::GpuSimBackend`]) and PJRT/XLA
//!   ([`backend::XlaBackend`]) implementations, typed
//!   [`backend::ServiceError`]s, and the [`backend::BufferPool`] that
//!   keeps the hot path allocation-free;
//! * [`coordinator`] — the typed, routed, sharded dispatcher (the
//!   moral equivalent of the Brook runtime): build a
//!   [`coordinator::Plan`] (shape-checked at build time), dispatch it
//!   for a future-like [`coordinator::Ticket`] with deadline/cancel
//!   lifecycle control; a [`coordinator::ServiceSpec`] gives every
//!   shard its own [`backend::BackendSpec`] (heterogeneous sets are
//!   first-class) plus a **fusion stage**
//!   ([`coordinator::ServiceSpec::fuse_window`] /
//!   [`coordinator::ServiceSpec::fuse_sizes`]) that packs cross-client
//!   same-op requests into padded fused launches and reports
//!   padding-waste telemetry; a pluggable
//!   [`coordinator::routing::RoutingPolicy`] — round-robin,
//!   queue-depth-aware, capability-aware op-affinity, or
//!   telemetry-driven measured routing — places each request over the
//!   live per-shard [`coordinator::routing::TelemetryView`]; and the
//!   **accuracy observatory** ([`coordinator::observatory`]) mirrors a
//!   configurable fraction of live traffic onto a native reference
//!   plus simulated GPU models, diffing replies lane-by-lane in ulps —
//!   the paper's Tables 2 and 5 as a continuous experiment
//!   ([`coordinator::Service::accuracy_report`]); in front of routing
//!   sits an opt-in **content-addressed result cache**
//!   ([`coordinator::ResultCache`], armed via
//!   [`coordinator::ServiceSpec::cache_mb`]): repeated identical
//!   grids resolve without touching a shard, concurrent identical
//!   misses coalesce single-flight behind one leader, memory stays
//!   under a byte budget via cost-aware segmented-LRU eviction, and
//!   hits are provably invisible to routing telemetry and the
//!   observatory; padding-waste EWMAs feed back into planning — the
//!   `measured` policy surcharges wasteful placements and
//!   [`coordinator::ServiceSpec::adaptive_ladder`] lets each shard
//!   densify its fuse ladder around hot sizes
//!   ([`coordinator::batcher::adapt`]);
//! * [`net`] — the **wire front end**: a std-only, length-prefixed
//!   binary protocol over TCP ([`net::frame`]) serving the coordinator
//!   to out-of-process clients; [`net::WireServer`] owns a
//!   [`coordinator::Handle`], admits work through per-client
//!   token-bucket budgets ([`net::admission`], keyed by
//!   [`net::ClientClass`]), sheds load from the live telemetry plane
//!   ([`net::shed`] — an `Overloaded { retry_after_ms }` frame when
//!   measured queue-depth × per-op latency already exceeds the
//!   declared deadline), and drains connections round-robin so one hot
//!   client cannot starve the fuse window; [`net::WireClient`] is the
//!   matching blocking client with the Ticket-style dispatch/wait
//!   surface;
//! * [`harness`] — workload generators and table emitters that regenerate
//!   every table of the paper's evaluation section, plus the
//!   substrate-neutral [`harness::timing::backend_grid`].
//!
//! See `DESIGN.md` for the module map and the experiment index
//! (which table each command regenerates, and the documented
//! substitutions this environment forces).

pub mod backend;
pub mod coordinator;
pub mod ff;
pub mod gpusim;
pub mod harness;
pub mod json;
pub mod mp;
pub mod net;
pub mod runtime;
pub mod util;
