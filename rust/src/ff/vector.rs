//! SoA vector kernels — the "CPU path" of the paper's Table 4.
//!
//! Each function is the scalar algorithm applied elementwise over
//! structure-of-arrays planes, mirroring the Pallas L1 kernels
//! **bit-for-bit** (same operation order, same mask split). The
//! integration test `runtime_matches_native` asserts that equivalence
//! against the XLA-executed artifacts.
//!
//! Two Add22 flavours are exposed because the paper benchmarks them
//! differently: the branch-free variant (GPU-style, Table 3 semantics)
//! and the branchy variant (what double-double CPU libraries of the era
//! used, the paper's Table 4 "Add22" with its pipeline-break cost).

use super::eft::{fast_two_sum, split, two_prod, two_sum};
use super::ff32::FF32;

/// Elementwise `s, e = two_sum(a, b)` over slices. Panics on length mismatch.
pub fn add12(a: &[f32], b: &[f32], s: &mut [f32], e: &mut [f32]) {
    let n = a.len();
    assert!(b.len() == n && s.len() == n && e.len() == n);
    for i in 0..n {
        let (si, ei) = two_sum(a[i], b[i]);
        s[i] = si;
        e[i] = ei;
    }
}

/// Elementwise mask split.
pub fn split_v(a: &[f32], hi: &mut [f32], lo: &mut [f32]) {
    let n = a.len();
    assert!(hi.len() == n && lo.len() == n);
    for i in 0..n {
        let (h, l) = split(a[i]);
        hi[i] = h;
        lo[i] = l;
    }
}

/// Elementwise exact product.
pub fn mul12(a: &[f32], b: &[f32], x: &mut [f32], y: &mut [f32]) {
    let n = a.len();
    assert!(b.len() == n && x.len() == n && y.len() == n);
    for i in 0..n {
        let (xi, yi) = two_prod(a[i], b[i]);
        x[i] = xi;
        y[i] = yi;
    }
}

/// Elementwise float-float addition, branch-free (kernel semantics).
pub fn add22(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
) {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n && rh.len() == n && rl.len() == n);
    for i in 0..n {
        let (sh, se) = two_sum(ah[i], bh[i]);
        let te = (al[i] + bl[i]) + se;
        let (h, l) = fast_two_sum(sh, te);
        rh[i] = h;
        rl[i] = l;
    }
}

/// Elementwise float-float addition, branchy (the paper's CPU Table 4
/// variant — kept for the Table 4 reproduction).
pub fn add22_branchy(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
) {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n && rh.len() == n && rl.len() == n);
    for i in 0..n {
        let a = FF32::from_parts(ah[i], al[i]);
        let b = FF32::from_parts(bh[i], bl[i]);
        let r = a.add22_branchy(b);
        rh[i] = r.hi;
        rl[i] = r.lo;
    }
}

/// Elementwise float-float multiplication.
pub fn mul22(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
) {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n && rh.len() == n && rl.len() == n);
    for i in 0..n {
        let (ph, pl) = two_prod(ah[i], bh[i]);
        let pl = pl + (ah[i] * bl[i] + al[i] * bh[i]);
        let (h, l) = fast_two_sum(ph, pl);
        rh[i] = h;
        rl[i] = l;
    }
}

/// Elementwise float-float division.
pub fn div22(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
) {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n && rh.len() == n && rl.len() == n);
    for i in 0..n {
        let q1 = ah[i] / bh[i];
        let (th, tl) = two_prod(q1, bh[i]);
        let r = (((ah[i] - th) - tl) + al[i] - q1 * bl[i]) / bh[i];
        let (h, l) = fast_two_sum(q1, r);
        rh[i] = h;
        rl[i] = l;
    }
}

/// Elementwise float-float multiply-add `r = a*b + c`.
#[allow(clippy::too_many_arguments)]
pub fn mad22(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], ch: &[f32], cl: &[f32],
    rh: &mut [f32], rl: &mut [f32],
) {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n && ch.len() == n && cl.len() == n);
    assert!(rh.len() == n && rl.len() == n);
    for i in 0..n {
        let a = FF32::from_parts(ah[i], al[i]);
        let b = FF32::from_parts(bh[i], bl[i]);
        let c = FF32::from_parts(ch[i], cl[i]);
        let r = a.mul22(b).add22(c);
        rh[i] = r.hi;
        rl[i] = r.lo;
    }
}

/// Single-precision baselines (Tables 3-4 comparators).
pub fn base_add(a: &[f32], b: &[f32], r: &mut [f32]) {
    for i in 0..a.len() {
        r[i] = a[i] + b[i];
    }
}

pub fn base_mul(a: &[f32], b: &[f32], r: &mut [f32]) {
    for i in 0..a.len() {
        r[i] = a[i] * b[i];
    }
}

pub fn base_mad(a: &[f32], b: &[f32], c: &[f32], r: &mut [f32]) {
    for i in 0..a.len() {
        r[i] = a[i] * b[i] + c[i];
    }
}

/// Dispatch an operator by catalogue name over SoA planes.
///
/// `inputs` and `outputs` follow the artifact manifest arities
/// (e.g. `add22`: 4 inputs, 2 outputs). Used by the coordinator's
/// native backend and by the integration tests.
pub fn dispatch(
    op: &str, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
) -> Result<(), String> {
    let mut slices: Vec<&mut [f32]> =
        outputs.iter_mut().map(|v| v.as_mut_slice()).collect();
    dispatch_slices(op, inputs, &mut slices)
}

/// [`dispatch`] over borrowed output windows — the form the chunked
/// worker pool of [`crate::backend::NativeBackend`] needs, where each
/// job owns a disjoint `&mut` window of every output plane.
pub fn dispatch_slices(
    op: &str, inputs: &[&[f32]], outputs: &mut [&mut [f32]],
) -> Result<(), String> {
    match op {
        "add12" => {
            let (a, b) = (inputs[0], inputs[1]);
            let (s, e) = split_two_mut(outputs);
            add12(a, b, s, e);
        }
        "split" => {
            let (h, l) = split_two_mut(outputs);
            split_v(inputs[0], h, l);
        }
        "mul12" => {
            let (x, y) = split_two_mut(outputs);
            mul12(inputs[0], inputs[1], x, y);
        }
        "add22" => {
            let (h, l) = split_two_mut(outputs);
            add22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "mul22" => {
            let (h, l) = split_two_mut(outputs);
            mul22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "div22" => {
            let (h, l) = split_two_mut(outputs);
            div22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "mad22" => {
            let (h, l) = split_two_mut(outputs);
            mad22(inputs[0], inputs[1], inputs[2], inputs[3], inputs[4], inputs[5], h, l);
        }
        "add" => base_add(inputs[0], inputs[1], &mut *outputs[0]),
        "mul" => base_mul(inputs[0], inputs[1], &mut *outputs[0]),
        "mad" => base_mad(inputs[0], inputs[1], inputs[2], &mut *outputs[0]),
        other => return Err(format!("unknown op {other}")),
    }
    Ok(())
}

/// Split the first two output windows apart — shared with the
/// lane-blocked dispatch in [`crate::ff::simd`].
pub(crate) fn split_two_mut<'a>(
    outputs: &'a mut [&mut [f32]],
) -> (&'a mut [f32], &'a mut [f32]) {
    let (a, b) = outputs.split_at_mut(1);
    (&mut *a[0], &mut *b[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn planes(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut hi = Vec::with_capacity(n);
        let mut lo = Vec::with_capacity(n);
        for _ in 0..n {
            let (h, l) = rng.ff_pair(-10, 10);
            hi.push(h);
            lo.push(l);
        }
        (hi, lo)
    }

    #[test]
    fn vector_matches_scalar_add22() {
        let mut rng = Rng::new(31);
        let n = 4096;
        let (ah, al) = planes(&mut rng, n);
        let (bh, bl) = planes(&mut rng, n);
        let mut rh = vec![0.0; n];
        let mut rl = vec![0.0; n];
        add22(&ah, &al, &bh, &bl, &mut rh, &mut rl);
        for i in 0..n {
            let want = FF32::from_parts(ah[i], al[i]) + FF32::from_parts(bh[i], bl[i]);
            assert_eq!((rh[i], rl[i]), (want.hi, want.lo), "i={i}");
        }
    }

    #[test]
    fn vector_matches_scalar_mul22() {
        let mut rng = Rng::new(32);
        let n = 4096;
        let (ah, al) = planes(&mut rng, n);
        let (bh, bl) = planes(&mut rng, n);
        let mut rh = vec![0.0; n];
        let mut rl = vec![0.0; n];
        mul22(&ah, &al, &bh, &bl, &mut rh, &mut rl);
        for i in 0..n {
            let want = FF32::from_parts(ah[i], al[i]) * FF32::from_parts(bh[i], bl[i]);
            assert_eq!((rh[i], rl[i]), (want.hi, want.lo), "i={i}");
        }
    }

    #[test]
    fn mul12_exactness_vectorised() {
        let mut rng = Rng::new(33);
        let n = 8192;
        let a = rng.fill_spread(n, -20, 20);
        let b = rng.fill_spread(n, -20, 20);
        let mut x = vec![0.0; n];
        let mut y = vec![0.0; n];
        mul12(&a, &b, &mut x, &mut y);
        for i in 0..n {
            assert_eq!(x[i] as f64 + y[i] as f64, a[i] as f64 * b[i] as f64);
        }
    }

    #[test]
    fn dispatch_all_ops_run() {
        let mut rng = Rng::new(34);
        let n = 256;
        let (ah, al) = planes(&mut rng, n);
        let (bh, bl) = planes(&mut rng, n);
        let (ch, cl) = planes(&mut rng, n);
        for (op, n_in, n_out) in [
            ("add12", 2, 2), ("split", 1, 2), ("mul12", 2, 2),
            ("add22", 4, 2), ("mul22", 4, 2), ("div22", 4, 2), ("mad22", 6, 2),
            ("add", 2, 1), ("mul", 2, 1), ("mad", 3, 1),
        ] {
            let ins: Vec<&[f32]> =
                [&ah[..], &al[..], &bh[..], &bl[..], &ch[..], &cl[..]][..n_in].to_vec();
            let mut outs = vec![vec![0.0f32; n]; n_out];
            dispatch(op, &ins, &mut outs).unwrap();
            // every op must write *something* non-trivially
            assert!(outs[0].iter().any(|&v| v != 0.0), "op {op} wrote zeros");
        }
        assert!(dispatch("nope", &[], &mut []).is_err());
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        let a = vec![0.0f32; 4];
        let b = vec![0.0f32; 5];
        let mut s = vec![0.0f32; 4];
        let mut e = vec![0.0f32; 4];
        add12(&a, &b, &mut s, &mut e);
    }
}
