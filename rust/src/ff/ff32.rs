//! [`FF32`]: the scalar float-float type (paper §4, Theorems 5–6).
//!
//! `FF32 { hi, lo }` represents the real number `hi + lo` with
//! `|lo| <= ulp(hi)/2`. Operators follow the paper's algorithms exactly:
//! `+` is Add22 (the branch-free GPU variant), `*` is Mul22, with the §7
//! extensions (`/`, sqrt, branchy CPU-style Add22) alongside.

use super::eft::{fast_two_sum, two_prod, two_sum};
use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A float-float number: the unevaluated sum of two `f32`s.
#[derive(Clone, Copy, Default, PartialEq)]
pub struct FF32 {
    /// Leading component (carries the sign and magnitude).
    pub hi: f32,
    /// Trailing component, `|lo| <= ulp(hi)/2` when normalised.
    pub lo: f32,
}

impl FF32 {
    pub const ZERO: FF32 = FF32 { hi: 0.0, lo: 0.0 };
    pub const ONE: FF32 = FF32 { hi: 1.0, lo: 0.0 };

    /// Construct from components **without** renormalising.
    /// Caller asserts `hi + lo` is already a valid float-float pair.
    #[inline]
    pub const fn from_parts(hi: f32, lo: f32) -> Self {
        FF32 { hi, lo }
    }

    /// Construct from components, renormalising with fast-two-sum.
    #[inline]
    pub fn renorm(hi: f32, lo: f32) -> Self {
        let (h, l) = fast_two_sum(hi, lo);
        FF32 { hi: h, lo: l }
    }

    /// Exact widening of a single `f32`.
    #[inline]
    pub fn from_f32(v: f32) -> Self {
        FF32 { hi: v, lo: 0.0 }
    }

    /// Best float-float approximation of an `f64` (exact when the f64
    /// has <= 49 significand bits, e.g. any sum/product of two f32s).
    #[inline]
    pub fn from_f64(v: f64) -> Self {
        let hi = v as f32;
        let lo = (v - hi as f64) as f32;
        FF32 { hi, lo }
    }

    /// Value as `f64` (exact: 24 + 24 bits fit in 53).
    #[inline]
    pub fn to_f64(self) -> f64 {
        self.hi as f64 + self.lo as f64
    }

    /// Paper Th. 5 — Add22, branch-free GPU variant (11 flops):
    /// two-sum on the high words, accumulate both low words, renormalise.
    #[inline]
    pub fn add22(self, rhs: FF32) -> FF32 {
        let (sh, se) = two_sum(self.hi, rhs.hi);
        let te = (self.lo + rhs.lo) + se;
        let (rh, rl) = fast_two_sum(sh, te);
        FF32 { hi: rh, lo: rl }
    }

    /// The *branchy* Add22 the paper benchmarks on CPUs (Table 4): picks
    /// the larger operand with a test instead of the 3 extra flops.
    /// Semantically equivalent accuracy class; slower on deep pipelines —
    /// the effect the paper measures ("the test ... breaks the execution
    /// pipeline").
    #[inline]
    pub fn add22_branchy(self, rhs: FF32) -> FF32 {
        let r = self.hi + rhs.hi;
        let s = if self.hi.abs() >= rhs.hi.abs() {
            ((self.hi - r) + rhs.hi) + rhs.lo + self.lo
        } else {
            ((rhs.hi - r) + self.hi) + self.lo + rhs.lo
        };
        let (rh, rl) = fast_two_sum(r, s);
        FF32 { hi: rh, lo: rl }
    }

    /// Higher-accuracy Add22 (two two-sums, 20 flops): the "accurate"
    /// double-double variant; error O(2^-47 |a+b|) — used by harnesses
    /// that need headroom over the paper's bound.
    #[inline]
    pub fn add22_accurate(self, rhs: FF32) -> FF32 {
        let (sh, se) = two_sum(self.hi, rhs.hi);
        let (tl, te) = two_sum(self.lo, rhs.lo);
        let se = se + tl;
        let (sh2, se2) = fast_two_sum(sh, se);
        let se2 = se2 + te;
        let (rh, rl) = fast_two_sum(sh2, se2);
        FF32 { hi: rh, lo: rl }
    }

    /// Paper Th. 6 — Mul22: exact two-product of the high words plus the
    /// cross terms, renormalised. Relative error <= 2^-44.
    #[inline]
    pub fn mul22(self, rhs: FF32) -> FF32 {
        let (ph, pl) = two_prod(self.hi, rhs.hi);
        let pl = pl + (self.hi * rhs.lo + self.lo * rhs.hi);
        let (rh, rl) = fast_two_sum(ph, pl);
        FF32 { hi: rh, lo: rl }
    }

    /// Float-float division (paper §7 future work): one reciprocal
    /// estimate + one float-float residual correction. Relative error
    /// ~2^-43.
    #[inline]
    pub fn div22(self, rhs: FF32) -> FF32 {
        let q1 = self.hi / rhs.hi;
        let (th, tl) = two_prod(q1, rhs.hi);
        let r = (((self.hi - th) - tl) + self.lo - q1 * rhs.lo) / rhs.hi;
        let (rh, rl) = fast_two_sum(q1, r);
        FF32 { hi: rh, lo: rl }
    }

    /// Float-float square root: Karp–Markstein style single correction.
    /// Relative error ~2^-44. Returns NaN pair for negative input.
    #[inline]
    pub fn sqrt22(self) -> FF32 {
        if self.hi < 0.0 {
            return FF32 { hi: f32::NAN, lo: f32::NAN };
        }
        if self.hi == 0.0 {
            return FF32::ZERO;
        }
        let q = self.hi.sqrt();
        let (th, tl) = two_prod(q, q);
        // r = (a - q^2) / (2q)
        let r = (((self.hi - th) - tl) + self.lo) / (2.0 * q);
        let (rh, rl) = fast_two_sum(q, r);
        FF32 { hi: rh, lo: rl }
    }

    /// Fused multiply-add in float-float: `self * b + c` (one Mul22 +
    /// one Add22 — the composite the mad22 kernel fuses).
    #[inline]
    pub fn mad22(self, b: FF32, c: FF32) -> FF32 {
        self.mul22(b).add22(c)
    }

    #[inline]
    pub fn abs(self) -> FF32 {
        if self.hi < 0.0 || (self.hi == 0.0 && self.lo < 0.0) { -self } else { self }
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.hi.is_finite() && self.lo.is_finite()
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        self.hi.is_nan() || self.lo.is_nan()
    }

    /// True when `|lo| <= ulp(hi)/2` (canonical form).
    pub fn is_normalised(self) -> bool {
        if self.lo == 0.0 {
            return true;
        }
        (self.lo.abs() as f64) <= crate::util::ulp_f32(self.hi) * 0.5
    }
}

impl Add for FF32 {
    type Output = FF32;
    #[inline]
    fn add(self, rhs: FF32) -> FF32 {
        self.add22(rhs)
    }
}

impl Sub for FF32 {
    type Output = FF32;
    #[inline]
    fn sub(self, rhs: FF32) -> FF32 {
        self.add22(-rhs)
    }
}

impl Mul for FF32 {
    type Output = FF32;
    #[inline]
    fn mul(self, rhs: FF32) -> FF32 {
        self.mul22(rhs)
    }
}

impl Div for FF32 {
    type Output = FF32;
    #[inline]
    fn div(self, rhs: FF32) -> FF32 {
        self.div22(rhs)
    }
}

impl Neg for FF32 {
    type Output = FF32;
    #[inline]
    fn neg(self) -> FF32 {
        FF32 { hi: -self.hi, lo: -self.lo }
    }
}

impl AddAssign for FF32 {
    fn add_assign(&mut self, rhs: FF32) {
        *self = *self + rhs;
    }
}
impl SubAssign for FF32 {
    fn sub_assign(&mut self, rhs: FF32) {
        *self = *self - rhs;
    }
}
impl MulAssign for FF32 {
    fn mul_assign(&mut self, rhs: FF32) {
        *self = *self * rhs;
    }
}
impl DivAssign for FF32 {
    fn div_assign(&mut self, rhs: FF32) {
        *self = *self / rhs;
    }
}

impl PartialOrd for FF32 {
    fn partial_cmp(&self, other: &FF32) -> Option<Ordering> {
        match self.hi.partial_cmp(&other.hi) {
            Some(Ordering::Equal) => self.lo.partial_cmp(&other.lo),
            ord => ord,
        }
    }
}

impl From<f32> for FF32 {
    fn from(v: f32) -> Self {
        FF32::from_f32(v)
    }
}

impl From<f64> for FF32 {
    fn from(v: f64) -> Self {
        FF32::from_f64(v)
    }
}

impl fmt::Debug for FF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FF32({:e} + {:e})", self.hi, self.lo)
    }
}

impl fmt::Display for FF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.17e}", self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_ff(rng: &mut Rng) -> (FF32, f64) {
        let (hi, lo) = rng.ff_pair(-12, 12);
        (FF32::from_parts(hi, lo), hi as f64 + lo as f64)
    }

    #[test]
    fn add22_respects_paper_bound() {
        let mut rng = Rng::new(21);
        for _ in 0..200_000 {
            let (a, a64) = rand_ff(&mut rng);
            let (b, b64) = rand_ff(&mut rng);
            let r = a + b;
            let want = a64 + b64;
            let err = (r.to_f64() - want).abs();
            let bound = (2f64.powi(-23) * (a.lo as f64 + b.lo as f64).abs())
                .max(2f64.powi(-43) * want.abs());
            assert!(err <= bound + 1e-300, "a={a:?} b={b:?} err={err:e}");
        }
    }

    #[test]
    fn add22_branchy_same_error_class() {
        let mut rng = Rng::new(22);
        for _ in 0..100_000 {
            let (a, a64) = rand_ff(&mut rng);
            let (b, b64) = rand_ff(&mut rng);
            let r = a.add22_branchy(b);
            let want = a64 + b64;
            let err = (r.to_f64() - want).abs();
            let bound = (2f64.powi(-23) * (a.lo as f64 + b.lo as f64).abs())
                .max(2f64.powi(-43) * want.abs());
            assert!(err <= bound + 1e-300, "a={a:?} b={b:?}");
        }
    }

    #[test]
    fn add22_accurate_tighter_than_plain() {
        // Individual samples can go either way (different rounding paths);
        // in aggregate the 20-flop variant must be at least as accurate.
        let mut rng = Rng::new(23);
        let (mut sum_plain, mut sum_acc) = (0.0f64, 0.0f64);
        for _ in 0..50_000 {
            let (a, a64) = rand_ff(&mut rng);
            let (b, b64) = rand_ff(&mut rng);
            let want = a64 + b64;
            let scale = want.abs().max(1e-300);
            sum_plain += (a.add22(b).to_f64() - want).abs() / scale;
            sum_acc += (a.add22_accurate(b).to_f64() - want).abs() / scale;
        }
        assert!(sum_acc <= sum_plain * 1.01 + 1e-12,
                "accurate {sum_acc:e} vs plain {sum_plain:e}");
    }

    #[test]
    fn mul22_relative_error_within_2pow44() {
        let mut rng = Rng::new(24);
        for _ in 0..200_000 {
            let (a, a64) = rand_ff(&mut rng);
            let (b, b64) = rand_ff(&mut rng);
            let r = a * b;
            let want = a64 * b64;
            if want == 0.0 || !r.is_finite() {
                continue;
            }
            let rel = ((r.to_f64() - want) / want).abs();
            assert!(rel <= 2f64.powi(-43), "a={a:?} b={b:?} rel=2^{}", rel.log2());
        }
    }

    #[test]
    fn div22_roundtrips_mul22() {
        let mut rng = Rng::new(25);
        for _ in 0..100_000 {
            let (a, a64) = rand_ff(&mut rng);
            let (b, b64) = rand_ff(&mut rng);
            if b.hi.abs() < 1e-6 {
                continue;
            }
            let q = a / b;
            let want = a64 / b64;
            let rel = ((q.to_f64() - want) / want).abs();
            assert!(rel <= 2f64.powi(-42), "a={a:?} b={b:?} rel=2^{}", rel.log2());
        }
    }

    #[test]
    fn sqrt22_accuracy() {
        let mut rng = Rng::new(26);
        for _ in 0..100_000 {
            let (a, a64) = rand_ff(&mut rng);
            let a = a.abs();
            let a64 = a64.abs();
            if a64 == 0.0 {
                continue;
            }
            let s = a.sqrt22();
            let want = a64.sqrt();
            let rel = ((s.to_f64() - want) / want).abs();
            assert!(rel <= 2f64.powi(-43), "a={a:?} rel=2^{}", rel.log2());
        }
        assert!(FF32::from_f32(-1.0).sqrt22().is_nan());
        assert_eq!(FF32::ZERO.sqrt22(), FF32::ZERO);
    }

    #[test]
    fn operators_produce_normalised_results() {
        let mut rng = Rng::new(27);
        for _ in 0..50_000 {
            let (a, _) = rand_ff(&mut rng);
            let (b, _) = rand_ff(&mut rng);
            assert!((a + b).is_normalised());
            assert!((a * b).is_normalised());
            if b.hi != 0.0 {
                assert!((a / b).is_normalised());
            }
        }
    }

    #[test]
    fn from_f64_roundtrip() {
        let mut rng = Rng::new(28);
        for _ in 0..100_000 {
            let v = rng.normal() * rng.uniform(-8.0, 8.0).exp2();
            let ff = FF32::from_f64(v);
            // 49-bit relative fidelity
            let rel = ((ff.to_f64() - v) / v).abs();
            assert!(rel <= 2f64.powi(-46), "v={v} rel=2^{}", rel.log2());
            assert!(ff.is_normalised());
        }
    }

    #[test]
    fn ordering_uses_both_words() {
        let a = FF32::from_parts(1.0, 1e-9);
        let b = FF32::from_parts(1.0, 2e-9);
        assert!(a < b);
        assert!(FF32::from_f32(2.0) > b);
    }

    #[test]
    fn neg_and_abs() {
        let a = FF32::from_f64(-1.25e-3);
        assert_eq!((-a).to_f64(), -a.to_f64());
        assert_eq!(a.abs().to_f64(), -a.to_f64());
        assert!(a.abs().to_f64() > 0.0);
        // negation is exact (sign flip on both words)
        assert_eq!((-(-a)), a);
    }

    #[test]
    fn mad22_equals_mul_then_add() {
        let mut rng = Rng::new(29);
        for _ in 0..50_000 {
            let (a, _) = rand_ff(&mut rng);
            let (b, _) = rand_ff(&mut rng);
            let (c, _) = rand_ff(&mut rng);
            let m = a.mad22(b, c);
            let n = (a * b) + c;
            assert_eq!(m, n);
        }
    }

    #[test]
    fn precision_demo_pi_plus_tiny() {
        // the headline capability: f32 loses this, FF32 keeps it
        let pi = FF32::from_f64(std::f64::consts::PI);
        let tiny = FF32::from_f64(1e-10);
        let sum = pi + tiny;
        let f32_sum = std::f32::consts::PI + 1e-10f32;
        assert_eq!(f32_sum, std::f32::consts::PI); // f32 swallowed it
        let err = (sum.to_f64() - (std::f64::consts::PI + 1e-10)).abs();
        assert!(err < 1e-13); // FF32 kept it
    }
}
