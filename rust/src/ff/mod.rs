//! The float-float ("single-single") numeric format — the paper's core
//! contribution, on native IEEE-754 hardware.
//!
//! A float-float number is the unevaluated sum `hi + lo` of two `f32`s
//! with `|lo| <= ulp(hi)/2`, giving ~49 bits of significand on IEEE
//! hardware (the paper quotes 44 bits under GPU arithmetic, where the
//! operators lose a few bits to faithful rounding). The module provides:
//!
//! * [`eft`] — the error-free transformations (Add12/two-sum, Split,
//!   Mul12/two-product) of the paper's §4.1;
//! * [`FF32`] — the scalar float-float type with full operator overloads
//!   (`+ - * /`), comparisons, and conversions;
//! * [`vector`] — SoA slice kernels mirroring the Pallas L1 kernels
//!   bit-for-bit (the "CPU path" of the paper's Table 4);
//! * [`simd`] — lane-blocked kernel tiers ([`KernelTier`]: scalar /
//!   blocked / blocked-FMA) with runtime CPU dispatch, the native
//!   backend's hot path;
//! * [`dd64`] — double-double on `f64` (Briggs/Bailey comparator, used
//!   by the examples to show the same algorithms at the next precision
//!   level);
//! * [`compensated`] — Sum2/Dot2/Horner compensated algorithms, the
//!   paper's §7 "future work".

pub mod compensated;
pub mod dd64;
pub mod eft;
pub mod ff32;
pub mod simd;
pub mod vector;

pub use dd64::DD64;
pub use eft::{fast_two_sum, split, split_dekker, two_prod, two_prod_fma, two_sum};
pub use ff32::FF32;
pub use simd::KernelTier;
