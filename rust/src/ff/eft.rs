//! Error-free transformations (paper §4.1, Theorems 2–4).
//!
//! These are the exact building blocks everything rests on: each returns
//! a result and the *exact* rounding error of that result, so a pair of
//! `f32`s carries twice the hardware precision.
//!
//! All functions operate on plain `f32` with round-to-nearest (native
//! CPU arithmetic). The same sequences under *simulated GPU arithmetic*
//! (truncated add, faithful mul, optional guard bit) live in
//! [`crate::gpusim::algorithms`], where the paper's GPU-conditions
//! theorems are actually exercised.

/// Knuth two-sum (paper Th. 2, "Add12"): returns `(s, r)` with
/// `s = fl(a + b)` and `s + r == a + b` **exactly**.
///
/// This is the branch-free 6-flop variant the paper prefers for GPUs
/// (no comparison of |a| vs |b|).
#[inline(always)]
pub fn two_sum(a: f32, b: f32) -> (f32, f32) {
    let s = a + b;
    let bb = s - a;
    let err = (a - (s - bb)) + (b - bb);
    (s, err)
}

/// Dekker fast-two-sum (3 flops): requires `|a| >= |b|` (or `a == 0`);
/// returns `(s, r)` with `s + r == a + b` exactly under that precondition.
#[inline(always)]
pub fn fast_two_sum(a: f32, b: f32) -> (f32, f32) {
    let s = a + b;
    let err = b - (s - a);
    (s, err)
}

/// Veltkamp/Dekker splitting, mask form: `a == hi + lo` with `hi` on 12
/// significand bits and `lo` on 12 bits (11 explicit + sign).
///
/// The kernels shipped to XLA use this form (immune to FP rewrites —
/// DESIGN.md §4b); it is EFT-equivalent to the paper's FP-only sequence
/// for every Mul12 purpose. `split_dekker` below is the paper-verbatim
/// variant.
#[inline(always)]
pub fn split(a: f32) -> (f32, f32) {
    let hi = f32::from_bits(a.to_bits() & 0xFFFF_F000);
    let lo = a - hi; // exact: low 12 bits of the significand
    (hi, lo)
}

/// Dekker splitting exactly as printed in the paper (Th. 3), with
/// splitting point s = 12: `c = a·(2^12 + 1); hi = c - (c - a); lo = a - hi`.
///
/// Valid on any IEEE round-to-nearest machine; may round `hi` *up* to a
/// 12-bit value larger than `|a|`'s leading bits (then `lo < 0`), which
/// is fine — the pair is still a non-overlapping exact decomposition.
///
/// The textbook sequence overflows for `|a| >= 2^115` (`4097·a` → inf,
/// poisoning the whole split with NaN); inputs that large take a scaled
/// path instead, so the decomposition stays exact all the way to
/// `f32::MAX`. The mask form ([`split`]) is immune by construction and
/// stays the kernel default.
#[inline(always)]
pub fn split_dekker(a: f32) -> (f32, f32) {
    const SPLIT: f32 = 4097.0; // 2^12 + 1
    // |a| >= 2^115 <=> biased exponent >= 115 + 127 (also catches
    // inf/NaN, which were garbage-in under the textbook sequence too)
    const HUGE: u32 = (115 + 127) << 23;
    if (a.to_bits() & 0x7F80_0000) >= HUGE {
        return split_dekker_huge(a);
    }
    let c = SPLIT * a;
    let a_big = c - a;
    let hi = c - a_big;
    let lo = a - hi;
    (hi, lo)
}

/// Overflow-safe Dekker split for `|a| >= 2^115`: run the sequence on
/// `a·2^-12` (an *exact* power-of-two scale at these magnitudes — no
/// underflow possible) and rescale. Rounding is scale-invariant across
/// the normal range, so for inputs the textbook path could handle this
/// produces bit-identical pairs. Within ~2^11 ulps of `f32::MAX` the
/// 12-bit rounding of `a` can land on 2^128 (the rescaled `hi` goes
/// infinite — no rounded-up Dekker pair exists in `f32`); the mask
/// split's truncated pair is the exact decomposition there.
#[cold]
fn split_dekker_huge(a: f32) -> (f32, f32) {
    const DOWN: f32 = 1.0 / 4096.0; // 2^-12
    const UP: f32 = 4096.0; // 2^12
    let a2 = a * DOWN;
    let c = 4097.0 * a2;
    let a_big = c - a2;
    let hi2 = c - a_big;
    let lo2 = a2 - hi2;
    let hi = hi2 * UP; // exact when finite (power-of-two scale)
    if hi.is_finite() {
        (hi, lo2 * UP)
    } else {
        split(a)
    }
}

/// Dekker two-product (paper Th. 4, "Mul12"): returns `(x, y)` with
/// `x = fl(a*b)` and `x + y == a * b` **exactly** (no FMA required —
/// this is the 17-flop sequence the paper runs on GPUs).
#[inline(always)]
pub fn two_prod(a: f32, b: f32) -> (f32, f32) {
    let x = a * b;
    let (a_hi, a_lo) = split(a);
    let (b_hi, b_lo) = split(b);
    let err1 = x - a_hi * b_hi;
    let err2 = err1 - a_lo * b_hi;
    let err3 = err2 - a_hi * b_lo;
    let y = a_lo * b_lo - err3;
    (x, y)
}

/// Two-product via hardware FMA: `y = fma(a, b, -x)` is the exact error.
/// Modern shortcut (not available on 2006 GPUs); used as the optimized
/// hot path after the §Perf pass and cross-checked against `two_prod`.
#[inline(always)]
pub fn two_prod_fma(a: f32, b: f32) -> (f32, f32) {
    let x = a * b;
    let y = f32::mul_add(a, b, -x);
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn exact_f64(hi: f32, lo: f32) -> f64 {
        hi as f64 + lo as f64
    }

    #[test]
    fn two_sum_exact_on_random_pairs() {
        let mut rng = Rng::new(1);
        for _ in 0..200_000 {
            let a = rng.spread_f32(-40, 40);
            let b = rng.spread_f32(-40, 40);
            let (s, r) = two_sum(a, b);
            if s.is_finite() {
                assert_eq!(exact_f64(s, r), a as f64 + b as f64, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn two_sum_handles_zero_and_sign() {
        assert_eq!(two_sum(0.0, 0.0), (0.0, 0.0));
        let (s, r) = two_sum(1.0, -1.0);
        assert_eq!(s, 0.0);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn fast_two_sum_exact_when_ordered() {
        let mut rng = Rng::new(2);
        for _ in 0..100_000 {
            let mut a = rng.spread_f32(-20, 20);
            let mut b = rng.spread_f32(-20, 20);
            if b.abs() > a.abs() {
                std::mem::swap(&mut a, &mut b);
            }
            let (s, r) = fast_two_sum(a, b);
            assert_eq!(exact_f64(s, r), a as f64 + b as f64, "a={a} b={b}");
        }
    }

    #[test]
    fn split_mask_is_exact_and_12bit() {
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-100, 100);
            let (hi, lo) = split(a);
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
            if hi != 0.0 {
                // hi representable on 12 significand bits
                let m = hi.abs() as f64;
                let (frac, _) = frexp(m);
                let scaled = frac * 4096.0;
                assert_eq!(scaled, scaled.round(), "hi={hi} not 12-bit");
            }
            // lo fits 12 bits and |lo| <= 2^-12 |a| scale
            if a != 0.0 {
                assert!(lo.abs() as f64 <= a.abs() as f64 * 2f64.powi(-11));
            }
        }
    }

    #[test]
    fn split_dekker_is_exact_and_nonoverlapping() {
        let mut rng = Rng::new(4);
        for _ in 0..100_000 {
            // keep away from overflow: c = 4097*a must be finite
            let a = rng.spread_f32(-100, 100);
            let (hi, lo) = split_dekker(a);
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
            // Dekker hi has at most 12 significand bits (possibly rounded up)
            if hi != 0.0 {
                let (frac, _) = frexp(hi.abs() as f64);
                let scaled = frac * 4096.0;
                assert_eq!(scaled, scaled.round(), "hi={hi} not 12-bit");
            }
        }
    }

    #[test]
    fn split_dekker_survives_huge_inputs() {
        // the textbook sequence turned these into inf/NaN (4097·a
        // overflows from |a| ≈ 2^115.99); the scaled path must stay
        // exact all the way out to f32::MAX
        let huge = [
            f32::MAX,
            -f32::MAX,
            f32::MAX / 2.0,
            f32::MAX / 4097.0,
            2f32.powi(115),
            -2f32.powi(115),
            2f32.powi(116) * 1.333,
            2f32.powi(127),
            1.7e38,
            -3.0e34,
        ];
        for &a in &huge {
            let (hi, lo) = split_dekker(a);
            assert!(hi.is_finite() && lo.is_finite(), "a={a}: ({hi}, {lo})");
            // both halves ≤ 24 bits, span ≤ 12 bits: the f64 sum is exact
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
            let (frac, _) = frexp(hi.abs() as f64);
            let scaled = frac * 4096.0;
            assert_eq!(scaled, scaled.round(), "a={a}: hi={hi} not 12-bit");
        }
        // random sweep over the previously-overflowing decades (cap the
        // exponent at 126 so the draws themselves stay finite)
        let mut rng = Rng::new(7);
        for _ in 0..50_000 {
            let a = rng.spread_f32(110, 126);
            let (hi, lo) = split_dekker(a);
            assert!(hi.is_finite(), "a={a}");
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
        }
    }

    #[test]
    fn split_dekker_huge_path_matches_textbook_where_both_work() {
        // between 2^115 and MAX/4097 the textbook sequence still works;
        // the scaled path must agree bit-for-bit there (rounding is
        // scale-invariant), so the guard threshold changes nothing
        let mut rng = Rng::new(8);
        for _ in 0..50_000 {
            let a = rng.spread_f32(115, 115); // |a| in [2^115, 2^116)
            let c = 4097.0f32 * a;
            if !c.is_finite() {
                continue; // past MAX/4097 — textbook has no answer here
            }
            let (hi, lo) = split_dekker(a);
            // textbook sequence, inline
            let a_big = c - a;
            let want_hi = c - a_big;
            let want_lo = a - want_hi;
            assert_eq!(hi.to_bits(), want_hi.to_bits(), "a={a}");
            assert_eq!(lo.to_bits(), want_lo.to_bits(), "a={a}");
        }
    }

    #[test]
    fn split_mask_is_immune_at_f32_max() {
        // the mask form never multiplies, so it is exact at the very
        // top of the range — this is why it stays the kernel default
        for &a in &[f32::MAX, -f32::MAX, f32::MAX / 2.0] {
            let (hi, lo) = split(a);
            assert!(hi.is_finite() && lo.is_finite());
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
        }
    }

    #[test]
    fn two_prod_exact_on_random_pairs() {
        let mut rng = Rng::new(5);
        for _ in 0..200_000 {
            // exponents chosen so product and its error stay normal
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let (x, y) = two_prod(a, b);
            // f64 holds the exact 48-bit product of two f32s
            assert_eq!(exact_f64(x, y), a as f64 * b as f64, "a={a} b={b}");
        }
    }

    #[test]
    fn two_prod_matches_fma_variant() {
        let mut rng = Rng::new(6);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let (x1, y1) = two_prod(a, b);
            let (x2, y2) = two_prod_fma(a, b);
            assert_eq!(x1, x2);
            assert_eq!(y1, y2, "a={a} b={b}");
        }
    }

    #[test]
    fn two_prod_known_values() {
        // 1.5 * pi_f32: error known to be representable
        let (x, y) = two_prod(1.5, std::f32::consts::PI);
        assert_eq!(x as f64 + y as f64, 1.5f64 * std::f32::consts::PI as f64);
        assert_ne!(y, 0.0);
    }

    /// libm-free frexp for tests.
    fn frexp(x: f64) -> (f64, i32) {
        if x == 0.0 {
            return (0.0, 0);
        }
        let e = x.abs().log2().floor() as i32 + 1;
        (x / 2f64.powi(e), e)
    }
}
