//! Error-free transformations (paper §4.1, Theorems 2–4).
//!
//! These are the exact building blocks everything rests on: each returns
//! a result and the *exact* rounding error of that result, so a pair of
//! `f32`s carries twice the hardware precision.
//!
//! All functions operate on plain `f32` with round-to-nearest (native
//! CPU arithmetic). The same sequences under *simulated GPU arithmetic*
//! (truncated add, faithful mul, optional guard bit) live in
//! [`crate::gpusim::algorithms`], where the paper's GPU-conditions
//! theorems are actually exercised.

/// Knuth two-sum (paper Th. 2, "Add12"): returns `(s, r)` with
/// `s = fl(a + b)` and `s + r == a + b` **exactly**.
///
/// This is the branch-free 6-flop variant the paper prefers for GPUs
/// (no comparison of |a| vs |b|).
#[inline(always)]
pub fn two_sum(a: f32, b: f32) -> (f32, f32) {
    let s = a + b;
    let bb = s - a;
    let err = (a - (s - bb)) + (b - bb);
    (s, err)
}

/// Dekker fast-two-sum (3 flops): requires `|a| >= |b|` (or `a == 0`);
/// returns `(s, r)` with `s + r == a + b` exactly under that precondition.
#[inline(always)]
pub fn fast_two_sum(a: f32, b: f32) -> (f32, f32) {
    let s = a + b;
    let err = b - (s - a);
    (s, err)
}

/// Veltkamp/Dekker splitting, mask form: `a == hi + lo` with `hi` on 12
/// significand bits and `lo` on 12 bits (11 explicit + sign).
///
/// The kernels shipped to XLA use this form (immune to FP rewrites —
/// DESIGN.md §4b); it is EFT-equivalent to the paper's FP-only sequence
/// for every Mul12 purpose. `split_dekker` below is the paper-verbatim
/// variant.
#[inline(always)]
pub fn split(a: f32) -> (f32, f32) {
    let hi = f32::from_bits(a.to_bits() & 0xFFFF_F000);
    let lo = a - hi; // exact: low 12 bits of the significand
    (hi, lo)
}

/// Dekker splitting exactly as printed in the paper (Th. 3), with
/// splitting point s = 12: `c = a·(2^12 + 1); hi = c - (c - a); lo = a - hi`.
///
/// Valid on any IEEE round-to-nearest machine; may round `hi` *up* to a
/// 12-bit value larger than `|a|`'s leading bits (then `lo < 0`), which
/// is fine — the pair is still a non-overlapping exact decomposition.
#[inline(always)]
pub fn split_dekker(a: f32) -> (f32, f32) {
    const SPLIT: f32 = 4097.0; // 2^12 + 1
    let c = SPLIT * a;
    let a_big = c - a;
    let hi = c - a_big;
    let lo = a - hi;
    (hi, lo)
}

/// Dekker two-product (paper Th. 4, "Mul12"): returns `(x, y)` with
/// `x = fl(a*b)` and `x + y == a * b` **exactly** (no FMA required —
/// this is the 17-flop sequence the paper runs on GPUs).
#[inline(always)]
pub fn two_prod(a: f32, b: f32) -> (f32, f32) {
    let x = a * b;
    let (a_hi, a_lo) = split(a);
    let (b_hi, b_lo) = split(b);
    let err1 = x - a_hi * b_hi;
    let err2 = err1 - a_lo * b_hi;
    let err3 = err2 - a_hi * b_lo;
    let y = a_lo * b_lo - err3;
    (x, y)
}

/// Two-product via hardware FMA: `y = fma(a, b, -x)` is the exact error.
/// Modern shortcut (not available on 2006 GPUs); used as the optimized
/// hot path after the §Perf pass and cross-checked against `two_prod`.
#[inline(always)]
pub fn two_prod_fma(a: f32, b: f32) -> (f32, f32) {
    let x = a * b;
    let y = f32::mul_add(a, b, -x);
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn exact_f64(hi: f32, lo: f32) -> f64 {
        hi as f64 + lo as f64
    }

    #[test]
    fn two_sum_exact_on_random_pairs() {
        let mut rng = Rng::new(1);
        for _ in 0..200_000 {
            let a = rng.spread_f32(-40, 40);
            let b = rng.spread_f32(-40, 40);
            let (s, r) = two_sum(a, b);
            if s.is_finite() {
                assert_eq!(exact_f64(s, r), a as f64 + b as f64, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn two_sum_handles_zero_and_sign() {
        assert_eq!(two_sum(0.0, 0.0), (0.0, 0.0));
        let (s, r) = two_sum(1.0, -1.0);
        assert_eq!(s, 0.0);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn fast_two_sum_exact_when_ordered() {
        let mut rng = Rng::new(2);
        for _ in 0..100_000 {
            let mut a = rng.spread_f32(-20, 20);
            let mut b = rng.spread_f32(-20, 20);
            if b.abs() > a.abs() {
                std::mem::swap(&mut a, &mut b);
            }
            let (s, r) = fast_two_sum(a, b);
            assert_eq!(exact_f64(s, r), a as f64 + b as f64, "a={a} b={b}");
        }
    }

    #[test]
    fn split_mask_is_exact_and_12bit() {
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-100, 100);
            let (hi, lo) = split(a);
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
            if hi != 0.0 {
                // hi representable on 12 significand bits
                let m = hi.abs() as f64;
                let (frac, _) = frexp(m);
                let scaled = frac * 4096.0;
                assert_eq!(scaled, scaled.round(), "hi={hi} not 12-bit");
            }
            // lo fits 12 bits and |lo| <= 2^-12 |a| scale
            if a != 0.0 {
                assert!(lo.abs() as f64 <= a.abs() as f64 * 2f64.powi(-11));
            }
        }
    }

    #[test]
    fn split_dekker_is_exact_and_nonoverlapping() {
        let mut rng = Rng::new(4);
        for _ in 0..100_000 {
            // keep away from overflow: c = 4097*a must be finite
            let a = rng.spread_f32(-100, 100);
            let (hi, lo) = split_dekker(a);
            assert_eq!(exact_f64(hi, lo), a as f64, "a={a}");
            // Dekker hi has at most 12 significand bits (possibly rounded up)
            if hi != 0.0 {
                let (frac, _) = frexp(hi.abs() as f64);
                let scaled = frac * 4096.0;
                assert_eq!(scaled, scaled.round(), "hi={hi} not 12-bit");
            }
        }
    }

    #[test]
    fn two_prod_exact_on_random_pairs() {
        let mut rng = Rng::new(5);
        for _ in 0..200_000 {
            // exponents chosen so product and its error stay normal
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let (x, y) = two_prod(a, b);
            // f64 holds the exact 48-bit product of two f32s
            assert_eq!(exact_f64(x, y), a as f64 * b as f64, "a={a} b={b}");
        }
    }

    #[test]
    fn two_prod_matches_fma_variant() {
        let mut rng = Rng::new(6);
        for _ in 0..100_000 {
            let a = rng.spread_f32(-30, 30);
            let b = rng.spread_f32(-30, 30);
            let (x1, y1) = two_prod(a, b);
            let (x2, y2) = two_prod_fma(a, b);
            assert_eq!(x1, x2);
            assert_eq!(y1, y2, "a={a} b={b}");
        }
    }

    #[test]
    fn two_prod_known_values() {
        // 1.5 * pi_f32: error known to be representable
        let (x, y) = two_prod(1.5, std::f32::consts::PI);
        assert_eq!(x as f64 + y as f64, 1.5f64 * std::f32::consts::PI as f64);
        assert_ne!(y, 0.0);
    }

    /// libm-free frexp for tests.
    fn frexp(x: f64) -> (f64, i32) {
        if x == 0.0 {
            return (0.0, 0);
        }
        let e = x.abs().log2().floor() as i32 + 1;
        (x / 2f64.powi(e), e)
    }
}
