//! Compensated algorithms (paper §7: "Using float-float representation
//! number in compensated algorithms has been shown to be more efficient
//! in term of performance for comparable accuracy").
//!
//! Three classics built on the EFTs, each in two precisions:
//! * `sum2` — Ogita–Rump–Oishi compensated summation (Sum2);
//! * `dot2` — compensated dot product (Dot2);
//! * `horner2` — compensated Horner polynomial evaluation;
//! plus float-float (FF32) reductions for apples-to-apples comparison
//! with the format itself.

use super::eft::{two_prod, two_sum};
use super::ff32::FF32;

/// Plain f32 summation (baseline).
pub fn sum_f32(x: &[f32]) -> f32 {
    x.iter().copied().sum()
}

/// Compensated summation (Sum2): f32 arithmetic, ~twice-working-precision
/// result returned as (sum, error_estimate_folded_in).
pub fn sum2(x: &[f32]) -> f32 {
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for &v in x {
        let (t, e) = two_sum(s, v);
        s = t;
        c += e;
    }
    s + c
}

/// Float-float summation: accumulate in FF32.
pub fn sum_ff(x: &[f32]) -> FF32 {
    let mut acc = FF32::ZERO;
    for &v in x {
        acc = acc + FF32::from_f32(v);
    }
    acc
}

/// Plain f32 dot product (baseline).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Compensated dot product (Dot2): EFT on every product and every
/// accumulation; result accurate as if computed in ~2x precision.
pub fn dot2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for i in 0..a.len() {
        let (p, pe) = two_prod(a[i], b[i]);
        let (t, se) = two_sum(s, p);
        s = t;
        c += pe + se;
    }
    s + c
}

/// Float-float dot product: Mul22 + Add22 all the way (what the dot2
/// L2 graph computes, sequential order).
pub fn dot_ff(a: &[f32], b: &[f32]) -> FF32 {
    assert_eq!(a.len(), b.len());
    let mut acc = FF32::ZERO;
    for i in 0..a.len() {
        let p = FF32::from_f32(a[i]) * FF32::from_f32(b[i]);
        acc = acc + p;
    }
    acc
}

/// Float-float dot product over ff inputs (SoA planes), pairwise
/// reduction — bit-matches the `dot2_n*` XLA artifact.
pub fn dot_ff_pairwise(ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32]) -> FF32 {
    let n = ah.len();
    assert!(al.len() == n && bh.len() == n && bl.len() == n);
    assert!(n.is_power_of_two(), "pairwise reduction wants a power of two");
    let mut h = vec![0.0f32; n];
    let mut l = vec![0.0f32; n];
    super::vector::mul22(ah, al, bh, bl, &mut h, &mut l);
    let mut m = n;
    while m > 1 {
        m /= 2;
        for i in 0..m {
            let a = FF32::from_parts(h[i], l[i]);
            let b = FF32::from_parts(h[i + m], l[i + m]);
            let r = a + b;
            h[i] = r.hi;
            l[i] = r.lo;
        }
    }
    FF32::from_parts(h[0], l[0])
}

/// Plain f32 Horner (baseline). Coefficients highest-degree first.
pub fn horner_f32(coeffs: &[f32], x: f32) -> f32 {
    let mut r = 0.0f32;
    for &c in coeffs {
        r = r * x + c;
    }
    r
}

/// Compensated Horner: EFT on the multiply and the add per step,
/// correction polynomial accumulated in f32.
pub fn horner2(coeffs: &[f32], x: f32) -> f32 {
    let mut r = 0.0f32;
    let mut c = 0.0f32;
    for &co in coeffs {
        let (p, pe) = two_prod(r, x);
        let (s, se) = two_sum(p, co);
        r = s;
        c = c * x + (pe + se);
    }
    r + c
}

/// Float-float Horner — bit-matches the `horner2_d*` XLA artifact
/// (coefficients as ff pairs, x as ff).
pub fn horner_ff(ch: &[f32], cl: &[f32], x: FF32) -> FF32 {
    assert_eq!(ch.len(), cl.len());
    let mut r = FF32::ZERO;
    for i in 0..ch.len() {
        r = r * x + FF32::from_parts(ch[i], cl[i]);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// f64 reference sum of f32s (exact enough for these sizes).
    fn sum_f64(x: &[f32]) -> f64 {
        x.iter().map(|&v| v as f64).sum()
    }

    fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    /// Ill-conditioned summation data: large cancellations.
    fn nasty_sum_data(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = Vec::with_capacity(2 * n);
        for _ in 0..n {
            let x = rng.spread_f32(0, 12);
            v.push(x);
            v.push(-x * (1.0 - 1e-3 * rng.f64() as f32));
        }
        v
    }

    #[test]
    fn sum2_beats_naive_sum() {
        let mut rng = Rng::new(51);
        let data = nasty_sum_data(&mut rng, 5000);
        let want = sum_f64(&data);
        let e_naive = (sum_f32(&data) as f64 - want).abs();
        let e_comp = (sum2(&data) as f64 - want).abs();
        assert!(e_comp <= e_naive, "comp {e_comp:e} vs naive {e_naive:e}");
        // compensated should be orders of magnitude better here
        assert!(e_comp < e_naive / 16.0 + 1e-6, "comp {e_comp:e} naive {e_naive:e}");
    }

    #[test]
    fn sum_ff_close_to_f64() {
        let mut rng = Rng::new(52);
        let data = nasty_sum_data(&mut rng, 5000);
        let want = sum_f64(&data);
        let got = sum_ff(&data).to_f64();
        let scale: f64 = data.iter().map(|&v| (v as f64).abs()).sum();
        assert!((got - want).abs() <= scale * 2f64.powi(-40));
    }

    #[test]
    fn dot2_beats_naive_dot() {
        let mut rng = Rng::new(53);
        let n = 4096;
        // correlated vectors -> cancellation in the dot product
        let a: Vec<f32> = (0..n).map(|_| rng.spread_f32(0, 10)).collect();
        let b: Vec<f32> = a.iter().map(|&x| {
            let noise = 1.0 + 1e-3 * rng.normal() as f32;
            if rng.next_u64() & 1 == 0 { noise / x } else { -noise / x }
        }).collect();
        let want = dot_f64(&a, &b);
        let e_naive = (dot_f32(&a, &b) as f64 - want).abs();
        let e_comp = (dot2(&a, &b) as f64 - want).abs();
        assert!(e_comp <= e_naive.max(1e-5));
    }

    #[test]
    fn dot_ff_matches_dot2_class() {
        let mut rng = Rng::new(54);
        let n = 2048;
        let a: Vec<f32> = (0..n).map(|_| rng.spread_f32(-4, 4)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.spread_f32(-4, 4)).collect();
        let want = dot_f64(&a, &b);
        let got = dot_ff(&a, &b).to_f64();
        let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        assert!((got - want).abs() <= scale * 2f64.powi(-42));
    }

    #[test]
    fn pairwise_dot_matches_sequential_class() {
        let mut rng = Rng::new(55);
        let n = 1024;
        let mut ah = vec![0.0; n];
        let mut al = vec![0.0; n];
        let mut bh = vec![0.0; n];
        let mut bl = vec![0.0; n];
        for i in 0..n {
            let (h, l) = rng.ff_pair(-4, 4);
            ah[i] = h;
            al[i] = l;
            let (h, l) = rng.ff_pair(-4, 4);
            bh[i] = h;
            bl[i] = l;
        }
        let want: f64 = (0..n)
            .map(|i| (ah[i] as f64 + al[i] as f64) * (bh[i] as f64 + bl[i] as f64))
            .sum();
        let got = dot_ff_pairwise(&ah, &al, &bh, &bl).to_f64();
        assert!((got - want).abs() <= want.abs().max(1.0) * 2f64.powi(-40));
    }

    #[test]
    fn horner2_beats_naive_near_root() {
        // (x-1)^5 expanded: catastrophic cancellation near x=1
        let coeffs = [1.0f32, -5.0, 10.0, -10.0, 5.0, -1.0];
        let x = 1.0009765625f32; // 1 + 2^-10
        let want = ((x as f64) - 1.0).powi(5);
        let e_naive = (horner_f32(&coeffs, x) as f64 - want).abs();
        let e_comp = (horner2(&coeffs, x) as f64 - want).abs();
        assert!(e_comp < e_naive, "comp {e_comp:e} naive {e_naive:e}");
        assert!(e_comp / want.abs() < 1e-4, "rel {e_comp:e}/{want:e}");
    }

    #[test]
    fn horner_ff_high_accuracy() {
        let mut rng = Rng::new(56);
        let deg = 15;
        let c64: Vec<f64> = (0..=deg).map(|_| rng.normal()).collect();
        let ch: Vec<f32> = c64.iter().map(|&v| v as f32).collect();
        let cl: Vec<f32> = c64.iter().zip(&ch).map(|(&v, &h)| (v - h as f64) as f32).collect();
        let x = FF32::from_f64(1.337);
        let got = horner_ff(&ch, &cl, x).to_f64();
        let mut want = 0.0f64;
        for &c in &c64 {
            want = want * 1.337 + c;
        }
        assert!(((got - want) / want).abs() < 2f64.powi(-40));
    }

    #[test]
    fn empty_and_single_element_edges() {
        assert_eq!(sum_f32(&[]), 0.0);
        assert_eq!(sum2(&[]), 0.0);
        assert_eq!(sum_ff(&[]).to_f64(), 0.0);
        assert_eq!(sum2(&[42.0]), 42.0);
        assert_eq!(dot2(&[], &[]), 0.0);
        assert_eq!(horner_f32(&[], 2.0), 0.0);
        assert_eq!(horner2(&[3.0], 2.0), 3.0);
    }
}
