//! Double-double on `f64` — the Briggs/Bailey format the paper adapts
//! (its [5]); ~106-bit significand. Used as a mid-tier comparator in the
//! examples (f32 < float-float < f64 < double-double < mp) and by the
//! accuracy harness when the `mp` oracle would be overkill.

use std::ops::{Add, Div, Mul, Neg, Sub};

/// Double-double number: unevaluated sum of two `f64`s.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DD64 {
    pub hi: f64,
    pub lo: f64,
}

#[inline(always)]
fn two_sum64(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bb = s - a;
    let err = (a - (s - bb)) + (b - bb);
    (s, err)
}

#[inline(always)]
fn fast_two_sum64(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let err = b - (s - a);
    (s, err)
}

#[inline(always)]
fn two_prod64(a: f64, b: f64) -> (f64, f64) {
    let x = a * b;
    let y = f64::mul_add(a, b, -x); // hardware FMA: exact error
    (x, y)
}

impl DD64 {
    pub const ZERO: DD64 = DD64 { hi: 0.0, lo: 0.0 };
    pub const ONE: DD64 = DD64 { hi: 1.0, lo: 0.0 };

    #[inline]
    pub const fn from_parts(hi: f64, lo: f64) -> Self {
        DD64 { hi, lo }
    }

    #[inline]
    pub fn from_f64(v: f64) -> Self {
        DD64 { hi: v, lo: 0.0 }
    }

    /// Nearest double-double to the exact product of two f64s.
    #[inline]
    pub fn from_product(a: f64, b: f64) -> Self {
        let (hi, lo) = two_prod64(a, b);
        DD64 { hi, lo }
    }

    /// Nearest double-double to the exact sum of two f64s.
    #[inline]
    pub fn from_sum(a: f64, b: f64) -> Self {
        let (hi, lo) = two_sum64(a, b);
        DD64 { hi, lo }
    }

    #[inline]
    pub fn to_f64(self) -> f64 {
        self.hi + self.lo
    }

    #[inline]
    pub fn abs(self) -> DD64 {
        if self.hi < 0.0 || (self.hi == 0.0 && self.lo < 0.0) { -self } else { self }
    }

    #[inline]
    pub fn add_dd(self, rhs: DD64) -> DD64 {
        let (sh, se) = two_sum64(self.hi, rhs.hi);
        let te = (self.lo + rhs.lo) + se;
        let (h, l) = fast_two_sum64(sh, te);
        DD64 { hi: h, lo: l }
    }

    #[inline]
    pub fn mul_dd(self, rhs: DD64) -> DD64 {
        let (ph, pl) = two_prod64(self.hi, rhs.hi);
        let pl = pl + (self.hi * rhs.lo + self.lo * rhs.hi);
        let (h, l) = fast_two_sum64(ph, pl);
        DD64 { hi: h, lo: l }
    }

    #[inline]
    pub fn div_dd(self, rhs: DD64) -> DD64 {
        let q1 = self.hi / rhs.hi;
        let (th, tl) = two_prod64(q1, rhs.hi);
        let r = (((self.hi - th) - tl) + self.lo - q1 * rhs.lo) / rhs.hi;
        let (h, l) = fast_two_sum64(q1, r);
        DD64 { hi: h, lo: l }
    }
}

impl Add for DD64 {
    type Output = DD64;
    fn add(self, rhs: DD64) -> DD64 {
        self.add_dd(rhs)
    }
}
impl Sub for DD64 {
    type Output = DD64;
    fn sub(self, rhs: DD64) -> DD64 {
        self.add_dd(-rhs)
    }
}
impl Mul for DD64 {
    type Output = DD64;
    fn mul(self, rhs: DD64) -> DD64 {
        self.mul_dd(rhs)
    }
}
impl Div for DD64 {
    type Output = DD64;
    fn div(self, rhs: DD64) -> DD64 {
        self.div_dd(rhs)
    }
}
impl Neg for DD64 {
    type Output = DD64;
    fn neg(self) -> DD64 {
        DD64 { hi: -self.hi, lo: -self.lo }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn from_product_is_exact() {
        let mut rng = Rng::new(41);
        for _ in 0..100_000 {
            let a = rng.normal();
            let b = rng.normal();
            let dd = DD64::from_product(a, b);
            // hi+lo reproduces the f64-rounded product plus its error
            assert_eq!(dd.hi, a * b);
            // error term is below an ulp of the product
            assert!(dd.lo.abs() <= (a * b).abs() * 2f64.powi(-52) + 1e-300);
        }
    }

    #[test]
    fn dd_addition_beats_f64_on_cancellation() {
        // (1 + 2^-80) - 1 = 2^-80: f64 loses it, DD64 keeps it
        let one = DD64::ONE;
        let tiny = DD64::from_parts(0.0, 0.0).add_dd(DD64 { hi: 2f64.powi(-80), lo: 0.0 });
        let sum = one.add_dd(tiny);
        let diff = sum.sub(one);
        assert_eq!(diff.to_f64(), 2f64.powi(-80));
    }

    #[test]
    fn mul_relative_error_tiny() {
        let mut rng = Rng::new(42);
        for _ in 0..50_000 {
            let a = DD64::from_sum(rng.normal(), rng.normal() * 1e-17);
            let b = DD64::from_sum(rng.normal(), rng.normal() * 1e-17);
            let p = a * b;
            // compare against f64 arithmetic: must agree to ~2^-52 at least
            let approx = a.to_f64() * b.to_f64();
            if approx != 0.0 {
                let rel = ((p.to_f64() - approx) / approx).abs();
                assert!(rel < 2f64.powi(-50));
            }
        }
    }

    #[test]
    fn div_roundtrip() {
        let mut rng = Rng::new(43);
        for _ in 0..50_000 {
            let a = DD64::from_sum(rng.normal(), rng.normal() * 1e-17);
            let b = DD64::from_sum(rng.normal() + 2.0, rng.normal() * 1e-17);
            let q = a / b;
            let back = q * b;
            let err = (back.to_f64() - a.to_f64()).abs();
            assert!(err <= a.to_f64().abs() * 2f64.powi(-95) + 1e-300);
        }
    }
}
