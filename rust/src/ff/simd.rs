//! Lane-blocked float-float kernels with a runtime-selected tier —
//! the raw-speed ceiling of the native backend (ROADMAP "SIMD/FMA
//! kernel rewrite").
//!
//! The paper's premise is squeezing double-float throughput out of
//! *vector* hardware; the scalar [`crate::ff::vector`] loops walk one
//! lane at a time through `two_sum`/`two_prod` and leave that ceiling
//! artificially low. This module restructures every servable op around
//! fixed-width [`LANES`]-blocks over the flat SoA planes: the inner
//! bodies are branch-free, free of per-lane bounds checks (blocks are
//! loaded into `[f32; LANES]` windows), and shaped for the
//! autovectorizer. Three tiers share the *identical* per-lane operation
//! sequence:
//!
//! * [`KernelTier::Scalar`] — the seed's `ff::vector` loops, kept as
//!   the portable bit-reference.
//! * [`KernelTier::Blocked`] — the lane-blocked bodies below, still
//!   Dekker/mask-split `two_prod`. Bit-identical to Scalar everywhere:
//!   lanes are independent, so blocking only reorders *between* lanes.
//! * [`KernelTier::BlockedFma`] — the exact product comes from
//!   [`two_prod_fma`] (`fma(a, b, -x)`, 2 flops) instead of Dekker's
//!   17-flop split dance. Bit-identical to Scalar on the in-range
//!   domain (paper Th. 3/4: both compute the *exact* product error);
//!   divergence only where Dekker's intermediates hit subnormals —
//!   pinned by `tests/kernel_tiers.rs`.
//!
//! Tier selection happens **once**, at [`crate::backend::NativeBackend`]
//! construction ([`KernelTier::resolve`]): an explicit
//! `BackendSpec`/`--kernel-tier` choice wins, then the
//! `FFGPU_KERNEL_TIER` env var, then [`KernelTier::detect`]. Detection
//! is deliberately conservative: `BlockedFma` is only picked when FMA
//! is *fast* — compiled in (`-C target-cpu=native`, aarch64) or
//! reachable through the `simd-intrinsics` AVX paths — because without
//! hardware lowering `f32::mul_add` is a correctly-rounded but slow
//! libm call. See DESIGN.md "Kernel tiers".

use super::eft::{fast_two_sum, split, two_prod, two_prod_fma, two_sum};
use super::vector;
use std::fmt;

/// Fixed block width of the lane-blocked kernels: 8 f32 lanes = one
/// AVX register, two NEON registers — and a comfortable unroll for the
/// autovectorizer on anything else.
pub const LANES: usize = 8;

/// Which kernel implementation the native backend runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelTier {
    /// The seed's scalar `ff::vector` loops — the portable reference.
    Scalar,
    /// Lane-blocked bodies, Dekker/mask-split exact product.
    Blocked,
    /// Lane-blocked bodies, FMA exact product (plus explicit AVX/FMA
    /// intrinsic paths when built with `--features simd-intrinsics`).
    BlockedFma,
}

impl KernelTier {
    /// Every tier, in escalation order.
    pub const ALL: [KernelTier; 3] =
        [KernelTier::Scalar, KernelTier::Blocked, KernelTier::BlockedFma];

    /// Stable label used by CLI flags, telemetry and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Blocked => "blocked",
            KernelTier::BlockedFma => "blocked-fma",
        }
    }

    /// Position in [`Self::ALL`] — the wire form the coordinator's
    /// shard metadata stores in an atomic cell.
    pub fn index(self) -> usize {
        match self {
            KernelTier::Scalar => 0,
            KernelTier::Blocked => 1,
            KernelTier::BlockedFma => 2,
        }
    }

    /// Inverse of [`Self::index`].
    pub fn from_index(ix: usize) -> Option<KernelTier> {
        KernelTier::ALL.get(ix).copied()
    }

    /// Parse a CLI/env tier name. `auto` (or empty) runs detection.
    pub fn parse(s: &str) -> Result<KernelTier, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelTier::Scalar),
            "blocked" | "simd" => Ok(KernelTier::Blocked),
            "blocked-fma" | "blocked_fma" | "blockedfma" | "fma" => {
                Ok(KernelTier::BlockedFma)
            }
            "" | "auto" => Ok(KernelTier::detect()),
            other => Err(format!(
                "unknown kernel tier '{other}' (scalar | blocked | blocked-fma | auto)"
            )),
        }
    }

    /// Whether this tier makes sense on the running host/build.
    /// `Scalar` and `Blocked` always do; `BlockedFma` only where FMA is
    /// fast (see [`fma_available`]). Forcing an unavailable tier is
    /// still allowed — results stay correct, only slower.
    pub fn available(self) -> bool {
        match self {
            KernelTier::Scalar | KernelTier::Blocked => true,
            KernelTier::BlockedFma => fma_available(),
        }
    }

    /// The best tier this host/build can run at full speed.
    pub fn detect() -> KernelTier {
        if fma_available() {
            KernelTier::BlockedFma
        } else {
            KernelTier::Blocked
        }
    }

    /// Resolution order used at backend construction: explicit request
    /// (spec / `--kernel-tier`) > `FFGPU_KERNEL_TIER` env var >
    /// [`Self::detect`]. A malformed env value warns and falls through.
    pub fn resolve(requested: Option<KernelTier>) -> KernelTier {
        if let Some(t) = requested {
            return t;
        }
        if let Ok(v) = std::env::var("FFGPU_KERNEL_TIER") {
            if !v.is_empty() {
                match KernelTier::parse(&v) {
                    Ok(t) => return t,
                    Err(e) => eprintln!("FFGPU_KERNEL_TIER ignored: {e}"),
                }
            }
        }
        KernelTier::detect()
    }
}

impl fmt::Display for KernelTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// True when `f32::mul_add` is a *fast* (single-instruction) FMA here,
/// rather than a correctly-rounded libm fallback:
///
/// * compiled with the `fma` target feature (`-C target-cpu=native` on
///   any FMA-capable x86_64), or
/// * aarch64, whose base ISA fuses (`fmadd`), or
/// * the `simd-intrinsics` AVX paths are compiled in **and** the CPU
///   reports AVX2+FMA at runtime (the intrinsic kernels carry their own
///   `#[target_feature]`, so no special RUSTFLAGS are needed).
///
/// Bare runtime detection without one of those escape hatches must
/// *not* enable the FMA tier: the default build would route the hot
/// path through a per-lane libm call and regress.
pub fn fma_available() -> bool {
    if cfg!(target_feature = "fma") {
        return true;
    }
    if cfg!(target_arch = "aarch64") {
        return true;
    }
    #[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
    {
        if avx::ready() {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Per-lane bodies — the single source of truth for the operation order.
// Each mirrors the corresponding `ff::vector` loop body exactly; the
// blocked drivers and the AVX tails both call these.
// ---------------------------------------------------------------------------

/// Exact product: Dekker (`FMA = false`) or hardware FMA (`FMA = true`).
#[inline(always)]
fn prod<const FMA: bool>(a: f32, b: f32) -> (f32, f32) {
    if FMA {
        two_prod_fma(a, b)
    } else {
        two_prod(a, b)
    }
}

#[inline(always)]
fn add12_lane(a: f32, b: f32) -> (f32, f32) {
    two_sum(a, b)
}

#[inline(always)]
fn split_lane(a: f32) -> (f32, f32) {
    split(a)
}

#[inline(always)]
fn mul12_lane<const FMA: bool>(a: f32, b: f32) -> (f32, f32) {
    prod::<FMA>(a, b)
}

#[inline(always)]
fn add22_lane(ah: f32, al: f32, bh: f32, bl: f32) -> (f32, f32) {
    let (sh, se) = two_sum(ah, bh);
    let te = (al + bl) + se;
    fast_two_sum(sh, te)
}

#[inline(always)]
fn mul22_lane<const FMA: bool>(ah: f32, al: f32, bh: f32, bl: f32) -> (f32, f32) {
    let (ph, pl) = prod::<FMA>(ah, bh);
    let pl = pl + (ah * bl + al * bh);
    fast_two_sum(ph, pl)
}

#[inline(always)]
fn div22_lane<const FMA: bool>(ah: f32, al: f32, bh: f32, bl: f32) -> (f32, f32) {
    let q1 = ah / bh;
    let (th, tl) = prod::<FMA>(q1, bh);
    let r = (((ah - th) - tl) + al - q1 * bl) / bh;
    fast_two_sum(q1, r)
}

#[inline(always)]
fn mad22_lane<const FMA: bool>(
    ah: f32, al: f32, bh: f32, bl: f32, ch: f32, cl: f32,
) -> (f32, f32) {
    let (mh, ml) = mul22_lane::<FMA>(ah, al, bh, bl);
    // add22 of the product and c — same sequence as FF32::add22
    let (sh, se) = two_sum(mh, ch);
    let te = (ml + cl) + se;
    fast_two_sum(sh, te)
}

// ---------------------------------------------------------------------------
// Block drivers: load LANES-wide windows into fixed arrays (one bounds
// check per block, none per lane), apply the lane body, store. The tail
// runs the *same* lane body scalar-wise, so chunk boundaries never
// change bits.
// ---------------------------------------------------------------------------

#[inline(always)]
fn blocks_1_2(
    a: &[f32], o1: &mut [f32], o2: &mut [f32], lane: impl Fn(f32) -> (f32, f32) + Copy,
) {
    let n = a.len();
    assert!(o1.len() == n && o2.len() == n);
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let mut r1 = [0.0f32; LANES];
        let mut r2 = [0.0f32; LANES];
        for j in 0..LANES {
            let (x, y) = lane(va[j]);
            r1[j] = x;
            r2[j] = y;
        }
        o1[i..i + LANES].copy_from_slice(&r1);
        o2[i..i + LANES].copy_from_slice(&r2);
        i += LANES;
    }
    while i < n {
        let (x, y) = lane(a[i]);
        o1[i] = x;
        o2[i] = y;
        i += 1;
    }
}

#[inline(always)]
fn blocks_2_2(
    a: &[f32], b: &[f32], o1: &mut [f32], o2: &mut [f32],
    lane: impl Fn(f32, f32) -> (f32, f32) + Copy,
) {
    let n = a.len();
    assert!(b.len() == n && o1.len() == n && o2.len() == n);
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let vb: [f32; LANES] = b[i..i + LANES].try_into().unwrap();
        let mut r1 = [0.0f32; LANES];
        let mut r2 = [0.0f32; LANES];
        for j in 0..LANES {
            let (x, y) = lane(va[j], vb[j]);
            r1[j] = x;
            r2[j] = y;
        }
        o1[i..i + LANES].copy_from_slice(&r1);
        o2[i..i + LANES].copy_from_slice(&r2);
        i += LANES;
    }
    while i < n {
        let (x, y) = lane(a[i], b[i]);
        o1[i] = x;
        o2[i] = y;
        i += 1;
    }
}

#[inline(always)]
fn blocks_4_2(
    a: &[f32], b: &[f32], c: &[f32], d: &[f32], o1: &mut [f32], o2: &mut [f32],
    lane: impl Fn(f32, f32, f32, f32) -> (f32, f32) + Copy,
) {
    let n = a.len();
    assert!(
        b.len() == n && c.len() == n && d.len() == n && o1.len() == n && o2.len() == n
    );
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let vb: [f32; LANES] = b[i..i + LANES].try_into().unwrap();
        let vc: [f32; LANES] = c[i..i + LANES].try_into().unwrap();
        let vd: [f32; LANES] = d[i..i + LANES].try_into().unwrap();
        let mut r1 = [0.0f32; LANES];
        let mut r2 = [0.0f32; LANES];
        for j in 0..LANES {
            let (x, y) = lane(va[j], vb[j], vc[j], vd[j]);
            r1[j] = x;
            r2[j] = y;
        }
        o1[i..i + LANES].copy_from_slice(&r1);
        o2[i..i + LANES].copy_from_slice(&r2);
        i += LANES;
    }
    while i < n {
        let (x, y) = lane(a[i], b[i], c[i], d[i]);
        o1[i] = x;
        o2[i] = y;
        i += 1;
    }
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn blocks_6_2(
    a: &[f32], b: &[f32], c: &[f32], d: &[f32], e: &[f32], f: &[f32], o1: &mut [f32],
    o2: &mut [f32], lane: impl Fn(f32, f32, f32, f32, f32, f32) -> (f32, f32) + Copy,
) {
    let n = a.len();
    assert!(b.len() == n && c.len() == n && d.len() == n && e.len() == n && f.len() == n);
    assert!(o1.len() == n && o2.len() == n);
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let vb: [f32; LANES] = b[i..i + LANES].try_into().unwrap();
        let vc: [f32; LANES] = c[i..i + LANES].try_into().unwrap();
        let vd: [f32; LANES] = d[i..i + LANES].try_into().unwrap();
        let ve: [f32; LANES] = e[i..i + LANES].try_into().unwrap();
        let vf: [f32; LANES] = f[i..i + LANES].try_into().unwrap();
        let mut r1 = [0.0f32; LANES];
        let mut r2 = [0.0f32; LANES];
        for j in 0..LANES {
            let (x, y) = lane(va[j], vb[j], vc[j], vd[j], ve[j], vf[j]);
            r1[j] = x;
            r2[j] = y;
        }
        o1[i..i + LANES].copy_from_slice(&r1);
        o2[i..i + LANES].copy_from_slice(&r2);
        i += LANES;
    }
    while i < n {
        let (x, y) = lane(a[i], b[i], c[i], d[i], e[i], f[i]);
        o1[i] = x;
        o2[i] = y;
        i += 1;
    }
}

#[inline(always)]
fn blocks_2_1(a: &[f32], b: &[f32], o: &mut [f32], lane: impl Fn(f32, f32) -> f32 + Copy) {
    let n = a.len();
    assert!(b.len() == n && o.len() == n);
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let vb: [f32; LANES] = b[i..i + LANES].try_into().unwrap();
        let mut r = [0.0f32; LANES];
        for j in 0..LANES {
            r[j] = lane(va[j], vb[j]);
        }
        o[i..i + LANES].copy_from_slice(&r);
        i += LANES;
    }
    while i < n {
        o[i] = lane(a[i], b[i]);
        i += 1;
    }
}

#[inline(always)]
fn blocks_3_1(
    a: &[f32], b: &[f32], c: &[f32], o: &mut [f32],
    lane: impl Fn(f32, f32, f32) -> f32 + Copy,
) {
    let n = a.len();
    assert!(b.len() == n && c.len() == n && o.len() == n);
    let mut i = 0;
    while i + LANES <= n {
        let va: [f32; LANES] = a[i..i + LANES].try_into().unwrap();
        let vb: [f32; LANES] = b[i..i + LANES].try_into().unwrap();
        let vc: [f32; LANES] = c[i..i + LANES].try_into().unwrap();
        let mut r = [0.0f32; LANES];
        for j in 0..LANES {
            r[j] = lane(va[j], vb[j], vc[j]);
        }
        o[i..i + LANES].copy_from_slice(&r);
        i += LANES;
    }
    while i < n {
        o[i] = lane(a[i], b[i], c[i]);
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Public blocked kernels — one per servable op. The `fma` flag on the
// product-bearing ops selects the exact-product variant; length
// mismatches panic like their `ff::vector` counterparts.
// ---------------------------------------------------------------------------

/// Lane-blocked `s, e = two_sum(a, b)`.
pub fn add12(a: &[f32], b: &[f32], s: &mut [f32], e: &mut [f32]) {
    blocks_2_2(a, b, s, e, add12_lane);
}

/// Lane-blocked mask split.
pub fn split_v(a: &[f32], hi: &mut [f32], lo: &mut [f32]) {
    blocks_1_2(a, hi, lo, split_lane);
}

/// Lane-blocked exact product (Dekker or FMA form).
pub fn mul12(fma: bool, a: &[f32], b: &[f32], x: &mut [f32], y: &mut [f32]) {
    if fma {
        blocks_2_2(a, b, x, y, mul12_lane::<true>);
    } else {
        blocks_2_2(a, b, x, y, mul12_lane::<false>);
    }
}

/// Lane-blocked branch-free float-float addition (no product, so no
/// FMA variant).
pub fn add22(
    ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
) {
    blocks_4_2(ah, al, bh, bl, rh, rl, add22_lane);
}

/// Lane-blocked float-float multiplication.
pub fn mul22(
    fma: bool, ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32],
    rl: &mut [f32],
) {
    if fma {
        blocks_4_2(ah, al, bh, bl, rh, rl, mul22_lane::<true>);
    } else {
        blocks_4_2(ah, al, bh, bl, rh, rl, mul22_lane::<false>);
    }
}

/// Lane-blocked float-float division.
pub fn div22(
    fma: bool, ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32],
    rl: &mut [f32],
) {
    if fma {
        blocks_4_2(ah, al, bh, bl, rh, rl, div22_lane::<true>);
    } else {
        blocks_4_2(ah, al, bh, bl, rh, rl, div22_lane::<false>);
    }
}

/// Lane-blocked float-float multiply-add `r = a*b + c`.
#[allow(clippy::too_many_arguments)]
pub fn mad22(
    fma: bool, ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], ch: &[f32], cl: &[f32],
    rh: &mut [f32], rl: &mut [f32],
) {
    if fma {
        blocks_6_2(ah, al, bh, bl, ch, cl, rh, rl, mad22_lane::<true>);
    } else {
        blocks_6_2(ah, al, bh, bl, ch, cl, rh, rl, mad22_lane::<false>);
    }
}

/// Lane-blocked single-precision baselines. `mad` stays two-rounding
/// (`a*b + c`, mul then add) in *every* tier — Rust never contracts,
/// and the FMA tier must not change baseline bits either.
pub fn base_add(a: &[f32], b: &[f32], r: &mut [f32]) {
    blocks_2_1(a, b, r, |x, y| x + y);
}

pub fn base_mul(a: &[f32], b: &[f32], r: &mut [f32]) {
    blocks_2_1(a, b, r, |x, y| x * y);
}

pub fn base_mad(a: &[f32], b: &[f32], c: &[f32], r: &mut [f32]) {
    blocks_3_1(a, b, c, r, |x, y, z| x * y + z);
}

// ---------------------------------------------------------------------------
// Tier dispatch — the entry point the native backend's workers call.
// ---------------------------------------------------------------------------

/// [`dispatch_slices`] over owned output vectors (the serial-path
/// convenience, mirroring [`vector::dispatch`]).
pub fn dispatch(
    tier: KernelTier, op: &str, inputs: &[&[f32]], outputs: &mut [Vec<f32>],
) -> Result<(), String> {
    let mut slices: Vec<&mut [f32]> =
        outputs.iter_mut().map(|v| v.as_mut_slice()).collect();
    dispatch_slices(tier, op, inputs, &mut slices)
}

/// Run `op` over SoA planes with the selected tier's kernels.
///
/// `Scalar` routes to [`vector::dispatch_slices`] verbatim; the blocked
/// tiers use the lane bodies above. `BlockedFma` additionally tries the
/// explicit AVX/FMA intrinsic kernels when the build carries them
/// (`--features simd-intrinsics`) and the CPU agrees at runtime.
pub fn dispatch_slices(
    tier: KernelTier, op: &str, inputs: &[&[f32]], outputs: &mut [&mut [f32]],
) -> Result<(), String> {
    if tier == KernelTier::Scalar {
        return vector::dispatch_slices(op, inputs, outputs);
    }
    #[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
    {
        if tier == KernelTier::BlockedFma
            && avx::ready()
            && avx::try_dispatch(op, inputs, outputs)
        {
            return Ok(());
        }
    }
    let fma = tier == KernelTier::BlockedFma;
    match op {
        "add12" => {
            let (s, e) = vector::split_two_mut(outputs);
            add12(inputs[0], inputs[1], s, e);
        }
        "split" => {
            let (h, l) = vector::split_two_mut(outputs);
            split_v(inputs[0], h, l);
        }
        "mul12" => {
            let (x, y) = vector::split_two_mut(outputs);
            mul12(fma, inputs[0], inputs[1], x, y);
        }
        "add22" => {
            let (h, l) = vector::split_two_mut(outputs);
            add22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "mul22" => {
            let (h, l) = vector::split_two_mut(outputs);
            mul22(fma, inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "div22" => {
            let (h, l) = vector::split_two_mut(outputs);
            div22(fma, inputs[0], inputs[1], inputs[2], inputs[3], h, l);
        }
        "mad22" => {
            let (h, l) = vector::split_two_mut(outputs);
            mad22(
                fma, inputs[0], inputs[1], inputs[2], inputs[3], inputs[4], inputs[5],
                h, l,
            );
        }
        "add" => base_add(inputs[0], inputs[1], outputs[0]),
        "mul" => base_mul(inputs[0], inputs[1], outputs[0]),
        "mad" => base_mad(inputs[0], inputs[1], inputs[2], outputs[0]),
        other => return Err(format!("unknown op {other}")),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Explicit AVX/FMA intrinsic paths (x86_64, `--features simd-intrinsics`).
// Every vector instruction maps 1:1 to one individually-rounded scalar
// op of the lane bodies — `_mm256_fmsub_ps(a, b, x) = fl(a·b − x)` is
// exactly `fma(a, b, -x)` — so results stay bit-identical to the
// portable BlockedFma blocks. Cross products and `q1·bl` use separate
// mul-then-add/sub intrinsics: explicit intrinsics never contract, so
// no accidental fusion can change bits.
// ---------------------------------------------------------------------------

#[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
mod avx {
    use core::arch::x86_64::*;

    use super::LANES;

    /// Runtime gate for the intrinsic kernels.
    pub(super) fn ready() -> bool {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }

    /// Run `op` through its intrinsic kernel; `false` when `op` has
    /// none (the caller falls back to the portable blocks). Caller must
    /// have verified [`ready`].
    pub(super) fn try_dispatch(
        op: &str, inputs: &[&[f32]], outputs: &mut [&mut [f32]],
    ) -> bool {
        use crate::ff::vector::split_two_mut;
        // SAFETY: `ready()` confirmed AVX2+FMA on this CPU; each kernel
        // asserts plane-length agreement before touching memory.
        unsafe {
            match op {
                "add12" => {
                    let (s, e) = split_two_mut(outputs);
                    add12(inputs[0], inputs[1], s, e);
                }
                "split" => {
                    let (h, l) = split_two_mut(outputs);
                    split_v(inputs[0], h, l);
                }
                "mul12" => {
                    let (x, y) = split_two_mut(outputs);
                    mul12(inputs[0], inputs[1], x, y);
                }
                "add22" => {
                    let (h, l) = split_two_mut(outputs);
                    add22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
                }
                "mul22" => {
                    let (h, l) = split_two_mut(outputs);
                    mul22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
                }
                "div22" => {
                    let (h, l) = split_two_mut(outputs);
                    div22(inputs[0], inputs[1], inputs[2], inputs[3], h, l);
                }
                "mad22" => {
                    let (h, l) = split_two_mut(outputs);
                    mad22(
                        inputs[0], inputs[1], inputs[2], inputs[3], inputs[4],
                        inputs[5], h, l,
                    );
                }
                _ => return false,
            }
        }
        true
    }

    #[inline]
    #[target_feature(enable = "avx,fma")]
    unsafe fn two_sum_ps(a: __m256, b: __m256) -> (__m256, __m256) {
        let s = _mm256_add_ps(a, b);
        let bb = _mm256_sub_ps(s, a);
        let err = _mm256_add_ps(
            _mm256_sub_ps(a, _mm256_sub_ps(s, bb)),
            _mm256_sub_ps(b, bb),
        );
        (s, err)
    }

    #[inline]
    #[target_feature(enable = "avx,fma")]
    unsafe fn fast_two_sum_ps(a: __m256, b: __m256) -> (__m256, __m256) {
        let s = _mm256_add_ps(a, b);
        let err = _mm256_sub_ps(b, _mm256_sub_ps(s, a));
        (s, err)
    }

    /// FMA exact product: `y = fl(a·b − x)` via `vfmsub`.
    #[inline]
    #[target_feature(enable = "avx,fma")]
    unsafe fn two_prod_ps(a: __m256, b: __m256) -> (__m256, __m256) {
        let x = _mm256_mul_ps(a, b);
        let y = _mm256_fmsub_ps(a, b, x);
        (x, y)
    }

    /// Mask split (`to_bits() & 0xFFFF_F000`) — bitwise, so trivially
    /// identical to the scalar form.
    #[inline]
    #[target_feature(enable = "avx,fma")]
    unsafe fn split_ps(a: __m256) -> (__m256, __m256) {
        let mask = _mm256_castsi256_ps(_mm256_set1_epi32(0xFFFF_F000u32 as i32));
        let hi = _mm256_and_ps(a, mask);
        let lo = _mm256_sub_ps(a, hi);
        (hi, lo)
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn add12(a: &[f32], b: &[f32], s: &mut [f32], e: &mut [f32]) {
        let n = a.len();
        assert!(b.len() == n && s.len() == n && e.len() == n);
        let mut i = 0;
        while i + LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let (vs, ve) = two_sum_ps(va, vb);
            _mm256_storeu_ps(s.as_mut_ptr().add(i), vs);
            _mm256_storeu_ps(e.as_mut_ptr().add(i), ve);
            i += LANES;
        }
        while i < n {
            let (x, y) = super::add12_lane(a[i], b[i]);
            s[i] = x;
            e[i] = y;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn split_v(a: &[f32], hi: &mut [f32], lo: &mut [f32]) {
        let n = a.len();
        assert!(hi.len() == n && lo.len() == n);
        let mut i = 0;
        while i + LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let (vh, vl) = split_ps(va);
            _mm256_storeu_ps(hi.as_mut_ptr().add(i), vh);
            _mm256_storeu_ps(lo.as_mut_ptr().add(i), vl);
            i += LANES;
        }
        while i < n {
            let (h, l) = super::split_lane(a[i]);
            hi[i] = h;
            lo[i] = l;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn mul12(a: &[f32], b: &[f32], x: &mut [f32], y: &mut [f32]) {
        let n = a.len();
        assert!(b.len() == n && x.len() == n && y.len() == n);
        let mut i = 0;
        while i + LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let (vx, vy) = two_prod_ps(va, vb);
            _mm256_storeu_ps(x.as_mut_ptr().add(i), vx);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), vy);
            i += LANES;
        }
        while i < n {
            let (xi, yi) = super::mul12_lane::<true>(a[i], b[i]);
            x[i] = xi;
            y[i] = yi;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn add22(
        ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
    ) {
        let n = ah.len();
        assert!(
            al.len() == n
                && bh.len() == n
                && bl.len() == n
                && rh.len() == n
                && rl.len() == n
        );
        let mut i = 0;
        while i + LANES <= n {
            let vah = _mm256_loadu_ps(ah.as_ptr().add(i));
            let val = _mm256_loadu_ps(al.as_ptr().add(i));
            let vbh = _mm256_loadu_ps(bh.as_ptr().add(i));
            let vbl = _mm256_loadu_ps(bl.as_ptr().add(i));
            let (sh, se) = two_sum_ps(vah, vbh);
            let te = _mm256_add_ps(_mm256_add_ps(val, vbl), se);
            let (h, l) = fast_two_sum_ps(sh, te);
            _mm256_storeu_ps(rh.as_mut_ptr().add(i), h);
            _mm256_storeu_ps(rl.as_mut_ptr().add(i), l);
            i += LANES;
        }
        while i < n {
            let (h, l) = super::add22_lane(ah[i], al[i], bh[i], bl[i]);
            rh[i] = h;
            rl[i] = l;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn mul22(
        ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
    ) {
        let n = ah.len();
        assert!(
            al.len() == n
                && bh.len() == n
                && bl.len() == n
                && rh.len() == n
                && rl.len() == n
        );
        let mut i = 0;
        while i + LANES <= n {
            let vah = _mm256_loadu_ps(ah.as_ptr().add(i));
            let val = _mm256_loadu_ps(al.as_ptr().add(i));
            let vbh = _mm256_loadu_ps(bh.as_ptr().add(i));
            let vbl = _mm256_loadu_ps(bl.as_ptr().add(i));
            let (ph, pl) = two_prod_ps(vah, vbh);
            // ah·bl and al·bh each rounded, then added — mirrors the
            // scalar `ah*bl + al*bh`, no fusion
            let cross =
                _mm256_add_ps(_mm256_mul_ps(vah, vbl), _mm256_mul_ps(val, vbh));
            let pl = _mm256_add_ps(pl, cross);
            let (h, l) = fast_two_sum_ps(ph, pl);
            _mm256_storeu_ps(rh.as_mut_ptr().add(i), h);
            _mm256_storeu_ps(rl.as_mut_ptr().add(i), l);
            i += LANES;
        }
        while i < n {
            let (h, l) = super::mul22_lane::<true>(ah[i], al[i], bh[i], bl[i]);
            rh[i] = h;
            rl[i] = l;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    unsafe fn div22(
        ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], rh: &mut [f32], rl: &mut [f32],
    ) {
        let n = ah.len();
        assert!(
            al.len() == n
                && bh.len() == n
                && bl.len() == n
                && rh.len() == n
                && rl.len() == n
        );
        let mut i = 0;
        while i + LANES <= n {
            let vah = _mm256_loadu_ps(ah.as_ptr().add(i));
            let val = _mm256_loadu_ps(al.as_ptr().add(i));
            let vbh = _mm256_loadu_ps(bh.as_ptr().add(i));
            let vbl = _mm256_loadu_ps(bl.as_ptr().add(i));
            let q1 = _mm256_div_ps(vah, vbh);
            let (th, tl) = two_prod_ps(q1, vbh);
            // (((ah - th) - tl) + al - q1·bl) / bh, every step rounded
            let num = _mm256_sub_ps(
                _mm256_add_ps(_mm256_sub_ps(_mm256_sub_ps(vah, th), tl), val),
                _mm256_mul_ps(q1, vbl),
            );
            let r = _mm256_div_ps(num, vbh);
            let (h, l) = fast_two_sum_ps(q1, r);
            _mm256_storeu_ps(rh.as_mut_ptr().add(i), h);
            _mm256_storeu_ps(rl.as_mut_ptr().add(i), l);
            i += LANES;
        }
        while i < n {
            let (h, l) = super::div22_lane::<true>(ah[i], al[i], bh[i], bl[i]);
            rh[i] = h;
            rl[i] = l;
            i += 1;
        }
    }

    /// # Safety
    /// CPU must support AVX2+FMA ([`ready`]).
    #[target_feature(enable = "avx,fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn mad22(
        ah: &[f32], al: &[f32], bh: &[f32], bl: &[f32], ch: &[f32], cl: &[f32],
        rh: &mut [f32], rl: &mut [f32],
    ) {
        let n = ah.len();
        assert!(
            al.len() == n
                && bh.len() == n
                && bl.len() == n
                && ch.len() == n
                && cl.len() == n
        );
        assert!(rh.len() == n && rl.len() == n);
        let mut i = 0;
        while i + LANES <= n {
            let vah = _mm256_loadu_ps(ah.as_ptr().add(i));
            let val = _mm256_loadu_ps(al.as_ptr().add(i));
            let vbh = _mm256_loadu_ps(bh.as_ptr().add(i));
            let vbl = _mm256_loadu_ps(bl.as_ptr().add(i));
            let vch = _mm256_loadu_ps(ch.as_ptr().add(i));
            let vcl = _mm256_loadu_ps(cl.as_ptr().add(i));
            // mul22 part
            let (ph, pl) = two_prod_ps(vah, vbh);
            let cross =
                _mm256_add_ps(_mm256_mul_ps(vah, vbl), _mm256_mul_ps(val, vbh));
            let pl = _mm256_add_ps(pl, cross);
            let (mh, ml) = fast_two_sum_ps(ph, pl);
            // add22 part
            let (sh, se) = two_sum_ps(mh, vch);
            let te = _mm256_add_ps(_mm256_add_ps(ml, vcl), se);
            let (h, l) = fast_two_sum_ps(sh, te);
            _mm256_storeu_ps(rh.as_mut_ptr().add(i), h);
            _mm256_storeu_ps(rl.as_mut_ptr().add(i), l);
            i += LANES;
        }
        while i < n {
            let (h, l) =
                super::mad22_lane::<true>(ah[i], al[i], bh[i], bl[i], ch[i], cl[i]);
            rh[i] = h;
            rl[i] = l;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload;

    const OPS: [(&str, usize); 10] = [
        ("add12", 2),
        ("split", 2),
        ("mul12", 2),
        ("add22", 2),
        ("mul22", 2),
        ("div22", 2),
        ("mad22", 2),
        ("add", 1),
        ("mul", 1),
        ("mad", 1),
    ];

    /// Sizes straddling the LANES boundary on both sides, plus odd
    /// tails that exercise the scalar remainder.
    const SIZES: [usize; 9] = [1, 7, 8, 9, 63, 64, 65, 1000, 8329];

    fn run(tier: KernelTier, op: &str, planes: &[Vec<f32>], n_out: usize) -> Vec<Vec<f32>> {
        let refs: Vec<&[f32]> = planes.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; planes[0].len()]; n_out];
        dispatch(tier, op, &refs, &mut outs).unwrap();
        outs
    }

    fn assert_tier_matches_scalar(tier: KernelTier) {
        for &(op, n_out) in &OPS {
            for &n in &SIZES {
                let planes = workload::planes_for(op, n, 0xBEEF ^ (n as u64));
                let want = run(KernelTier::Scalar, op, &planes, n_out);
                let got = run(tier, op, &planes, n_out);
                for (o, (pw, pg)) in want.iter().zip(&got).enumerate() {
                    for i in 0..n {
                        assert_eq!(
                            pw[i].to_bits(),
                            pg[i].to_bits(),
                            "tier={tier} op={op} n={n} out{o} lane{i}: \
                             got {} want {}",
                            pg[i],
                            pw[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_matches_scalar_bitwise_every_op() {
        assert_tier_matches_scalar(KernelTier::Blocked);
    }

    #[test]
    fn blocked_fma_matches_scalar_bitwise_in_range() {
        // correctness does not need *fast* FMA — mul_add is correctly
        // rounded even through libm — so this parity check always runs
        assert_tier_matches_scalar(KernelTier::BlockedFma);
    }

    #[test]
    fn tier_names_parse_round_trip() {
        for tier in KernelTier::ALL {
            assert_eq!(KernelTier::parse(tier.name()).unwrap(), tier);
            assert_eq!(KernelTier::from_index(tier.index()), Some(tier));
        }
        assert_eq!(KernelTier::parse("FMA").unwrap(), KernelTier::BlockedFma);
        assert_eq!(KernelTier::parse(" blocked ").unwrap(), KernelTier::Blocked);
        assert_eq!(KernelTier::parse("auto").unwrap(), KernelTier::detect());
        assert!(KernelTier::parse("warp").is_err());
        assert_eq!(KernelTier::from_index(3), None);
    }

    #[test]
    fn detect_returns_an_available_tier() {
        let t = KernelTier::detect();
        assert!(t.available(), "detected tier {t} must be runnable");
        assert_ne!(t, KernelTier::Scalar, "detection never de-escalates to scalar");
    }

    #[test]
    fn resolve_prefers_explicit_request() {
        // explicit spec choice wins over env/detection unconditionally
        for tier in KernelTier::ALL {
            assert_eq!(KernelTier::resolve(Some(tier)), tier);
        }
    }

    #[test]
    fn dispatch_rejects_unknown_ops() {
        for tier in KernelTier::ALL {
            assert!(dispatch(tier, "nope", &[], &mut []).is_err());
        }
    }
}
