//! Dynamic batching onto fixed launch sizes.
//!
//! AOT compilation fixes stream lengths (the paper's grid: 4096 …
//! 1048576), so arbitrary-size requests must be packed: same-operator
//! requests are concatenated, the result is padded up to a quantised
//! launch size (or split across several launches when it exceeds the
//! largest), and output planes are sliced back per request. Two
//! consumers share this planner: the XLA backend (compiled artifact
//! sizes) and the coordinator's fusion stage
//! ([`crate::coordinator::ServiceSpec::fuse_sizes`]).
//!
//! Padding values are operator-aware ([`Op::pad_value`]): `div22` pads
//! the divisor with ones so the padding lanes don't produce NaNs that
//! could trap slow paths.
//!
//! # Examples
//!
//! ```
//! use ffgpu::coordinator::batcher;
//!
//! // 20000 lanes over the paper's ladder: the tail splits across
//! // 4096 + 16384 (480 pad lanes) instead of one 65536 launch
//! let launches = batcher::plan(20000, &[4096, 16384, 65536]).unwrap();
//! assert_eq!(launches.len(), 2);
//! let padded: usize = launches.iter().map(|l| l.size - l.len).sum();
//! assert_eq!(padded, 480);
//! assert!(batcher::waste(&launches) < 0.03);
//! ```

use crate::backend::Op;

/// (n_inputs, n_outputs) for every operator the coordinator serves.
///
/// Thin string-keyed view over the typed catalogue ([`Op::arity`]),
/// kept for the harnesses and tests that grew up on the tuple form.
pub fn op_arity(op: &str) -> Option<(usize, usize)> {
    Op::parse(op).ok().map(Op::arity)
}

/// A launch plan: one compiled-size execution covering a slice of the
/// concatenated batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Launch {
    /// Artifact stream size to use.
    pub size: usize,
    /// Range of the concatenated batch this launch covers.
    pub start: usize,
    pub len: usize,
}

/// Plan launches for `total` elements over the available compiled
/// `sizes` (ascending). Fill with the largest size while the remainder
/// exceeds it; for the tail, compare the single smallest-fitting launch
/// against splitting the tail across **two** smaller sizes and pick
/// whichever pads less (ties go to the single launch — fewer launches).
///
/// The old greedy tail took the single fit unconditionally, which the
/// measured padding fractions in `BENCH_coordinator.json` showed to be
/// the dominant waste: 20000 elements over `[4096, 16384, 65536]` used
/// to launch one 65536 (45536 padded lanes); the split tail launches
/// 16384 + 4096 (480 padded lanes).
///
/// Returns `None` when `sizes` is empty.
pub fn plan(total: usize, sizes: &[usize]) -> Option<Vec<Launch>> {
    if sizes.is_empty() || total == 0 {
        return None;
    }
    let largest = *sizes.last().unwrap();
    if largest == 0 {
        // a zero-only ladder cannot cover anything (and would spin the
        // head loop below); treat it like no ladder at all
        return None;
    }
    let mut launches = Vec::new();
    let mut start = 0usize;
    let mut remaining = total;
    while remaining > largest {
        launches.push(Launch { size: largest, start, len: largest });
        start += largest;
        remaining -= largest;
    }
    let single = *sizes.iter().find(|&&s| s >= remaining).unwrap_or(&largest);
    // best two-launch split: a full launch of some smaller size plus
    // the smallest size that fits what's left
    let mut best_pair: Option<(usize, usize)> = None;
    for &s1 in sizes.iter().filter(|&&s| s < remaining) {
        let rest = remaining - s1;
        if let Some(&s2) = sizes.iter().find(|&&s| s >= rest) {
            let better = match best_pair {
                Some((a, b)) => s1 + s2 < a + b,
                None => true,
            };
            if better {
                best_pair = Some((s1, s2));
            }
        }
    }
    match best_pair {
        Some((s1, s2)) if s1 + s2 < single => {
            launches.push(Launch { size: s1, start, len: s1 });
            launches.push(Launch { size: s2, start: start + s1, len: remaining - s1 });
        }
        _ => launches.push(Launch { size: single, start, len: remaining }),
    }
    Some(launches)
}

/// Measured padding-waste EWMA above which
/// [`adapt`] starts densifying a ladder.
pub const ADAPT_WASTE_THRESHOLD: f64 = 0.15;

/// Smallest rung [`adapt`] will synthesise — paper kernels below this
/// are launch-overhead-bound, so finer quantisation stops paying.
pub const ADAPT_MIN_RUNG: usize = 64;

/// Waste-fed ladder adaptation: given the configured `base` ladder and
/// the shard's measured per-op padding-waste EWMA
/// ([`crate::coordinator::metrics::Telemetry::waste`]), return the
/// ladder to plan this group with.
///
/// While the signal is cold or healthy (`None`, or ≤
/// [`ADAPT_WASTE_THRESHOLD`]) the base ladder is used untouched —
/// adaptation never perturbs a well-packed workload. A hot waste EWMA
/// means real traffic keeps landing between rungs, so the ladder is
/// **densified**: a half-size rung below the smallest (if it stays ≥
/// [`ADAPT_MIN_RUNG`]) plus the midpoint of every adjacent pair, so
/// tails find a closer fit. E.g. 6000-lane groups over
/// `[4096, 16384, 65536]` pad 2192 lanes/group (4096+4096); the
/// densified ladder plans 4096+2048 and pads 144.
///
/// The extra rungs cost nothing to "compile" on the served substrates
/// (native and gpusim size launches dynamically); XLA-style AOT
/// substrates would hold the base ladder, which is why adaptation is
/// opt-in per spec rather than always-on.
pub fn adapt(base: &[usize], waste: Option<f64>) -> Vec<usize> {
    let hot = matches!(waste, Some(w) if w > ADAPT_WASTE_THRESHOLD);
    if !hot || base.is_empty() {
        return base.to_vec();
    }
    let mut out = base.to_vec();
    let lo = base[0] / 2;
    if lo >= ADAPT_MIN_RUNG {
        out.push(lo);
    }
    for pair in base.windows(2) {
        let mid = pair[0] + (pair[1] - pair[0]) / 2;
        out.push(mid);
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Padding waste fraction of a plan (extra lanes / useful lanes).
pub fn waste(plan: &[Launch]) -> f64 {
    let useful: usize = plan.iter().map(|l| l.len).sum();
    let launched: usize = plan.iter().map(|l| l.size).sum();
    if useful == 0 {
        return 0.0;
    }
    (launched - useful) as f64 / useful as f64
}

/// Concatenate the `plane`-th input of every request, padded to `size`.
pub fn gather_plane(
    requests: &[&crate::coordinator::OpRequest], plane: usize, size: usize,
    start: usize, len: usize, op: Op,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(size);
    gather_plane_into(requests, plane, size, start, len, op, &mut out);
    out
}

/// [`gather_plane`] into a caller-provided buffer (cleared first) — the
/// allocation-free path the shard dispatch loop uses with its
/// [`crate::backend::BufferPool`].
#[allow(clippy::too_many_arguments)]
pub fn gather_plane_into(
    requests: &[&crate::coordinator::OpRequest], plane: usize, size: usize,
    start: usize, len: usize, op: Op, out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(size);
    // walk the concatenated space [start, start+len)
    let mut skipped = 0usize;
    for r in requests {
        let rl = r.len();
        if skipped + rl <= start {
            skipped += rl;
            continue;
        }
        let from = start.saturating_sub(skipped);
        let need = (start + len).saturating_sub(skipped.max(start));
        let take = need.min(rl - from);
        out.extend_from_slice(&r.inputs[plane][from..from + take]);
        skipped += rl;
        if out.len() >= len {
            break;
        }
    }
    debug_assert_eq!(out.len(), len);
    out.resize(size, op.pad_value(plane));
}

/// Scatter one launch's output planes back into per-request buffers.
///
/// `acc[r]` holds `n_out` planes per request, pre-sized.
pub fn scatter_outputs(
    requests: &[&crate::coordinator::OpRequest], outputs: &[Vec<f32>],
    start: usize, len: usize, acc: &mut [Vec<Vec<f32>>],
) {
    let mut pos = 0usize; // position within this launch's useful region
    let mut skipped = 0usize;
    for (ri, r) in requests.iter().enumerate() {
        let rl = r.len();
        if skipped + rl <= start {
            skipped += rl;
            continue;
        }
        if pos >= len {
            break;
        }
        let from = start.saturating_sub(skipped); // offset within request
        let take = (rl - from).min(len - pos);
        for (oi, out_plane) in outputs.iter().enumerate() {
            acc[ri][oi][from..from + take]
                .copy_from_slice(&out_plane[pos..pos + take]);
        }
        pos += take;
        skipped += rl;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OpRequest;
    use std::sync::mpsc;

    #[test]
    fn plan_fits_smallest() {
        let sizes = [4096, 16384, 65536];
        let p = plan(1000, &sizes).unwrap();
        assert_eq!(p, vec![Launch { size: 4096, start: 0, len: 1000 }]);
        assert!(waste(&p) > 3.0);
    }

    #[test]
    fn plan_exact_fit_has_no_waste() {
        let p = plan(16384, &[4096, 16384]).unwrap();
        assert_eq!(p, vec![Launch { size: 16384, start: 0, len: 16384 }]);
        assert_eq!(waste(&p), 0.0);
    }

    #[test]
    fn plan_splits_oversize() {
        let sizes = [4096, 16384];
        let p = plan(40000, &sizes).unwrap();
        // head: two full largest launches; tail 7232 split across two
        // 4096 launches (960 padded lanes) instead of one 16384 (9152)
        assert_eq!(p.len(), 4);
        assert_eq!(p[0], Launch { size: 16384, start: 0, len: 16384 });
        assert_eq!(p[1], Launch { size: 16384, start: 16384, len: 16384 });
        assert_eq!(p[2], Launch { size: 4096, start: 32768, len: 4096 });
        assert_eq!(p[3], Launch { size: 4096, start: 36864, len: 40000 - 36864 });
        assert!(waste(&p) < 9152.0 / 40000.0);
    }

    #[test]
    fn plan_tail_splits_only_when_it_pads_less() {
        let sizes = [4096, 16384, 65536];
        // 20000: single tail = 65536 (45536 pad); split = 4096 + 16384
        // (480 pad) wins
        let p = plan(20000, &sizes).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0], Launch { size: 4096, start: 0, len: 4096 });
        assert_eq!(p[1], Launch { size: 16384, start: 4096, len: 20000 - 4096 });
        let padded: usize = p.iter().map(|l| l.size - l.len).sum();
        assert_eq!(padded, 480);
        // 5000: single tail 16384 (11384 pad) vs 4096 + 4096 (3192 pad)
        let p = plan(5000, &sizes).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].size, 4096);
        assert_eq!(p[1], Launch { size: 4096, start: 4096, len: 904 });
        // 3000: nothing smaller fits a split — the single 4096 stays
        let p = plan(3000, &sizes).unwrap();
        assert_eq!(p, vec![Launch { size: 4096, start: 0, len: 3000 }]);
        // exact fit: ties go to the single launch
        let p = plan(16384, &sizes).unwrap();
        assert_eq!(p, vec![Launch { size: 16384, start: 0, len: 16384 }]);
    }

    #[test]
    fn adapt_leaves_cold_or_healthy_ladders_alone() {
        let base = [4096, 16384, 65536];
        assert_eq!(adapt(&base, None), base.to_vec());
        assert_eq!(adapt(&base, Some(0.05)), base.to_vec());
        assert_eq!(adapt(&base, Some(ADAPT_WASTE_THRESHOLD)), base.to_vec());
        assert!(adapt(&[], Some(0.9)).is_empty());
    }

    #[test]
    fn adapt_densifies_hot_ladders() {
        let base = [4096, 16384, 65536];
        let dense = adapt(&base, Some(0.4));
        assert_eq!(dense, vec![2048, 4096, 10240, 16384, 40960, 65536]);
        // ascending + deduped, as batcher::plan requires
        assert!(dense.windows(2).all(|w| w[0] < w[1]));
        // the motivating shape: 6000-lane groups pad far less
        let before: usize =
            plan(6000, &base).unwrap().iter().map(|l| l.size - l.len).sum();
        let after: usize =
            plan(6000, &dense).unwrap().iter().map(|l| l.size - l.len).sum();
        assert_eq!(before, 2192);
        assert_eq!(after, 144);
    }

    #[test]
    fn adapt_respects_minimum_rung() {
        // half of 64 would be 32 < ADAPT_MIN_RUNG: no sub-rung appears
        let dense = adapt(&[64, 256], Some(0.5));
        assert_eq!(dense, vec![64, 160, 256]);
        // 128 halves cleanly to 64
        let dense = adapt(&[128], Some(0.5));
        assert_eq!(dense, vec![64, 128]);
    }

    #[test]
    fn plan_empty_inputs() {
        assert!(plan(0, &[4096]).is_none());
        assert!(plan(100, &[]).is_none());
        // a zero-only ladder can cover nothing and must not spin
        assert!(plan(100, &[0]).is_none());
    }

    fn mk_req(op: Op, vals: &[f32]) -> (OpRequest, mpsc::Receiver<super::super::request::OpResult>) {
        let (tx, rx) = mpsc::channel();
        let planes: Vec<Vec<f32>> = (0..op.n_in())
            .map(|p| vals.iter().map(|&v| v + p as f32 * 100.0).collect())
            .collect();
        (OpRequest::new(op, planes, tx), rx)
    }

    #[test]
    fn gather_concatenates_and_pads() {
        let (r1, _g1) = mk_req(Op::Add, &[1.0, 2.0]);
        let (r2, _g2) = mk_req(Op::Add, &[3.0, 4.0, 5.0]);
        let reqs = [&r1, &r2];
        let plane = gather_plane(&reqs, 0, 8, 0, 5, Op::Add);
        assert_eq!(plane, vec![1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0]);
        let plane1 = gather_plane(&reqs, 1, 8, 0, 5, Op::Add);
        assert_eq!(&plane1[..5], &[101.0, 102.0, 103.0, 104.0, 105.0]);
    }

    #[test]
    fn gather_windows_across_requests() {
        let (r1, _g1) = mk_req(Op::Add, &[1.0, 2.0, 3.0]);
        let (r2, _g2) = mk_req(Op::Add, &[4.0, 5.0]);
        let reqs = [&r1, &r2];
        // window [2, 5): last of r1 + all of r2
        let plane = gather_plane(&reqs, 0, 4, 2, 3, Op::Add);
        assert_eq!(plane, vec![3.0, 4.0, 5.0, 0.0]);
    }

    #[test]
    fn div22_pads_divisor_with_ones() {
        let (r, _g) = mk_req(Op::Div22, &[1.0]);
        let reqs = [&r];
        let bh = gather_plane(&reqs, 2, 4, 0, 1, Op::Div22);
        assert_eq!(bh, vec![201.0, 1.0, 1.0, 1.0]);
        let bl = gather_plane(&reqs, 3, 4, 0, 1, Op::Div22);
        assert_eq!(bl, vec![301.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_roundtrips_gather() {
        let (r1, _g1) = mk_req(Op::Add, &[1.0, 2.0, 3.0]);
        let (r2, _g2) = mk_req(Op::Add, &[4.0, 5.0]);
        let reqs = [&r1, &r2];
        let mut acc = vec![vec![vec![0.0f32; 3]; 1], vec![vec![0.0f32; 2]; 1]];
        // one launch covering everything; output = input0 * 10
        let launch_out = vec![vec![10.0, 20.0, 30.0, 40.0, 50.0, 0.0]];
        scatter_outputs(&reqs, &launch_out, 0, 5, &mut acc);
        assert_eq!(acc[0][0], vec![10.0, 20.0, 30.0]);
        assert_eq!(acc[1][0], vec![40.0, 50.0]);
    }

    #[test]
    fn scatter_with_split_launches() {
        let (r1, _g1) = mk_req(Op::Add, &[1.0, 2.0, 3.0]);
        let (r2, _g2) = mk_req(Op::Add, &[4.0, 5.0]);
        let reqs = [&r1, &r2];
        let mut acc = vec![vec![vec![0.0f32; 3]; 1], vec![vec![0.0f32; 2]; 1]];
        // launch 1 covers [0,2), launch 2 covers [2,5)
        scatter_outputs(&reqs, &[vec![10.0, 20.0]], 0, 2, &mut acc);
        scatter_outputs(&reqs, &[vec![30.0, 40.0, 50.0]], 2, 3, &mut acc);
        assert_eq!(acc[0][0], vec![10.0, 20.0, 30.0]);
        assert_eq!(acc[1][0], vec![40.0, 50.0]);
    }
}
