//! The typed client surface: build a [`Plan`], dispatch it, hold a
//! [`Ticket`].
//!
//! A [`Plan`] is a request that has **already been validated** — arity
//! and plane shapes are checked when the plan is built
//! ([`Plan::new`] / [`RequestBuilder::build`]), so a plan that exists
//! can always be dispatched, and the shard threads never see malformed
//! input. Dispatching ([`crate::coordinator::Handle::dispatch`])
//! returns a [`Ticket`], a future-like handle on the reply: callers
//! can block ([`Ticket::wait`]), poll ([`Ticket::try_wait`]), or bound
//! the wait ([`Ticket::wait_timeout`]) — the seed's stringly-typed
//! blocking `call(op, planes)` is gone; this is the only path.
//!
//! Tickets also carry **lifecycle control**: [`Ticket::deadline`] arms
//! an expiry and [`Ticket::cancel`] abandons the request, both backed
//! by a [`TicketState`] shared atomically with the shard that holds the
//! request. The shard serve loop checks that state *before* executing
//! a group (replying [`ServiceError::Cancelled`] /
//! [`ServiceError::DeadlineExceeded`] instead of burning backend
//! time), and the client-side waits honour the same state — a ticket
//! whose deadline passes resolves promptly even if its shard is
//! saturated, and marks itself cancelled so the shard skips it later.
//!
//! # Examples
//!
//! ```
//! use ffgpu::backend::{Op, ServiceError};
//! use ffgpu::coordinator::Plan;
//!
//! // one-shot validation: a Plan that exists has the right shapes
//! let plan = Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])?;
//! assert_eq!((plan.op(), plan.len()), (Op::Add, 2));
//!
//! // or incrementally, plane by plane
//! let plan = Plan::builder(Op::Mad)
//!     .plane(vec![1.0, 2.0])
//!     .planes([vec![3.0, 4.0], vec![5.0, 6.0]])
//!     .build()?;
//! assert_eq!(plan.len(), 2);
//!
//! // failures are specific, typed, and happen before dispatch
//! assert!(matches!(
//!     Plan::new(Op::Add22, vec![vec![1.0]; 3]),
//!     Err(ServiceError::Arity { want: 4, got: 3, .. })
//! ));
//! # Ok::<(), ffgpu::backend::ServiceError>(())
//! ```

use super::request::OpResult;
use crate::backend::{Op, ServiceError};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A validated, ready-to-dispatch request: one operator plus its SoA
/// input planes.
#[derive(Debug)]
pub struct Plan {
    op: Op,
    inputs: Vec<Vec<f32>>,
    len: usize,
}

impl Plan {
    /// Validate `inputs` against `op` and wrap them. This is the only
    /// constructor — a `Plan` is proof the shapes are right.
    pub fn new(op: Op, inputs: Vec<Vec<f32>>) -> Result<Plan, ServiceError> {
        let len = op.validate_planes(&inputs)?;
        Ok(Plan { op, inputs, len })
    }

    /// Start an incremental [`RequestBuilder`] for `op`.
    pub fn builder(op: Op) -> RequestBuilder {
        RequestBuilder { op, inputs: Vec::with_capacity(op.n_in()) }
    }

    pub fn op(&self) -> Op {
        self.op
    }

    /// Elements per plane.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false — zero-length plans fail validation.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn inputs(&self) -> &[Vec<f32>] {
        &self.inputs
    }

    pub(crate) fn into_parts(self) -> (Op, Vec<Vec<f32>>, usize) {
        (self.op, self.inputs, self.len)
    }
}

/// Incremental [`Plan`] construction: push planes one at a time, then
/// [`build`](RequestBuilder::build) to validate the whole request.
#[derive(Debug)]
pub struct RequestBuilder {
    op: Op,
    inputs: Vec<Vec<f32>>,
}

impl RequestBuilder {
    /// Append one input plane.
    pub fn plane(mut self, plane: Vec<f32>) -> RequestBuilder {
        self.inputs.push(plane);
        self
    }

    /// Append several input planes.
    pub fn planes(mut self, planes: impl IntoIterator<Item = Vec<f32>>) -> RequestBuilder {
        self.inputs.extend(planes);
        self
    }

    /// Validate and produce the [`Plan`].
    pub fn build(self) -> Result<Plan, ServiceError> {
        Plan::new(self.op, self.inputs)
    }
}

/// Shared lifecycle state of one dispatched request: a cancellation
/// flag plus an optional deadline, visible to both the client-side
/// [`Ticket`] and the shard thread holding the
/// [`crate::coordinator::OpRequest`].
///
/// Lock-free: the deadline is stored as nanoseconds after the
/// dispatch instant (`u64::MAX` = none), so both sides evaluate expiry
/// against their own `Instant::now()` without coordination.
#[derive(Debug)]
pub struct TicketState {
    created: Instant,
    cancelled: AtomicBool,
    deadline_ns: AtomicU64,
}

impl Default for TicketState {
    fn default() -> Self {
        TicketState::new()
    }
}

impl TicketState {
    const NO_DEADLINE: u64 = u64::MAX;

    pub fn new() -> TicketState {
        TicketState {
            created: Instant::now(),
            cancelled: AtomicBool::new(false),
            deadline_ns: AtomicU64::new(Self::NO_DEADLINE),
        }
    }

    /// Abandon the request: a shard that has not executed it yet will
    /// skip it and reply [`ServiceError::Cancelled`].
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Arm (or tighten/extend) the deadline: `d` from the dispatch
    /// instant.
    pub fn set_deadline(&self, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(Self::NO_DEADLINE - 1);
        self.deadline_ns.store(ns.min(Self::NO_DEADLINE - 1), Ordering::Release);
    }

    /// Whether the deadline (if armed) has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        let dl = self.deadline_ns.load(Ordering::Acquire);
        dl != Self::NO_DEADLINE
            && now.saturating_duration_since(self.created).as_nanos() as u64 >= dl
    }

    /// Time left until the deadline (`None` when no deadline is armed;
    /// zero when already expired).
    pub fn remaining(&self) -> Option<Duration> {
        let dl = self.deadline_ns.load(Ordering::Acquire);
        if dl == Self::NO_DEADLINE {
            return None;
        }
        Some(Duration::from_nanos(dl).saturating_sub(self.created.elapsed()))
    }
}

/// A future-like handle on one dispatched request's reply.
///
/// Produced by [`crate::coordinator::Handle::dispatch`]; resolves to an
/// [`OpResult`]. Also records *where* the request went
/// ([`Ticket::shard`]) — the routing policies make that placement
/// observable, and tests/benches assert against it — and shares a
/// [`TicketState`] with the shard for deadlines and cancellation.
#[derive(Debug)]
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<OpResult>,
    pub(crate) op: Op,
    pub(crate) shard: usize,
    pub(crate) len: usize,
    pub(crate) state: std::sync::Arc<TicketState>,
}

impl Ticket {
    /// The operator this ticket answers for.
    pub fn op(&self) -> Op {
        self.op
    }

    /// Shard index the routing policy placed the request on.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Elements per plane of the dispatched request.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false — the underlying plan was validated non-empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Arm a deadline `d` from the dispatch instant (chainable:
    /// `h.dispatch(plan)?.deadline(Duration::from_millis(1))`). Both
    /// sides honour it: the shard skips the request once expired
    /// (replying [`ServiceError::DeadlineExceeded`] without executing),
    /// and the client-side waits return the same error promptly even
    /// when the shard is saturated and never gets to reply in time.
    pub fn deadline(self, d: Duration) -> Ticket {
        self.state.set_deadline(d);
        self
    }

    /// Abandon the request. A shard that has not executed it yet skips
    /// it; subsequent waits on this ticket resolve to
    /// [`ServiceError::Cancelled`].
    pub fn cancel(&self) {
        self.state.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.state.is_cancelled()
    }

    /// The verdict for a ticket whose shared state is already marked
    /// cancelled — either by an explicit [`Ticket::cancel`]
    /// (`Cancelled`) or by a previously issued deadline miss
    /// (`DeadlineExceeded`, recognisable because the deadline has
    /// passed). `None` while the request is still live. The verdict is
    /// **sticky**: once a miss was reported, a reply the shard sent
    /// late must not double-resolve the ticket as `Ok` on a later
    /// poll, so callers return this without draining the channel.
    fn sticky_verdict(&self) -> Option<ServiceError> {
        if !self.state.is_cancelled() {
            return None;
        }
        Some(if self.state.expired(Instant::now()) {
            ServiceError::DeadlineExceeded
        } else {
            ServiceError::Cancelled
        })
    }

    /// Block until the reply arrives, the deadline (if armed) passes,
    /// or the ticket was cancelled. A shard that died before answering
    /// surfaces as [`ServiceError::QueueClosed`]. Explicit cancellation
    /// resolves `Cancelled` deterministically; with a deadline, a reply
    /// that arrived *in time* still wins over a late wait (the channel
    /// is drained before the expiry verdict), and an expired wait marks
    /// the request cancelled so the shard never executes it late.
    pub fn wait(self) -> OpResult {
        if let Some(e) = self.sticky_verdict() {
            return Err(e);
        }
        match self.state.remaining() {
            None => self.rx.recv().map_err(|_| ServiceError::QueueClosed)?,
            // an already-expired deadline gives a zero timeout, which
            // still drains an in-time reply waiting in the channel
            Some(rem) => match self.rx.recv_timeout(rem) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    self.state.cancel();
                    Err(ServiceError::DeadlineExceeded)
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Err(ServiceError::QueueClosed)
                }
            },
        }
    }

    /// Non-blocking poll: `None` while the reply is still pending.
    /// Explicit cancellation resolves `Cancelled`; otherwise an
    /// arrived reply wins, then deadline expiry.
    pub fn try_wait(&self) -> Option<OpResult> {
        if let Some(e) = self.sticky_verdict() {
            return Some(Err(e));
        }
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => {
                if self.state.expired(Instant::now()) {
                    self.state.cancel();
                    Some(Err(ServiceError::DeadlineExceeded))
                } else {
                    None
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(ServiceError::QueueClosed)),
        }
    }

    /// Block for at most `timeout` (clamped to the armed deadline);
    /// `None` on caller timeout (the ticket stays usable — wait again
    /// or poll), `Some(Err(DeadlineExceeded))` once the deadline
    /// passes with no reply in the channel.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<OpResult> {
        if let Some(e) = self.sticky_verdict() {
            return Some(Err(e));
        }
        let effective = match self.state.remaining() {
            Some(rem) => timeout.min(rem),
            None => timeout,
        };
        // a zero effective timeout (expired deadline) still drains an
        // in-time reply before the expiry verdict below
        match self.rx.recv_timeout(effective) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if self.state.expired(Instant::now()) {
                    self.state.cancel();
                    Some(Err(ServiceError::DeadlineExceeded))
                } else {
                    None
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ServiceError::QueueClosed))
            }
        }
    }

    /// Unwrap into the raw reply receiver, for callers that want to
    /// select/park on the channel directly.
    pub fn into_receiver(self) -> mpsc::Receiver<OpResult> {
        self.rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_new_validates_at_build_time() {
        let p = Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(p.op(), Op::Add);
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
        assert_eq!(p.inputs().len(), 2);

        assert!(matches!(
            Plan::new(Op::Add22, vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { want: 4, got: 3, .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![1.0; 2], vec![1.0; 3]]),
            Err(ServiceError::RaggedPlanes { plane: 1, .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { op: Op::Add })
        ));
    }

    #[test]
    fn builder_accumulates_planes() {
        let p = Plan::builder(Op::Mad)
            .plane(vec![1.0, 2.0])
            .planes([vec![3.0, 4.0], vec![5.0, 6.0]])
            .build()
            .unwrap();
        assert_eq!(p.op(), Op::Mad);
        assert_eq!(p.len(), 2);

        let short = Plan::builder(Op::Mad).plane(vec![1.0]).build();
        assert!(matches!(short, Err(ServiceError::Arity { want: 3, got: 1, .. })));
    }

    fn ticket(rx: mpsc::Receiver<OpResult>, shard: usize, len: usize) -> Ticket {
        Ticket {
            rx,
            op: Op::Add,
            shard,
            len,
            state: std::sync::Arc::new(TicketState::new()),
        }
    }

    #[test]
    fn ticket_resolves_and_polls() {
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 3, 2);
        assert_eq!(t.op(), Op::Add);
        assert_eq!(t.shard(), 3);
        assert_eq!(t.len(), 2);
        assert!(t.try_wait().is_none());
        assert!(t.wait_timeout(Duration::from_millis(1)).is_none());
        tx.send(Ok(vec![vec![42.0, 43.0]])).unwrap();
        let out = t.wait().unwrap();
        assert_eq!(out[0], vec![42.0, 43.0]);
    }

    #[test]
    fn dropped_reply_channel_is_queue_closed() {
        let (tx, rx) = mpsc::channel::<OpResult>();
        drop(tx);
        let t = ticket(rx, 0, 1);
        assert_eq!(t.try_wait(), Some(Err(ServiceError::QueueClosed)));
        assert_eq!(t.wait(), Err(ServiceError::QueueClosed));
    }

    #[test]
    fn cancelled_ticket_resolves_cancelled() {
        let (_tx, rx) = mpsc::channel::<OpResult>();
        let t = ticket(rx, 0, 1);
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled());
        assert_eq!(t.try_wait(), Some(Err(ServiceError::Cancelled)));
        assert_eq!(t.wait_timeout(Duration::from_millis(1)),
                   Some(Err(ServiceError::Cancelled)));
        assert_eq!(t.wait(), Err(ServiceError::Cancelled));
    }

    #[test]
    fn expired_deadline_resolves_deadline_exceeded() {
        let (_tx, rx) = mpsc::channel::<OpResult>();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(2));
        let t0 = std::time::Instant::now();
        assert_eq!(t.wait(), Err(ServiceError::DeadlineExceeded));
        // resolved by the deadline, not a hung recv
        assert!(t0.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn reply_before_deadline_wins() {
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 0, 1).deadline(Duration::from_secs(30));
        tx.send(Ok(vec![vec![7.0]])).unwrap();
        assert_eq!(t.wait().unwrap()[0], vec![7.0]);
    }

    #[test]
    fn in_time_reply_wins_over_late_wait() {
        // the reply arrived within the deadline; a client that only
        // gets around to waiting after the deadline passed must still
        // receive it, not a spurious DeadlineExceeded
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(5));
        tx.send(Ok(vec![vec![1.5]])).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(t.wait().unwrap()[0], vec![1.5]);
        // same through the polling APIs
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(5));
        tx.send(Ok(vec![vec![2.5]])).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(t.try_wait().unwrap().unwrap()[0], vec![2.5]);
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(5));
        tx.send(Ok(vec![vec![3.5]])).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(
            t.wait_timeout(Duration::from_secs(1)).unwrap().unwrap()[0],
            vec![3.5]
        );
    }

    #[test]
    fn wait_timeout_reports_expiry_and_marks_cancelled() {
        let (_tx, rx) = mpsc::channel::<OpResult>();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(t.wait_timeout(Duration::from_secs(10)),
                   Some(Err(ServiceError::DeadlineExceeded)));
        // the expiry marked the shared state cancelled so the shard
        // will skip the request
        assert!(t.is_cancelled());
    }

    #[test]
    fn expired_verdict_is_stable_across_polls() {
        let (_tx, rx) = mpsc::channel::<OpResult>();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(t.try_wait(), Some(Err(ServiceError::DeadlineExceeded)));
        // expiry marks the shared state cancelled (so the shard skips
        // the request), but the client-facing verdict must not flip to
        // Cancelled on later polls
        assert!(t.is_cancelled());
        assert_eq!(t.try_wait(), Some(Err(ServiceError::DeadlineExceeded)));
        assert_eq!(
            t.wait_timeout(Duration::from_millis(1)),
            Some(Err(ServiceError::DeadlineExceeded))
        );
        assert_eq!(t.wait(), Err(ServiceError::DeadlineExceeded));
    }

    #[test]
    fn deadline_verdict_is_sticky_against_late_replies() {
        let (tx, rx) = mpsc::channel();
        let t = ticket(rx, 0, 1).deadline(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        // the miss is reported once...
        assert_eq!(t.try_wait(), Some(Err(ServiceError::DeadlineExceeded)));
        // ...then a late reply lands; the ticket must not double-resolve
        tx.send(Ok(vec![vec![9.0]])).unwrap();
        assert_eq!(t.try_wait(), Some(Err(ServiceError::DeadlineExceeded)));
        assert_eq!(
            t.wait_timeout(Duration::from_millis(1)),
            Some(Err(ServiceError::DeadlineExceeded))
        );
        assert_eq!(t.wait(), Err(ServiceError::DeadlineExceeded));
    }

    #[test]
    fn ticket_state_expiry_is_shared_view() {
        let s = TicketState::new();
        assert!(!s.expired(std::time::Instant::now()));
        assert_eq!(s.remaining(), None);
        s.set_deadline(Duration::from_secs(1000));
        assert!(!s.expired(std::time::Instant::now()));
        assert!(s.remaining().unwrap() > Duration::from_secs(900));
        s.set_deadline(Duration::ZERO);
        assert!(s.expired(std::time::Instant::now()));
        assert_eq!(s.remaining().unwrap(), Duration::ZERO);
    }
}
