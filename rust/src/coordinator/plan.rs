//! The typed client surface: build a [`Plan`], dispatch it, hold a
//! [`Ticket`].
//!
//! A [`Plan`] is a request that has **already been validated** — arity
//! and plane shapes are checked when the plan is built
//! ([`Plan::new`] / [`RequestBuilder::build`]), so a plan that exists
//! can always be dispatched, and the shard threads never see malformed
//! input. Dispatching ([`crate::coordinator::Handle::dispatch`])
//! returns a [`Ticket`], a future-like handle on the reply: callers
//! can block ([`Ticket::wait`]), poll ([`Ticket::try_wait`]), or bound
//! the wait ([`Ticket::wait_timeout`]) — the seed's blocking
//! `call(op, planes)` survives only as a deprecated shim over this
//! path.

use super::request::OpResult;
use crate::backend::{Op, ServiceError};
use std::sync::mpsc;
use std::time::Duration;

/// A validated, ready-to-dispatch request: one operator plus its SoA
/// input planes.
#[derive(Debug)]
pub struct Plan {
    op: Op,
    inputs: Vec<Vec<f32>>,
    len: usize,
}

impl Plan {
    /// Validate `inputs` against `op` and wrap them. This is the only
    /// constructor — a `Plan` is proof the shapes are right.
    pub fn new(op: Op, inputs: Vec<Vec<f32>>) -> Result<Plan, ServiceError> {
        let len = op.validate_planes(&inputs)?;
        Ok(Plan { op, inputs, len })
    }

    /// Start an incremental [`RequestBuilder`] for `op`.
    pub fn builder(op: Op) -> RequestBuilder {
        RequestBuilder { op, inputs: Vec::with_capacity(op.n_in()) }
    }

    pub fn op(&self) -> Op {
        self.op
    }

    /// Elements per plane.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false — zero-length plans fail validation.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn inputs(&self) -> &[Vec<f32>] {
        &self.inputs
    }

    pub(crate) fn into_parts(self) -> (Op, Vec<Vec<f32>>, usize) {
        (self.op, self.inputs, self.len)
    }
}

/// Incremental [`Plan`] construction: push planes one at a time, then
/// [`build`](RequestBuilder::build) to validate the whole request.
#[derive(Debug)]
pub struct RequestBuilder {
    op: Op,
    inputs: Vec<Vec<f32>>,
}

impl RequestBuilder {
    /// Append one input plane.
    pub fn plane(mut self, plane: Vec<f32>) -> RequestBuilder {
        self.inputs.push(plane);
        self
    }

    /// Append several input planes.
    pub fn planes(mut self, planes: impl IntoIterator<Item = Vec<f32>>) -> RequestBuilder {
        self.inputs.extend(planes);
        self
    }

    /// Validate and produce the [`Plan`].
    pub fn build(self) -> Result<Plan, ServiceError> {
        Plan::new(self.op, self.inputs)
    }
}

/// A future-like handle on one dispatched request's reply.
///
/// Produced by [`crate::coordinator::Handle::dispatch`]; resolves to an
/// [`OpResult`]. Also records *where* the request went
/// ([`Ticket::shard`]) — the routing policies make that placement
/// observable, and tests/benches assert against it.
#[derive(Debug)]
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<OpResult>,
    pub(crate) op: Op,
    pub(crate) shard: usize,
    pub(crate) len: usize,
}

impl Ticket {
    /// The operator this ticket answers for.
    pub fn op(&self) -> Op {
        self.op
    }

    /// Shard index the routing policy placed the request on.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Elements per plane of the dispatched request.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false — the underlying plan was validated non-empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Block until the reply arrives. A shard that died before
    /// answering surfaces as [`ServiceError::QueueClosed`].
    pub fn wait(self) -> OpResult {
        self.rx.recv().map_err(|_| ServiceError::QueueClosed)?
    }

    /// Non-blocking poll: `None` while the reply is still pending.
    pub fn try_wait(&self) -> Option<OpResult> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(ServiceError::QueueClosed)),
        }
    }

    /// Block for at most `timeout`; `None` on timeout (the ticket stays
    /// usable — wait again or poll).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<OpResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ServiceError::QueueClosed))
            }
        }
    }

    /// Unwrap into the raw reply receiver (the deprecated
    /// `Handle::submit` shim returns this).
    pub fn into_receiver(self) -> mpsc::Receiver<OpResult> {
        self.rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_new_validates_at_build_time() {
        let p = Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(p.op(), Op::Add);
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
        assert_eq!(p.inputs().len(), 2);

        assert!(matches!(
            Plan::new(Op::Add22, vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { want: 4, got: 3, .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![1.0; 2], vec![1.0; 3]]),
            Err(ServiceError::RaggedPlanes { plane: 1, .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { op: Op::Add })
        ));
    }

    #[test]
    fn builder_accumulates_planes() {
        let p = Plan::builder(Op::Mad)
            .plane(vec![1.0, 2.0])
            .planes([vec![3.0, 4.0], vec![5.0, 6.0]])
            .build()
            .unwrap();
        assert_eq!(p.op(), Op::Mad);
        assert_eq!(p.len(), 2);

        let short = Plan::builder(Op::Mad).plane(vec![1.0]).build();
        assert!(matches!(short, Err(ServiceError::Arity { want: 3, got: 1, .. })));
    }

    #[test]
    fn ticket_resolves_and_polls() {
        let (tx, rx) = mpsc::channel();
        let t = Ticket { rx, op: Op::Add, shard: 3, len: 2 };
        assert_eq!(t.op(), Op::Add);
        assert_eq!(t.shard(), 3);
        assert_eq!(t.len(), 2);
        assert!(t.try_wait().is_none());
        assert!(t.wait_timeout(Duration::from_millis(1)).is_none());
        tx.send(Ok(vec![vec![42.0, 43.0]])).unwrap();
        let out = t.wait().unwrap();
        assert_eq!(out[0], vec![42.0, 43.0]);
    }

    #[test]
    fn dropped_reply_channel_is_queue_closed() {
        let (tx, rx) = mpsc::channel::<OpResult>();
        drop(tx);
        let t = Ticket { rx, op: Op::Add, shard: 0, len: 1 };
        assert_eq!(t.try_wait(), Some(Err(ServiceError::QueueClosed)));
        assert_eq!(t.wait(), Err(ServiceError::QueueClosed));
    }
}
