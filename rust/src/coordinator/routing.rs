//! Pluggable request routing over a (possibly heterogeneous) shard set.
//!
//! The seed hard-coded round-robin submission inside `Handle`. With
//! per-shard [`crate::backend::BackendSpec`]s (e.g. 6 native shards +
//! one `gpusim:nv35` canary) placement becomes a real decision, so it
//! is a trait: a [`RoutingPolicy`] maps `(op, batch length)` plus a
//! [`TelemetryView`] of the live shard set — substrate label, queue
//! depth, per-op capability and *measured* throughput/latency EWMAs
//! ([`Telemetry`]) — to a shard index. Four implementations ship,
//! selectable via [`Routing`] from config or `--routing` on the CLI:
//!
//! * [`RoundRobin`] — the seed's behaviour: even spray, no state read;
//! * [`QueueDepth`] — least-loaded: picks the shard with the fewest
//!   in-flight requests (rotating tie-break), so a slow substrate —
//!   the soft-float stream VM, say — naturally receives less work;
//! * [`OpAffinity`] — pins each operator to one home shard
//!   (`op.index() % shards`), walking forward past shards whose backend
//!   does not serve the op, keeping per-op state (compiled-artifact
//!   caches, staging buffers sized for that op's arity) hot;
//! * [`Measured`] — telemetry-driven: only shards that serve the op
//!   natively are candidates, cold candidates are explored least-loaded
//!   first, and once every candidate has a measured rate the pick
//!   minimises estimated completion time `(depth+1) · len / Melem/s` —
//!   a slow canary keeps a trickle of probes at most.
//!
//! Custom policies plug in through
//! [`crate::coordinator::Service::start_with_policy`].
//!
//! # Examples
//!
//! ```
//! use ffgpu::backend::BackendSpec;
//! use ffgpu::coordinator::{Routing, Service, ServiceSpec};
//!
//! // two native shards routed least-loaded, selected CLI-style
//! let spec = ServiceSpec::uniform(BackendSpec::native_single(), 2)
//!     .with_routing(Routing::from_cli("queue-depth")?);
//! let svc = Service::start(spec)?;
//! assert_eq!(svc.routing(), "queue-depth");
//! // the telemetry view policies route over is readable by callers too
//! assert_eq!(svc.telemetry().len(), 2);
//! assert_eq!(svc.telemetry().queue_depth(0), 0);
//! # Ok::<(), ffgpu::backend::ServiceError>(())
//! ```

use super::metrics::{StageSplit, Telemetry};
use crate::backend::{KernelTier, Op, ServiceError};
use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

/// Every-op capability mask (`Op::COUNT <= 32`).
const ALL_OPS_MASK: u32 = (1 << Op::COUNT) - 1;

/// Sentinel in [`ShardMeta::tier`] while the kernel tier is unknown
/// (pre-build, or a substrate without CPU kernel tiers).
const TIER_UNSET: u8 = u8::MAX;

/// Sentinel in [`ShardMeta::node`] for an unpinned shard.
const NODE_UNSET: usize = usize::MAX;

/// Live, routing-visible state of one shard: which substrate it runs,
/// how many requests it currently has in flight, which operators its
/// backend serves, and the measured per-op telemetry.
#[derive(Debug)]
pub struct ShardMeta {
    label: &'static str,
    depth: AtomicUsize,
    /// Capability bitmask over `Op::index()`; seeded all-ones and
    /// replaced with the backend's real catalogue
    /// ([`crate::backend::KernelBackend::ops`]) when the shard thread
    /// builds its backend — before `Service::start` returns, so no
    /// routable request ever sees the placeholder.
    supports: AtomicU32,
    /// Kernel tier of the shard's backend, as `KernelTier::index() as
    /// u8` ([`TIER_UNSET`] = none): published like `supports`, when the
    /// shard thread builds its backend, so telemetry and banners can
    /// attribute Melem/s to a tier.
    tier: AtomicU8,
    /// NUMA node this shard is pinned to ([`NODE_UNSET`] = unpinned):
    /// published like `supports`, when the shard thread builds its
    /// backend, so telemetry and bench rows can attribute throughput
    /// to placement.
    node: AtomicUsize,
    /// Gather/execute/scatter time split of this shard's fused groups
    /// (EWMA; written by the shard thread after each fused group).
    stages: StageSplit,
    telemetry: Telemetry,
}

impl ShardMeta {
    pub(crate) fn new(label: &'static str) -> ShardMeta {
        ShardMeta {
            label,
            depth: AtomicUsize::new(0),
            supports: AtomicU32::new(ALL_OPS_MASK),
            tier: AtomicU8::new(TIER_UNSET),
            node: AtomicUsize::new(NODE_UNSET),
            stages: StageSplit::default(),
            telemetry: Telemetry::new(),
        }
    }

    /// Substrate label of the backend this shard owns ("native",
    /// "gpusim", "xla").
    pub fn label(&self) -> &'static str {
        self.label
    }

    /// Requests submitted to this shard and not yet replied to.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Whether this shard's backend serves `op`.
    pub fn supports(&self, op: Op) -> bool {
        self.supports.load(Ordering::Relaxed) & (1 << op.index()) != 0
    }

    /// The operators this shard's backend serves, in catalogue order.
    pub fn supported_ops(&self) -> Vec<Op> {
        Op::ALL.into_iter().filter(|&op| self.supports(op)).collect()
    }

    /// Measured per-op telemetry of this shard (EWMA throughput and
    /// group latency, written by the shard thread after each group).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// The CPU kernel tier this shard's backend runs, `None` for
    /// substrates where tiers do not apply (gpusim, XLA) or before the
    /// backend is built.
    pub fn kernel_tier(&self) -> Option<KernelTier> {
        match self.tier.load(Ordering::Relaxed) {
            TIER_UNSET => None,
            ix => KernelTier::from_index(ix as usize),
        }
    }

    pub(crate) fn set_supports(&self, ops: &[Op]) {
        let mask = ops.iter().fold(0u32, |m, op| m | (1 << op.index()));
        self.supports.store(mask, Ordering::Relaxed);
    }

    pub(crate) fn set_kernel_tier(&self, tier: Option<KernelTier>) {
        let v = tier.map_or(TIER_UNSET, |t| t.index() as u8);
        self.tier.store(v, Ordering::Relaxed);
    }

    /// The NUMA node this shard's backend is pinned to (`None` =
    /// unpinned — NUMA off, single-node host, or a non-native shard).
    pub fn numa_node(&self) -> Option<usize> {
        match self.node.load(Ordering::Relaxed) {
            NODE_UNSET => None,
            n => Some(n),
        }
    }

    pub(crate) fn set_numa_node(&self, node: Option<usize>) {
        self.node.store(node.unwrap_or(NODE_UNSET), Ordering::Relaxed);
    }

    /// Gather/execute/scatter split of this shard's fused groups.
    pub fn stage_split(&self) -> &StageSplit {
        &self.stages
    }

    pub(crate) fn enter(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn leave(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::Relaxed);
    }
}

/// What a routing policy routes over: a read-only, lock-free view of
/// the whole shard set — label, queue depth, per-op capability and
/// measured rate/latency per shard.
#[derive(Clone, Copy, Debug)]
pub struct TelemetryView<'a> {
    shards: &'a [ShardMeta],
}

impl<'a> TelemetryView<'a> {
    pub fn new(shards: &'a [ShardMeta]) -> TelemetryView<'a> {
        TelemetryView { shards }
    }

    /// Number of shards in the set (never 0 for a running service).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn label(&self, shard: usize) -> &'static str {
        self.shards[shard].label()
    }

    pub fn queue_depth(&self, shard: usize) -> usize {
        self.shards[shard].queue_depth()
    }

    pub fn supports(&self, shard: usize, op: Op) -> bool {
        self.shards[shard].supports(op)
    }

    /// CPU kernel tier of `shard`'s backend (`None` on non-native
    /// substrates) — lets Melem/s readings be attributed to a tier.
    pub fn kernel_tier(&self, shard: usize) -> Option<KernelTier> {
        self.shards[shard].kernel_tier()
    }

    /// NUMA node `shard` is pinned to (`None` = unpinned).
    pub fn numa_node(&self, shard: usize) -> Option<usize> {
        self.shards[shard].numa_node()
    }

    /// Gather/execute/scatter seconds split (EWMA) of `shard`'s fused
    /// groups, `None` before the first fused group runs there.
    pub fn stage_split(&self, shard: usize) -> Option<(f64, f64, f64)> {
        self.shards[shard].stage_split().split()
    }

    /// Measured throughput of `op` on `shard` (Melem/s), `None` while
    /// that (shard, op) cell is cold.
    pub fn measured_rate(&self, shard: usize, op: Op) -> Option<f64> {
        self.shards[shard].telemetry().rate(op)
    }

    /// Measured group latency of `op` on `shard` (seconds), `None`
    /// while cold.
    pub fn measured_latency(&self, shard: usize, op: Op) -> Option<f64> {
        self.shards[shard].telemetry().latency(op)
    }

    /// Measured padding-waste fraction of `op`'s fused groups on
    /// `shard` (padded lanes / launched lanes, EWMA), `None` while
    /// cold — the fusion-quality signal planning-aware policies read.
    pub fn measured_waste(&self, shard: usize, op: Op) -> Option<f64> {
        self.shards[shard].telemetry().waste(op)
    }

    /// Executed groups of `op` on `shard` so far.
    pub fn samples(&self, shard: usize, op: Op) -> u64 {
        self.shards[shard].telemetry().samples(op)
    }

    /// Groups of `op` routed into execution on `shard` (>= samples;
    /// what measured routing's cold-exploration checks).
    pub fn attempts(&self, shard: usize, op: Op) -> u64 {
        self.shards[shard].telemetry().attempts(op)
    }

    /// Estimated seconds until a request of `op` enqueued on `shard`
    /// **now** would complete: `(queue_depth + 1) ×` the measured
    /// per-group latency EWMA — every request ahead of it plus its own
    /// group. `None` while the (shard, op) cell is cold (no executed
    /// group yet), which callers must treat as "admit": a cold shard
    /// cannot justify shedding.
    ///
    /// This is the load-shedding input the wire front end reads
    /// ([`crate::net::ShedPolicy`]): shed when the best achievable
    /// estimate exceeds the request's declared deadline.
    pub fn estimated_wait(&self, shard: usize, op: Op) -> Option<f64> {
        let lat = self.measured_latency(shard, op)?;
        Some((self.queue_depth(shard) + 1) as f64 * lat)
    }

    /// Minimum [`TelemetryView::estimated_wait`] across the shards
    /// that serve `op`. `None` when every capable shard is cold — the
    /// service has no measured basis to refuse work on.
    pub fn best_estimated_wait(&self, op: Op) -> Option<f64> {
        (0..self.len())
            .filter(|&s| self.supports(s, op))
            .filter_map(|s| self.estimated_wait(s, op))
            .min_by(|a, b| a.total_cmp(b))
    }
}

/// A shard-placement strategy. Implementations must be cheap — this
/// runs on every submission — and thread-safe (handles are cloned
/// across client threads).
pub trait RoutingPolicy: Send + Sync {
    /// Short policy name for logs/metrics ("round-robin", ...).
    fn name(&self) -> &'static str;

    /// Pick a shard index in `0..view.len()` for a `len`-element batch
    /// of `op`. The view is never empty.
    fn route(&self, op: Op, len: usize, view: &TelemetryView) -> usize;
}

/// Even spray in submission order — the seed's behaviour.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&self, _op: Op, _len: usize, view: &TelemetryView) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % view.len()
    }
}

/// Least-loaded: the shard with the smallest in-flight count wins;
/// ties rotate so equal shards still share work evenly.
#[derive(Debug, Default)]
pub struct QueueDepth {
    tie: AtomicUsize,
}

impl QueueDepth {
    pub fn new() -> QueueDepth {
        QueueDepth::default()
    }
}

impl RoutingPolicy for QueueDepth {
    fn name(&self) -> &'static str {
        "queue-depth"
    }

    fn route(&self, _op: Op, _len: usize, view: &TelemetryView) -> usize {
        let n = view.len();
        let start = self.tie.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = view.queue_depth(start);
        for off in 1..n {
            let i = (start + off) % n;
            let d = view.queue_depth(i);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }
}

/// Capability-aware per-operator home shard.
///
/// The home is `op.index() % shards`; if the home shard's backend does
/// not serve the op, the pin walks forward to the next shard that does
/// (wrapping), so an op is never parked on a shard that would only
/// answer [`ServiceError::Unsupported`]. Every request for a given
/// operator lands on the same shard, keeping whatever per-op state that
/// shard's backend holds — XLA compiled-artifact caches, gpusim staging
/// buffers sized for the op's arity — hot, at the cost of per-op
/// (rather than per-request) load spreading.
#[derive(Debug, Default)]
pub struct OpAffinity;

impl OpAffinity {
    pub fn new() -> OpAffinity {
        OpAffinity
    }

    /// The home shard this policy starts from for `op` on a
    /// `shards`-wide set (the pick when the home supports the op).
    pub fn home(op: Op, shards: usize) -> usize {
        op.index() % shards.max(1)
    }
}

impl RoutingPolicy for OpAffinity {
    fn name(&self) -> &'static str {
        "op-affinity"
    }

    fn route(&self, op: Op, _len: usize, view: &TelemetryView) -> usize {
        let n = view.len();
        let home = OpAffinity::home(op, n);
        for off in 0..n {
            let i = (home + off) % n;
            if view.supports(i, op) {
                return i;
            }
        }
        // nobody claims the op: keep the deterministic pin and let the
        // home backend report Unsupported
        home
    }
}

/// Telemetry-driven placement: route by *measured* capability, not a
/// static pin (the point of serving float-float on heterogeneous
/// substrates — the same op is 2–10× apart across them, paper
/// Tables 3/4).
///
/// * Candidates are the shards whose backend serves the op
///   ([`ShardMeta::supports`]); if none claims it, every shard is a
///   candidate and the backend's own `Unsupported` reply surfaces.
/// * While any candidate is **cold** (never *attempted* for this op)
///   *and idle*, one is picked — cheap exploration that seeds every
///   cell. The pick is seeded by the published
///   [`KernelTier`]: among several cold idle candidates the one with
///   the highest tier (widest SIMD/FMA kernels) takes the first
///   groups, so the cold-start guess already reflects the one
///   capability signal the backend publishes before any measurement
///   exists; equal (or absent) tiers fall back to the rotating
///   tie-break. Coldness is by attempts, not
///   successes, and busy cold candidates are skipped, so a shard that
///   keeps failing, or whose slow first group is queued or in flight,
///   cannot black-hole an op's traffic: at most one probe rides on a
///   cold shard at a time while the rest of the burst routes by
///   measurement.
/// * Among measured candidates the pick minimises estimated
///   completion time `(queue_depth + 1) · len / rate · (1 + waste)` —
///   a slow shard (the gpusim canary, say) only wins when the fast
///   shards are backlogged in proportion to how much slower it is, and
///   the padding-waste EWMA surcharges shards whose fused launches of
///   this op keep padding (phantom lanes the useful-lane rate cannot
///   see). Candidates
///   attempted but never measured (failing, or mid-first-group) are
///   skipped; if *no* candidate is measured yet, least-loaded keeps
///   traffic moving.
#[derive(Debug, Default)]
pub struct Measured {
    tie: AtomicUsize,
}

impl Measured {
    pub fn new() -> Measured {
        Measured::default()
    }
}

impl RoutingPolicy for Measured {
    fn name(&self) -> &'static str {
        "measured"
    }

    fn route(&self, op: Op, len: usize, view: &TelemetryView) -> usize {
        let n = view.len();
        let any_support = (0..n).any(|i| view.supports(i, op));
        let candidate = |i: usize| !any_support || view.supports(i, op);
        let start = self.tie.fetch_add(1, Ordering::Relaxed) % n;

        // cold exploration: an *idle*, never-attempted candidate first,
        // highest published kernel tier winning ties. Requiring depth 0
        // caps exploration at one in-flight probe per cold shard — a
        // burst arriving while the probe grinds routes onward to
        // measured shards instead of piling on.
        if let Some(i) = best_cold(view, op, start, &candidate) {
            return i;
        }

        // warm: minimise estimated completion time among measured
        // candidates (attempted-but-unmeasured shards — failing, or
        // mid-first-group — are skipped)
        let mut best: Option<(f64, usize)> = None;
        for off in 0..n {
            let i = (start + off) % n;
            if !candidate(i) {
                continue;
            }
            let Some(rate) = view.measured_rate(i, op) else { continue };
            let backlog = view.queue_depth(i) as f64 + 1.0;
            // waste-fed penalty: a shard whose fused groups of this op
            // keep padding heavily burns substrate time the rate EWMA
            // (useful lanes only) cannot see — (1 + waste) charges the
            // estimate for those phantom lanes, so a poorly-packing
            // shard loses traffic in proportion to its waste fraction
            let waste = view.measured_waste(i, op).unwrap_or(0.0);
            let score = backlog * (len as f64 / 1e6) / rate.max(1e-9) * (1.0 + waste);
            let better = match best {
                Some((best_s, _)) => score < best_s,
                None => true,
            };
            if better {
                best = Some((score, i));
            }
        }
        if let Some((_, i)) = best {
            return i;
        }

        // nothing measured yet (every candidate failing or still on its
        // first group): least-loaded candidate keeps traffic moving
        least_loaded(view, start, candidate).unwrap_or(start)
    }
}

/// Measured routing's cold-exploration pick: among candidates never
/// attempted for `op` *and* currently idle, the one whose backend
/// publishes the highest [`KernelTier`] wins (tierless substrates rank
/// lowest). Scanning from `start` keeps equal-tier ties rotating, so a
/// homogeneous shard set still seeds every cell round-robin. `None`
/// when no cold idle candidate exists.
fn best_cold<F: Fn(usize) -> bool>(
    view: &TelemetryView, op: Op, start: usize, keep: &F,
) -> Option<usize> {
    let n = view.len();
    let mut best: Option<(usize, usize)> = None; // (tier_rank, shard)
    for off in 0..n {
        let i = (start + off) % n;
        if !keep(i) || view.attempts(i, op) != 0 || view.queue_depth(i) != 0 {
            continue;
        }
        // rank 0 = no published tier, 1.. = KernelTier::index() + 1,
        // so a tiered native shard always beats a tierless substrate
        let rank = view.kernel_tier(i).map_or(0, |t| t.index() + 1);
        let better = match best {
            Some((best_r, _)) => rank > best_r,
            None => true,
        };
        if better {
            best = Some((rank, i));
        }
    }
    best.map(|(_, i)| i)
}

/// Least-loaded shard among those `keep` accepts, scanning from
/// `start` so equal depths rotate (the first minimum in rotated order
/// wins). `None` when `keep` rejects every shard.
fn least_loaded<F: Fn(usize) -> bool>(
    view: &TelemetryView, start: usize, keep: F,
) -> Option<usize> {
    let n = view.len();
    let mut best: Option<(usize, usize)> = None; // (depth, shard)
    for off in 0..n {
        let i = (start + off) % n;
        if !keep(i) {
            continue;
        }
        let d = view.queue_depth(i);
        let better = match best {
            Some((best_d, _)) => d < best_d,
            None => true,
        };
        if better {
            best = Some((d, i));
        }
    }
    best.map(|(_, i)| i)
}

/// Config/CLI-level policy selector (the `Clone`-able recipe;
/// [`Routing::build`] materialises the shared policy object).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Routing {
    #[default]
    RoundRobin,
    QueueDepth,
    OpAffinity,
    Measured,
}

impl Routing {
    /// Every built-in policy, in CLI order.
    pub const ALL: [Routing; 4] = [
        Routing::RoundRobin,
        Routing::QueueDepth,
        Routing::OpAffinity,
        Routing::Measured,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::QueueDepth => "queue-depth",
            Routing::OpAffinity => "op-affinity",
            Routing::Measured => "measured",
        }
    }

    /// Parse a `--routing` value: `round-robin`/`rr`,
    /// `queue-depth`/`least-loaded`, `op-affinity`/`affinity`,
    /// `measured`.
    pub fn from_cli(name: &str) -> Result<Routing, ServiceError> {
        match name {
            "round-robin" | "rr" => Ok(Routing::RoundRobin),
            "queue-depth" | "least-loaded" => Ok(Routing::QueueDepth),
            "op-affinity" | "affinity" => Ok(Routing::OpAffinity),
            "measured" => Ok(Routing::Measured),
            other => Err(ServiceError::Backend(format!(
                "unknown routing policy '{other}' \
                 (try round-robin, queue-depth, op-affinity, measured)"
            ))),
        }
    }

    /// Materialise the policy object handles will share.
    pub fn build(self) -> Arc<dyn RoutingPolicy> {
        match self {
            Routing::RoundRobin => Arc::new(RoundRobin::new()),
            Routing::QueueDepth => Arc::new(QueueDepth::new()),
            Routing::OpAffinity => Arc::new(OpAffinity::new()),
            Routing::Measured => Arc::new(Measured::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas(n: usize) -> Vec<ShardMeta> {
        (0..n).map(|_| ShardMeta::new("native")).collect()
    }

    /// Warm one (shard, op) cell the way the serve loop does: an
    /// attempt recorded pre-execute, a sample on success.
    fn warm(m: &ShardMeta, op: Op, elements: u64, seconds: f64) {
        m.telemetry().record_attempt(op);
        m.telemetry().record(op, elements, seconds, 0);
    }

    #[test]
    fn round_robin_cycles() {
        let m = metas(3);
        let v = TelemetryView::new(&m);
        let p = RoundRobin::new();
        let picks: Vec<usize> = (0..6).map(|_| p.route(Op::Add, 10, &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(p.name(), "round-robin");
    }

    #[test]
    fn queue_depth_picks_least_loaded() {
        let m = metas(3);
        m[0].enter();
        m[0].enter();
        m[1].enter();
        let v = TelemetryView::new(&m);
        // shard 2 is empty: every pick lands there until depths change
        let p = QueueDepth::new();
        for _ in 0..4 {
            assert_eq!(p.route(Op::Add, 10, &v), 2);
        }
        m[2].enter();
        m[2].enter();
        m[2].enter();
        // now shard 1 (depth 1) is the minimum
        assert_eq!(p.route(Op::Add, 10, &v), 1);
        m[1].leave(1);
        assert_eq!(m[1].queue_depth(), 0);
    }

    #[test]
    fn queue_depth_ties_rotate() {
        let m = metas(4);
        let v = TelemetryView::new(&m);
        let p = QueueDepth::new();
        let picks: Vec<usize> = (0..4).map(|_| p.route(Op::Add, 10, &v)).collect();
        // all depths equal: the rotating start spreads the picks
        assert_eq!(picks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn op_affinity_is_deterministic_and_total() {
        let m = metas(3);
        let v = TelemetryView::new(&m);
        let p = OpAffinity::new();
        for op in Op::ALL {
            let s = p.route(op, 10, &v);
            assert_eq!(s, op.index() % 3);
            // repeat picks never move
            assert_eq!(p.route(op, 99, &v), s);
        }
        // a 2-shard set still covers both shards across the catalogue
        let m2 = metas(2);
        let v2 = TelemetryView::new(&m2);
        let picked: std::collections::HashSet<usize> =
            Op::ALL.iter().map(|&op| p.route(op, 1, &v2)).collect();
        assert_eq!(picked.len(), 2);
    }

    #[test]
    fn op_affinity_never_routes_to_non_supporting_shard() {
        let m = metas(3);
        // shard layout: 0 serves everything, 1 serves only Add, 2 serves
        // everything except Mul22/Div22
        m[1].set_supports(&[Op::Add]);
        let all_but: Vec<Op> =
            Op::ALL.into_iter().filter(|&o| o != Op::Mul22 && o != Op::Div22).collect();
        m[2].set_supports(&all_but);
        let v = TelemetryView::new(&m);
        let p = OpAffinity::new();
        for op in Op::ALL {
            let s = p.route(op, 10, &v);
            assert!(v.supports(s, op), "{op} pinned to non-supporting shard {s}");
            // still deterministic
            assert_eq!(p.route(op, 10, &v), s);
        }
        // Mul22's home is shard 1 (index 4 % 3): neither 1 (Add only)
        // nor 2 (no Mul22) serves it, so the walk wraps to shard 0
        assert_eq!(p.route(Op::Mul22, 10, &v), 0);
    }

    #[test]
    fn op_affinity_falls_back_to_home_when_unclaimed() {
        let m = metas(2);
        m[0].set_supports(&[]);
        m[1].set_supports(&[]);
        let v = TelemetryView::new(&m);
        let p = OpAffinity::new();
        // nobody serves it: keep the deterministic home pin
        assert_eq!(p.route(Op::Mul22, 10, &v), OpAffinity::home(Op::Mul22, 2));
    }

    #[test]
    fn measured_explores_cold_candidates_first() {
        let m = metas(3);
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        // everything cold: three picks spread over all three shards
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            let s = p.route(Op::Add22, 1000, &v);
            warm(&m[s], Op::Add22, 1000, 1e-3);
            seen.insert(s);
        }
        assert_eq!(seen.len(), 3, "cold exploration must seed every shard");
    }

    #[test]
    fn measured_synthetic_slow_shard_loses_traffic() {
        let m = metas(2);
        // warm both cells: shard 0 measures 100 Melem/s, shard 1 is a
        // thousand times slower (the gpusim canary shape)
        warm(&m[0], Op::Mul22, 100_000_000, 1.0);
        warm(&m[1], Op::Mul22, 100_000, 1.0);
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        for _ in 0..20 {
            assert_eq!(p.route(Op::Mul22, 4096, &v), 0);
        }
        // even a moderately backlogged fast shard still beats the slow
        // one: (depth+1) ratio must exceed the 1000x rate gap to flip
        for _ in 0..10 {
            m[0].enter();
        }
        assert_eq!(p.route(Op::Mul22, 4096, &v), 0);
        // but an extreme backlog does flip the pick — the slow shard is
        // starved, not banned
        for _ in 0..2000 {
            m[0].enter();
        }
        assert_eq!(p.route(Op::Mul22, 4096, &v), 1);
    }

    #[test]
    fn measured_high_waste_shard_loses_traffic() {
        let m = metas(2);
        // identical useful-lane rates (1000 Melem/s each), but shard 1's
        // fused groups pad half their launched lanes: its (1 + waste)
        // surcharge must lose it the tie
        warm(&m[0], Op::Add22, 1_000_000_000, 1.0);
        m[1].telemetry().record_attempt(Op::Add22);
        m[1].telemetry().record(Op::Add22, 1_000_000_000, 1.0, 1_000_000_000);
        let v = TelemetryView::new(&m);
        assert!((v.measured_waste(1, Op::Add22).unwrap() - 0.5).abs() < 1e-12);
        let p = Measured::new();
        for _ in 0..10 {
            assert_eq!(p.route(Op::Add22, 4096, &v), 0);
        }
        // the penalty is proportional, not a ban: once the clean shard
        // backlogs past the waste surcharge, the wasteful one wins
        // (score0 = 4·4096/1e6/1000 > score1 = 1.5·4096/1e6/1000)
        for _ in 0..3 {
            m[0].enter();
        }
        assert_eq!(p.route(Op::Add22, 4096, &v), 1);
    }

    #[test]
    fn measured_only_considers_supporting_shards() {
        let m = metas(3);
        m[0].set_supports(&[Op::Add]);
        // shards 1 and 2 serve Mul22; 1 is measured fast, 2 cold
        m[1].set_supports(&[Op::Mul22]);
        m[2].set_supports(&[Op::Mul22]);
        warm(&m[1], Op::Mul22, 10_000_000, 1.0);
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        // cold candidate 2 is explored first, never shard 0
        assert_eq!(p.route(Op::Mul22, 100, &v), 2);
        warm(&m[2], Op::Mul22, 10_000_000, 1.0);
        for _ in 0..10 {
            let s = p.route(Op::Mul22, 100, &v);
            assert!(s == 1 || s == 2, "routed {s} which does not serve mul22");
        }
    }

    #[test]
    fn measured_cold_start_prefers_higher_kernel_tiers() {
        // three cold shards: scalar, blocked-fma, and one with no
        // published tier. The cold-start guess must ride the published
        // capability ladder — widest kernels first, tierless last.
        let m = metas(3);
        m[0].set_kernel_tier(Some(KernelTier::Scalar));
        m[1].set_kernel_tier(Some(KernelTier::BlockedFma));
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        // repeated cold picks all land on the blocked-fma shard until
        // it is attempted — the rotating tie-break must not override
        // the tier ranking
        for _ in 0..3 {
            assert_eq!(p.route(Op::Add22, 1000, &v), 1);
        }
        warm(&m[1], Op::Add22, 1000, 1e-3);
        // next-best cold candidate: the scalar shard beats tierless
        assert_eq!(p.route(Op::Add22, 1000, &v), 0);
        warm(&m[0], Op::Add22, 1000, 1e-3);
        // tierless shard still gets its probe last
        assert_eq!(p.route(Op::Add22, 1000, &v), 2);
    }

    #[test]
    fn measured_cold_exploration_skips_busy_cold_shards() {
        // the canary is cold for this op but already has work queued
        // (e.g. its first probe, or another op's slow group): a burst
        // must route to the measured shard, not pile onto the canary
        let m = metas(2);
        warm(&m[0], Op::Div22, 10_000_000, 1.0);
        m[1].enter();
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        for _ in 0..10 {
            assert_eq!(p.route(Op::Div22, 100, &v), 0);
        }
        // once idle again, the cold shard gets its probe
        m[1].leave(1);
        assert_eq!(p.route(Op::Div22, 100, &v), 1);
    }

    #[test]
    fn measured_skips_attempted_but_unmeasured_shards() {
        // shard 1 was tried (attempts > 0) but never succeeded — a
        // failing backend or a slow first group still in flight. It
        // must not look "cold" and attract the op's traffic.
        let m = metas(2);
        warm(&m[0], Op::Mul22, 10_000_000, 1.0);
        m[1].telemetry().record_attempt(Op::Mul22);
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        for _ in 0..10 {
            assert_eq!(p.route(Op::Mul22, 100, &v), 0);
        }
    }

    #[test]
    fn measured_unmeasured_everywhere_falls_back_to_least_loaded() {
        // every candidate attempted, none measured (startup burst or
        // all failing): traffic keeps moving, least-loaded first
        let m = metas(2);
        m[0].telemetry().record_attempt(Op::Add22);
        m[1].telemetry().record_attempt(Op::Add22);
        m[0].enter();
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        for _ in 0..4 {
            assert_eq!(p.route(Op::Add22, 100, &v), 1);
        }
    }

    #[test]
    fn measured_falls_back_to_all_shards_when_unclaimed() {
        let m = metas(2);
        m[0].set_supports(&[]);
        m[1].set_supports(&[]);
        let v = TelemetryView::new(&m);
        let p = Measured::new();
        let s = p.route(Op::Add, 10, &v);
        assert!(s < 2);
    }

    #[test]
    fn shard_meta_capability_surface() {
        let m = ShardMeta::new("native");
        // placeholder: everything supported until the backend publishes
        assert!(Op::ALL.into_iter().all(|op| m.supports(op)));
        m.set_supports(&[Op::Add22, Op::Mul22]);
        assert!(m.supports(Op::Add22));
        assert!(!m.supports(Op::Div22));
        assert_eq!(m.supported_ops(), vec![Op::Add22, Op::Mul22]);
    }

    #[test]
    fn shard_meta_publishes_kernel_tier() {
        let m = ShardMeta::new("native");
        assert_eq!(m.kernel_tier(), None, "unset until the backend is built");
        for tier in KernelTier::ALL {
            m.set_kernel_tier(Some(tier));
            assert_eq!(m.kernel_tier(), Some(tier));
        }
        m.set_kernel_tier(None);
        assert_eq!(m.kernel_tier(), None);
        let metas = [m];
        assert_eq!(TelemetryView::new(&metas).kernel_tier(0), None);
    }

    #[test]
    fn shard_meta_publishes_numa_node_and_stage_split() {
        let m = ShardMeta::new("native");
        assert_eq!(m.numa_node(), None, "unset until the backend is built");
        m.set_numa_node(Some(1));
        assert_eq!(m.numa_node(), Some(1));
        m.set_numa_node(None);
        assert_eq!(m.numa_node(), None);
        assert_eq!(m.stage_split().split(), None, "cold until a fused group runs");
        m.stage_split().record(1e-3, 5e-3, 2e-3);
        let metas = [m];
        let v = TelemetryView::new(&metas);
        assert_eq!(v.numa_node(0), None);
        let (g, e, s) = v.stage_split(0).expect("recorded split visible");
        assert!((g - 1e-3).abs() < 1e-12);
        assert!((e - 5e-3).abs() < 1e-12);
        assert!((s - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn routing_selector_parses_and_builds() {
        assert_eq!(Routing::from_cli("round-robin").unwrap(), Routing::RoundRobin);
        assert_eq!(Routing::from_cli("rr").unwrap(), Routing::RoundRobin);
        assert_eq!(Routing::from_cli("queue-depth").unwrap(), Routing::QueueDepth);
        assert_eq!(Routing::from_cli("least-loaded").unwrap(), Routing::QueueDepth);
        assert_eq!(Routing::from_cli("op-affinity").unwrap(), Routing::OpAffinity);
        assert_eq!(Routing::from_cli("measured").unwrap(), Routing::Measured);
        assert!(Routing::from_cli("random").is_err());
        for r in Routing::ALL {
            assert_eq!(r.build().name(), r.name());
        }
        assert_eq!(Routing::default(), Routing::RoundRobin);
    }
}
