//! Pluggable request routing over a (possibly heterogeneous) shard set.
//!
//! The seed hard-coded round-robin submission inside `Handle`. With
//! per-shard [`crate::backend::BackendSpec`]s (e.g. 6 native shards +
//! one `gpusim:nv35` canary) placement becomes a real decision, so it
//! is now a trait: a [`RoutingPolicy`] maps `(op, batch length)` plus
//! the live per-shard state ([`ShardMeta`]: substrate label, queue
//! depth) to a shard index. Three implementations ship, selectable via
//! [`Routing`] from config or `--routing` on the CLI:
//!
//! * [`RoundRobin`] — the seed's behaviour: even spray, no state read;
//! * [`QueueDepth`] — least-loaded: picks the shard with the fewest
//!   in-flight requests (rotating tie-break), so a slow substrate —
//!   the soft-float stream VM, say — naturally receives less work;
//! * [`OpAffinity`] — pins each operator to one home shard
//!   (`op.index() % shards`), keeping per-op state (compiled-artifact
//!   caches, staging buffers sized for that op's arity) hot.
//!
//! Custom policies plug in through
//! [`crate::coordinator::Service::start_with_policy`].

use crate::backend::{Op, ServiceError};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Live, routing-visible state of one shard: which substrate it runs
/// and how many requests it currently has in flight.
#[derive(Debug)]
pub struct ShardMeta {
    label: &'static str,
    depth: AtomicUsize,
}

impl ShardMeta {
    pub(crate) fn new(label: &'static str) -> ShardMeta {
        ShardMeta { label, depth: AtomicUsize::new(0) }
    }

    /// Substrate label of the backend this shard owns ("native",
    /// "gpusim", "xla").
    pub fn label(&self) -> &'static str {
        self.label
    }

    /// Requests submitted to this shard and not yet replied to.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub(crate) fn enter(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn leave(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::Relaxed);
    }
}

/// A shard-placement strategy. Implementations must be cheap — this
/// runs on every submission — and thread-safe (handles are cloned
/// across client threads).
pub trait RoutingPolicy: Send + Sync {
    /// Short policy name for logs/metrics ("round-robin", ...).
    fn name(&self) -> &'static str;

    /// Pick a shard index in `0..shards.len()` for a `len`-element
    /// batch of `op`. `shards` is never empty.
    fn route(&self, op: Op, len: usize, shards: &[ShardMeta]) -> usize;
}

/// Even spray in submission order — the seed's behaviour.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&self, _op: Op, _len: usize, shards: &[ShardMeta]) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % shards.len()
    }
}

/// Least-loaded: the shard with the smallest in-flight count wins;
/// ties rotate so equal shards still share work evenly.
#[derive(Debug, Default)]
pub struct QueueDepth {
    tie: AtomicUsize,
}

impl QueueDepth {
    pub fn new() -> QueueDepth {
        QueueDepth::default()
    }
}

impl RoutingPolicy for QueueDepth {
    fn name(&self) -> &'static str {
        "queue-depth"
    }

    fn route(&self, _op: Op, _len: usize, shards: &[ShardMeta]) -> usize {
        let n = shards.len();
        let start = self.tie.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = shards[start].queue_depth();
        for off in 1..n {
            let i = (start + off) % n;
            let d = shards[i].queue_depth();
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }
}

/// Deterministic per-operator home shard: `op.index() % shards`.
///
/// Every request for a given operator lands on the same shard, so
/// whatever per-op state that shard's backend holds — XLA
/// compiled-artifact caches, gpusim staging buffers sized for the op's
/// arity — stays hot, at the cost of per-op (rather than per-request)
/// load spreading.
#[derive(Debug, Default)]
pub struct OpAffinity;

impl OpAffinity {
    pub fn new() -> OpAffinity {
        OpAffinity
    }

    /// The home shard this policy sends `op` to on a `shards`-wide set.
    pub fn home(op: Op, shards: usize) -> usize {
        op.index() % shards.max(1)
    }
}

impl RoutingPolicy for OpAffinity {
    fn name(&self) -> &'static str {
        "op-affinity"
    }

    fn route(&self, op: Op, _len: usize, shards: &[ShardMeta]) -> usize {
        OpAffinity::home(op, shards.len())
    }
}

/// Config/CLI-level policy selector (the `Clone`-able recipe;
/// [`Routing::build`] materialises the shared policy object).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Routing {
    #[default]
    RoundRobin,
    QueueDepth,
    OpAffinity,
}

impl Routing {
    /// Every built-in policy, in CLI order.
    pub const ALL: [Routing; 3] =
        [Routing::RoundRobin, Routing::QueueDepth, Routing::OpAffinity];

    pub fn name(self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::QueueDepth => "queue-depth",
            Routing::OpAffinity => "op-affinity",
        }
    }

    /// Parse a `--routing` value: `round-robin`/`rr`,
    /// `queue-depth`/`least-loaded`, `op-affinity`/`affinity`.
    pub fn from_cli(name: &str) -> Result<Routing, ServiceError> {
        match name {
            "round-robin" | "rr" => Ok(Routing::RoundRobin),
            "queue-depth" | "least-loaded" => Ok(Routing::QueueDepth),
            "op-affinity" | "affinity" => Ok(Routing::OpAffinity),
            other => Err(ServiceError::Backend(format!(
                "unknown routing policy '{other}' \
                 (try round-robin, queue-depth, op-affinity)"
            ))),
        }
    }

    /// Materialise the policy object handles will share.
    pub fn build(self) -> Arc<dyn RoutingPolicy> {
        match self {
            Routing::RoundRobin => Arc::new(RoundRobin::new()),
            Routing::QueueDepth => Arc::new(QueueDepth::new()),
            Routing::OpAffinity => Arc::new(OpAffinity::new()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas(n: usize) -> Vec<ShardMeta> {
        (0..n).map(|_| ShardMeta::new("native")).collect()
    }

    #[test]
    fn round_robin_cycles() {
        let m = metas(3);
        let p = RoundRobin::new();
        let picks: Vec<usize> = (0..6).map(|_| p.route(Op::Add, 10, &m)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(p.name(), "round-robin");
    }

    #[test]
    fn queue_depth_picks_least_loaded() {
        let m = metas(3);
        m[0].enter();
        m[0].enter();
        m[1].enter();
        // shard 2 is empty: every pick lands there until depths change
        let p = QueueDepth::new();
        for _ in 0..4 {
            assert_eq!(p.route(Op::Add, 10, &m), 2);
        }
        m[2].enter();
        m[2].enter();
        m[2].enter();
        // now shard 1 (depth 1) is the minimum
        assert_eq!(p.route(Op::Add, 10, &m), 1);
        m[1].leave(1);
        assert_eq!(m[1].queue_depth(), 0);
    }

    #[test]
    fn queue_depth_ties_rotate() {
        let m = metas(4);
        let p = QueueDepth::new();
        let picks: Vec<usize> = (0..4).map(|_| p.route(Op::Add, 10, &m)).collect();
        // all depths equal: the rotating start spreads the picks
        assert_eq!(picks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn op_affinity_is_deterministic_and_total() {
        let m = metas(3);
        let p = OpAffinity::new();
        for op in Op::ALL {
            let s = p.route(op, 10, &m);
            assert_eq!(s, op.index() % 3);
            // repeat picks never move
            assert_eq!(p.route(op, 99, &m), s);
        }
        // a 2-shard set still covers both shards across the catalogue
        let m2 = metas(2);
        let picked: std::collections::HashSet<usize> =
            Op::ALL.iter().map(|&op| p.route(op, 1, &m2)).collect();
        assert_eq!(picked.len(), 2);
    }

    #[test]
    fn routing_selector_parses_and_builds() {
        assert_eq!(Routing::from_cli("round-robin").unwrap(), Routing::RoundRobin);
        assert_eq!(Routing::from_cli("rr").unwrap(), Routing::RoundRobin);
        assert_eq!(Routing::from_cli("queue-depth").unwrap(), Routing::QueueDepth);
        assert_eq!(Routing::from_cli("least-loaded").unwrap(), Routing::QueueDepth);
        assert_eq!(Routing::from_cli("op-affinity").unwrap(), Routing::OpAffinity);
        assert!(Routing::from_cli("random").is_err());
        for r in Routing::ALL {
            assert_eq!(r.build().name(), r.name());
        }
        assert_eq!(Routing::default(), Routing::RoundRobin);
    }
}
