//! Coordinator observability: counters + latency summary + the
//! telemetry plane.
//!
//! With sharded dispatch each shard thread owns one `Metrics` (no
//! cross-shard contention on the hot path); [`Snapshot::merged`] folds
//! the per-shard snapshots into the service-wide view.
//!
//! Besides the write-only counter bag, this module owns the **measured
//! telemetry** the routing layer reads live: [`Telemetry`] keeps one
//! [`OpEwma`] cell per operator — an exponentially-weighted moving
//! average of throughput (Melem/s) and group latency, written by the
//! owning shard thread after each executed group and read lock-free
//! (f64 bits in atomics, release-published via the sample count) by every
//! [`crate::coordinator::routing::RoutingPolicy`] on every dispatch.
//! The cells live inside [`crate::coordinator::routing::ShardMeta`], so
//! a policy sees label, queue depth, capability and measured rate in
//! one place.

use crate::backend::Op;
use crate::util::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics, updated by the device thread, read by anyone.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    launches: u64,
    elements: u64,
    padded_elements: u64,
    errors: u64,
    cancelled: u64,
    expired: u64,
    latency: Summary,
}

/// A point-in-time snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub launches: u64,
    pub elements: u64,
    pub padded_elements: u64,
    pub errors: u64,
    /// Requests skipped because the client cancelled the ticket.
    pub cancelled: u64,
    /// Requests skipped because their deadline had already passed when
    /// the shard reached them.
    pub expired: u64,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
    /// Batches that contributed to the latency summary (weights the
    /// mean when merging shard snapshots).
    pub latency_count: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, requests: usize, launches: usize, useful: u64, padded: u64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += requests as u64;
        g.batches += 1;
        g.launches += launches as u64;
        g.elements += useful;
        g.padded_elements += padded;
    }

    pub fn record_latency(&self, seconds: f64) {
        self.inner.lock().unwrap().latency.add(seconds);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record `n` failed requests at once — a failed group must count
    /// one error **per request** so `errors` reconciles against
    /// `requests`.
    pub fn record_errors(&self, n: usize) {
        self.inner.lock().unwrap().errors += n as u64;
    }

    pub fn record_cancelled(&self, n: usize) {
        self.inner.lock().unwrap().cancelled += n as u64;
    }

    pub fn record_expired(&self, n: usize) {
        self.inner.lock().unwrap().expired += n as u64;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            launches: g.launches,
            elements: g.elements,
            padded_elements: g.padded_elements,
            errors: g.errors,
            cancelled: g.cancelled,
            expired: g.expired,
            mean_latency_s: if g.latency.count > 0 { g.latency.mean() } else { 0.0 },
            max_latency_s: if g.latency.count > 0 { g.latency.max } else { 0.0 },
            latency_count: g.latency.count,
        }
    }
}

impl Snapshot {
    /// Fraction of launched lanes that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            return 0.0;
        }
        self.padded_elements as f64 / total as f64
    }

    /// Fold per-shard snapshots into the service-wide view (counters
    /// sum; the latency mean is weighted by each shard's batch count).
    pub fn merged(parts: &[Snapshot]) -> Snapshot {
        let mut total = Snapshot::default();
        let mut weighted = 0.0f64;
        for s in parts {
            total.requests += s.requests;
            total.batches += s.batches;
            total.launches += s.launches;
            total.elements += s.elements;
            total.padded_elements += s.padded_elements;
            total.errors += s.errors;
            total.cancelled += s.cancelled;
            total.expired += s.expired;
            total.latency_count += s.latency_count;
            total.max_latency_s = total.max_latency_s.max(s.max_latency_s);
            weighted += s.mean_latency_s * s.latency_count as f64;
        }
        if total.latency_count > 0 {
            total.mean_latency_s = weighted / total.latency_count as f64;
        }
        total
    }
}

/// EWMA smoothing factor: ~the last four groups dominate, so a shard
/// that speeds up or bogs down is re-weighted within a handful of
/// batches.
const EWMA_ALPHA: f64 = 0.25;

/// One lock-free EWMA cell: measured throughput (Melem/s) and group
/// latency (seconds) for one operator on one shard.
///
/// Written by exactly one shard thread (after each executed group),
/// read by every dispatching client thread; the f64s are stored as
/// bits in atomics and release-published through the sample count —
/// readers may see a value one sample stale, never a torn or
/// un-initialised one.
#[derive(Debug, Default)]
pub struct OpEwma {
    rate_bits: AtomicU64,
    latency_bits: AtomicU64,
    /// Padding-waste fraction of the op's fused groups (padded lanes /
    /// launched lanes) — how well the fusion stage is packing this op.
    waste_bits: AtomicU64,
    samples: AtomicU64,
    /// Groups *routed into execution*, recorded before the backend
    /// runs. Distinct from `samples` so a shard whose backend keeps
    /// failing — or whose slow first group is still in flight — stops
    /// looking "cold" to measured routing and cannot black-hole an
    /// op's traffic.
    attempts: AtomicU64,
}

impl OpEwma {
    fn record(&self, rate: f64, latency: f64, waste: f64) {
        let n = self.samples.load(Ordering::Relaxed);
        let (r, l, w) = if n == 0 {
            (rate, latency, waste)
        } else {
            let prev_r = f64::from_bits(self.rate_bits.load(Ordering::Relaxed));
            let prev_l = f64::from_bits(self.latency_bits.load(Ordering::Relaxed));
            let prev_w = f64::from_bits(self.waste_bits.load(Ordering::Relaxed));
            (
                EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * prev_r,
                EWMA_ALPHA * latency + (1.0 - EWMA_ALPHA) * prev_l,
                EWMA_ALPHA * waste + (1.0 - EWMA_ALPHA) * prev_w,
            )
        };
        self.rate_bits.store(r.to_bits(), Ordering::Relaxed);
        // Release-publish via `samples`: a reader that Acquire-loads a
        // nonzero count is guaranteed to see the bit stores above, so
        // `Some(0.0)` can never be observed on a freshly warmed cell
        self.latency_bits.store(l.to_bits(), Ordering::Relaxed);
        self.waste_bits.store(w.to_bits(), Ordering::Relaxed);
        self.samples.store(n + 1, Ordering::Release);
    }

    fn rate(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.rate_bits.load(Ordering::Relaxed)))
        }
    }

    fn latency(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.latency_bits.load(Ordering::Relaxed)))
        }
    }

    fn waste(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.waste_bits.load(Ordering::Relaxed)))
        }
    }

    fn samples(&self) -> u64 {
        self.samples.load(Ordering::Acquire)
    }

    fn record_attempt(&self) {
        self.attempts.fetch_add(1, Ordering::Relaxed);
    }

    fn attempts(&self) -> u64 {
        self.attempts.load(Ordering::Relaxed)
    }
}

/// Per-shard measured telemetry: one [`OpEwma`] per catalogue operator.
///
/// Lives inside [`crate::coordinator::routing::ShardMeta`]; the shard
/// thread is the only writer, routing policies the readers.
#[derive(Debug)]
pub struct Telemetry {
    cells: [OpEwma; Op::COUNT],
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { cells: std::array::from_fn(|_| OpEwma::default()) }
    }

    /// Record one executed group: `elements` useful lanes served in
    /// `seconds` with `padded` extra lanes launched beyond them (the
    /// fusion stage's pad-to-ladder waste). The rate EWMA counts useful
    /// lanes only — padding shows up in [`Telemetry::waste`], not as
    /// phantom throughput. Degenerate timings (`seconds <= 0`, e.g. a
    /// coarse clock) are dropped rather than poisoning the EWMA with
    /// infinities.
    pub fn record(&self, op: Op, elements: u64, seconds: f64, padded: u64) {
        if seconds <= 0.0 {
            return;
        }
        let rate = elements as f64 / seconds / 1e6;
        let launched = elements + padded;
        let waste = if launched == 0 { 0.0 } else { padded as f64 / launched as f64 };
        self.cells[op.index()].record(rate, seconds, waste);
    }

    /// Measured throughput for `op` in Melem/s; `None` while cold (no
    /// group of `op` has executed on this shard yet).
    pub fn rate(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].rate()
    }

    /// Measured group latency for `op` in seconds; `None` while cold.
    pub fn latency(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].latency()
    }

    /// Measured padding-waste fraction of `op`'s groups (padded lanes /
    /// launched lanes, EWMA); `None` while cold. 0.0 means every launch
    /// was exactly full — the fusion quality signal planning reads.
    pub fn waste(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].waste()
    }

    /// Groups of `op` that have fed this cell.
    pub fn samples(&self, op: Op) -> u64 {
        self.cells[op.index()].samples()
    }

    /// Mark a group of `op` as routed into execution (called by the
    /// shard before the backend runs). A cell with attempts but no
    /// samples is a shard that was tried and never succeeded (or is
    /// mid-first-group) — measured routing skips it instead of
    /// treating it as unexplored.
    pub fn record_attempt(&self, op: Op) {
        self.cells[op.index()].record_attempt();
    }

    /// Groups of `op` routed into execution on this shard (>= samples).
    pub fn attempts(&self, op: Op) -> u64 {
        self.cells[op.index()].attempts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_accumulates() {
        let m = Metrics::new();
        m.record_batch(3, 1, 1000, 24);
        m.record_batch(1, 2, 5000, 0);
        m.record_latency(0.5);
        m.record_latency(1.5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.launches, 3);
        assert_eq!(s.elements, 6000);
        assert_eq!(s.errors, 1);
        assert_eq!(s.mean_latency_s, 1.0);
        assert_eq!(s.max_latency_s, 1.5);
        assert!(s.padding_fraction() > 0.0 && s.padding_fraction() < 0.01);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
    }

    #[test]
    fn merged_sums_counters_and_weights_latency() {
        let a = Metrics::new();
        a.record_batch(3, 1, 1000, 0);
        a.record_latency(1.0);
        let b = Metrics::new();
        b.record_batch(1, 2, 500, 10);
        b.record_latency(2.0);
        b.record_latency(4.0);
        b.record_error();
        let m = Snapshot::merged(&[a.snapshot(), b.snapshot()]);
        assert_eq!(m.requests, 4);
        assert_eq!(m.batches, 2);
        assert_eq!(m.launches, 3);
        assert_eq!(m.elements, 1500);
        assert_eq!(m.padded_elements, 10);
        assert_eq!(m.errors, 1);
        assert_eq!(m.latency_count, 3);
        assert_eq!(m.max_latency_s, 4.0);
        // (1.0*1 + 3.0*2) / 3
        assert!((m.mean_latency_s - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(Snapshot::merged(&[]).requests, 0);
    }

    #[test]
    fn per_request_error_and_lifecycle_counters() {
        let m = Metrics::new();
        // a failed 8-request group records 8 errors, not 1
        m.record_errors(8);
        m.record_error();
        m.record_cancelled(2);
        m.record_expired(3);
        let s = m.snapshot();
        assert_eq!(s.errors, 9);
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.expired, 3);
        let merged = Snapshot::merged(&[s.clone(), s]);
        assert_eq!(merged.errors, 18);
        assert_eq!(merged.cancelled, 4);
        assert_eq!(merged.expired, 6);
    }

    #[test]
    fn telemetry_is_cold_until_first_sample() {
        let t = Telemetry::new();
        for op in Op::ALL {
            assert_eq!(t.rate(op), None);
            assert_eq!(t.latency(op), None);
            assert_eq!(t.waste(op), None);
            assert_eq!(t.samples(op), 0);
        }
        t.record(Op::Mul22, 1_000_000, 0.5, 0); // 2 Melem/s
        assert_eq!(t.samples(Op::Mul22), 1);
        assert!((t.rate(Op::Mul22).unwrap() - 2.0).abs() < 1e-12);
        assert!((t.latency(Op::Mul22).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(t.waste(Op::Mul22), Some(0.0));
        // other ops stay cold
        assert_eq!(t.rate(Op::Add22), None);
    }

    #[test]
    fn telemetry_ewma_tracks_recent_samples() {
        let t = Telemetry::new();
        t.record(Op::Add22, 1_000_000, 1.0, 0); // 1 Melem/s
        for _ in 0..40 {
            t.record(Op::Add22, 9_000_000, 1.0, 0); // 9 Melem/s
        }
        let r = t.rate(Op::Add22).unwrap();
        // converged towards the recent rate, clear of the first sample
        assert!(r > 8.5 && r <= 9.0, "rate={r}");
        assert_eq!(t.samples(Op::Add22), 41);
    }

    #[test]
    fn telemetry_waste_tracks_padding_not_throughput() {
        let t = Telemetry::new();
        // 3000 useful lanes, 1096 padded: waste 1096/4096, and the
        // rate counts the 3000 useful lanes only
        t.record(Op::Div22, 3000, 1e-3, 1096);
        let w = t.waste(Op::Div22).unwrap();
        assert!((w - 1096.0 / 4096.0).abs() < 1e-12, "waste={w}");
        assert!((t.rate(Op::Div22).unwrap() - 3.0).abs() < 1e-12);
        // exactly-full launches pull the EWMA towards zero
        for _ in 0..40 {
            t.record(Op::Div22, 4096, 1e-3, 0);
        }
        assert!(t.waste(Op::Div22).unwrap() < 0.01);
    }

    #[test]
    fn attempts_track_tries_independently_of_success() {
        let t = Telemetry::new();
        assert_eq!(t.attempts(Op::Mul22), 0);
        // a failing shard records the attempt but never a sample: it
        // is no longer "cold" yet has no measured rate
        t.record_attempt(Op::Mul22);
        assert_eq!(t.attempts(Op::Mul22), 1);
        assert_eq!(t.samples(Op::Mul22), 0);
        assert_eq!(t.rate(Op::Mul22), None);
        // the shard records every attempt pre-execute, so a success
        // (attempt + sample) keeps attempts == executions, not 2x
        t.record_attempt(Op::Mul22);
        t.record(Op::Mul22, 1_000_000, 1.0, 0);
        assert_eq!(t.attempts(Op::Mul22), 2);
        assert_eq!(t.samples(Op::Mul22), 1);
    }

    #[test]
    fn telemetry_drops_degenerate_timings() {
        let t = Telemetry::new();
        t.record(Op::Add, 1000, 0.0, 0);
        t.record(Op::Add, 1000, -1.0, 0);
        assert_eq!(t.samples(Op::Add), 0);
        assert_eq!(t.rate(Op::Add), None);
    }
}
