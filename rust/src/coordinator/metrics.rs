//! Coordinator observability: counters + latency summary + the
//! telemetry plane.
//!
//! With sharded dispatch each shard thread owns one `Metrics` (no
//! cross-shard contention on the hot path); [`Snapshot::merged`] folds
//! the per-shard snapshots into the service-wide view.
//!
//! Besides the write-only counter bag, this module owns the **measured
//! telemetry** the routing layer reads live: [`Telemetry`] keeps one
//! [`OpEwma`] cell per operator — an exponentially-weighted moving
//! average of throughput (Melem/s) and group latency, written by the
//! owning shard thread after each executed group and read lock-free
//! (f64 bits in atomics, release-published via the sample count) by every
//! [`crate::coordinator::routing::RoutingPolicy`] on every dispatch.
//! The cells live inside [`crate::coordinator::routing::ShardMeta`], so
//! a policy sees label, queue depth, capability and measured rate in
//! one place.
//!
//! The same single-writer/lock-free-reader pattern carries the
//! **accuracy plane**: [`OpAccuracy`] cells aggregate the observatory's
//! per-(model, op) ulp-diff statistics ([`crate::backend::UlpDiff`]) —
//! min/max/mean ulp error, a relative-error EWMA, and the
//! worst-offender lane capture ([`WorstLane`]) — written only by the
//! observatory thread and read by
//! [`crate::coordinator::Service::accuracy_report`].

use crate::backend::{Op, UlpDiff};
use crate::util::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics, updated by the device thread, read by anyone.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    launches: u64,
    elements: u64,
    padded_elements: u64,
    errors: u64,
    cancelled: u64,
    expired: u64,
    pool_dropped: u64,
    latency: Summary,
}

/// A point-in-time snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub launches: u64,
    pub elements: u64,
    pub padded_elements: u64,
    pub errors: u64,
    /// Requests skipped because the client cancelled the ticket.
    pub cancelled: u64,
    /// Requests skipped because their deadline had already passed when
    /// the shard reached them.
    pub expired: u64,
    /// Staging buffers dropped by byte-capped free lists (shard pool +
    /// worker arenas) instead of being retained.
    pub pool_dropped: u64,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
    /// Batches that contributed to the latency summary (weights the
    /// mean when merging shard snapshots).
    pub latency_count: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, requests: usize, launches: usize, useful: u64, padded: u64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += requests as u64;
        g.batches += 1;
        g.launches += launches as u64;
        g.elements += useful;
        g.padded_elements += padded;
    }

    pub fn record_latency(&self, seconds: f64) {
        self.inner.lock().unwrap().latency.add(seconds);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record `n` failed requests at once — a failed group must count
    /// one error **per request** so `errors` reconciles against
    /// `requests`.
    pub fn record_errors(&self, n: usize) {
        self.inner.lock().unwrap().errors += n as u64;
    }

    pub fn record_cancelled(&self, n: usize) {
        self.inner.lock().unwrap().cancelled += n as u64;
    }

    pub fn record_expired(&self, n: usize) {
        self.inner.lock().unwrap().expired += n as u64;
    }

    /// `n` buffers dropped on free-list overflow since last recorded.
    pub fn record_pool_dropped(&self, n: u64) {
        self.inner.lock().unwrap().pool_dropped += n;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            launches: g.launches,
            elements: g.elements,
            padded_elements: g.padded_elements,
            errors: g.errors,
            cancelled: g.cancelled,
            expired: g.expired,
            pool_dropped: g.pool_dropped,
            mean_latency_s: if g.latency.count > 0 { g.latency.mean() } else { 0.0 },
            max_latency_s: if g.latency.count > 0 { g.latency.max } else { 0.0 },
            latency_count: g.latency.count,
        }
    }
}

/// Per-tenant dispatch attribution: how much work each tenant pushed
/// through a [`crate::coordinator::Handle`], and how often the serving
/// surface pushed back.
///
/// Written by [`crate::coordinator::Handle::dispatch_tagged`] and by
/// the wire front end's admission/shed rejections
/// ([`crate::net::WireServer`]); snapshotted by
/// [`crate::coordinator::Service::tenant_metrics`] and shipped in the
/// wire `Status` frame. One `Mutex`-guarded map: tenant attribution is
/// off the per-shard hot path (it ticks once per dispatch, not per
/// lane), so a lock is fine where the routing telemetry needed
/// atomics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Requests this tenant dispatched into the shard set.
    pub requests: u64,
    /// Total lanes across those requests.
    pub lanes: u64,
    /// Requests rejected by telemetry-driven load shedding.
    pub shed: u64,
    /// Requests rejected by token-bucket admission (rate or in-flight
    /// byte budget).
    pub denied: u64,
}

/// The ledger of [`TenantCounters`] per tenant name.
#[derive(Debug, Default)]
pub struct TenantLedger {
    tenants: Mutex<std::collections::BTreeMap<String, TenantCounters>>,
}

impl TenantLedger {
    pub fn new() -> TenantLedger {
        TenantLedger::default()
    }

    fn with<F: FnOnce(&mut TenantCounters)>(&self, tenant: &str, f: F) {
        let mut g = self.tenants.lock().unwrap();
        f(g.entry(tenant.to_string()).or_default());
    }

    /// One request of `lanes` lanes dispatched for `tenant`.
    pub fn record_dispatch(&self, tenant: &str, lanes: u64) {
        self.with(tenant, |c| {
            c.requests += 1;
            c.lanes += lanes;
        });
    }

    /// One request rejected by load shedding.
    pub fn record_shed(&self, tenant: &str) {
        self.with(tenant, |c| c.shed += 1);
    }

    /// One request rejected by token-bucket admission.
    pub fn record_denied(&self, tenant: &str) {
        self.with(tenant, |c| c.denied += 1);
    }

    /// Point-in-time copy of every tenant's counters.
    pub fn snapshot(&self) -> std::collections::BTreeMap<String, TenantCounters> {
        self.tenants.lock().unwrap().clone()
    }
}

impl Snapshot {
    /// Fraction of launched lanes that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            return 0.0;
        }
        self.padded_elements as f64 / total as f64
    }

    /// Fold per-shard snapshots into the service-wide view (counters
    /// sum; the latency mean is weighted by each shard's batch count).
    pub fn merged(parts: &[Snapshot]) -> Snapshot {
        let mut total = Snapshot::default();
        let mut weighted = 0.0f64;
        for s in parts {
            total.requests += s.requests;
            total.batches += s.batches;
            total.launches += s.launches;
            total.elements += s.elements;
            total.padded_elements += s.padded_elements;
            total.errors += s.errors;
            total.cancelled += s.cancelled;
            total.expired += s.expired;
            total.pool_dropped += s.pool_dropped;
            total.latency_count += s.latency_count;
            total.max_latency_s = total.max_latency_s.max(s.max_latency_s);
            weighted += s.mean_latency_s * s.latency_count as f64;
        }
        if total.latency_count > 0 {
            total.mean_latency_s = weighted / total.latency_count as f64;
        }
        total
    }
}

/// EWMA smoothing factor: ~the last four groups dominate, so a shard
/// that speeds up or bogs down is re-weighted within a handful of
/// batches.
const EWMA_ALPHA: f64 = 0.25;

/// One lock-free EWMA cell: measured throughput (Melem/s) and group
/// latency (seconds) for one operator on one shard.
///
/// Written by exactly one shard thread (after each executed group),
/// read by every dispatching client thread; the f64s are stored as
/// bits in atomics and release-published through the sample count —
/// readers may see a value one sample stale, never a torn or
/// un-initialised one.
#[derive(Debug, Default)]
pub struct OpEwma {
    rate_bits: AtomicU64,
    latency_bits: AtomicU64,
    /// Padding-waste fraction of the op's fused groups (padded lanes /
    /// launched lanes) — how well the fusion stage is packing this op.
    waste_bits: AtomicU64,
    samples: AtomicU64,
    /// Groups *routed into execution*, recorded before the backend
    /// runs. Distinct from `samples` so a shard whose backend keeps
    /// failing — or whose slow first group is still in flight — stops
    /// looking "cold" to measured routing and cannot black-hole an
    /// op's traffic.
    attempts: AtomicU64,
}

impl OpEwma {
    fn record(&self, rate: f64, latency: f64, waste: f64) {
        let n = self.samples.load(Ordering::Relaxed);
        let (r, l, w) = if n == 0 {
            (rate, latency, waste)
        } else {
            let prev_r = f64::from_bits(self.rate_bits.load(Ordering::Relaxed));
            let prev_l = f64::from_bits(self.latency_bits.load(Ordering::Relaxed));
            let prev_w = f64::from_bits(self.waste_bits.load(Ordering::Relaxed));
            (
                EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * prev_r,
                EWMA_ALPHA * latency + (1.0 - EWMA_ALPHA) * prev_l,
                EWMA_ALPHA * waste + (1.0 - EWMA_ALPHA) * prev_w,
            )
        };
        self.rate_bits.store(r.to_bits(), Ordering::Relaxed);
        // Release-publish via `samples`: a reader that Acquire-loads a
        // nonzero count is guaranteed to see the bit stores above, so
        // `Some(0.0)` can never be observed on a freshly warmed cell
        self.latency_bits.store(l.to_bits(), Ordering::Relaxed);
        self.waste_bits.store(w.to_bits(), Ordering::Relaxed);
        self.samples.store(n + 1, Ordering::Release);
    }

    fn rate(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.rate_bits.load(Ordering::Relaxed)))
        }
    }

    fn latency(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.latency_bits.load(Ordering::Relaxed)))
        }
    }

    fn waste(&self) -> Option<f64> {
        if self.samples.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.waste_bits.load(Ordering::Relaxed)))
        }
    }

    fn samples(&self) -> u64 {
        self.samples.load(Ordering::Acquire)
    }

    fn record_attempt(&self) {
        self.attempts.fetch_add(1, Ordering::Relaxed);
    }

    fn attempts(&self) -> u64 {
        self.attempts.load(Ordering::Relaxed)
    }
}

/// Per-shard measured telemetry: one [`OpEwma`] per catalogue operator.
///
/// Lives inside [`crate::coordinator::routing::ShardMeta`]; the shard
/// thread is the only writer, routing policies the readers.
#[derive(Debug)]
pub struct Telemetry {
    cells: [OpEwma; Op::COUNT],
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { cells: std::array::from_fn(|_| OpEwma::default()) }
    }

    /// Record one executed group: `elements` useful lanes served in
    /// `seconds` with `padded` extra lanes launched beyond them (the
    /// fusion stage's pad-to-ladder waste). The rate EWMA counts useful
    /// lanes only — padding shows up in [`Telemetry::waste`], not as
    /// phantom throughput. Degenerate timings (`seconds <= 0`, e.g. a
    /// coarse clock) are dropped rather than poisoning the EWMA with
    /// infinities.
    pub fn record(&self, op: Op, elements: u64, seconds: f64, padded: u64) {
        if seconds <= 0.0 {
            return;
        }
        let rate = elements as f64 / seconds / 1e6;
        let launched = elements + padded;
        let waste = if launched == 0 { 0.0 } else { padded as f64 / launched as f64 };
        self.cells[op.index()].record(rate, seconds, waste);
    }

    /// Measured throughput for `op` in Melem/s; `None` while cold (no
    /// group of `op` has executed on this shard yet).
    pub fn rate(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].rate()
    }

    /// Measured group latency for `op` in seconds; `None` while cold.
    pub fn latency(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].latency()
    }

    /// Measured padding-waste fraction of `op`'s groups (padded lanes /
    /// launched lanes, EWMA); `None` while cold. 0.0 means every launch
    /// was exactly full — the fusion quality signal planning reads.
    pub fn waste(&self, op: Op) -> Option<f64> {
        self.cells[op.index()].waste()
    }

    /// Groups of `op` that have fed this cell.
    pub fn samples(&self, op: Op) -> u64 {
        self.cells[op.index()].samples()
    }

    /// Mark a group of `op` as routed into execution (called by the
    /// shard before the backend runs). A cell with attempts but no
    /// samples is a shard that was tried and never succeeded (or is
    /// mid-first-group) — measured routing skips it instead of
    /// treating it as unexplored.
    pub fn record_attempt(&self, op: Op) {
        self.cells[op.index()].record_attempt();
    }

    /// Groups of `op` routed into execution on this shard (>= samples).
    pub fn attempts(&self, op: Op) -> u64 {
        self.cells[op.index()].attempts()
    }
}

/// Data-path stage split of one shard's fused groups: EWMA seconds per
/// group spent gathering launch inputs, executing kernels, and
/// scattering results back to requests.
///
/// Same single-writer/lock-free-reader discipline as [`OpEwma`]: the
/// shard thread records after each fused group, the bits are
/// release-published through the sample count, and readers (bench
/// `data_path` rows, [`crate::coordinator::routing::TelemetryView`])
/// may see a value one group stale, never a torn one. This is the
/// signal that attributes a NUMA win (or loss) to the staging copies
/// rather than the kernels.
#[derive(Debug, Default)]
pub struct StageSplit {
    gather_bits: AtomicU64,
    execute_bits: AtomicU64,
    scatter_bits: AtomicU64,
    samples: AtomicU64,
}

impl StageSplit {
    /// Fold one fused group's stage timings (seconds) into the EWMAs.
    pub fn record(&self, gather: f64, execute: f64, scatter: f64) {
        let n = self.samples.load(Ordering::Relaxed);
        let (g, e, s) = if n == 0 {
            (gather, execute, scatter)
        } else {
            let pg = f64::from_bits(self.gather_bits.load(Ordering::Relaxed));
            let pe = f64::from_bits(self.execute_bits.load(Ordering::Relaxed));
            let ps = f64::from_bits(self.scatter_bits.load(Ordering::Relaxed));
            (
                EWMA_ALPHA * gather + (1.0 - EWMA_ALPHA) * pg,
                EWMA_ALPHA * execute + (1.0 - EWMA_ALPHA) * pe,
                EWMA_ALPHA * scatter + (1.0 - EWMA_ALPHA) * ps,
            )
        };
        self.gather_bits.store(g.to_bits(), Ordering::Relaxed);
        self.execute_bits.store(e.to_bits(), Ordering::Relaxed);
        self.scatter_bits.store(s.to_bits(), Ordering::Relaxed);
        self.samples.store(n + 1, Ordering::Release);
    }

    /// `(gather, execute, scatter)` EWMA seconds per fused group;
    /// `None` until the first fused group runs.
    pub fn split(&self) -> Option<(f64, f64, f64)> {
        if self.samples.load(Ordering::Acquire) == 0 {
            return None;
        }
        Some((
            f64::from_bits(self.gather_bits.load(Ordering::Relaxed)),
            f64::from_bits(self.execute_bits.load(Ordering::Relaxed)),
            f64::from_bits(self.scatter_bits.load(Ordering::Relaxed)),
        ))
    }

    /// Fused groups folded in.
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Acquire)
    }
}

/// One operator's result-cache counters (monotonic, relaxed — each is
/// an independent tally, no cross-field ordering to publish).
#[derive(Debug, Default)]
struct CacheOpCell {
    hits: AtomicU64,
    misses: AtomicU64,
    /// Concurrent identical misses that attached to an in-flight
    /// leader instead of dispatching (single-flight followers).
    coalesced: AtomicU64,
    inserted_bytes: AtomicU64,
    evictions: AtomicU64,
}

/// Per-op result-cache telemetry: one [`CacheOpCell`] per catalogue
/// operator, owned by [`crate::coordinator::cache::ResultCache`].
///
/// Deliberately separate from [`Telemetry`]: shard EWMAs drive
/// routing, and cache activity must stay invisible there — a hit is
/// work *not* done on any shard.
#[derive(Debug)]
pub struct CacheTelemetry {
    cells: [CacheOpCell; Op::COUNT],
}

impl Default for CacheTelemetry {
    fn default() -> Self {
        CacheTelemetry::new()
    }
}

/// Snapshot of one operator's cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheOpStats {
    pub hits: u64,
    pub misses: u64,
    pub coalesced: u64,
    pub inserted_bytes: u64,
    pub evictions: u64,
}

impl CacheTelemetry {
    pub fn new() -> CacheTelemetry {
        CacheTelemetry { cells: std::array::from_fn(|_| CacheOpCell::default()) }
    }

    pub fn record_hit(&self, op: Op) {
        self.cells[op.index()].hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_miss(&self, op: Op) {
        self.cells[op.index()].misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_coalesced(&self, op: Op) {
        self.cells[op.index()].coalesced.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_insert(&self, op: Op, bytes: u64) {
        self.cells[op.index()].inserted_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_eviction(&self, op: Op) {
        self.cells[op.index()].evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// One operator's counters.
    pub fn op_stats(&self, op: Op) -> CacheOpStats {
        let c = &self.cells[op.index()];
        CacheOpStats {
            hits: c.hits.load(Ordering::Relaxed),
            misses: c.misses.load(Ordering::Relaxed),
            coalesced: c.coalesced.load(Ordering::Relaxed),
            inserted_bytes: c.inserted_bytes.load(Ordering::Relaxed),
            evictions: c.evictions.load(Ordering::Relaxed),
        }
    }

    /// Counters summed across all operators.
    pub fn totals(&self) -> CacheOpStats {
        let mut t = CacheOpStats::default();
        for op in Op::ALL {
            let s = self.op_stats(op);
            t.hits += s.hits;
            t.misses += s.misses;
            t.coalesced += s.coalesced;
            t.inserted_bytes += s.inserted_bytes;
            t.evictions += s.evictions;
        }
        t
    }
}

/// The inputs and outputs of the worst lane one accuracy cell has
/// seen: what the observatory captures so the largest error is
/// reproducible, not just a number.
#[derive(Clone, Debug, PartialEq)]
pub struct WorstLane {
    /// Signed ulp error of the lane.
    pub ulp: f64,
    /// Relative error of the lane (0.0 where the reference was zero).
    pub rel: f64,
    /// The request's input planes at the lane (`n_in` values).
    pub inputs: Vec<f32>,
    /// The observed output words at the lane (`n_out` values).
    pub got: Vec<f32>,
    /// The reference output words at the lane.
    pub reference: Vec<f32>,
}

/// One accuracy cell: cumulative ulp-diff statistics of one operator
/// under one arithmetic model, mirrored from live traffic.
///
/// Same discipline as [`OpEwma`]: exactly one writer (the observatory
/// thread), lock-free readers (f64 bits in atomics, release-published
/// through the lane count). The worst-offender capture sits behind a
/// `Mutex` — it is replaced only when a new maximum appears and read
/// only by reports, never on a hot path.
#[derive(Debug, Default)]
pub struct OpAccuracy {
    lanes: AtomicU64,
    groups: AtomicU64,
    non_finite: AtomicU64,
    min_ulp_bits: AtomicU64,
    max_ulp_bits: AtomicU64,
    sum_abs_ulp_bits: AtomicU64,
    max_rel_bits: AtomicU64,
    rel_ewma_bits: AtomicU64,
    worst: Mutex<Option<WorstLane>>,
}

impl OpAccuracy {
    /// Fold one diffed slice into the cell. `worst` carries the lane
    /// capture for `d.worst_lane` when the caller resolved it; it
    /// replaces the stored offender only if its |ulp| is larger.
    pub fn record(&self, d: &UlpDiff, worst: Option<WorstLane>) {
        self.non_finite.fetch_add(d.non_finite, Ordering::Relaxed);
        if d.lanes == 0 {
            return;
        }
        let n = self.lanes.load(Ordering::Relaxed);
        let (min, max, sum, rel_max, rel_ewma) = if n == 0 {
            (d.min_ulp, d.max_ulp, d.sum_abs_ulp, d.max_rel, d.max_rel)
        } else {
            let prev_min = f64::from_bits(self.min_ulp_bits.load(Ordering::Relaxed));
            let prev_max = f64::from_bits(self.max_ulp_bits.load(Ordering::Relaxed));
            let prev_sum =
                f64::from_bits(self.sum_abs_ulp_bits.load(Ordering::Relaxed));
            let prev_rel = f64::from_bits(self.max_rel_bits.load(Ordering::Relaxed));
            let prev_ewma =
                f64::from_bits(self.rel_ewma_bits.load(Ordering::Relaxed));
            (
                prev_min.min(d.min_ulp),
                prev_max.max(d.max_ulp),
                prev_sum + d.sum_abs_ulp,
                prev_rel.max(d.max_rel),
                EWMA_ALPHA * d.max_rel + (1.0 - EWMA_ALPHA) * prev_ewma,
            )
        };
        self.min_ulp_bits.store(min.to_bits(), Ordering::Relaxed);
        self.max_ulp_bits.store(max.to_bits(), Ordering::Relaxed);
        self.sum_abs_ulp_bits.store(sum.to_bits(), Ordering::Relaxed);
        self.max_rel_bits.store(rel_max.to_bits(), Ordering::Relaxed);
        self.rel_ewma_bits.store(rel_ewma.to_bits(), Ordering::Relaxed);
        self.groups.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = worst {
            let mut g = self.worst.lock().unwrap();
            let replace = match g.as_ref() {
                Some(cur) => w.ulp.abs() > cur.ulp.abs(),
                None => true,
            };
            if replace {
                *g = Some(w);
            }
        }
        // release-publish: a reader that sees the new lane count also
        // sees every bit store above
        self.lanes.store(n + d.lanes, Ordering::Release);
    }

    /// Lanes compared so far (0 = cold cell).
    pub fn lanes(&self) -> u64 {
        self.lanes.load(Ordering::Acquire)
    }

    /// Diff groups folded in (what the relative-error EWMA samples).
    pub fn groups(&self) -> u64 {
        self.groups.load(Ordering::Relaxed)
    }

    /// Non-finite lanes excluded from the statistics.
    pub fn non_finite(&self) -> u64 {
        self.non_finite.load(Ordering::Relaxed)
    }

    fn loaded(&self, bits: &AtomicU64) -> Option<f64> {
        if self.lanes.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(bits.load(Ordering::Relaxed)))
        }
    }

    /// Most negative signed ulp error; `None` while cold.
    pub fn min_ulp(&self) -> Option<f64> {
        self.loaded(&self.min_ulp_bits)
    }

    /// Most positive signed ulp error; `None` while cold.
    pub fn max_ulp(&self) -> Option<f64> {
        self.loaded(&self.max_ulp_bits)
    }

    /// Mean |ulp error| over every compared lane; `None` while cold.
    pub fn mean_abs_ulp(&self) -> Option<f64> {
        let lanes = self.lanes();
        if lanes == 0 {
            return None;
        }
        Some(f64::from_bits(self.sum_abs_ulp_bits.load(Ordering::Relaxed)) / lanes as f64)
    }

    /// Largest relative error observed; `None` while cold.
    pub fn max_rel(&self) -> Option<f64> {
        self.loaded(&self.max_rel_bits)
    }

    /// EWMA of per-group max relative error; `None` while cold.
    pub fn rel_ewma(&self) -> Option<f64> {
        self.loaded(&self.rel_ewma_bits)
    }

    /// The captured worst-offender lane, if any group produced one.
    pub fn worst(&self) -> Option<WorstLane> {
        self.worst.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_accumulates() {
        let m = Metrics::new();
        m.record_batch(3, 1, 1000, 24);
        m.record_batch(1, 2, 5000, 0);
        m.record_latency(0.5);
        m.record_latency(1.5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.launches, 3);
        assert_eq!(s.elements, 6000);
        assert_eq!(s.errors, 1);
        assert_eq!(s.mean_latency_s, 1.0);
        assert_eq!(s.max_latency_s, 1.5);
        assert!(s.padding_fraction() > 0.0 && s.padding_fraction() < 0.01);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
    }

    #[test]
    fn merged_sums_counters_and_weights_latency() {
        let a = Metrics::new();
        a.record_batch(3, 1, 1000, 0);
        a.record_latency(1.0);
        let b = Metrics::new();
        b.record_batch(1, 2, 500, 10);
        b.record_latency(2.0);
        b.record_latency(4.0);
        b.record_error();
        let m = Snapshot::merged(&[a.snapshot(), b.snapshot()]);
        assert_eq!(m.requests, 4);
        assert_eq!(m.batches, 2);
        assert_eq!(m.launches, 3);
        assert_eq!(m.elements, 1500);
        assert_eq!(m.padded_elements, 10);
        assert_eq!(m.errors, 1);
        assert_eq!(m.latency_count, 3);
        assert_eq!(m.max_latency_s, 4.0);
        // (1.0*1 + 3.0*2) / 3
        assert!((m.mean_latency_s - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(Snapshot::merged(&[]).requests, 0);
    }

    #[test]
    fn per_request_error_and_lifecycle_counters() {
        let m = Metrics::new();
        // a failed 8-request group records 8 errors, not 1
        m.record_errors(8);
        m.record_error();
        m.record_cancelled(2);
        m.record_expired(3);
        let s = m.snapshot();
        assert_eq!(s.errors, 9);
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.expired, 3);
        let merged = Snapshot::merged(&[s.clone(), s]);
        assert_eq!(merged.errors, 18);
        assert_eq!(merged.cancelled, 4);
        assert_eq!(merged.expired, 6);
    }

    #[test]
    fn telemetry_is_cold_until_first_sample() {
        let t = Telemetry::new();
        for op in Op::ALL {
            assert_eq!(t.rate(op), None);
            assert_eq!(t.latency(op), None);
            assert_eq!(t.waste(op), None);
            assert_eq!(t.samples(op), 0);
        }
        t.record(Op::Mul22, 1_000_000, 0.5, 0); // 2 Melem/s
        assert_eq!(t.samples(Op::Mul22), 1);
        assert!((t.rate(Op::Mul22).unwrap() - 2.0).abs() < 1e-12);
        assert!((t.latency(Op::Mul22).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(t.waste(Op::Mul22), Some(0.0));
        // other ops stay cold
        assert_eq!(t.rate(Op::Add22), None);
    }

    #[test]
    fn telemetry_ewma_tracks_recent_samples() {
        let t = Telemetry::new();
        t.record(Op::Add22, 1_000_000, 1.0, 0); // 1 Melem/s
        for _ in 0..40 {
            t.record(Op::Add22, 9_000_000, 1.0, 0); // 9 Melem/s
        }
        let r = t.rate(Op::Add22).unwrap();
        // converged towards the recent rate, clear of the first sample
        assert!(r > 8.5 && r <= 9.0, "rate={r}");
        assert_eq!(t.samples(Op::Add22), 41);
    }

    #[test]
    fn telemetry_waste_tracks_padding_not_throughput() {
        let t = Telemetry::new();
        // 3000 useful lanes, 1096 padded: waste 1096/4096, and the
        // rate counts the 3000 useful lanes only
        t.record(Op::Div22, 3000, 1e-3, 1096);
        let w = t.waste(Op::Div22).unwrap();
        assert!((w - 1096.0 / 4096.0).abs() < 1e-12, "waste={w}");
        assert!((t.rate(Op::Div22).unwrap() - 3.0).abs() < 1e-12);
        // exactly-full launches pull the EWMA towards zero
        for _ in 0..40 {
            t.record(Op::Div22, 4096, 1e-3, 0);
        }
        assert!(t.waste(Op::Div22).unwrap() < 0.01);
    }

    #[test]
    fn attempts_track_tries_independently_of_success() {
        let t = Telemetry::new();
        assert_eq!(t.attempts(Op::Mul22), 0);
        // a failing shard records the attempt but never a sample: it
        // is no longer "cold" yet has no measured rate
        t.record_attempt(Op::Mul22);
        assert_eq!(t.attempts(Op::Mul22), 1);
        assert_eq!(t.samples(Op::Mul22), 0);
        assert_eq!(t.rate(Op::Mul22), None);
        // the shard records every attempt pre-execute, so a success
        // (attempt + sample) keeps attempts == executions, not 2x
        t.record_attempt(Op::Mul22);
        t.record(Op::Mul22, 1_000_000, 1.0, 0);
        assert_eq!(t.attempts(Op::Mul22), 2);
        assert_eq!(t.samples(Op::Mul22), 1);
    }

    #[test]
    fn stage_split_is_cold_then_tracks_recent_groups() {
        let s = StageSplit::default();
        assert_eq!(s.split(), None);
        assert_eq!(s.samples(), 0);
        s.record(0.010, 0.080, 0.005);
        let (g, e, sc) = s.split().unwrap();
        assert!((g - 0.010).abs() < 1e-12);
        assert!((e - 0.080).abs() < 1e-12);
        assert!((sc - 0.005).abs() < 1e-12);
        // converges to the recent split, clear of the seed
        for _ in 0..40 {
            s.record(0.001, 0.100, 0.002);
        }
        let (g, e, sc) = s.split().unwrap();
        assert!(g < 0.002, "gather={g}");
        assert!(e > 0.095, "execute={e}");
        assert!(sc < 0.003, "scatter={sc}");
        assert_eq!(s.samples(), 41);
    }

    #[test]
    fn pool_drop_counter_accumulates_and_merges() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().pool_dropped, 0);
        m.record_pool_dropped(3);
        m.record_pool_dropped(2);
        let s = m.snapshot();
        assert_eq!(s.pool_dropped, 5);
        assert_eq!(Snapshot::merged(&[s.clone(), s]).pool_dropped, 10);
    }

    #[test]
    fn telemetry_drops_degenerate_timings() {
        let t = Telemetry::new();
        t.record(Op::Add, 1000, 0.0, 0);
        t.record(Op::Add, 1000, -1.0, 0);
        assert_eq!(t.samples(Op::Add), 0);
        assert_eq!(t.rate(Op::Add), None);
    }

    fn diff(lanes: u64, min: f64, max: f64, sum_abs: f64, rel: f64) -> UlpDiff {
        UlpDiff {
            lanes,
            min_ulp: min,
            max_ulp: max,
            sum_abs_ulp: sum_abs,
            max_rel: rel,
            ..UlpDiff::default()
        }
    }

    #[test]
    fn accuracy_cell_is_cold_until_first_group() {
        let c = OpAccuracy::default();
        assert_eq!(c.lanes(), 0);
        assert_eq!(c.max_ulp(), None);
        assert_eq!(c.min_ulp(), None);
        assert_eq!(c.mean_abs_ulp(), None);
        assert_eq!(c.max_rel(), None);
        assert_eq!(c.rel_ewma(), None);
        assert!(c.worst().is_none());
        // a diff with no compared lanes keeps the cell cold
        c.record(&diff(0, 0.0, 0.0, 0.0, 0.0), None);
        assert_eq!(c.lanes(), 0);
        assert_eq!(c.max_ulp(), None);
    }

    #[test]
    fn accuracy_cell_merges_intervals_and_means() {
        let c = OpAccuracy::default();
        c.record(&diff(100, -0.5, 0.25, 10.0, 1e-8), None);
        c.record(&diff(300, -0.1, 0.75, 30.0, 4e-9), None);
        assert_eq!(c.lanes(), 400);
        assert_eq!(c.groups(), 2);
        assert_eq!(c.min_ulp(), Some(-0.5));
        assert_eq!(c.max_ulp(), Some(0.75));
        assert_eq!(c.mean_abs_ulp(), Some(0.1));
        assert_eq!(c.max_rel(), Some(1e-8));
        // EWMA seeded on the first group, pulled towards the second
        let e = c.rel_ewma().unwrap();
        assert!(e < 1e-8 && e > 4e-9, "e={e}");
    }

    #[test]
    fn accuracy_worst_offender_only_grows() {
        let c = OpAccuracy::default();
        let big = WorstLane {
            ulp: -2.5,
            rel: 1e-7,
            inputs: vec![1.0, 2.0],
            got: vec![3.0],
            reference: vec![3.5],
        };
        c.record(&diff(1, -2.5, 0.0, 2.5, 1e-7), Some(big.clone()));
        let small = WorstLane { ulp: 0.5, ..big.clone() };
        c.record(&diff(1, 0.0, 0.5, 0.5, 1e-9), Some(small));
        // the smaller-|ulp| capture must not displace the offender
        assert_eq!(c.worst(), Some(big));
        assert_eq!(c.non_finite(), 0);
        c.record(&diff(0, 0.0, 0.0, 0.0, 0.0), None);
        assert_eq!(c.worst().unwrap().ulp, -2.5);
    }

    #[test]
    fn tenant_ledger_attributes_per_tenant() {
        let l = TenantLedger::new();
        l.record_dispatch("alice", 4096);
        l.record_dispatch("alice", 1024);
        l.record_dispatch("bob", 512);
        l.record_shed("bob");
        l.record_denied("carol");
        let snap = l.snapshot();
        assert_eq!(
            snap["alice"],
            TenantCounters { requests: 2, lanes: 5120, shed: 0, denied: 0 }
        );
        assert_eq!(snap["bob"], TenantCounters { requests: 1, lanes: 512, shed: 1, denied: 0 });
        assert_eq!(snap["carol"], TenantCounters { requests: 0, lanes: 0, shed: 0, denied: 1 });
        assert_eq!(snap.len(), 3);
    }

    #[test]
    fn cache_telemetry_counts_per_op_and_totals() {
        let t = CacheTelemetry::new();
        t.record_miss(Op::Add22);
        t.record_insert(Op::Add22, 4096);
        t.record_hit(Op::Add22);
        t.record_hit(Op::Add22);
        t.record_coalesced(Op::Add22);
        t.record_miss(Op::Mul22);
        t.record_insert(Op::Mul22, 1024);
        t.record_eviction(Op::Add22);
        let a = t.op_stats(Op::Add22);
        assert_eq!(
            a,
            CacheOpStats { hits: 2, misses: 1, coalesced: 1, inserted_bytes: 4096, evictions: 1 }
        );
        // other ops untouched
        assert_eq!(t.op_stats(Op::Div22), CacheOpStats::default());
        let sum = t.totals();
        assert_eq!(sum.hits, 2);
        assert_eq!(sum.misses, 2);
        assert_eq!(sum.inserted_bytes, 5120);
        assert_eq!(sum.coalesced, 1);
        assert_eq!(sum.evictions, 1);
    }
}
