//! Coordinator observability: counters + latency summary.
//!
//! With sharded dispatch each shard thread owns one `Metrics` (no
//! cross-shard contention on the hot path); [`Snapshot::merged`] folds
//! the per-shard snapshots into the service-wide view.

use crate::util::Summary;
use std::sync::Mutex;

/// Shared metrics, updated by the device thread, read by anyone.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    launches: u64,
    elements: u64,
    padded_elements: u64,
    errors: u64,
    latency: Summary,
}

/// A point-in-time snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub launches: u64,
    pub elements: u64,
    pub padded_elements: u64,
    pub errors: u64,
    pub mean_latency_s: f64,
    pub max_latency_s: f64,
    /// Batches that contributed to the latency summary (weights the
    /// mean when merging shard snapshots).
    pub latency_count: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, requests: usize, launches: usize, useful: u64, padded: u64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += requests as u64;
        g.batches += 1;
        g.launches += launches as u64;
        g.elements += useful;
        g.padded_elements += padded;
    }

    pub fn record_latency(&self, seconds: f64) {
        self.inner.lock().unwrap().latency.add(seconds);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            launches: g.launches,
            elements: g.elements,
            padded_elements: g.padded_elements,
            errors: g.errors,
            mean_latency_s: if g.latency.count > 0 { g.latency.mean() } else { 0.0 },
            max_latency_s: if g.latency.count > 0 { g.latency.max } else { 0.0 },
            latency_count: g.latency.count,
        }
    }
}

impl Snapshot {
    /// Fraction of launched lanes that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.elements + self.padded_elements;
        if total == 0 {
            return 0.0;
        }
        self.padded_elements as f64 / total as f64
    }

    /// Fold per-shard snapshots into the service-wide view (counters
    /// sum; the latency mean is weighted by each shard's batch count).
    pub fn merged(parts: &[Snapshot]) -> Snapshot {
        let mut total = Snapshot::default();
        let mut weighted = 0.0f64;
        for s in parts {
            total.requests += s.requests;
            total.batches += s.batches;
            total.launches += s.launches;
            total.elements += s.elements;
            total.padded_elements += s.padded_elements;
            total.errors += s.errors;
            total.latency_count += s.latency_count;
            total.max_latency_s = total.max_latency_s.max(s.max_latency_s);
            weighted += s.mean_latency_s * s.latency_count as f64;
        }
        if total.latency_count > 0 {
            total.mean_latency_s = weighted / total.latency_count as f64;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_accumulates() {
        let m = Metrics::new();
        m.record_batch(3, 1, 1000, 24);
        m.record_batch(1, 2, 5000, 0);
        m.record_latency(0.5);
        m.record_latency(1.5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.launches, 3);
        assert_eq!(s.elements, 6000);
        assert_eq!(s.errors, 1);
        assert_eq!(s.mean_latency_s, 1.0);
        assert_eq!(s.max_latency_s, 1.5);
        assert!(s.padding_fraction() > 0.0 && s.padding_fraction() < 0.01);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
    }

    #[test]
    fn merged_sums_counters_and_weights_latency() {
        let a = Metrics::new();
        a.record_batch(3, 1, 1000, 0);
        a.record_latency(1.0);
        let b = Metrics::new();
        b.record_batch(1, 2, 500, 10);
        b.record_latency(2.0);
        b.record_latency(4.0);
        b.record_error();
        let m = Snapshot::merged(&[a.snapshot(), b.snapshot()]);
        assert_eq!(m.requests, 4);
        assert_eq!(m.batches, 2);
        assert_eq!(m.launches, 3);
        assert_eq!(m.elements, 1500);
        assert_eq!(m.padded_elements, 10);
        assert_eq!(m.errors, 1);
        assert_eq!(m.latency_count, 3);
        assert_eq!(m.max_latency_s, 4.0);
        // (1.0*1 + 3.0*2) / 3
        assert!((m.mean_latency_s - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(Snapshot::merged(&[]).requests, 0);
    }
}
