//! The coordinator service: N shard threads running the two-stage
//! execution pipeline over the backend layer.
//!
//! Clients hold a cheap cloneable [`Handle`], build typed
//! [`Plan`]s (shape-checked at build time), and
//! [`dispatch`](Handle::dispatch) them; a
//! [`RoutingPolicy`](crate::coordinator::routing::RoutingPolicy)
//! places each request on a shard and the caller gets a future-like
//! [`Ticket`]. Each shard owns one
//! [`crate::backend::KernelBackend`] instance (built *on* the shard
//! thread — PJRT wrapper types are not `Send`), its own
//! [`crate::backend::BufferPool`], and its own [`Metrics`] (no
//! cross-shard contention on the hot path).
//!
//! **The fusion stage.** A shard drains whatever is pending; with a
//! [`ServiceSpec::fuse_window`] armed it then holds the batch open —
//! up to the window past the first arrival — so requests from
//! different clients land in the *same* launch instead of whichever
//! drain happened to catch them. Same-operator requests of any sizes
//! are concatenated and, when a [`ServiceSpec::fuse_sizes`] ladder is
//! configured, packed into padded launches by
//! [`batcher::plan`] (operator-aware pad values:
//! `div22` pads its divisor with ones); outputs are sliced back per
//! request, and each group's padding-waste fraction feeds the shard's
//! per-op telemetry ([`crate::coordinator::metrics::Telemetry::waste`])
//! where measured routing — and `BENCH_coordinator.json` — can see
//! fusion quality.
//!
//! The shard set is described by a [`ServiceSpec`] and may be
//! **heterogeneous**: one [`crate::backend::BackendSpec`] per shard
//! (e.g. `[native, native, gpusim:nv35]` — two workhorses and an
//! arithmetic-model canary).

use super::batcher;
use super::cache::{CacheFill, CacheStats, Decision, ResultCache};
use super::metrics::{Metrics, Snapshot, TenantCounters, TenantLedger};
use super::observatory::{
    self, AccuracyReport, ObsLink, ObsMsg, ObservatorySpec, TicketSet,
};
use super::plan::{Plan, Ticket, TicketState};
use super::request::OpRequest;
use super::routing::{Routing, RoutingPolicy, ShardMeta, TelemetryView};
use super::trace::TraceRecorder;
use crate::backend::{
    fingerprint, BackendSpec, BufferPool, ExecJob, KernelBackend, LaunchOut, NumaMode,
    Op, ServiceError, Topology,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The paper's stream-size grid (Tables 3/4), doubling as the default
/// fusion ladder: `--fuse-window` packs fused batches up to these
/// launch sizes unless the spec configures its own.
pub const PAPER_FUSE_SIZES: [usize; 5] = [4096, 16384, 65536, 262144, 1048576];

/// Slice length for the fuse-window wait: deadlines arm on tickets
/// *after* dispatch, so the window drain re-checks them at least this
/// often instead of sleeping the whole window blind.
const DEADLINE_POLL_SLICE: Duration = Duration::from_millis(1);

/// Service configuration: one [`BackendSpec`] **per shard**, the
/// routing policy that places requests across them, the fusion
/// stage's window/ladder, and (optionally) the accuracy observatory
/// that mirrors a fraction of traffic for continuous Table-2/Table-5
/// style measurement.
#[derive(Clone, Debug)]
pub struct ServiceSpec {
    /// One backend recipe per shard; heterogeneous sets are first-class
    /// (`[native, native, gpusim:nv35]`). Must be non-empty.
    pub shards: Vec<BackendSpec>,
    /// Max requests coalesced into one batch per operator.
    pub max_batch: usize,
    /// Which built-in [`RoutingPolicy`] places requests
    /// ([`Service::start_with_policy`] accepts custom ones).
    pub routing: Routing,
    /// How long a shard holds a batch open past the first arrival so
    /// more same-op requests can fuse into the same launch. Zero (the
    /// default) launches as soon as the queue is drained — the
    /// pre-fusion behaviour. The cost is up to one window of extra
    /// latency on an idle service; the payoff is long packed batches,
    /// the regime the paper's throughput curves reward. The window
    /// never holds a request to (or past) its deadline: once the
    /// tightest pending deadline falls inside the remaining window,
    /// the batch launches immediately with whatever has arrived.
    pub fuse_window: Duration,
    /// Quantised launch sizes for the fusion stage. Fused groups are
    /// packed into padded launches over this ladder by
    /// [`batcher::plan`]; empty (the default) launches each group at
    /// its exact concatenated size with no padding. Sanitised at
    /// [`Service::start`]: zero rungs are dropped and the ladder is
    /// sorted and deduplicated (a zero rung would spin the planner).
    pub fuse_sizes: Vec<usize>,
    /// Arm the accuracy observatory: mirror a fraction of live traffic
    /// onto a native reference plus simulated GPU models and aggregate
    /// per-(model, op) ulp-error statistics
    /// ([`Service::accuracy_report`]). `None` (the default) serves
    /// without observation.
    pub observe: Option<ObservatorySpec>,
    /// Byte budget of the content-addressed result cache in MiB
    /// ([`crate::coordinator::cache`]). 0 (the default) serves without
    /// a cache: every dispatch routes to a shard.
    pub cache_mb: usize,
    /// Let each shard *adapt* its fusion ladder per operator from the
    /// measured padding-waste EWMA ([`batcher::adapt`]): a ladder
    /// that keeps padding gains denser rungs until the waste drains.
    /// Off by default — the static ladder is the paper-faithful grid.
    pub adaptive_ladder: bool,
    /// NUMA placement mode for native shards whose spec leaves the
    /// node unpinned (`BackendSpec::Native { node: None, .. }`).
    /// `None` (the default) reads `FFGPU_NUMA` at start
    /// ([`NumaMode::from_env`]); `Some(mode)` overrides the
    /// environment. Under [`NumaMode::Auto`] unpinned native shards
    /// are assigned round-robin over the host's NUMA nodes
    /// ([`Topology::assign`]) — a clean no-op on single-node hosts.
    /// An explicit per-shard `node` always wins over the mode.
    pub numa: Option<NumaMode>,
    /// Arm a live traffic recorder
    /// ([`crate::coordinator::trace::TraceRecorder`]): every dispatch
    /// is captured at the coordinator boundary — before the cache
    /// lookup, the observatory sampler and the routing policy — so
    /// recording is invisible to shard telemetry, and past its byte
    /// budget the recorder drops instead of blocking. `None` (the
    /// default) serves without recording.
    pub recorder: Option<Arc<TraceRecorder>>,
}

impl Default for ServiceSpec {
    fn default() -> Self {
        ServiceSpec::uniform(BackendSpec::native(), 1)
    }
}

impl ServiceSpec {
    /// `shards` identical shards of `backend` (the seed's shape).
    pub fn uniform(backend: BackendSpec, shards: usize) -> ServiceSpec {
        ServiceSpec {
            shards: vec![backend; shards.max(1)],
            max_batch: 64,
            routing: Routing::default(),
            fuse_window: Duration::ZERO,
            fuse_sizes: Vec::new(),
            observe: None,
            cache_mb: 0,
            adaptive_ladder: false,
            numa: None,
            recorder: None,
        }
    }

    /// One shard per entry of `shards`, in order.
    pub fn heterogeneous(shards: Vec<BackendSpec>) -> ServiceSpec {
        ServiceSpec { shards, ..ServiceSpec::default() }
    }

    pub fn with_routing(mut self, routing: Routing) -> ServiceSpec {
        self.routing = routing;
        self
    }

    pub fn with_max_batch(mut self, max_batch: usize) -> ServiceSpec {
        self.max_batch = max_batch;
        self
    }

    /// Arm the fusion window (see [`ServiceSpec::fuse_window`]).
    pub fn with_fuse_window(mut self, window: Duration) -> ServiceSpec {
        self.fuse_window = window;
        self
    }

    /// Configure the fusion launch-size ladder (ascending; see
    /// [`ServiceSpec::fuse_sizes`]).
    pub fn with_fuse_sizes(mut self, sizes: Vec<usize>) -> ServiceSpec {
        self.fuse_sizes = sizes;
        self
    }

    /// Arm the accuracy observatory (see [`ServiceSpec::observe`] and
    /// [`crate::coordinator::observatory`]). Validated at
    /// [`Service::start`]: unknown model names or an out-of-range
    /// fraction fail startup.
    pub fn with_observatory(mut self, observe: ObservatorySpec) -> ServiceSpec {
        self.observe = Some(observe);
        self
    }

    /// Arm the content-addressed result cache with a `mb`-MiB byte
    /// budget (see [`ServiceSpec::cache_mb`]).
    pub fn with_cache_mb(mut self, mb: usize) -> ServiceSpec {
        self.cache_mb = mb;
        self
    }

    /// Let shards adapt their fusion ladders from measured padding
    /// waste (see [`ServiceSpec::adaptive_ladder`]).
    pub fn with_adaptive_ladder(mut self, on: bool) -> ServiceSpec {
        self.adaptive_ladder = on;
        self
    }

    /// Force the NUMA placement mode (see [`ServiceSpec::numa`]),
    /// overriding `FFGPU_NUMA`.
    pub fn with_numa(mut self, mode: NumaMode) -> ServiceSpec {
        self.numa = Some(mode);
        self
    }

    /// Arm a live traffic recorder (see [`ServiceSpec::recorder`]).
    /// The caller keeps its own `Arc` clone to snapshot the trace
    /// ([`TraceRecorder::trace`]) while the service runs.
    pub fn with_recorder(mut self, recorder: Arc<TraceRecorder>) -> ServiceSpec {
        self.recorder = Some(recorder);
        self
    }

    /// Parse a CLI-style shard list: comma-separated
    /// [`BackendSpec::from_cli`] entries, each optionally repeated with
    /// `*N` — `"native*6,gpusim:nv35"` is six native shards plus one
    /// NV35 canary.
    pub fn from_cli(
        shard_spec: &str, artifacts: &std::path::Path,
    ) -> Result<ServiceSpec, ServiceError> {
        let mut shards = Vec::new();
        for part in shard_spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = match part.split_once('*') {
                Some((n, c)) => {
                    let count = c.parse::<usize>().map_err(|_| {
                        ServiceError::Backend(format!("bad shard count '{c}' in '{part}'"))
                    })?;
                    if count == 0 {
                        // a typo like `native*0` would silently drop the
                        // entry and reroute all traffic to the others
                        return Err(ServiceError::Backend(format!(
                            "zero shard count in '{part}'"
                        )));
                    }
                    (n, count)
                }
                None => (part, 1),
            };
            let spec = BackendSpec::from_cli(name, artifacts)?;
            for _ in 0..count {
                shards.push(spec.clone());
            }
        }
        if shards.is_empty() {
            return Err(ServiceError::Backend(format!(
                "empty shard spec '{shard_spec}'"
            )));
        }
        Ok(ServiceSpec::heterogeneous(shards))
    }
}

/// Per-shard slice of the spec the device thread needs.
#[derive(Clone)]
struct ShardConfig {
    max_batch: usize,
    fuse_window: Duration,
    fuse_sizes: Vec<usize>,
    adaptive_ladder: bool,
}

enum Msg {
    Submit(OpRequest),
    Shutdown,
}

/// Running coordinator; dropping it shuts every shard down.
pub struct Service {
    txs: Vec<mpsc::Sender<Msg>>,
    meta: Arc<Vec<ShardMeta>>,
    policy: Arc<dyn RoutingPolicy>,
    metrics: Vec<Arc<Metrics>>,
    live: Arc<AtomicUsize>,
    joins: Vec<JoinHandle<()>>,
    obs: Option<ObsLink>,
    obs_join: Option<JoinHandle<()>>,
    tenants: Arc<TenantLedger>,
    cache: Option<Arc<ResultCache>>,
    recorder: Option<Arc<TraceRecorder>>,
}

/// Cheap cloneable submission handle; placement is delegated to the
/// service's routing policy.
#[derive(Clone)]
pub struct Handle {
    txs: Vec<mpsc::Sender<Msg>>,
    meta: Arc<Vec<ShardMeta>>,
    policy: Arc<dyn RoutingPolicy>,
    obs: Option<ObsLink>,
    tenants: Arc<TenantLedger>,
    cache: Option<Arc<ResultCache>>,
    recorder: Option<Arc<TraceRecorder>>,
}

impl Handle {
    /// Route and enqueue one request on a shard; the planes are
    /// already `Arc`-shared so fusion, persistent workers — and the
    /// observatory's mirror, which clones the same `Arc`s — never copy
    /// a lane.
    fn submit_to_shard(
        &self, op: Op, inputs: Vec<Arc<Vec<f32>>>, len: usize,
        mut fill: Option<CacheFill>, deadline: Option<Duration>,
    ) -> Result<Ticket, ServiceError> {
        let view = TelemetryView::new(&self.meta);
        let shard = self.policy.route(op, len, &view) % self.txs.len();
        if let Some(f) = fill.as_mut() {
            // attribution only: followers that resolve off this leader
            // report the shard that actually executed
            f.set_shard(shard);
        }
        let (reply, rx) = mpsc::channel();
        let state = Arc::new(TicketState::new());
        // arm the deadline *before* the request enters the shard queue:
        // the shard's lifecycle triage then sees it on first contact, so
        // an already-expired deadline (e.g. a replayed zero-deadline
        // record) is deterministically skipped, never raced
        if let Some(d) = deadline {
            state.set_deadline(d);
        }
        let req = OpRequest { op, inputs, reply, ctrl: state.clone(), fill };
        self.meta[shard].enter();
        if self.txs[shard].send(Msg::Submit(req)).is_err() {
            self.meta[shard].leave(1);
            return Err(ServiceError::QueueClosed);
        }
        Ok(Ticket { rx, op, shard, len, state })
    }

    /// Dispatch a validated [`Plan`]: the routing policy picks a shard,
    /// the request is enqueued (its planes move into `Arc`s so the
    /// fusion stage and persistent backend workers can share them
    /// without copying), and the reply arrives on the returned
    /// [`Ticket`].
    ///
    /// With an observatory armed ([`ServiceSpec::observe`]), a sampled
    /// fraction of dispatches is mirrored onto the observatory's own
    /// backends **after** routing — the mirror is an `Arc`-clone of the
    /// input planes and never touches a shard queue or its telemetry.
    ///
    /// With a result cache armed ([`ServiceSpec::cache_mb`]), the
    /// dispatch is resolved against it *first* — before the observatory
    /// sampler ticks and before the routing policy runs — so hits and
    /// coalesced follows are invisible to both: no queue-depth bump, no
    /// rate-EWMA sample, no mirror. A hit's reply is pre-sent into the
    /// ticket's channel, which preserves the full lifecycle contract
    /// ([`Ticket::wait_timeout`] drains the channel before ruling
    /// expiry, and an explicit [`Ticket::cancel`] still wins) exactly
    /// as if a shard had replied instantly.
    pub fn dispatch(&self, plan: Plan) -> Result<Ticket, ServiceError> {
        self.dispatch_inner(plan, "", None)
    }

    /// The shared dispatch body behind [`Handle::dispatch`],
    /// [`Handle::dispatch_tagged`] and
    /// [`Handle::dispatch_tagged_deadline`].
    ///
    /// With a trace recorder armed ([`ServiceSpec::recorder`]), the
    /// request is logged here — before the cache lookup, before the
    /// sampler ticks and before routing — so the capture is complete
    /// (cache hits are requests too) and provably invisible: the
    /// recorder appends to its own buffer and never touches shard
    /// telemetry, queue depths or the observatory.
    fn dispatch_inner(
        &self, plan: Plan, tenant: &str, deadline: Option<Duration>,
    ) -> Result<Ticket, ServiceError> {
        if let Some(rec) = &self.recorder {
            rec.log(plan.op(), plan.inputs(), tenant, deadline);
        }
        let (op, raw, len) = plan.into_parts();
        let mut fill = None;
        if let Some(cache) = &self.cache {
            let key = fingerprint(op, &raw);
            let (reply, rx) = mpsc::channel();
            let state = Arc::new(TicketState::new());
            if let Some(d) = deadline {
                state.set_deadline(d);
            }
            match cache.begin(op, key, &reply, &state) {
                Decision::Hit { planes, shard } => {
                    let _ = reply.send(Ok(planes.as_ref().clone()));
                    return Ok(Ticket { rx, op, shard, len, state });
                }
                Decision::Follow { shard } => {
                    // the leader's shard resolves this ticket; rx was
                    // attached under the cache's stripe lock
                    return Ok(Ticket { rx, op, shard, len, state });
                }
                Decision::Lead => {
                    fill = Some(CacheFill::new(cache.clone(), op, key));
                }
            }
        }
        let inputs: Vec<Arc<Vec<f32>>> = raw.into_iter().map(Arc::new).collect();
        // sampling ticks per dispatch; the clone is refcount bumps only
        let mirror = match &self.obs {
            Some(o) if o.ctl.sample() => Some(inputs.clone()),
            _ => None,
        };
        let ticket = self.submit_to_shard(op, inputs, len, fill, deadline)?;
        if let (Some(o), Some(planes)) = (&self.obs, mirror) {
            o.send_mirror(op, planes, len, None);
        }
        Ok(ticket)
    }

    /// [`Handle::dispatch`] with **tenant attribution**: the dispatch
    /// is recorded against `tenant` in the service's
    /// [`TenantLedger`] before routing, so multi-tenant front ends
    /// (the wire server tags each connection's tenant here) can
    /// account per-client traffic without wrapping the handle.
    pub fn dispatch_tagged(&self, tenant: &str, plan: Plan) -> Result<Ticket, ServiceError> {
        self.tenants.record_dispatch(tenant, plan.len() as u64);
        self.dispatch_inner(plan, tenant, None)
    }

    /// [`Handle::dispatch_tagged`] with a deadline armed **before**
    /// the request enters a shard queue (measured from dispatch).
    /// Arming early matters twice: the fuse window's tightest-deadline
    /// check sees the bound from the first drain, and an
    /// already-expired deadline (a replayed zero-deadline record) is
    /// deterministically triaged to
    /// [`ServiceError::DeadlineExceeded`] instead of racing the shard.
    /// The wire front end and [`super::trace::replay`] both dispatch
    /// through here; with a recorder armed the deadline is captured in
    /// the trace record.
    pub fn dispatch_tagged_deadline(
        &self, tenant: &str, plan: Plan, deadline: Option<Duration>,
    ) -> Result<Ticket, ServiceError> {
        self.tenants.record_dispatch(tenant, plan.len() as u64);
        self.dispatch_inner(plan, tenant, deadline)
    }

    /// The armed trace recorder, if any ([`ServiceSpec::recorder`]) —
    /// front ends use this to annotate tenants
    /// ([`TraceRecorder::note_class`]) and snapshot the capture.
    pub fn trace_recorder(&self) -> Option<&Arc<TraceRecorder>> {
        self.recorder.as_ref()
    }

    /// The per-tenant attribution ledger (shared with the service).
    /// Front ends record their admission/shed rejections here so
    /// [`Service::tenant_metrics`] reconciles accepted vs pushed-back
    /// traffic per tenant.
    pub fn tenant_ledger(&self) -> &TenantLedger {
        &self.tenants
    }

    /// [`Handle::dispatch`], with the mirror **forced** (regardless of
    /// the sampling fraction) and a per-request verdict: the returned
    /// [`TicketSet`] resolves to both the serving reply and a
    /// [`super::observatory::MirrorReport`] holding one ulp-diff per
    /// observed model over exactly this request's lanes. Fails with
    /// [`ServiceError::Backend`] when no observatory is armed.
    pub fn dispatch_mirrored(&self, plan: Plan) -> Result<TicketSet, ServiceError> {
        let Some(obs) = self.obs.clone() else {
            return Err(ServiceError::Backend(
                "observatory not armed (ServiceSpec::with_observatory / --observe)"
                    .into(),
            ));
        };
        let (op, raw, len) = plan.into_parts();
        let inputs: Vec<Arc<Vec<f32>>> = raw.into_iter().map(Arc::new).collect();
        let mirror_planes = inputs.clone();
        // forced-measurement path: bypass the cache (no lookup, no
        // fill) *and* the trace recorder — mirrored probes are
        // instrumentation, not client traffic, so replaying a trace
        // must not replay them
        let ticket = self.submit_to_shard(op, inputs, len, None, None)?;
        let (rtx, rrx) = mpsc::channel();
        if !obs.send_mirror(op, mirror_planes, len, Some(rtx.clone())) {
            // observatory gone (service shutting down): deliver the
            // "mirror did not run" report so the ticket still resolves
            let _ = rtx.send(super::observatory::MirrorReport {
                op,
                len,
                models: Vec::new(),
            });
        }
        Ok(TicketSet::new(ticket, rrx))
    }

    /// Number of shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// In-flight request count per shard (what queue-depth routing
    /// reads).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.meta.iter().map(ShardMeta::queue_depth).collect()
    }

    /// The live telemetry view routing policies route over — label,
    /// queue depth, per-op capability and measured rates per shard.
    pub fn telemetry(&self) -> TelemetryView<'_> {
        TelemetryView::new(&self.meta)
    }

    /// Aggregate result-cache counters and occupancy; `None` when no
    /// cache is armed ([`ServiceSpec::cache_mb`] = 0).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }
}

impl Service {
    /// Start one device thread per shard of the spec; fails if any
    /// backend refuses to build.
    pub fn start(spec: ServiceSpec) -> Result<Service, ServiceError> {
        let policy = spec.routing.build();
        Service::start_with_policy(spec, policy)
    }

    /// [`Service::start`] with a caller-supplied routing policy — the
    /// plug-in point for policies beyond the built-in [`Routing`] set.
    pub fn start_with_policy(
        spec: ServiceSpec, policy: Arc<dyn RoutingPolicy>,
    ) -> Result<Service, ServiceError> {
        if spec.shards.is_empty() {
            return Err(ServiceError::Backend("empty shard set".into()));
        }
        // fail fast on a bad observatory spec — before any shard thread
        // exists
        if let Some(o) = &spec.observe {
            o.validate()?;
        }
        let observe = spec.observe.clone();
        // sanitise the fusion ladder: a zero rung would make
        // `batcher::plan`'s head loop spin forever on the shard
        // thread, and the planner's contract wants ascending unique
        // sizes. An all-zero ladder degrades to exact-size launches.
        let mut fuse_sizes = spec.fuse_sizes.clone();
        fuse_sizes.retain(|&s| s > 0);
        fuse_sizes.sort_unstable();
        fuse_sizes.dedup();
        let cfg = ShardConfig {
            max_batch: spec.max_batch.max(1),
            fuse_window: spec.fuse_window,
            fuse_sizes,
            adaptive_ladder: spec.adaptive_ladder,
        };
        let cache = (spec.cache_mb > 0)
            .then(|| Arc::new(ResultCache::with_budget(spec.cache_mb << 20)));
        let recorder = spec.recorder.clone();
        // resolve NUMA placement into the per-shard specs, once, here:
        // an explicit per-shard pin wins; unpinned native shards get a
        // node from the mode (round-robin over the host topology under
        // Auto — Topology::assign is None on single-node hosts, so the
        // whole machinery degrades to unpinned where pinning cannot
        // help). Non-native shards never pin.
        let numa = spec.numa.unwrap_or_else(NumaMode::from_env);
        let topo = Topology::detect();
        let mut shard_specs = spec.shards;
        for (shard, s) in shard_specs.iter_mut().enumerate() {
            if let BackendSpec::Native { node, .. } = s {
                if node.is_none() {
                    *node = match numa {
                        NumaMode::Off => None,
                        NumaMode::Node(n) => Some(n),
                        NumaMode::Auto => topo.assign(shard),
                    };
                }
            }
        }
        let shards = shard_specs.len();
        let meta: Arc<Vec<ShardMeta>> =
            Arc::new(shard_specs.iter().map(|s| ShardMeta::new(s.label())).collect());
        let live = Arc::new(AtomicUsize::new(0));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), ServiceError>>();
        let mut txs = Vec::with_capacity(shards);
        let mut metrics = Vec::with_capacity(shards);
        let mut joins = Vec::with_capacity(shards);
        for (shard, backend_spec) in shard_specs.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Msg>();
            let m = Arc::new(Metrics::new());
            let (c2, m2, l2, r2, meta2) =
                (cfg.clone(), m.clone(), live.clone(), ready_tx.clone(), meta.clone());
            let join = std::thread::Builder::new()
                .name(format!("ffgpu-shard-{shard}"))
                .spawn(move || {
                    device_thread(backend_spec, c2, rx, r2, m2, l2, meta2, shard)
                })
                .map_err(|e| {
                    ServiceError::Backend(format!("spawn shard {shard}: {e}"))
                })?;
            txs.push(tx);
            metrics.push(m);
            joins.push(join);
        }
        drop(ready_tx);
        for _ in 0..shards {
            ready_rx
                .recv()
                .map_err(|_| {
                    ServiceError::Backend("device thread died during startup".into())
                })??;
        }
        // the observatory rides beside the shard set: its own thread,
        // its own backends, fed by Arc-clones at dispatch
        let (obs, obs_join) = match observe {
            Some(ospec) => {
                let (tx, rx) = mpsc::channel();
                let ctl = Arc::new(observatory::ObsCtl::new(&ospec));
                let join = observatory::spawn(ospec, ctl.clone(), rx)?;
                (Some(ObsLink { tx, ctl }), Some(join))
            }
            None => (None, None),
        };
        let tenants = Arc::new(TenantLedger::new());
        Ok(Service {
            txs,
            meta,
            policy,
            metrics,
            live,
            joins,
            obs,
            obs_join,
            tenants,
            cache,
            recorder,
        })
    }

    pub fn handle(&self) -> Handle {
        Handle {
            txs: self.txs.clone(),
            meta: self.meta.clone(),
            policy: self.policy.clone(),
            obs: self.obs.clone(),
            tenants: self.tenants.clone(),
            cache: self.cache.clone(),
            recorder: self.recorder.clone(),
        }
    }

    /// Service-wide metrics (all shards merged).
    pub fn metrics(&self) -> Snapshot {
        let parts: Vec<Snapshot> = self.metrics.iter().map(|m| m.snapshot()).collect();
        Snapshot::merged(&parts)
    }

    /// Per-shard snapshots (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Snapshot> {
        self.metrics.iter().map(|m| m.snapshot()).collect()
    }

    /// Substrate label per shard, in shard order.
    pub fn shard_labels(&self) -> Vec<&'static str> {
        self.meta.iter().map(ShardMeta::label).collect()
    }

    /// The live telemetry view (label, queue depth, capability,
    /// measured rates) over the whole shard set.
    pub fn telemetry(&self) -> TelemetryView<'_> {
        TelemetryView::new(&self.meta)
    }

    /// Measured EWMA throughput of `op` on `shard` in Melem/s (`None`
    /// while that cell is cold).
    pub fn measured_rate(&self, shard: usize, op: Op) -> Option<f64> {
        self.meta[shard].telemetry().rate(op)
    }

    /// Measured EWMA padding-waste fraction of `op`'s fused groups on
    /// `shard` (`None` while cold).
    pub fn measured_waste(&self, shard: usize, op: Op) -> Option<f64> {
        self.meta[shard].telemetry().waste(op)
    }

    /// Operators `shard`'s backend declared at spawn
    /// ([`crate::backend::KernelBackend::ops`]).
    pub fn shard_supported_ops(&self, shard: usize) -> Vec<Op> {
        self.meta[shard].supported_ops()
    }

    /// CPU kernel tier per shard, in shard order (`None` on substrates
    /// without tiers — gpusim, XLA).
    pub fn shard_kernel_tiers(&self) -> Vec<Option<crate::backend::KernelTier>> {
        self.meta.iter().map(ShardMeta::kernel_tier).collect()
    }

    /// NUMA node per shard, in shard order (`None` = unpinned: NUMA
    /// off, a single-node host, or a non-native substrate).
    pub fn shard_numa_nodes(&self) -> Vec<Option<usize>> {
        self.meta.iter().map(ShardMeta::numa_node).collect()
    }

    /// Gather/execute/scatter seconds split (EWMA) of `shard`'s fused
    /// groups, `None` before any fused group ran there.
    pub fn shard_stage_split(&self, shard: usize) -> Option<(f64, f64, f64)> {
        self.meta[shard].stage_split().split()
    }

    /// Whether an accuracy observatory rides beside this service.
    pub fn has_observatory(&self) -> bool {
        self.obs.is_some()
    }

    /// Snapshot the observatory's live accuracy surface — per-(model,
    /// op) ulp-error intervals, means, relative-error EWMAs and
    /// worst-offender captures. `None` when no observatory is armed.
    ///
    /// The snapshot is **flushed**: every mirror queued before this
    /// call is folded in before the report is taken (the call blocks
    /// while the observatory catches up).
    pub fn accuracy_report(&self) -> Option<AccuracyReport> {
        let obs = self.obs.as_ref()?;
        let (tx, rx) = mpsc::channel();
        if obs.tx.send(ObsMsg::Flush(tx)).is_ok() {
            // a dead observatory drops the ack sender; fall through to
            // whatever was already recorded
            let _ = rx.recv();
        }
        let mut rep = AccuracyReport::collect(&obs.ctl);
        rep.serving_tiers = self
            .meta
            .iter()
            .map(|m| (m.label().to_string(), m.kernel_tier()))
            .collect();
        Some(rep)
    }

    /// Per-tenant dispatch attribution recorded by the wire front end
    /// (and anything else that routes through
    /// [`Handle::dispatch_tagged`]). Empty until a tagged dispatch or
    /// shed/denial is recorded.
    pub fn tenant_metrics(&self) -> std::collections::BTreeMap<String, TenantCounters> {
        self.tenants.snapshot()
    }

    /// Aggregate result-cache counters and occupancy; `None` when no
    /// cache is armed ([`ServiceSpec::cache_mb`] = 0).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// The armed trace recorder, if any ([`ServiceSpec::recorder`]).
    pub fn trace_recorder(&self) -> Option<&Arc<TraceRecorder>> {
        self.recorder.as_ref()
    }

    /// Name of the active routing policy.
    pub fn routing(&self) -> &'static str {
        self.policy.name()
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    pub fn is_running(&self) -> bool {
        self.live.load(Ordering::Relaxed) > 0
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        self.txs.clear();
        if let Some(obs) = &self.obs {
            let _ = obs.tx.send(ObsMsg::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        if let Some(j) = self.obs_join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn device_thread(
    spec: BackendSpec, cfg: ShardConfig, rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), ServiceError>>, metrics: Arc<Metrics>,
    live: Arc<AtomicUsize>, meta: Arc<Vec<ShardMeta>>, shard: usize,
) {
    // build the substrate on this thread (backends need not be Send)
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // publish the real op catalogue into the routing-visible meta
    // *before* acking: no dispatch can race the placeholder mask
    // because `Service::start` only returns after every shard acks
    meta[shard].set_supports(&backend.ops());
    // same deal for the kernel tier the backend resolved (None on
    // substrates without CPU kernel tiers) — banners and telemetry
    // readers can attribute this shard's Melem/s from the first batch
    meta[shard].set_kernel_tier(backend.kernel_tier());
    // and the NUMA node the spec resolved this shard to (None =
    // unpinned), so telemetry and bench rows can attribute throughput
    // to placement
    meta[shard].set_numa_node(spec.numa_node());
    // count as live *before* acking, so `is_running()` is already true
    // the moment `Service::start` returns
    live.fetch_add(1, Ordering::Relaxed);
    let _ = ready.send(Ok(()));
    let mut pool = BufferPool::new();
    let mut pool_drops_seen = 0u64;

    loop {
        // block for the first message, then drain the queue; with a
        // fuse window armed, keep the batch open for stragglers until
        // the window (measured from the first arrival) closes or the
        // batch fills
        let first = match rx.recv() {
            Ok(Msg::Submit(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let t0 = Instant::now();
        let mut pending: Vec<OpRequest> = vec![first];
        let mut shutdown = false;
        loop {
            while pending.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(Msg::Submit(r)) => pending.push(r),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(_) => break,
                }
            }
            if shutdown || pending.len() >= cfg.max_batch || cfg.fuse_window.is_zero() {
                break;
            }
            let elapsed = t0.elapsed();
            if elapsed >= cfg.fuse_window {
                break;
            }
            let wait = cfg.fuse_window - elapsed;
            // never hold a request to (or past) its deadline: if the
            // tightest pending deadline lands inside the remaining
            // window, launch now so the request still has its whole
            // budget for execution
            if let Some(tightest) =
                pending.iter().filter_map(|r| r.ctrl.remaining()).min()
            {
                if tightest <= wait {
                    break;
                }
            }
            // wait in short slices: deadlines are armed on the ticket
            // *after* dispatch, so a long sleep could miss one — the
            // slice bounds how stale the check above can get
            match rx.recv_timeout(wait.min(DEADLINE_POLL_SLICE)) {
                Ok(Msg::Submit(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                // re-check the window and the deadlines, keep waiting
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // group by operator, preserving arrival order
        let mut groups: Vec<(Op, Vec<OpRequest>)> = Vec::new();
        for r in pending {
            match groups.iter().position(|(op, _)| *op == r.op) {
                Some(i) => groups[i].1.push(r),
                None => groups.push((r.op, vec![r])),
            }
        }
        let mut executed_any = false;
        for (op, reqs) in groups {
            // waste-fed planning: when adaptation is armed, densify the
            // ladder for ops whose measured padding-waste EWMA runs hot
            // (a fresh Vec per group — the EWMA moves batch to batch)
            let adapted: Vec<usize>;
            let ladder: &[usize] = if cfg.adaptive_ladder && !cfg.fuse_sizes.is_empty()
            {
                adapted =
                    batcher::adapt(&cfg.fuse_sizes, meta[shard].telemetry().waste(op));
                &adapted
            } else {
                &cfg.fuse_sizes
            };
            executed_any |= serve_group(
                backend.as_mut(), &mut pool, &metrics, &meta[shard], op, reqs, ladder,
            );
        }
        // triage-only drains (every request cancelled/expired) ran no
        // backend work — logging their ~0 latency would drag the batch
        // mean below any batch that actually executed
        if executed_any {
            metrics.record_latency(t0.elapsed().as_secs_f64());
        }
        // forward free-list overflow drops (shard pool + backend worker
        // arenas, both cumulative) into the shard's metrics as a delta
        let drops = pool.dropped() + backend.stats().arena_dropped;
        if drops > pool_drops_seen {
            metrics.record_pool_dropped(drops - pool_drops_seen);
            pool_drops_seen = drops;
        }
        if shutdown {
            break;
        }
    }
    live.fetch_sub(1, Ordering::Relaxed);
}

/// The fusion stage: execute one operator group as fused launches
/// through the backend trait.
///
/// Cancelled and deadline-expired requests are triaged out *before*
/// the backend runs — a client that gave up never costs substrate
/// time; it gets [`ServiceError::Cancelled`] /
/// [`ServiceError::DeadlineExceeded`] instead.
///
/// Requests of any sizes are concatenated; with a `fuse_sizes` ladder
/// the concatenation is packed into padded launches by
/// [`batcher::plan`] (gathers pad with [`Op::pad_value`], so e.g.
/// `div22` padding lanes divide by one, never by zero), and each
/// launch's outputs are sliced back per request — padding lanes never
/// reach a reply.
///
/// When the backend has a staging crew
/// ([`KernelBackend::staging_workers`] > 1 — the multi-worker native
/// backend), the gather and scatter copies run **on that crew** in
/// parallel, one job per plane / per request range, on the same
/// (possibly node-pinned) threads that execute the kernels; the staged
/// copies mirror the serial loops byte for byte, so replies are
/// bit-identical either way. Otherwise (workers=1, gpusim, XLA) the
/// serial loops below run on the shard thread. Either way the
/// per-stage seconds land in the shard's [`ShardMeta::stage_split`]
/// EWMA.
///
/// The shard's queue depth ([`ShardMeta`]) is decremented *before* the
/// replies go out, so once a client holds its reply the routing
/// policies already see the drained depth. Successful groups feed the
/// shard's per-op telemetry ([`ShardMeta::telemetry`]): throughput
/// counts useful lanes only, and the group's padding-waste fraction
/// lands in the waste EWMA measured routing and planning read.
///
/// Returns whether the backend actually executed (false when triage
/// emptied the group) so the caller can keep no-work drains out of the
/// batch-latency summary.
fn serve_group(
    backend: &mut dyn KernelBackend, pool: &mut BufferPool, metrics: &Metrics,
    meta: &ShardMeta, op: Op, reqs: Vec<OpRequest>, fuse_sizes: &[usize],
) -> bool {
    // lifecycle triage: drop dead requests before burning backend time.
    // Expiry is checked first so a deadline miss is attributed to
    // `expired` even when the client's timed-out wait already marked
    // the shared state cancelled — `cancelled` counts explicit
    // abandonment only.
    let now = Instant::now();
    let mut live = Vec::with_capacity(reqs.len());
    for mut r in reqs {
        if r.ctrl.expired(now) {
            // mark it so a racing client-side wait agrees the request
            // is dead
            r.ctrl.cancel();
            metrics.record_expired(1);
            let _ = r.reply.send(Err(ServiceError::DeadlineExceeded));
            if promote_follower(&mut r, now) {
                // a live single-flight follower takes over leadership:
                // the request stays in the group (and keeps its queue
                // slot — the work is still in flight) with the
                // follower's reply channel and lifecycle state
                live.push(r);
            } else {
                meta.leave(1);
                // dropping `r` drops its unresolved fill (if any),
                // clearing the in-flight cache entry
            }
        } else if r.ctrl.is_cancelled() {
            metrics.record_cancelled(1);
            let _ = r.reply.send(Err(ServiceError::Cancelled));
            if promote_follower(&mut r, now) {
                live.push(r);
            } else {
                meta.leave(1);
            }
        } else {
            live.push(r);
        }
    }
    let mut reqs = live;
    if reqs.is_empty() {
        return false;
    }

    // no per-batch `supports` pre-check: backends return
    // `ServiceError::Unsupported` themselves, and the default
    // `supports` impl allocates a catalogue Vec — not hot-path material
    let (n_in, n_out) = op.arity();

    // fast path: a lone request with no ladder executes straight off
    // its own shared planes (no gather/scatter copies) and its output
    // planes become the reply
    if reqs.len() == 1 && fuse_sizes.is_empty() {
        let n = reqs[0].len();
        let job = match ExecJob::from_shared(op, reqs[0].inputs.clone()) {
            Ok(j) => j,
            Err(e) => {
                meta.leave(1);
                fail_group(metrics, &mut reqs, e);
                return true;
            }
        };
        let mut outs = vec![vec![0.0f32; n]; n_out];
        // attempt recorded pre-execute: a failing or slow shard stops
        // looking cold to measured routing
        meta.telemetry().record_attempt(op);
        let t_exec = Instant::now();
        let result = backend.execute(&job, &mut outs);
        let exec_s = t_exec.elapsed().as_secs_f64();
        meta.leave(1);
        let req = &mut reqs[0];
        match result {
            Ok(rep) => {
                meta.telemetry().record(op, n as u64, exec_s, rep.padded_elements);
                metrics.record_batch(1, rep.launches, n as u64, rep.padded_elements);
                let outs = match req.fill.take() {
                    // cache leader: insert + fan out to followers, then
                    // reply with the (possibly reclaimed) planes
                    Some(mut fill) => fill.complete(outs, exec_s),
                    None => outs,
                };
                let _ = req.reply.send(Ok(outs));
            }
            Err(e) => {
                metrics.record_error();
                if let Some(mut fill) = req.fill.take() {
                    fill.fail(&e);
                }
                let _ = req.reply.send(Err(e));
            }
        }
        return true;
    }

    let refs: Vec<&OpRequest> = reqs.iter().collect();
    let total: usize = refs.iter().map(|r| r.len()).sum();

    // pack the concatenation into launches: exact-size when no ladder
    // is configured, padded ladder launches otherwise
    let launches = if fuse_sizes.is_empty() {
        vec![batcher::Launch { size: total, start: 0, len: total }]
    } else {
        batcher::plan(total, fuse_sizes).expect("non-empty batch over non-empty ladder")
    };

    meta.telemetry().record_attempt(op);
    let t_exec = Instant::now();
    let mut failure: Option<ServiceError> = None;
    let mut launches_done = 0usize;
    let mut padded = 0u64;
    let (mut gather_s, mut execute_s, mut scatter_s) = (0.0f64, 0.0f64, 0.0f64);
    // per-request output accumulators (owned by the replies)
    let mut acc: Vec<Vec<Vec<f32>>>;

    if backend.staging_workers() > 1 {
        // parallel data path: gathers and scatters run on the backend's
        // persistent (and, when placed, node-pinned) worker crew — one
        // job per plane for gathers, contiguous request ranges for
        // scatters. The staged copies are byte-for-byte the serial
        // loops below ([`crate::backend::native::gather_window_into`]
        // mirrors [`batcher::gather_plane_into`]), so outputs stay
        // bit-identical to serial serving.
        let sources: Vec<Vec<Arc<Vec<f32>>>> = (0..n_in)
            .map(|p| refs.iter().map(|r| r.inputs[p].clone()).collect())
            .collect();
        let mut staged: Vec<LaunchOut> = Vec::with_capacity(launches.len());
        for l in &launches {
            let t_g = Instant::now();
            let gathered =
                match backend.stage_gather(op, &sources, l.size, l.start, l.len) {
                    Ok(g) => g,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                };
            gather_s += t_g.elapsed().as_secs_f64();
            let (homes, planes): (Vec<usize>, Vec<Arc<Vec<f32>>>) =
                gathered.into_iter().map(|(w, b)| (w, Arc::new(b))).unzip();
            let job = match ExecJob::from_shared(op, planes) {
                Ok(j) => j,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            let mut outs: Vec<Vec<f32>> = (0..n_out).map(|_| pool.take(l.size)).collect();
            let t_e = Instant::now();
            let result = backend.execute(&job, &mut outs);
            execute_s += t_e.elapsed().as_secs_f64();
            // each gather plane goes home: the workers dropped their
            // Arc clones before reporting, so the unwrap succeeds and
            // the buffer returns to the arena of the worker that
            // faulted its pages in — never to another node's
            for (plane, home) in job.into_inputs().into_iter().zip(homes) {
                if let Ok(buf) = Arc::try_unwrap(plane) {
                    backend.stage_reclaim(home, buf);
                }
            }
            match result {
                Ok(rep) => {
                    launches_done += rep.launches;
                    padded += rep.padded_elements + (l.size - l.len) as u64;
                    staged.push(LaunchOut { start: l.start, len: l.len, outs });
                }
                Err(e) => {
                    for b in outs {
                        pool.put(b);
                    }
                    failure = Some(e);
                }
            }
            if failure.is_some() {
                break;
            }
        }
        acc = Vec::new();
        if failure.is_none() {
            // request spans over the concatenation, in arrival order
            let mut spans = Vec::with_capacity(refs.len());
            let mut off = 0usize;
            for r in &refs {
                spans.push((off, r.len()));
                off += r.len();
            }
            let t_s = Instant::now();
            match backend.stage_scatter(staged, &spans, n_out) {
                Ok((planes, reclaimed)) => {
                    scatter_s += t_s.elapsed().as_secs_f64();
                    acc = planes;
                    for b in reclaimed {
                        pool.put(b);
                    }
                }
                Err(e) => failure = Some(e),
            }
            if failure.is_none() && acc.len() != refs.len() {
                failure =
                    Some(ServiceError::Backend("staged scatter shape mismatch".into()));
            }
        } else {
            for lo in staged {
                for b in lo.outs {
                    pool.put(b);
                }
            }
        }
    } else {
        // serial data path: the workers=1 degenerate case and
        // substrates without a staging crew (gpusim, XLA) — also the
        // baseline the parallel stage is benchmarked against
        acc = refs.iter().map(|r| vec![vec![0.0f32; r.len()]; n_out]).collect();
        for l in &launches {
            // gather this launch's window into pooled, padded planes
            let t_g = Instant::now();
            let mut planes: Vec<Arc<Vec<f32>>> = Vec::with_capacity(n_in);
            for p in 0..n_in {
                let mut buf = pool.take_empty();
                batcher::gather_plane_into(&refs, p, l.size, l.start, l.len, op, &mut buf);
                planes.push(Arc::new(buf));
            }
            gather_s += t_g.elapsed().as_secs_f64();
            let job = match ExecJob::from_shared(op, planes) {
                Ok(j) => j,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            let mut outs: Vec<Vec<f32>> = (0..n_out).map(|_| pool.take(l.size)).collect();
            let t_e = Instant::now();
            let result = backend.execute(&job, &mut outs);
            execute_s += t_e.elapsed().as_secs_f64();
            // reclaim the gather planes: persistent workers dropped
            // their Arc clones before reporting their last chunk, so
            // the unwrap succeeds and the buffers go back to the pool
            for plane in job.into_inputs() {
                if let Ok(buf) = Arc::try_unwrap(plane) {
                    pool.put(buf);
                }
            }
            match result {
                Ok(rep) => {
                    let t_s = Instant::now();
                    batcher::scatter_outputs(&refs, &outs, l.start, l.len, &mut acc);
                    scatter_s += t_s.elapsed().as_secs_f64();
                    launches_done += rep.launches;
                    padded += rep.padded_elements + (l.size - l.len) as u64;
                }
                Err(e) => failure = Some(e),
            }
            for b in outs {
                pool.put(b);
            }
            if failure.is_some() {
                break;
            }
        }
    }
    let exec_s = t_exec.elapsed().as_secs_f64();
    drop(refs);
    meta.leave(reqs.len());

    match failure {
        None => {
            meta.telemetry().record(op, total as u64, exec_s, padded);
            meta.stage_split().record(gather_s, execute_s, scatter_s);
            metrics.record_batch(reqs.len(), launches_done, total as u64, padded);
            for (r, planes) in reqs.iter_mut().zip(acc) {
                let planes = match r.fill.take() {
                    Some(mut fill) => {
                        // the cache's recompute-cost signal: this
                        // request's lane-proportional share of the
                        // group's measured execution time
                        let cost = exec_s * r.len() as f64 / total.max(1) as f64;
                        fill.complete(planes, cost)
                    }
                    None => planes,
                };
                let _ = r.reply.send(Ok(planes));
            }
        }
        Some(e) => {
            fail_group(metrics, &mut reqs, e);
        }
    }
    true
}

/// A dead cache leader hands its in-flight entry to a live parked
/// follower: the follower's reply sender and lifecycle state are
/// substituted into the request, which stays in the group. Dead
/// followers (expired first, then cancelled — same triage order as
/// leaders) get their own verdicts and are skipped. Followers never
/// entered a shard queue, so no queue-depth or shard-metrics
/// accounting applies to them here. Returns false when no live
/// follower exists.
fn promote_follower(r: &mut OpRequest, now: Instant) -> bool {
    let Some(fill) = r.fill.as_ref() else { return false };
    while let Some((tx, ctrl)) = fill.pop_follower() {
        if ctrl.expired(now) {
            ctrl.cancel();
            let _ = tx.send(Err(ServiceError::DeadlineExceeded));
        } else if ctrl.is_cancelled() {
            let _ = tx.send(Err(ServiceError::Cancelled));
        } else {
            r.reply = tx;
            r.ctrl = ctrl;
            return true;
        }
    }
    false
}

fn fail_group(metrics: &Metrics, reqs: &mut [OpRequest], err: ServiceError) {
    // one error per request, not per group — `errors` must reconcile
    // against `requests`
    metrics.record_errors(reqs.len());
    for r in reqs {
        if let Some(mut fill) = r.fill.take() {
            // execution errors are the computation's outcome: followers
            // share them
            fill.fail(&err);
        }
        let _ = r.reply.send(Err(err.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::FF32;
    use crate::util::Rng;

    fn cpu_service() -> Service {
        Service::start(ServiceSpec::default()).unwrap()
    }

    fn add22_planes(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut planes = vec![Vec::with_capacity(n); 4];
        for _ in 0..n {
            let (ah, al) = rng.ff_pair(-8, 8);
            let (bh, bl) = rng.ff_pair(-8, 8);
            planes[0].push(ah);
            planes[1].push(al);
            planes[2].push(bh);
            planes[3].push(bl);
        }
        planes
    }

    fn run(h: &Handle, op: Op, planes: Vec<Vec<f32>>) -> super::super::request::OpResult {
        h.dispatch(Plan::new(op, planes)?)?.wait()
    }

    #[test]
    fn cpu_backend_serves_add22() {
        let svc = cpu_service();
        let h = svc.handle();
        let n = 1000;
        let planes = add22_planes(n, 131);
        let out = run(&h, Op::Add22, planes.clone()).unwrap();
        assert_eq!(out.len(), 2);
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!((out[0][i], out[1][i]), (want.hi, want.lo), "i={i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.elements, n as u64);
    }

    #[test]
    fn plan_validation_rejects_before_dispatch() {
        assert!(matches!(
            Plan::new(Op::Add22, vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0]]),
            Err(ServiceError::RaggedPlanes { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { .. })
        ));
    }

    #[test]
    fn tickets_resolve_out_of_order() {
        let svc = cpu_service();
        let h = svc.handle();
        let mut tickets = Vec::new();
        let mut wants = Vec::new();
        for k in 1..=12u32 {
            let n = 10 * k as usize;
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![k as f32; n];
            wants.push(a.iter().zip(&b).map(|(x, y)| x + y).collect::<Vec<f32>>());
            let plan = Plan::builder(Op::Add).plane(a).plane(b).build().unwrap();
            tickets.push(h.dispatch(plan).unwrap());
        }
        // resolve newest-first: replies are independent of wait order
        for (ticket, want) in tickets.into_iter().zip(wants).rev() {
            assert_eq!(ticket.op(), Op::Add);
            let out = ticket.wait().unwrap();
            assert_eq!(out[0], want);
        }
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let svc = cpu_service();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let n = 100 + t * 13;
                let a: Vec<f32> = (0..n).map(|i| (t * 1000 + i) as f32).collect();
                let b = vec![1.0f32; n];
                let out = run(&h, Op::Add, vec![a.clone(), b]).unwrap();
                for i in 0..n {
                    assert_eq!(out[0][i], a[i] + 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 8);
    }

    #[test]
    fn shutdown_on_drop() {
        let svc = cpu_service();
        let h = svc.handle();
        drop(svc);
        // handle now fails cleanly
        assert_eq!(
            run(&h, Op::Add, vec![vec![1.0], vec![2.0]]).unwrap_err(),
            ServiceError::QueueClosed
        );
    }

    #[test]
    fn sharded_service_spreads_requests() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 4).with_max_batch(16),
        )
        .unwrap();
        assert_eq!(svc.shards(), 4);
        assert_eq!(svc.routing(), "round-robin");
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for round in 0..10usize {
                    let n = 50 + round;
                    let planes = add22_planes(n, t * 100 + round as u64);
                    let out = run(&h, Op::Add22, planes.clone()).unwrap();
                    for i in 0..n {
                        let want = FF32::from_parts(planes[0][i], planes[1][i])
                            + FF32::from_parts(planes[2][i], planes[3][i]);
                        assert_eq!(
                            (out[0][i], out[1][i]),
                            (want.hi, want.lo),
                            "t={t} round={round} i={i}"
                        );
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let per_shard = svc.shard_metrics();
        assert_eq!(per_shard.len(), 4);
        let total: u64 = per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(total, 80);
        // round-robin: every shard saw work
        assert!(
            per_shard.iter().all(|s| s.requests > 0),
            "idle shard: {per_shard:?}"
        );
        assert_eq!(svc.metrics().requests, 80);
        assert_eq!(svc.metrics().errors, 0);
    }

    #[test]
    fn op_affinity_pins_ops_to_home_shards() {
        use super::super::routing::OpAffinity;
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 3)
                .with_routing(Routing::OpAffinity),
        )
        .unwrap();
        assert_eq!(svc.routing(), "op-affinity");
        let h = svc.handle();
        for op in [Op::Add22, Op::Mul22, Op::Add, Op::Mul12] {
            let planes = crate::harness::workload::planes_for(op.name(), 64, 9);
            for _ in 0..3 {
                let t = h.dispatch(Plan::new(op, planes.clone()).unwrap()).unwrap();
                assert_eq!(t.shard(), OpAffinity::home(op, 3), "{op}");
                t.wait().unwrap();
            }
        }
        // all of add22's requests landed on its home shard
        let per_shard = svc.shard_metrics();
        assert!(per_shard[OpAffinity::home(Op::Add22, 3)].requests >= 3);
    }

    #[test]
    fn queue_depths_drain_to_zero() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2)
                .with_routing(Routing::QueueDepth),
        )
        .unwrap();
        let h = svc.handle();
        let mut tickets = Vec::new();
        for k in 0..6 {
            let planes = add22_planes(200, k);
            tickets.push(h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        // every reply received => every shard has replied => depths at 0
        assert_eq!(h.queue_depths(), vec![0, 0]);
        assert_eq!(svc.metrics().requests, 6);
    }

    #[test]
    fn spawn_publishes_capabilities_and_groups_feed_telemetry() {
        let svc = cpu_service();
        let h = svc.handle();
        // the placeholder mask was replaced by the backend's catalogue
        assert_eq!(svc.shard_supported_ops(0), Op::ALL.to_vec());
        assert_eq!(svc.measured_rate(0, Op::Add22), None, "cold before any group");
        run(&h, Op::Add22, add22_planes(2000, 17)).unwrap();
        // the reply channel synchronises the shard's telemetry store
        let rate = svc.measured_rate(0, Op::Add22).expect("warm after a group");
        assert!(rate > 0.0);
        assert_eq!(svc.telemetry().samples(0, Op::Add22), 1);
        // no ladder configured: the group launched at its exact size
        assert_eq!(svc.measured_waste(0, Op::Add22), Some(0.0));
        assert_eq!(svc.measured_rate(0, Op::Mul22), None, "other ops stay cold");
        assert!(svc.telemetry().supports(0, Op::Mul22));
    }

    #[test]
    fn measured_routing_serves_end_to_end() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 3)
                .with_routing(Routing::Measured),
        )
        .unwrap();
        assert_eq!(svc.routing(), "measured");
        let h = svc.handle();
        for k in 0..9 {
            let planes = add22_planes(400, k);
            let out = run(&h, Op::Add22, planes).unwrap();
            assert_eq!(out.len(), 2);
        }
        assert_eq!(svc.metrics().requests, 9);
        assert_eq!(svc.metrics().errors, 0);
        // cold exploration touched every shard at least once
        let touched = (0..3).filter(|&s| svc.measured_rate(s, Op::Add22).is_some()).count();
        assert_eq!(touched, 3, "exploration must seed every shard");
    }

    #[test]
    fn cancelled_ticket_resolves_client_side() {
        let svc = cpu_service();
        let h = svc.handle();
        let t = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0], vec![2.0]]).unwrap())
            .unwrap();
        t.cancel();
        // whether or not the shard already replied, the verdict is
        // Cancelled — the client abandoned the request
        assert_eq!(t.wait(), Err(ServiceError::Cancelled));
    }

    #[test]
    fn generous_deadline_does_not_interfere() {
        let svc = cpu_service();
        let h = svc.handle();
        let t = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap())
            .unwrap()
            .deadline(std::time::Duration::from_secs(60));
        assert_eq!(t.wait().unwrap()[0], vec![4.0, 6.0]);
        assert_eq!(svc.metrics().expired, 0);
    }

    #[test]
    fn heterogeneous_spec_builds_labelled_shards() {
        let svc = Service::start(ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::gpusim_ieee(),
        ]))
        .unwrap();
        assert_eq!(svc.shard_labels(), vec!["native", "gpusim"]);
        // tier attribution: the native shard published a concrete
        // kernel tier before start() returned; gpusim has none
        let tiers = svc.shard_kernel_tiers();
        assert!(tiers[0].is_some(), "native shard must report its tier");
        assert_eq!(tiers[1], None, "gpusim has no CPU kernel tier");
        assert_eq!(svc.telemetry().kernel_tier(0), tiers[0]);
        let out = run(&svc.handle(), Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
    }

    #[test]
    fn empty_shard_set_is_rejected() {
        let err = Service::start(ServiceSpec::heterogeneous(vec![]))
            .err()
            .expect("must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    fn spec_from_cli_parses_heterogeneous_sets() {
        let dir = std::path::Path::new("artifacts");
        let spec = ServiceSpec::from_cli("native*2,gpusim:nv35", dir).unwrap();
        assert_eq!(spec.shards.len(), 3);
        assert_eq!(spec.shards[0].label(), "native");
        assert_eq!(spec.shards[1].label(), "native");
        match &spec.shards[2] {
            BackendSpec::GpuSim { model } => assert_eq!(model, "nv35"),
            other => panic!("{other:?}"),
        }
        // fusion defaults: off until armed
        assert!(spec.fuse_window.is_zero());
        assert!(spec.fuse_sizes.is_empty());
        // cache and adaptive planning default off too
        assert_eq!(spec.cache_mb, 0);
        assert!(!spec.adaptive_ladder);
        assert!(ServiceSpec::from_cli("", dir).is_err());
        assert!(ServiceSpec::from_cli("native*lots", dir).is_err());
        assert!(ServiceSpec::from_cli("native*0,gpusim", dir).is_err());
        assert!(ServiceSpec::from_cli("voodoo", dir).is_err());
    }

    #[test]
    fn gpusim_backend_is_servable() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1).with_max_batch(8),
        )
        .unwrap();
        let h = svc.handle();
        let n = 200;
        let planes = add22_planes(n, 99);
        let out = run(&h, Op::Add22, planes.clone()).unwrap();
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (want.hi.to_bits(), want.lo.to_bits()),
                "i={i}"
            );
        }
    }

    #[test]
    fn bad_backend_spec_fails_startup() {
        let err = Service::start(
            ServiceSpec::uniform(BackendSpec::GpuSim { model: "voodoo2".into() }, 2),
        )
        .err()
        .expect("startup must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    fn fuse_window_coalesces_concurrent_requests() {
        // dispatch a burst while the shard's window holds the first
        // batch open: everything fuses into far fewer launches
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_max_batch(64)
                .with_fuse_window(Duration::from_millis(40)),
        )
        .unwrap();
        let h = svc.handle();
        let mut tickets = Vec::new();
        let mut wants = Vec::new();
        for k in 0..8u64 {
            let n = 40 + 13 * k as usize;
            let planes = add22_planes(n, 0x3A + k);
            wants.push(planes.clone());
            tickets.push(h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap());
        }
        for (t, planes) in tickets.into_iter().zip(wants) {
            let out = t.wait().unwrap();
            for i in 0..planes[0].len() {
                let want = FF32::from_parts(planes[0][i], planes[1][i])
                    + FF32::from_parts(planes[2][i], planes[3][i]);
                assert_eq!((out[0][i], out[1][i]), (want.hi, want.lo), "i={i}");
            }
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 8);
        assert!(
            m.batches < 8,
            "window never fused: {} batches for {} requests",
            m.batches,
            m.requests
        );
    }

    #[test]
    fn fuse_ladder_pads_launches_and_records_waste() {
        // three mixed-size div22 requests fuse and pad up the ladder;
        // answers stay bit-identical to unfused serving and the pad
        // lanes (divisor padded with ones) never reach a reply
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_max_batch(64)
                .with_fuse_window(Duration::from_millis(40))
                .with_fuse_sizes(vec![256, 1024, 4096]),
        )
        .unwrap();
        let plain = Service::start(ServiceSpec::default()).unwrap();
        let h = svc.handle();
        let sizes = [100usize, 200, 300];
        let all: Vec<Vec<Vec<f32>>> = sizes
            .iter()
            .enumerate()
            .map(|(k, &n)| crate::harness::workload::planes_for("div22", n, k as u64))
            .collect();
        let tickets: Vec<Ticket> = all
            .iter()
            .map(|p| h.dispatch(Plan::new(Op::Div22, p.clone()).unwrap()).unwrap())
            .collect();
        for (t, planes) in tickets.into_iter().zip(&all) {
            let got = t.wait().unwrap();
            let want = plain
                .handle()
                .dispatch(Plan::new(Op::Div22, planes.clone()).unwrap())
                .unwrap()
                .wait()
                .unwrap();
            for (pg, pw) in got.iter().zip(&want) {
                for i in 0..pg.len() {
                    assert_eq!(pg[i].to_bits(), pw[i].to_bits(), "lane {i}");
                }
            }
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 3);
        // whatever the grouping, every launch was padded up the ladder
        assert!(m.padded_elements > 0, "ladder never padded: {m:?}");
        assert!(m.padding_fraction() > 0.0);
        let waste = svc.measured_waste(0, Op::Div22).expect("warm after groups");
        assert!(waste > 0.0, "telemetry missed the padding waste");
    }

    #[test]
    fn adaptive_ladder_pads_less_than_static_on_awkward_sizes() {
        // a 6000-lane stream against a 1024/4096/16384/65536 ladder
        // tail-splits to 4096+4096 (26.8% waste) — past the 15%
        // adaptation threshold. With `adaptive_ladder` armed the hot
        // waste EWMA densifies later batches (2560+4096, 9.9%), so the
        // cumulative padding fraction must come out strictly below the
        // static ladder's. Sequential dispatch->wait keeps one request
        // per batch, which makes both plans deterministic.
        let rounds = 6u64;
        let mut fractions = Vec::new();
        for adaptive in [false, true] {
            let mut spec = ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_fuse_window(Duration::from_millis(1))
                .with_fuse_sizes(vec![1024, 4096, 16384, 65536]);
            if adaptive {
                spec = spec.with_adaptive_ladder(true);
            }
            let svc = Service::start(spec).unwrap();
            let h = svc.handle();
            for seed in 0..rounds {
                let planes = crate::harness::workload::planes_for("add22", 6000, seed);
                h.dispatch(Plan::new(Op::Add22, planes).unwrap())
                    .unwrap()
                    .wait()
                    .unwrap();
            }
            // waste metrics for a batch land after its reply is sent
            std::thread::sleep(Duration::from_millis(50));
            fractions.push(svc.metrics().padding_fraction());
        }
        assert!(fractions[0] > 0.15, "static ladder should run hot: {fractions:?}");
        assert!(
            fractions[1] < fractions[0],
            "adaptive ladder must waste less padding than static: {fractions:?}"
        );
    }

    #[test]
    fn fuse_window_never_holds_a_deadline_armed_request() {
        // a window far longer than the deadline: the shard must launch
        // as soon as it notices the deadline instead of fusing the
        // request straight into an expiry
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_fuse_window(Duration::from_millis(400)),
        )
        .unwrap();
        let h = svc.handle();
        let t0 = Instant::now();
        let t = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap())
            .unwrap()
            .deadline(Duration::from_millis(150));
        assert_eq!(t.wait().unwrap()[0], vec![4.0, 6.0]);
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "the window held a deadline-armed request for its full length"
        );
        assert_eq!(svc.metrics().expired, 0);
        assert_eq!(svc.metrics().errors, 0);
    }

    #[test]
    fn degenerate_fuse_ladders_are_sanitised() {
        // a zero rung would spin batcher::plan forever and an unsorted
        // ladder violates its ascending contract; Service::start
        // cleans both, so serving just works
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_fuse_window(Duration::from_millis(5))
                .with_fuse_sizes(vec![0, 4096, 256, 256]),
        )
        .unwrap();
        let out = run(&svc.handle(), Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
        // 2 useful lanes padded up to the 256 rung
        assert_eq!(svc.metrics().padded_elements, 254);
        // an all-zero ladder degrades to exact-size launches
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_fuse_window(Duration::from_millis(5))
                .with_fuse_sizes(vec![0, 0]),
        )
        .unwrap();
        let out = run(&svc.handle(), Op::Add, vec![vec![1.0], vec![2.0]]).unwrap();
        assert_eq!(out[0], vec![3.0]);
        assert_eq!(svc.metrics().padded_elements, 0);
    }

    #[test]
    fn typed_dispatch_covers_the_old_shim_scenarios() {
        // the scenarios the deprecated Handle::submit/call shims used
        // to cover, now first-party: parse boundary, every build-time
        // rejection class, blocking and receiver-style resolution
        assert!(matches!(
            Op::parse("frobnicate"),
            Err(ServiceError::UnknownOp(_))
        ));
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2).with_max_batch(16),
        )
        .unwrap();
        let h = svc.handle();
        let out = run(&h, Op::Add22, add22_planes(50, 7)).unwrap();
        assert_eq!(out.len(), 2);
        assert!(matches!(
            Plan::new(Op::Add22, vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0]]),
            Err(ServiceError::RaggedPlanes { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { .. })
        ));
        // receiver-style resolution (what `submit` used to return)
        let rx = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap())
            .unwrap()
            .into_receiver();
        assert_eq!(rx.recv().unwrap().unwrap()[0], vec![4.0, 6.0]);
    }

    #[test]
    fn parallel_staging_matches_serial_bitwise() {
        // the same mixed-size bursts through a staged (workers: 4) and
        // a serial (workers: 1) shard with a ladder whose rungs
        // straddle the chunk size and lane seams: replies must match
        // bit for bit. The kernels are elementwise, so parity must
        // hold regardless of how the fuse window happens to group each
        // burst — the staged gather/scatter copies are the serial
        // loops, spread over the crew.
        let mk = |workers: usize| {
            Service::start(
                ServiceSpec::uniform(
                    BackendSpec::Native { chunk: 1024, workers, tier: None, node: None },
                    1,
                )
                .with_max_batch(64)
                .with_fuse_window(Duration::from_millis(40))
                .with_fuse_sizes(vec![256, 1024, 4096]),
            )
            .unwrap()
        };
        let staged = mk(4);
        let serial = mk(1);
        let sizes = [100usize, 777, 1024, 2048, 4097];
        for op in [Op::Add22, Op::Div22] {
            let all: Vec<Vec<Vec<f32>>> = sizes
                .iter()
                .enumerate()
                .map(|(k, &n)| {
                    crate::harness::workload::planes_for(op.name(), n, 7 * k as u64 + 1)
                })
                .collect();
            let ts: Vec<Ticket> = all
                .iter()
                .map(|p| {
                    staged.handle().dispatch(Plan::new(op, p.clone()).unwrap()).unwrap()
                })
                .collect();
            let tr: Vec<Ticket> = all
                .iter()
                .map(|p| {
                    serial.handle().dispatch(Plan::new(op, p.clone()).unwrap()).unwrap()
                })
                .collect();
            for (k, (a, b)) in ts.into_iter().zip(tr).enumerate() {
                let oa = a.wait().unwrap();
                let ob = b.wait().unwrap();
                assert_eq!(oa.len(), ob.len());
                for (p, (pa, pb)) in oa.iter().zip(&ob).enumerate() {
                    assert_eq!(pa.len(), pb.len(), "{op} req {k} plane {p}");
                    for i in 0..pa.len() {
                        assert_eq!(
                            pa[i].to_bits(),
                            pb[i].to_bits(),
                            "{op} req {k} plane {p} lane {i}"
                        );
                    }
                }
            }
        }
        assert_eq!(staged.metrics().errors, 0);
        assert_eq!(serial.metrics().errors, 0);
        // both shards recorded a gather/execute/scatter split
        for svc in [&staged, &serial] {
            let (g, e, s) = svc.shard_stage_split(0).expect("split after fused groups");
            assert!(g >= 0.0 && e > 0.0 && s >= 0.0, "split {g}/{e}/{s}");
        }
    }

    #[test]
    fn numa_modes_resolve_shard_placement() {
        // an explicit per-shard pin always wins, even under Off
        let svc = Service::start(
            ServiceSpec::uniform(
                BackendSpec::Native { chunk: 0, workers: 2, tier: None, node: Some(3) },
                1,
            )
            .with_numa(NumaMode::Off),
        )
        .unwrap();
        assert_eq!(svc.shard_numa_nodes(), vec![Some(3)]);
        // a forced Node(0) pins every unpinned native shard there (node
        // 0 always exists — the fallback topology is node 0 = all CPUs)
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2)
                .with_numa(NumaMode::Node(0)),
        )
        .unwrap();
        assert_eq!(svc.shard_numa_nodes(), vec![Some(0), Some(0)]);
        let out = run(&svc.handle(), Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
        // Off leaves everything unpinned
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2)
                .with_numa(NumaMode::Off),
        )
        .unwrap();
        assert_eq!(svc.shard_numa_nodes(), vec![None, None]);
        // Auto round-robins over the host topology; on a single-node
        // (or containerized) host that degrades to a clean no-op —
        // pinned here so CI boxes exercise the degenerate path
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2)
                .with_numa(NumaMode::Auto),
        )
        .unwrap();
        let topo = Topology::detect();
        let want: Vec<Option<usize>> = (0..2).map(|s| topo.assign(s)).collect();
        assert_eq!(svc.shard_numa_nodes(), want);
        if topo.is_single_node() {
            assert_eq!(svc.shard_numa_nodes(), vec![None, None]);
        }
        // non-native substrates never pin, whatever the mode
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1)
                .with_numa(NumaMode::Node(0)),
        )
        .unwrap();
        assert_eq!(svc.shard_numa_nodes(), vec![None]);
    }

    #[test]
    fn cache_hit_serves_bit_identical_without_reexecuting() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1).with_cache_mb(16),
        )
        .unwrap();
        let h = svc.handle();
        let planes = add22_planes(500, 17);
        let cold = run(&h, Op::Add22, planes.clone()).unwrap();
        let warm = run(&h, Op::Add22, planes.clone()).unwrap();
        for p in 0..2 {
            for i in 0..500 {
                assert_eq!(cold[p][i].to_bits(), warm[p][i].to_bits(), "p={p} i={i}");
            }
        }
        // the warm dispatch never reached the shard
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        let s = svc.cache_stats().unwrap();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.live_bytes > 0 && s.live_bytes <= s.budget_bytes);
        // different content is a fresh miss, not a collision hit
        let other = run(&h, Op::Add22, add22_planes(500, 18)).unwrap();
        assert_eq!(other.len(), 2);
        assert_eq!(svc.cache_stats().unwrap().misses, 2);
    }

    #[test]
    fn cache_hit_honors_deadline_and_cancel_semantics() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1).with_cache_mb(16),
        )
        .unwrap();
        let h = svc.handle();
        let planes = add22_planes(64, 3);
        run(&h, Op::Add22, planes.clone()).unwrap(); // warm the cache
        // hit-before-deadline: the pre-sent reply is drained before any
        // expiry verdict, even when the wait happens after the deadline
        // has technically passed
        let t = h
            .dispatch(Plan::new(Op::Add22, planes.clone()).unwrap())
            .unwrap()
            .deadline(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        let out = t.wait().expect("hit reply beats expiry, like any arrived reply");
        assert_eq!(out.len(), 2);
        // cancel-after-hit: explicit cancellation is sticky and wins
        // over the already-delivered reply, exactly as with a shard
        let t = h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap();
        t.cancel();
        assert!(matches!(t.wait(), Err(ServiceError::Cancelled)));
        // both dispatches above were cache hits — shard saw one request
        assert_eq!(svc.metrics().requests, 1);
    }

    #[test]
    fn single_flight_coalesces_concurrent_identical_dispatches() {
        // hold the leader's batch open with a fuse window so identical
        // dispatches from other threads land while it is in flight
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 1)
                .with_cache_mb(16)
                .with_max_batch(64)
                .with_fuse_window(Duration::from_millis(50)),
        )
        .unwrap();
        let h = svc.handle();
        let planes = add22_planes(2000, 23);
        let n_clients = 8;
        let tickets: Vec<Ticket> = (0..n_clients)
            .map(|_| h.dispatch(Plan::new(Op::Add22, planes.clone()).unwrap()).unwrap())
            .collect();
        let mut outs = Vec::new();
        for t in tickets {
            outs.push(t.wait().unwrap());
        }
        for o in &outs[1..] {
            for p in 0..2 {
                for i in 0..2000 {
                    assert_eq!(o[p][i].to_bits(), outs[0][p][i].to_bits());
                }
            }
        }
        // exactly one execution: one attempt on the only shard, one
        // request through its metrics, N-1 coalesced followers
        assert_eq!(svc.telemetry().attempts(0, Op::Add22), 1);
        assert_eq!(svc.metrics().requests, 1);
        let s = svc.cache_stats().unwrap();
        assert_eq!((s.misses, s.coalesced), (1, (n_clients - 1) as u64));
    }

    #[test]
    fn cached_service_survives_mixed_traffic_on_gpusim() {
        // hit outputs must be bit-identical to cold misses on the
        // simulated-GPU substrate too (its arithmetic differs from
        // native — the cache must never cross substrates' results)
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1).with_cache_mb(8),
        )
        .unwrap();
        let h = svc.handle();
        let planes = add22_planes(300, 41);
        let cold = run(&h, Op::Add22, planes.clone()).unwrap();
        let warm = run(&h, Op::Add22, planes).unwrap();
        for p in 0..2 {
            for i in 0..300 {
                assert_eq!(cold[p][i].to_bits(), warm[p][i].to_bits());
            }
        }
        assert_eq!(svc.cache_stats().unwrap().hits, 1);
    }
}
