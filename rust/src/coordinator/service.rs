//! The coordinator service: N shard threads draining batched queues
//! through the backend layer.
//!
//! Clients hold a cheap cloneable [`Handle`] and submit
//! [`OpRequest`]s; requests round-robin over `shards` device threads.
//! Each shard owns one [`crate::backend::KernelBackend`] instance
//! (built *on* the shard thread — PJRT wrapper types are not `Send`),
//! its own [`crate::backend::BufferPool`], and its own
//! [`Metrics`] (no cross-shard contention on the hot path). A shard
//! coalesces whatever is pending (up to `max_batch` requests per
//! operator), gathers the group into pooled planes, executes through
//! `Box<dyn KernelBackend>`, and scatters replies.
//!
//! Which substrate runs is a [`crate::backend::BackendSpec`]: native
//! multicore kernels, the gpusim stream VM (any GPU arithmetic model),
//! or PJRT/XLA artifacts. The seed's two-variant [`Backend`] enum
//! remains as a deprecated shim.

use crate::backend::{self, BackendSpec, BufferPool, KernelBackend, ServiceError};
use super::batcher;
use super::metrics::{Metrics, Snapshot};
use super::request::{OpRequest, OpResult};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// The seed's engine selector, kept as a shim for old call sites.
#[deprecated(note = "use crate::backend::BackendSpec")]
#[derive(Clone, Debug)]
pub enum Backend {
    /// PJRT XLA artifacts from this directory (the "GPU path").
    Xla(PathBuf),
    /// Native rust kernels (the "CPU path" / mock).
    Cpu,
}

#[allow(deprecated)]
impl From<Backend> for BackendSpec {
    fn from(b: Backend) -> BackendSpec {
        match b {
            Backend::Xla(dir) => BackendSpec::Xla { artifacts: dir, precompile: false },
            // the seed's Cpu path was single-threaded; the shim keeps
            // that behaviour so old measurements stay comparable
            Backend::Cpu => BackendSpec::native_single(),
        }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Which substrate each shard builds.
    pub backend: BackendSpec,
    /// Device threads, each owning one backend instance (>= 1).
    pub shards: usize,
    /// Max requests coalesced into one batch per operator.
    pub max_batch: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { backend: BackendSpec::native(), shards: 1, max_batch: 64 }
    }
}

impl ServiceConfig {
    /// Shim constructor for the deprecated [`Backend`] enum.
    #[allow(deprecated)]
    pub fn legacy(backend: Backend) -> ServiceConfig {
        ServiceConfig { backend: backend.into(), ..Default::default() }
    }
}

enum Msg {
    Submit(OpRequest),
    Shutdown,
}

/// Running coordinator; dropping it shuts every shard down.
pub struct Service {
    txs: Vec<mpsc::Sender<Msg>>,
    rr: Arc<AtomicUsize>,
    metrics: Vec<Arc<Metrics>>,
    live: Arc<AtomicUsize>,
    joins: Vec<JoinHandle<()>>,
}

/// Cheap cloneable submission handle (round-robins over shards).
#[derive(Clone)]
pub struct Handle {
    txs: Vec<mpsc::Sender<Msg>>,
    rr: Arc<AtomicUsize>,
}

impl Handle {
    /// Submit and return the reply receiver (async pattern).
    pub fn submit(
        &self, op: &str, inputs: Vec<Vec<f32>>,
    ) -> Result<mpsc::Receiver<OpResult>, ServiceError> {
        let (reply, rx) = mpsc::channel();
        let req = OpRequest { op: op.into(), inputs, reply };
        req.validate()?;
        let shard = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.txs[shard]
            .send(Msg::Submit(req))
            .map_err(|_| ServiceError::QueueClosed)?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, op: &str, inputs: Vec<Vec<f32>>) -> OpResult {
        let rx = self.submit(op, inputs)?;
        rx.recv().map_err(|_| ServiceError::QueueClosed)?
    }

    /// Number of shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

impl Service {
    /// Start `config.shards` device threads; fails if any backend
    /// refuses to build.
    pub fn start(config: ServiceConfig) -> Result<Service, ServiceError> {
        let shards = config.shards.max(1);
        let max_batch = config.max_batch.max(1);
        let live = Arc::new(AtomicUsize::new(0));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), ServiceError>>();
        let mut txs = Vec::with_capacity(shards);
        let mut metrics = Vec::with_capacity(shards);
        let mut joins = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let m = Arc::new(Metrics::new());
            let spec = config.backend.clone();
            let (m2, l2, r2) = (m.clone(), live.clone(), ready_tx.clone());
            let join = std::thread::Builder::new()
                .name(format!("ffgpu-shard-{shard}"))
                .spawn(move || device_thread(spec, max_batch, rx, r2, m2, l2))
                .map_err(|e| {
                    ServiceError::Backend(format!("spawn shard {shard}: {e}"))
                })?;
            txs.push(tx);
            metrics.push(m);
            joins.push(join);
        }
        drop(ready_tx);
        for _ in 0..shards {
            ready_rx
                .recv()
                .map_err(|_| {
                    ServiceError::Backend("device thread died during startup".into())
                })??;
        }
        Ok(Service { txs, rr: Arc::new(AtomicUsize::new(0)), metrics, live, joins })
    }

    pub fn handle(&self) -> Handle {
        Handle { txs: self.txs.clone(), rr: self.rr.clone() }
    }

    /// Service-wide metrics (all shards merged).
    pub fn metrics(&self) -> Snapshot {
        let parts: Vec<Snapshot> = self.metrics.iter().map(|m| m.snapshot()).collect();
        Snapshot::merged(&parts)
    }

    /// Per-shard snapshots (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Snapshot> {
        self.metrics.iter().map(|m| m.snapshot()).collect()
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    pub fn is_running(&self) -> bool {
        self.live.load(Ordering::Relaxed) > 0
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn device_thread(
    spec: BackendSpec, max_batch: usize, rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), ServiceError>>, metrics: Arc<Metrics>,
    live: Arc<AtomicUsize>,
) {
    // build the substrate on this thread (backends need not be Send)
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // count as live *before* acking, so `is_running()` is already true
    // the moment `Service::start` returns
    live.fetch_add(1, Ordering::Relaxed);
    let _ = ready.send(Ok(()));
    let mut pool = BufferPool::new();

    loop {
        // block for the first message, then greedily drain the queue
        let first = match rx.recv() {
            Ok(Msg::Submit(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let t0 = Instant::now();
        let mut pending: Vec<OpRequest> = vec![first];
        let mut shutdown = false;
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Submit(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }

        // group by operator, preserving arrival order
        let mut groups: Vec<(String, Vec<OpRequest>)> = Vec::new();
        for r in pending {
            match groups.iter().position(|(op, _)| *op == r.op) {
                Some(i) => groups[i].1.push(r),
                None => groups.push((r.op.clone(), vec![r])),
            }
        }
        for (op, reqs) in groups {
            serve_group(backend.as_mut(), &mut pool, &metrics, &op, reqs);
        }
        metrics.record_latency(t0.elapsed().as_secs_f64());
        if shutdown {
            break;
        }
    }
    live.fetch_sub(1, Ordering::Relaxed);
}

/// Execute one operator group as a single concatenated batch through
/// the backend trait.
fn serve_group(
    backend: &mut dyn KernelBackend, pool: &mut BufferPool, metrics: &Metrics,
    op: &str, reqs: Vec<OpRequest>,
) {
    let Some(spec) = backend::op_spec(op) else {
        fail_group(metrics, &reqs, ServiceError::UnknownOp(op.to_string()));
        return;
    };
    // no per-batch `supports` pre-check: backends return
    // `ServiceError::Unsupported` themselves, and the default
    // `supports` impl allocates a catalogue Vec — not hot-path material
    let (n_in, n_out) = (spec.n_in, spec.n_out);

    // fast path: a lone request executes straight out of its own planes
    // and its output planes become the reply (no gather/scatter copies)
    if reqs.len() == 1 {
        let req = &reqs[0];
        let n = req.len();
        let input_refs: Vec<&[f32]> = req.inputs.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; n]; n_out];
        match backend.execute(op, &input_refs, &mut outs) {
            Ok(rep) => {
                metrics.record_batch(1, rep.launches, n as u64, rep.padded_elements);
                let _ = req.reply.send(Ok(outs));
            }
            Err(e) => {
                metrics.record_error();
                let _ = req.reply.send(Err(e));
            }
        }
        return;
    }

    let refs: Vec<&OpRequest> = reqs.iter().collect();
    let total: usize = refs.iter().map(|r| r.len()).sum();

    // gather the concatenated batch into pooled planes
    let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(n_in);
    for p in 0..n_in {
        let mut buf = pool.take_empty();
        batcher::gather_plane_into(&refs, p, total, 0, total, op, &mut buf);
        inputs.push(buf);
    }
    let input_refs: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
    let mut outs: Vec<Vec<f32>> = (0..n_out).map(|_| pool.take(total)).collect();

    let result = backend.execute(op, &input_refs, &mut outs);
    drop(input_refs);

    match result {
        Ok(rep) => {
            // per-request output accumulators (owned by the replies)
            let mut acc: Vec<Vec<Vec<f32>>> =
                refs.iter().map(|r| vec![vec![0.0f32; r.len()]; n_out]).collect();
            batcher::scatter_outputs(&refs, &outs, 0, total, &mut acc);
            metrics.record_batch(
                refs.len(), rep.launches, total as u64, rep.padded_elements,
            );
            for (r, planes) in reqs.iter().zip(acc) {
                let _ = r.reply.send(Ok(planes));
            }
        }
        Err(e) => {
            fail_group(metrics, &reqs, e);
        }
    }
    for b in inputs {
        pool.put(b);
    }
    for b in outs {
        pool.put(b);
    }
}

fn fail_group(metrics: &Metrics, reqs: &[OpRequest], err: ServiceError) {
    metrics.record_error();
    for r in reqs {
        let _ = r.reply.send(Err(err.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::FF32;
    use crate::util::Rng;

    fn cpu_service() -> Service {
        Service::start(ServiceConfig::default()).unwrap()
    }

    fn add22_planes(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut planes = vec![Vec::with_capacity(n); 4];
        for _ in 0..n {
            let (ah, al) = rng.ff_pair(-8, 8);
            let (bh, bl) = rng.ff_pair(-8, 8);
            planes[0].push(ah);
            planes[1].push(al);
            planes[2].push(bh);
            planes[3].push(bl);
        }
        planes
    }

    #[test]
    fn cpu_backend_serves_add22() {
        let svc = cpu_service();
        let h = svc.handle();
        let n = 1000;
        let planes = add22_planes(n, 131);
        let out = h.call("add22", planes.clone()).unwrap();
        assert_eq!(out.len(), 2);
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!((out[0][i], out[1][i]), (want.hi, want.lo), "i={i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.elements, n as u64);
    }

    #[test]
    fn rejects_bad_requests_at_submit() {
        let svc = cpu_service();
        let h = svc.handle();
        assert!(matches!(
            h.call("frobnicate", vec![vec![1.0]]),
            Err(ServiceError::UnknownOp(_))
        ));
        assert!(matches!(
            h.call("add22", vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { .. })
        ));
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let svc = cpu_service();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let n = 100 + t * 13;
                let a: Vec<f32> = (0..n).map(|i| (t * 1000 + i) as f32).collect();
                let b = vec![1.0f32; n];
                let out = h.call("add", vec![a.clone(), b]).unwrap();
                for i in 0..n {
                    assert_eq!(out[0][i], a[i] + 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 8);
    }

    #[test]
    fn shutdown_on_drop() {
        let svc = cpu_service();
        let h = svc.handle();
        drop(svc);
        // handle now fails cleanly
        assert_eq!(
            h.call("add", vec![vec![1.0], vec![2.0]]).unwrap_err(),
            ServiceError::QueueClosed
        );
    }

    #[test]
    fn sharded_service_spreads_requests() {
        let svc = Service::start(ServiceConfig {
            backend: BackendSpec::native_single(),
            shards: 4,
            max_batch: 16,
        })
        .unwrap();
        assert_eq!(svc.shards(), 4);
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for round in 0..10usize {
                    let n = 50 + round;
                    let planes = add22_planes(n, t * 100 + round as u64);
                    let out = h.call("add22", planes.clone()).unwrap();
                    for i in 0..n {
                        let want = FF32::from_parts(planes[0][i], planes[1][i])
                            + FF32::from_parts(planes[2][i], planes[3][i]);
                        assert_eq!(
                            (out[0][i], out[1][i]),
                            (want.hi, want.lo),
                            "t={t} round={round} i={i}"
                        );
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let per_shard = svc.shard_metrics();
        assert_eq!(per_shard.len(), 4);
        let total: u64 = per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(total, 80);
        // round-robin: every shard saw work
        assert!(
            per_shard.iter().all(|s| s.requests > 0),
            "idle shard: {per_shard:?}"
        );
        assert_eq!(svc.metrics().requests, 80);
        assert_eq!(svc.metrics().errors, 0);
    }

    #[test]
    fn gpusim_backend_is_servable() {
        let svc = Service::start(ServiceConfig {
            backend: BackendSpec::gpusim_ieee(),
            shards: 1,
            max_batch: 8,
        })
        .unwrap();
        let h = svc.handle();
        let n = 200;
        let planes = add22_planes(n, 99);
        let out = h.call("add22", planes.clone()).unwrap();
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (want.hi.to_bits(), want.lo.to_bits()),
                "i={i}"
            );
        }
    }

    #[test]
    fn bad_backend_spec_fails_startup() {
        let err = Service::start(ServiceConfig {
            backend: BackendSpec::GpuSim { model: "voodoo2".into() },
            shards: 2,
            max_batch: 8,
        })
        .err()
        .expect("startup must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_backend_shim_still_works() {
        let svc = Service::start(ServiceConfig::legacy(Backend::Cpu)).unwrap();
        let h = svc.handle();
        let out = h.call("add", vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
    }
}
