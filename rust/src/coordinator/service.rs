//! The coordinator service: a device thread draining a batched queue.
//!
//! PJRT wrapper types are not `Sync`, so the [`crate::runtime::Runtime`]
//! lives on one dedicated thread (the "device thread" — the analogue of
//! a GPU command queue). Clients hold a cheap cloneable [`Handle`] and
//! submit [`OpRequest`]s; the device thread coalesces whatever is
//! pending (up to `max_batch` requests per operator), plans launches
//! over the compiled sizes, executes, and scatters replies.
//!
//! `Backend::Cpu` serves the same API from the native `ff::vector`
//! kernels — the paper's Table 4 path, and a mock for artifact-free
//! tests.

use super::batcher::{self, op_arity};
use super::metrics::Metrics;
use super::request::{OpRequest, OpResult};
use crate::ff::vector;
use crate::runtime::Runtime;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Which engine executes batches.
#[derive(Clone, Debug)]
pub enum Backend {
    /// PJRT XLA artifacts from this directory (the "GPU path").
    Xla(PathBuf),
    /// Native rust kernels (the "CPU path" / mock).
    Cpu,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub backend: Backend,
    /// Max requests coalesced into one batch per operator.
    pub max_batch: usize,
    /// Precompile all stream artifacts at startup (vs on first use).
    pub precompile: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { backend: Backend::Cpu, max_batch: 64, precompile: false }
    }
}

enum Msg {
    Submit(OpRequest),
    Shutdown,
}

/// Running coordinator; dropping it shuts the device thread down.
pub struct Service {
    tx: mpsc::Sender<Msg>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct Handle {
    tx: mpsc::Sender<Msg>,
}

impl Handle {
    /// Submit and return the reply receiver (async pattern).
    pub fn submit(&self, op: &str, inputs: Vec<Vec<f32>>) -> Result<mpsc::Receiver<OpResult>, String> {
        let (reply, rx) = mpsc::channel();
        let req = OpRequest { op: op.into(), inputs, reply };
        req.validate()?;
        self.tx.send(Msg::Submit(req)).map_err(|_| "service stopped".to_string())?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn call(&self, op: &str, inputs: Vec<Vec<f32>>) -> OpResult {
        let rx = self.submit(op, inputs)?;
        rx.recv().map_err(|_| "service dropped reply".to_string())?
    }
}

impl Service {
    /// Start the device thread.
    pub fn start(config: ServiceConfig) -> Result<Service, String> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let m2 = metrics.clone();
        let r2 = running.clone();
        // engine construction happens *on* the device thread (Runtime is
        // not Send); report startup errors through a channel
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let cfg = config.clone();
        let join = std::thread::Builder::new()
            .name("ffgpu-device".into())
            .spawn(move || device_thread(cfg, rx, ready_tx, m2, r2))
            .map_err(|e| e.to_string())?;
        ready_rx
            .recv()
            .map_err(|_| "device thread died during startup".to_string())??;
        Ok(Service { tx, metrics, running, join: Some(join) })
    }

    pub fn handle(&self) -> Handle {
        Handle { tx: self.tx.clone() }
    }

    pub fn metrics(&self) -> super::metrics::Snapshot {
        self.metrics.snapshot()
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::Relaxed)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn device_thread(
    config: ServiceConfig, rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), String>>, metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    // build the engine on this thread
    let runtime = match &config.backend {
        Backend::Xla(dir) => match Runtime::new(dir) {
            Ok(rt) => {
                if config.precompile {
                    let names: Vec<String> = rt
                        .manifest()
                        .entries
                        .iter()
                        .filter(|e| e.kind == "stream")
                        .map(|e| e.name.clone())
                        .collect();
                    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                    if let Err(e) = rt.precompile(&refs) {
                        let _ = ready.send(Err(e));
                        running.store(false, Ordering::Relaxed);
                        return;
                    }
                }
                Some(rt)
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                running.store(false, Ordering::Relaxed);
                return;
            }
        },
        Backend::Cpu => None,
    };
    let _ = ready.send(Ok(()));

    loop {
        // block for the first message, then greedily drain the queue
        let first = match rx.recv() {
            Ok(Msg::Submit(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let t0 = Instant::now();
        let mut pending: Vec<OpRequest> = vec![first];
        let mut shutdown = false;
        while pending.len() < config.max_batch {
            match rx.try_recv() {
                Ok(Msg::Submit(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }

        // group by operator, preserving order
        let mut groups: HashMap<String, Vec<OpRequest>> = HashMap::new();
        for r in pending {
            groups.entry(r.op.clone()).or_default().push(r);
        }
        for (op, reqs) in groups {
            serve_group(&config, runtime.as_ref(), &metrics, &op, reqs);
        }
        metrics.record_latency(t0.elapsed().as_secs_f64());
        if shutdown {
            break;
        }
    }
    running.store(false, Ordering::Relaxed);
}

/// Execute one operator group as a single concatenated batch.
fn serve_group(
    config: &ServiceConfig, runtime: Option<&Runtime>, metrics: &Metrics,
    op: &str, reqs: Vec<OpRequest>,
) {
    let Some((n_in, n_out)) = op_arity(op) else {
        for r in reqs {
            let _ = r.reply.send(Err(format!("unknown op '{op}'")));
        }
        metrics.record_error();
        return;
    };
    let refs: Vec<&OpRequest> = reqs.iter().collect();
    let total: usize = refs.iter().map(|r| r.len()).sum();

    // per-request output accumulators
    let mut acc: Vec<Vec<Vec<f32>>> =
        refs.iter().map(|r| vec![vec![0.0f32; r.len()]; n_out]).collect();

    let result: Result<u64, String> = match (&config.backend, runtime) {
        (Backend::Cpu, _) | (_, None) => {
            // native path: one "launch", no padding
            let inputs: Vec<Vec<f32>> = (0..n_in)
                .map(|p| batcher::gather_plane(&refs, p, total, 0, total, op))
                .collect();
            let input_refs: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
            let mut outs = vec![vec![0.0f32; total]; n_out];
            match vector::dispatch(op, &input_refs, &mut outs) {
                Ok(()) => {
                    batcher::scatter_outputs(&refs, &outs, 0, total, &mut acc);
                    metrics.record_batch(refs.len(), 1, total as u64, 0);
                    Ok(0)
                }
                Err(e) => Err(e),
            }
        }
        (Backend::Xla(_), Some(rt)) => {
            let sizes: Vec<usize> = rt.manifest().by_op(op).iter().map(|e| e.n).collect();
            match batcher::plan(total, &sizes) {
                None => Err(format!("no compiled artifacts for op '{op}'")),
                Some(launches) => {
                    let mut padded = 0u64;
                    let mut err = None;
                    for l in &launches {
                        let name = format!("{op}_n{}", l.size);
                        let inputs: Vec<Vec<f32>> = (0..n_in)
                            .map(|p| {
                                batcher::gather_plane(&refs, p, l.size, l.start, l.len, op)
                            })
                            .collect();
                        let input_refs: Vec<&[f32]> =
                            inputs.iter().map(Vec::as_slice).collect();
                        match rt.execute(&name, &input_refs) {
                            Ok(outs) => {
                                batcher::scatter_outputs(&refs, &outs, l.start, l.len, &mut acc);
                                padded += (l.size - l.len) as u64;
                            }
                            Err(e) => {
                                err = Some(e);
                                break;
                            }
                        }
                    }
                    match err {
                        None => {
                            metrics.record_batch(
                                refs.len(), launches.len(), total as u64, padded,
                            );
                            Ok(padded)
                        }
                        Some(e) => Err(e),
                    }
                }
            }
        }
    };

    match result {
        Ok(_) => {
            for (r, planes) in reqs.iter().zip(acc) {
                let _ = r.reply.send(Ok(planes));
            }
        }
        Err(e) => {
            metrics.record_error();
            for r in &reqs {
                let _ = r.reply.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::FF32;
    use crate::util::Rng;

    fn cpu_service() -> Service {
        Service::start(ServiceConfig { backend: Backend::Cpu, ..Default::default() })
            .unwrap()
    }

    #[test]
    fn cpu_backend_serves_add22() {
        let svc = cpu_service();
        let h = svc.handle();
        let mut rng = Rng::new(131);
        let n = 1000;
        let mut planes = vec![Vec::with_capacity(n); 4];
        for _ in 0..n {
            let (ah, al) = rng.ff_pair(-8, 8);
            let (bh, bl) = rng.ff_pair(-8, 8);
            planes[0].push(ah);
            planes[1].push(al);
            planes[2].push(bh);
            planes[3].push(bl);
        }
        let out = h.call("add22", planes.clone()).unwrap();
        assert_eq!(out.len(), 2);
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!((out[0][i], out[1][i]), (want.hi, want.lo), "i={i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.elements, n as u64);
    }

    #[test]
    fn rejects_bad_requests_at_submit() {
        let svc = cpu_service();
        let h = svc.handle();
        assert!(h.call("frobnicate", vec![vec![1.0]]).is_err());
        assert!(h.call("add22", vec![vec![1.0]; 3]).is_err());
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let svc = cpu_service();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let n = 100 + t * 13;
                let a: Vec<f32> = (0..n).map(|i| (t * 1000 + i) as f32).collect();
                let b = vec![1.0f32; n];
                let out = h.call("add", vec![a.clone(), b]).unwrap();
                for i in 0..n {
                    assert_eq!(out[0][i], a[i] + 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 8);
    }

    #[test]
    fn shutdown_on_drop() {
        let svc = cpu_service();
        let h = svc.handle();
        drop(svc);
        // handle now fails cleanly
        assert!(h.call("add", vec![vec![1.0], vec![2.0]]).is_err());
    }
}
