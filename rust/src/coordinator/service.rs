//! The coordinator service: N shard threads draining batched queues
//! through the backend layer.
//!
//! Clients hold a cheap cloneable [`Handle`], build typed
//! [`Plan`]s (shape-checked at build time), and
//! [`dispatch`](Handle::dispatch) them; a
//! [`RoutingPolicy`](crate::coordinator::routing::RoutingPolicy)
//! places each request on a shard and the caller gets a future-like
//! [`Ticket`]. Each shard owns one
//! [`crate::backend::KernelBackend`] instance (built *on* the shard
//! thread — PJRT wrapper types are not `Send`), its own
//! [`crate::backend::BufferPool`], and its own [`Metrics`] (no
//! cross-shard contention on the hot path). A shard coalesces whatever
//! is pending (up to `max_batch` requests per operator), gathers the
//! group into pooled planes, executes through
//! `Box<dyn KernelBackend>`, and scatters replies.
//!
//! The shard set is described by a [`ServiceSpec`] and may be
//! **heterogeneous**: one [`crate::backend::BackendSpec`] per shard
//! (e.g. `[native, native, gpusim:nv35]` — two workhorses and an
//! arithmetic-model canary). The seed's single-spec [`ServiceConfig`]
//! and two-variant [`Backend`] enum remain as deprecated shims.

use super::batcher;
use super::metrics::{Metrics, Snapshot};
use super::plan::{Plan, Ticket, TicketState};
use super::request::{OpRequest, OpResult};
use super::routing::{Routing, RoutingPolicy, ShardMeta, TelemetryView};
use crate::backend::{BackendSpec, BufferPool, KernelBackend, Op, ServiceError};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// The seed's engine selector, kept as a shim for old call sites.
#[deprecated(note = "use crate::backend::BackendSpec")]
#[derive(Clone, Debug)]
pub enum Backend {
    /// PJRT XLA artifacts from this directory (the "GPU path").
    Xla(PathBuf),
    /// Native rust kernels (the "CPU path" / mock).
    Cpu,
}

#[allow(deprecated)]
impl From<Backend> for BackendSpec {
    fn from(b: Backend) -> BackendSpec {
        match b {
            Backend::Xla(dir) => BackendSpec::Xla { artifacts: dir, precompile: false },
            // the seed's Cpu path was single-threaded; the shim keeps
            // that behaviour so old measurements stay comparable
            Backend::Cpu => BackendSpec::native_single(),
        }
    }
}

/// The seed's uniform-shard configuration, kept as a shim: every shard
/// builds the same `backend` and submission is round-robin.
#[deprecated(note = "use ServiceSpec: per-shard BackendSpecs plus a Routing policy")]
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Which substrate each shard builds.
    pub backend: BackendSpec,
    /// Device threads, each owning one backend instance (>= 1).
    pub shards: usize,
    /// Max requests coalesced into one batch per operator.
    pub max_batch: usize,
}

#[allow(deprecated)]
impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { backend: BackendSpec::native(), shards: 1, max_batch: 64 }
    }
}

#[allow(deprecated)]
impl ServiceConfig {
    /// Shim constructor for the deprecated [`Backend`] enum.
    pub fn legacy(backend: Backend) -> ServiceConfig {
        ServiceConfig { backend: backend.into(), ..Default::default() }
    }
}

#[allow(deprecated)]
impl From<ServiceConfig> for ServiceSpec {
    fn from(c: ServiceConfig) -> ServiceSpec {
        ServiceSpec::uniform(c.backend, c.shards).with_max_batch(c.max_batch)
    }
}

/// Service configuration: one [`BackendSpec`] **per shard** plus the
/// routing policy that places requests across them.
#[derive(Clone, Debug)]
pub struct ServiceSpec {
    /// One backend recipe per shard; heterogeneous sets are first-class
    /// (`[native, native, gpusim:nv35]`). Must be non-empty.
    pub shards: Vec<BackendSpec>,
    /// Max requests coalesced into one batch per operator.
    pub max_batch: usize,
    /// Which built-in [`RoutingPolicy`] places requests
    /// ([`Service::start_with_policy`] accepts custom ones).
    pub routing: Routing,
}

impl Default for ServiceSpec {
    fn default() -> Self {
        ServiceSpec::uniform(BackendSpec::native(), 1)
    }
}

impl ServiceSpec {
    /// `shards` identical shards of `backend` (the seed's shape).
    pub fn uniform(backend: BackendSpec, shards: usize) -> ServiceSpec {
        ServiceSpec {
            shards: vec![backend; shards.max(1)],
            max_batch: 64,
            routing: Routing::default(),
        }
    }

    /// One shard per entry of `shards`, in order.
    pub fn heterogeneous(shards: Vec<BackendSpec>) -> ServiceSpec {
        ServiceSpec { shards, max_batch: 64, routing: Routing::default() }
    }

    pub fn with_routing(mut self, routing: Routing) -> ServiceSpec {
        self.routing = routing;
        self
    }

    pub fn with_max_batch(mut self, max_batch: usize) -> ServiceSpec {
        self.max_batch = max_batch;
        self
    }

    /// Parse a CLI-style shard list: comma-separated
    /// [`BackendSpec::from_cli`] entries, each optionally repeated with
    /// `*N` — `"native*6,gpusim:nv35"` is six native shards plus one
    /// NV35 canary.
    pub fn from_cli(
        shard_spec: &str, artifacts: &std::path::Path,
    ) -> Result<ServiceSpec, ServiceError> {
        let mut shards = Vec::new();
        for part in shard_spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = match part.split_once('*') {
                Some((n, c)) => {
                    let count = c.parse::<usize>().map_err(|_| {
                        ServiceError::Backend(format!("bad shard count '{c}' in '{part}'"))
                    })?;
                    if count == 0 {
                        // a typo like `native*0` would silently drop the
                        // entry and reroute all traffic to the others
                        return Err(ServiceError::Backend(format!(
                            "zero shard count in '{part}'"
                        )));
                    }
                    (n, count)
                }
                None => (part, 1),
            };
            let spec = BackendSpec::from_cli(name, artifacts)?;
            for _ in 0..count {
                shards.push(spec.clone());
            }
        }
        if shards.is_empty() {
            return Err(ServiceError::Backend(format!(
                "empty shard spec '{shard_spec}'"
            )));
        }
        Ok(ServiceSpec::heterogeneous(shards))
    }
}

enum Msg {
    Submit(OpRequest),
    Shutdown,
}

/// Running coordinator; dropping it shuts every shard down.
pub struct Service {
    txs: Vec<mpsc::Sender<Msg>>,
    meta: Arc<Vec<ShardMeta>>,
    policy: Arc<dyn RoutingPolicy>,
    metrics: Vec<Arc<Metrics>>,
    live: Arc<AtomicUsize>,
    joins: Vec<JoinHandle<()>>,
}

/// Cheap cloneable submission handle; placement is delegated to the
/// service's routing policy.
#[derive(Clone)]
pub struct Handle {
    txs: Vec<mpsc::Sender<Msg>>,
    meta: Arc<Vec<ShardMeta>>,
    policy: Arc<dyn RoutingPolicy>,
}

impl Handle {
    /// Dispatch a validated [`Plan`]: the routing policy picks a shard,
    /// the request is enqueued, and the reply arrives on the returned
    /// [`Ticket`].
    pub fn dispatch(&self, plan: Plan) -> Result<Ticket, ServiceError> {
        let (op, inputs, len) = plan.into_parts();
        let view = TelemetryView::new(&self.meta);
        let shard = self.policy.route(op, len, &view) % self.txs.len();
        let (reply, rx) = mpsc::channel();
        let state = Arc::new(TicketState::new());
        let req = OpRequest { op, inputs, reply, ctrl: state.clone() };
        self.meta[shard].enter();
        if self.txs[shard].send(Msg::Submit(req)).is_err() {
            self.meta[shard].leave(1);
            return Err(ServiceError::QueueClosed);
        }
        Ok(Ticket { rx, op, shard, len, state })
    }

    /// Submit by operator name and return the raw reply receiver.
    #[deprecated(note = "build a typed Plan and use Handle::dispatch")]
    pub fn submit(
        &self, op: &str, inputs: Vec<Vec<f32>>,
    ) -> Result<mpsc::Receiver<OpResult>, ServiceError> {
        let plan = Plan::new(Op::parse(op)?, inputs)?;
        Ok(self.dispatch(plan)?.into_receiver())
    }

    /// Submit by operator name and block for the result.
    #[deprecated(note = "build a typed Plan and use Handle::dispatch(...)?.wait()")]
    pub fn call(&self, op: &str, inputs: Vec<Vec<f32>>) -> OpResult {
        let plan = Plan::new(Op::parse(op)?, inputs)?;
        self.dispatch(plan)?.wait()
    }

    /// Number of shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// In-flight request count per shard (what queue-depth routing
    /// reads).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.meta.iter().map(ShardMeta::queue_depth).collect()
    }

    /// The live telemetry view routing policies route over — label,
    /// queue depth, per-op capability and measured rates per shard.
    pub fn telemetry(&self) -> TelemetryView<'_> {
        TelemetryView::new(&self.meta)
    }
}

impl Service {
    /// Start one device thread per shard of the spec; fails if any
    /// backend refuses to build. Accepts a [`ServiceSpec`] or (via the
    /// deprecated shim) an old `ServiceConfig`.
    pub fn start(config: impl Into<ServiceSpec>) -> Result<Service, ServiceError> {
        let spec = config.into();
        let policy = spec.routing.build();
        Service::start_with_policy(spec, policy)
    }

    /// [`Service::start`] with a caller-supplied routing policy — the
    /// plug-in point for policies beyond the built-in [`Routing`] set.
    pub fn start_with_policy(
        spec: ServiceSpec, policy: Arc<dyn RoutingPolicy>,
    ) -> Result<Service, ServiceError> {
        if spec.shards.is_empty() {
            return Err(ServiceError::Backend("empty shard set".into()));
        }
        let max_batch = spec.max_batch.max(1);
        let shards = spec.shards.len();
        let meta: Arc<Vec<ShardMeta>> =
            Arc::new(spec.shards.iter().map(|s| ShardMeta::new(s.label())).collect());
        let live = Arc::new(AtomicUsize::new(0));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), ServiceError>>();
        let mut txs = Vec::with_capacity(shards);
        let mut metrics = Vec::with_capacity(shards);
        let mut joins = Vec::with_capacity(shards);
        for (shard, backend_spec) in spec.shards.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Msg>();
            let m = Arc::new(Metrics::new());
            let (m2, l2, r2, meta2) =
                (m.clone(), live.clone(), ready_tx.clone(), meta.clone());
            let join = std::thread::Builder::new()
                .name(format!("ffgpu-shard-{shard}"))
                .spawn(move || {
                    device_thread(backend_spec, max_batch, rx, r2, m2, l2, meta2, shard)
                })
                .map_err(|e| {
                    ServiceError::Backend(format!("spawn shard {shard}: {e}"))
                })?;
            txs.push(tx);
            metrics.push(m);
            joins.push(join);
        }
        drop(ready_tx);
        for _ in 0..shards {
            ready_rx
                .recv()
                .map_err(|_| {
                    ServiceError::Backend("device thread died during startup".into())
                })??;
        }
        Ok(Service { txs, meta, policy, metrics, live, joins })
    }

    pub fn handle(&self) -> Handle {
        Handle {
            txs: self.txs.clone(),
            meta: self.meta.clone(),
            policy: self.policy.clone(),
        }
    }

    /// Service-wide metrics (all shards merged).
    pub fn metrics(&self) -> Snapshot {
        let parts: Vec<Snapshot> = self.metrics.iter().map(|m| m.snapshot()).collect();
        Snapshot::merged(&parts)
    }

    /// Per-shard snapshots (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Snapshot> {
        self.metrics.iter().map(|m| m.snapshot()).collect()
    }

    /// Substrate label per shard, in shard order.
    pub fn shard_labels(&self) -> Vec<&'static str> {
        self.meta.iter().map(ShardMeta::label).collect()
    }

    /// The live telemetry view (label, queue depth, capability,
    /// measured rates) over the whole shard set.
    pub fn telemetry(&self) -> TelemetryView<'_> {
        TelemetryView::new(&self.meta)
    }

    /// Measured EWMA throughput of `op` on `shard` in Melem/s (`None`
    /// while that cell is cold).
    pub fn measured_rate(&self, shard: usize, op: Op) -> Option<f64> {
        self.meta[shard].telemetry().rate(op)
    }

    /// Operators `shard`'s backend declared at spawn
    /// ([`crate::backend::KernelBackend::ops`]).
    pub fn shard_supported_ops(&self, shard: usize) -> Vec<Op> {
        self.meta[shard].supported_ops()
    }

    /// Name of the active routing policy.
    pub fn routing(&self) -> &'static str {
        self.policy.name()
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    pub fn is_running(&self) -> bool {
        self.live.load(Ordering::Relaxed) > 0
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        self.txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn device_thread(
    spec: BackendSpec, max_batch: usize, rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<(), ServiceError>>, metrics: Arc<Metrics>,
    live: Arc<AtomicUsize>, meta: Arc<Vec<ShardMeta>>, shard: usize,
) {
    // build the substrate on this thread (backends need not be Send)
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // publish the real op catalogue into the routing-visible meta
    // *before* acking: no dispatch can race the placeholder mask
    // because `Service::start` only returns after every shard acks
    meta[shard].set_supports(&backend.ops());
    // count as live *before* acking, so `is_running()` is already true
    // the moment `Service::start` returns
    live.fetch_add(1, Ordering::Relaxed);
    let _ = ready.send(Ok(()));
    let mut pool = BufferPool::new();

    loop {
        // block for the first message, then greedily drain the queue
        let first = match rx.recv() {
            Ok(Msg::Submit(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let t0 = Instant::now();
        let mut pending: Vec<OpRequest> = vec![first];
        let mut shutdown = false;
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Submit(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(_) => break,
            }
        }

        // group by operator, preserving arrival order
        let mut groups: Vec<(Op, Vec<OpRequest>)> = Vec::new();
        for r in pending {
            match groups.iter().position(|(op, _)| *op == r.op) {
                Some(i) => groups[i].1.push(r),
                None => groups.push((r.op, vec![r])),
            }
        }
        let mut executed_any = false;
        for (op, reqs) in groups {
            executed_any |=
                serve_group(backend.as_mut(), &mut pool, &metrics, &meta[shard], op, reqs);
        }
        // triage-only drains (every request cancelled/expired) ran no
        // backend work — logging their ~0 latency would drag the batch
        // mean below any batch that actually executed
        if executed_any {
            metrics.record_latency(t0.elapsed().as_secs_f64());
        }
        if shutdown {
            break;
        }
    }
    live.fetch_sub(1, Ordering::Relaxed);
}

/// Execute one operator group as a single concatenated batch through
/// the backend trait.
///
/// Cancelled and deadline-expired requests are triaged out *before*
/// the backend runs — a client that gave up never costs substrate
/// time; it gets [`ServiceError::Cancelled`] /
/// [`ServiceError::DeadlineExceeded`] instead.
///
/// The shard's queue depth ([`ShardMeta`]) is decremented *before* the
/// replies go out, so once a client holds its reply the routing
/// policies already see the drained depth. Successful groups feed the
/// shard's per-op telemetry EWMA ([`ShardMeta::telemetry`]) that
/// measured routing reads.
///
/// Returns whether the backend actually executed (false when triage
/// emptied the group) so the caller can keep no-work drains out of the
/// batch-latency summary.
fn serve_group(
    backend: &mut dyn KernelBackend, pool: &mut BufferPool, metrics: &Metrics,
    meta: &ShardMeta, op: Op, reqs: Vec<OpRequest>,
) -> bool {
    // lifecycle triage: drop dead requests before burning backend time.
    // Expiry is checked first so a deadline miss is attributed to
    // `expired` even when the client's timed-out wait already marked
    // the shared state cancelled — `cancelled` counts explicit
    // abandonment only.
    let now = Instant::now();
    let mut live = Vec::with_capacity(reqs.len());
    for r in reqs {
        if r.ctrl.expired(now) {
            // mark it so a racing client-side wait agrees the request
            // is dead
            r.ctrl.cancel();
            meta.leave(1);
            metrics.record_expired(1);
            let _ = r.reply.send(Err(ServiceError::DeadlineExceeded));
        } else if r.ctrl.is_cancelled() {
            meta.leave(1);
            metrics.record_cancelled(1);
            let _ = r.reply.send(Err(ServiceError::Cancelled));
        } else {
            live.push(r);
        }
    }
    let reqs = live;
    if reqs.is_empty() {
        return false;
    }

    // no per-batch `supports` pre-check: backends return
    // `ServiceError::Unsupported` themselves, and the default
    // `supports` impl allocates a catalogue Vec — not hot-path material
    let (n_in, n_out) = op.arity();

    // fast path: a lone request executes straight out of its own planes
    // and its output planes become the reply (no gather/scatter copies)
    if reqs.len() == 1 {
        let req = &reqs[0];
        let n = req.len();
        let input_refs: Vec<&[f32]> = req.inputs.iter().map(Vec::as_slice).collect();
        let mut outs = vec![vec![0.0f32; n]; n_out];
        // attempt recorded pre-execute: a failing or slow shard stops
        // looking cold to measured routing
        meta.telemetry().record_attempt(op);
        let t_exec = Instant::now();
        let result = backend.execute(op, &input_refs, &mut outs);
        let exec_s = t_exec.elapsed().as_secs_f64();
        meta.leave(1);
        match result {
            Ok(rep) => {
                meta.telemetry().record(op, n as u64, exec_s);
                metrics.record_batch(1, rep.launches, n as u64, rep.padded_elements);
                let _ = req.reply.send(Ok(outs));
            }
            Err(e) => {
                metrics.record_error();
                let _ = req.reply.send(Err(e));
            }
        }
        return true;
    }

    let refs: Vec<&OpRequest> = reqs.iter().collect();
    let total: usize = refs.iter().map(|r| r.len()).sum();

    // gather the concatenated batch into pooled planes
    let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(n_in);
    for p in 0..n_in {
        let mut buf = pool.take_empty();
        batcher::gather_plane_into(&refs, p, total, 0, total, op, &mut buf);
        inputs.push(buf);
    }
    let input_refs: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
    let mut outs: Vec<Vec<f32>> = (0..n_out).map(|_| pool.take(total)).collect();

    meta.telemetry().record_attempt(op);
    let t_exec = Instant::now();
    let result = backend.execute(op, &input_refs, &mut outs);
    let exec_s = t_exec.elapsed().as_secs_f64();
    drop(input_refs);
    meta.leave(reqs.len());

    match result {
        Ok(rep) => {
            meta.telemetry().record(op, total as u64, exec_s);
            // per-request output accumulators (owned by the replies)
            let mut acc: Vec<Vec<Vec<f32>>> =
                refs.iter().map(|r| vec![vec![0.0f32; r.len()]; n_out]).collect();
            batcher::scatter_outputs(&refs, &outs, 0, total, &mut acc);
            metrics.record_batch(
                refs.len(), rep.launches, total as u64, rep.padded_elements,
            );
            for (r, planes) in reqs.iter().zip(acc) {
                let _ = r.reply.send(Ok(planes));
            }
        }
        Err(e) => {
            fail_group(metrics, &reqs, e);
        }
    }
    for b in inputs {
        pool.put(b);
    }
    for b in outs {
        pool.put(b);
    }
    true
}

fn fail_group(metrics: &Metrics, reqs: &[OpRequest], err: ServiceError) {
    // one error per request, not per group — `errors` must reconcile
    // against `requests`
    metrics.record_errors(reqs.len());
    for r in reqs {
        let _ = r.reply.send(Err(err.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::FF32;
    use crate::util::Rng;

    fn cpu_service() -> Service {
        Service::start(ServiceSpec::default()).unwrap()
    }

    fn add22_planes(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut planes = vec![Vec::with_capacity(n); 4];
        for _ in 0..n {
            let (ah, al) = rng.ff_pair(-8, 8);
            let (bh, bl) = rng.ff_pair(-8, 8);
            planes[0].push(ah);
            planes[1].push(al);
            planes[2].push(bh);
            planes[3].push(bl);
        }
        planes
    }

    fn run(h: &Handle, op: Op, planes: Vec<Vec<f32>>) -> OpResult {
        h.dispatch(Plan::new(op, planes)?)?.wait()
    }

    #[test]
    fn cpu_backend_serves_add22() {
        let svc = cpu_service();
        let h = svc.handle();
        let n = 1000;
        let planes = add22_planes(n, 131);
        let out = run(&h, Op::Add22, planes.clone()).unwrap();
        assert_eq!(out.len(), 2);
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!((out[0][i], out[1][i]), (want.hi, want.lo), "i={i}");
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.elements, n as u64);
    }

    #[test]
    fn plan_validation_rejects_before_dispatch() {
        assert!(matches!(
            Plan::new(Op::Add22, vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0]]),
            Err(ServiceError::RaggedPlanes { .. })
        ));
        assert!(matches!(
            Plan::new(Op::Add, vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { .. })
        ));
    }

    #[test]
    fn tickets_resolve_out_of_order() {
        let svc = cpu_service();
        let h = svc.handle();
        let mut tickets = Vec::new();
        let mut wants = Vec::new();
        for k in 1..=12u32 {
            let n = 10 * k as usize;
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![k as f32; n];
            wants.push(a.iter().zip(&b).map(|(x, y)| x + y).collect::<Vec<f32>>());
            let plan = Plan::builder(Op::Add).plane(a).plane(b).build().unwrap();
            tickets.push(h.dispatch(plan).unwrap());
        }
        // resolve newest-first: replies are independent of wait order
        for (ticket, want) in tickets.into_iter().zip(wants).rev() {
            assert_eq!(ticket.op(), Op::Add);
            let out = ticket.wait().unwrap();
            assert_eq!(out[0], want);
        }
    }

    #[test]
    fn concurrent_clients_get_their_own_answers() {
        let svc = cpu_service();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let n = 100 + t * 13;
                let a: Vec<f32> = (0..n).map(|i| (t * 1000 + i) as f32).collect();
                let b = vec![1.0f32; n];
                let out = run(&h, Op::Add, vec![a.clone(), b]).unwrap();
                for i in 0..n {
                    assert_eq!(out[0][i], a[i] + 1.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.requests, 8);
    }

    #[test]
    fn shutdown_on_drop() {
        let svc = cpu_service();
        let h = svc.handle();
        drop(svc);
        // handle now fails cleanly
        assert_eq!(
            run(&h, Op::Add, vec![vec![1.0], vec![2.0]]).unwrap_err(),
            ServiceError::QueueClosed
        );
    }

    #[test]
    fn sharded_service_spreads_requests() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 4).with_max_batch(16),
        )
        .unwrap();
        assert_eq!(svc.shards(), 4);
        assert_eq!(svc.routing(), "round-robin");
        let mut joins = Vec::new();
        for t in 0..8u64 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for round in 0..10usize {
                    let n = 50 + round;
                    let planes = add22_planes(n, t * 100 + round as u64);
                    let out = run(&h, Op::Add22, planes.clone()).unwrap();
                    for i in 0..n {
                        let want = FF32::from_parts(planes[0][i], planes[1][i])
                            + FF32::from_parts(planes[2][i], planes[3][i]);
                        assert_eq!(
                            (out[0][i], out[1][i]),
                            (want.hi, want.lo),
                            "t={t} round={round} i={i}"
                        );
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let per_shard = svc.shard_metrics();
        assert_eq!(per_shard.len(), 4);
        let total: u64 = per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(total, 80);
        // round-robin: every shard saw work
        assert!(
            per_shard.iter().all(|s| s.requests > 0),
            "idle shard: {per_shard:?}"
        );
        assert_eq!(svc.metrics().requests, 80);
        assert_eq!(svc.metrics().errors, 0);
    }

    #[test]
    fn op_affinity_pins_ops_to_home_shards() {
        use super::super::routing::OpAffinity;
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 3)
                .with_routing(Routing::OpAffinity),
        )
        .unwrap();
        assert_eq!(svc.routing(), "op-affinity");
        let h = svc.handle();
        for op in [Op::Add22, Op::Mul22, Op::Add, Op::Mul12] {
            let planes = crate::harness::workload::planes_for(op.name(), 64, 9);
            for _ in 0..3 {
                let t = h.dispatch(Plan::new(op, planes.clone()).unwrap()).unwrap();
                assert_eq!(t.shard(), OpAffinity::home(op, 3), "{op}");
                t.wait().unwrap();
            }
        }
        // all of add22's requests landed on its home shard
        let per_shard = svc.shard_metrics();
        assert!(per_shard[OpAffinity::home(Op::Add22, 3)].requests >= 3);
    }

    #[test]
    fn queue_depths_drain_to_zero() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 2)
                .with_routing(Routing::QueueDepth),
        )
        .unwrap();
        let h = svc.handle();
        let mut tickets = Vec::new();
        for k in 0..6 {
            let planes = add22_planes(200, k);
            tickets.push(h.dispatch(Plan::new(Op::Add22, planes).unwrap()).unwrap());
        }
        for t in tickets {
            t.wait().unwrap();
        }
        // every reply received => every shard has replied => depths at 0
        assert_eq!(h.queue_depths(), vec![0, 0]);
        assert_eq!(svc.metrics().requests, 6);
    }

    #[test]
    fn spawn_publishes_capabilities_and_groups_feed_telemetry() {
        let svc = cpu_service();
        let h = svc.handle();
        // the placeholder mask was replaced by the backend's catalogue
        assert_eq!(svc.shard_supported_ops(0), Op::ALL.to_vec());
        assert_eq!(svc.measured_rate(0, Op::Add22), None, "cold before any group");
        run(&h, Op::Add22, add22_planes(2000, 17)).unwrap();
        // the reply channel synchronises the shard's telemetry store
        let rate = svc.measured_rate(0, Op::Add22).expect("warm after a group");
        assert!(rate > 0.0);
        assert_eq!(svc.telemetry().samples(0, Op::Add22), 1);
        assert_eq!(svc.measured_rate(0, Op::Mul22), None, "other ops stay cold");
        assert!(svc.telemetry().supports(0, Op::Mul22));
    }

    #[test]
    fn measured_routing_serves_end_to_end() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::native_single(), 3)
                .with_routing(Routing::Measured),
        )
        .unwrap();
        assert_eq!(svc.routing(), "measured");
        let h = svc.handle();
        for k in 0..9 {
            let planes = add22_planes(400, k);
            let out = run(&h, Op::Add22, planes).unwrap();
            assert_eq!(out.len(), 2);
        }
        assert_eq!(svc.metrics().requests, 9);
        assert_eq!(svc.metrics().errors, 0);
        // cold exploration touched every shard at least once
        let touched = (0..3).filter(|&s| svc.measured_rate(s, Op::Add22).is_some()).count();
        assert_eq!(touched, 3, "exploration must seed every shard");
    }

    #[test]
    fn cancelled_ticket_resolves_client_side() {
        let svc = cpu_service();
        let h = svc.handle();
        let t = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0], vec![2.0]]).unwrap())
            .unwrap();
        t.cancel();
        // whether or not the shard already replied, the verdict is
        // Cancelled — the client abandoned the request
        assert_eq!(t.wait(), Err(ServiceError::Cancelled));
    }

    #[test]
    fn generous_deadline_does_not_interfere() {
        let svc = cpu_service();
        let h = svc.handle();
        let t = h
            .dispatch(Plan::new(Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap())
            .unwrap()
            .deadline(std::time::Duration::from_secs(60));
        assert_eq!(t.wait().unwrap()[0], vec![4.0, 6.0]);
        assert_eq!(svc.metrics().expired, 0);
    }

    #[test]
    fn heterogeneous_spec_builds_labelled_shards() {
        let svc = Service::start(ServiceSpec::heterogeneous(vec![
            BackendSpec::native_single(),
            BackendSpec::gpusim_ieee(),
        ]))
        .unwrap();
        assert_eq!(svc.shard_labels(), vec!["native", "gpusim"]);
        let out = run(&svc.handle(), Op::Add, vec![vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
    }

    #[test]
    fn empty_shard_set_is_rejected() {
        let err = Service::start(ServiceSpec::heterogeneous(vec![]))
            .err()
            .expect("must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    fn spec_from_cli_parses_heterogeneous_sets() {
        let dir = std::path::Path::new("artifacts");
        let spec = ServiceSpec::from_cli("native*2,gpusim:nv35", dir).unwrap();
        assert_eq!(spec.shards.len(), 3);
        assert_eq!(spec.shards[0].label(), "native");
        assert_eq!(spec.shards[1].label(), "native");
        match &spec.shards[2] {
            BackendSpec::GpuSim { model } => assert_eq!(model, "nv35"),
            other => panic!("{other:?}"),
        }
        assert!(ServiceSpec::from_cli("", dir).is_err());
        assert!(ServiceSpec::from_cli("native*lots", dir).is_err());
        assert!(ServiceSpec::from_cli("native*0,gpusim", dir).is_err());
        assert!(ServiceSpec::from_cli("voodoo", dir).is_err());
    }

    #[test]
    fn gpusim_backend_is_servable() {
        let svc = Service::start(
            ServiceSpec::uniform(BackendSpec::gpusim_ieee(), 1).with_max_batch(8),
        )
        .unwrap();
        let h = svc.handle();
        let n = 200;
        let planes = add22_planes(n, 99);
        let out = run(&h, Op::Add22, planes.clone()).unwrap();
        for i in 0..n {
            let want = FF32::from_parts(planes[0][i], planes[1][i])
                + FF32::from_parts(planes[2][i], planes[3][i]);
            assert_eq!(
                (out[0][i].to_bits(), out[1][i].to_bits()),
                (want.hi.to_bits(), want.lo.to_bits()),
                "i={i}"
            );
        }
    }

    #[test]
    fn bad_backend_spec_fails_startup() {
        let err = Service::start(
            ServiceSpec::uniform(BackendSpec::GpuSim { model: "voodoo2".into() }, 2),
        )
        .err()
        .expect("startup must fail");
        assert!(matches!(err, ServiceError::Backend(_)));
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_backend_shim_still_works() {
        let svc = Service::start(ServiceConfig::legacy(Backend::Cpu)).unwrap();
        let h = svc.handle();
        let out = h.call("add", vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(out[0], vec![4.0, 6.0]);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_string_shims_delegate_to_typed_path() {
        let svc = Service::start(ServiceConfig {
            backend: BackendSpec::native_single(),
            shards: 2,
            max_batch: 16,
        })
        .unwrap();
        let h = svc.handle();
        // call: happy path + every parse/validation error class
        let out = h.call("add22", add22_planes(50, 7)).unwrap();
        assert_eq!(out.len(), 2);
        assert!(matches!(
            h.call("frobnicate", vec![vec![1.0]]),
            Err(ServiceError::UnknownOp(_))
        ));
        assert!(matches!(
            h.call("add22", vec![vec![1.0]; 3]),
            Err(ServiceError::Arity { .. })
        ));
        assert!(matches!(
            h.call("add", vec![vec![1.0, 2.0], vec![3.0]]),
            Err(ServiceError::RaggedPlanes { .. })
        ));
        assert!(matches!(
            h.call("add", vec![vec![], vec![]]),
            Err(ServiceError::EmptyBatch { .. })
        ));
        // submit: async receiver shape preserved
        let rx = h.submit("add", vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap()[0], vec![4.0, 6.0]);
    }
}
